"""Minimal functional NN library over flat parameter lists.

The rust runtime feeds parameters positionally (one PJRT buffer per tensor),
so models are expressed over a *flat list* of arrays with a canonical order,
not a pytree. Each layer helper consumes a slice of the list via ``Cursor``.

All dense compute routes through the Layer-1 Pallas kernel
(``kernels.dense.fused_dense``); attention score/context matmuls use jnp
einsum (they are small relative to the projections at our scales).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.dense import fused_dense


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape/name metadata for one parameter tensor (manifest + init)."""

    name: str
    shape: Tuple[int, ...]

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


class Cursor:
    """Walks a flat param list in declaration order during forward()."""

    def __init__(self, params: Sequence[jax.Array]):
        self._params = list(params)
        self._i = 0

    def take(self, n: int) -> List[jax.Array]:
        out = self._params[self._i : self._i + n]
        if len(out) != n:
            raise ValueError("parameter list exhausted")
        self._i += n
        return out

    def done(self) -> None:
        if self._i != len(self._params):
            raise ValueError(
                f"forward consumed {self._i} of {len(self._params)} params"
            )


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def init_params(specs: Sequence[ParamSpec], key: jax.Array) -> List[jax.Array]:
    """He/Glorot-style init driven purely by the spec names.

    ``*_w`` dense kernels get LeCun-normal scaled by fan-in; ``*_b`` biases
    and layernorm ``*_beta`` start at zero; layernorm ``*_gamma`` at one;
    ``*_emb`` embeddings at N(0, 0.02).
    """
    out: List[jax.Array] = []
    keys = jax.random.split(key, max(len(specs), 2))
    for spec, k in zip(specs, keys):
        n = spec.name
        if n.endswith("_gamma"):
            out.append(jnp.ones(spec.shape, jnp.float32))
        elif n.endswith("_b") or n.endswith("_beta"):
            out.append(jnp.zeros(spec.shape, jnp.float32))
        elif n.endswith("_emb"):
            out.append(0.02 * jax.random.normal(k, spec.shape, jnp.float32))
        else:
            fan_in = spec.shape[0] if len(spec.shape) >= 1 else 1
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            out.append(scale * jax.random.normal(k, spec.shape, jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def dense_specs(name: str, d_in: int, d_out: int) -> List[ParamSpec]:
    return [ParamSpec(f"{name}_w", (d_in, d_out)), ParamSpec(f"{name}_b", (d_out,))]


def dense(cur: Cursor, x: jax.Array, activation: str = "none") -> jax.Array:
    w, b = cur.take(2)
    return fused_dense(x, w, b, activation=activation)


def layernorm_specs(name: str, d: int) -> List[ParamSpec]:
    return [ParamSpec(f"{name}_gamma", (d,)), ParamSpec(f"{name}_beta", (d,))]


def layernorm(cur: Cursor, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    gamma, beta = cur.take(2)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


# ---------------------------------------------------------------------------
# Transformer block (pre-LN, causal)
# ---------------------------------------------------------------------------


def block_specs(name: str, d: int, d_ff: int) -> List[ParamSpec]:
    return (
        layernorm_specs(f"{name}_ln1", d)
        + dense_specs(f"{name}_qkv", d, 3 * d)
        + dense_specs(f"{name}_attnout", d, d)
        + layernorm_specs(f"{name}_ln2", d)
        + dense_specs(f"{name}_ff1", d, d_ff)
        + dense_specs(f"{name}_ff2", d_ff, d)
    )


def transformer_block(
    cur: Cursor, x: jax.Array, *, n_heads: int
) -> jax.Array:
    """x: (B, T, D) -> (B, T, D), causal self-attention + GELU MLP."""
    batch, seq, d = x.shape
    dh = d // n_heads

    h = layernorm(cur, x)
    qkv = dense(cur, h.reshape(batch * seq, d)).reshape(batch, seq, 3, n_heads, dh)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # (B, T, H, dh)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(dh)
    mask = jnp.tril(jnp.ones((seq, seq), jnp.bool_))
    scores = jnp.where(mask[None, None], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(batch * seq, d)
    attn = dense(cur, ctx).reshape(batch, seq, d)
    x = x + attn

    h = layernorm(cur, x)
    h = dense(cur, h.reshape(batch * seq, d), activation="gelu")
    h = dense(cur, h).reshape(batch, seq, d)
    return x + h


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy; labels are int class ids, logits (..., C)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def xent_sum_and_correct(
    logits: jax.Array, labels: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """(summed NLL, count of correct argmax predictions) for eval."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    )
    return jnp.sum(nll), correct
