"""AOT compile path: lower every model-zoo graph to HLO text + manifest.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Emits, per model:
    <out>/<model>/train_r{ratio}.hlo.txt   one per partial-training ratio
    <out>/<model>/eval.hlo.txt
    <out>/<model>/init.hlo.txt
and a single ``<out>/manifest.json`` describing parameter layout, shapes and
the ratio -> trainable-boundary mapping consumed by the rust runtime
(``rust/src/runtime/manifest.rs``).

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True`` —
the rust side unwraps with ``to_tuple()``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as zoo


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def ratio_tag(r: float) -> str:
    """0.125 -> 'r0125', 1.0 -> 'r1000' (stable filenames)."""
    return f"r{int(round(r * 1000)):04d}"


def lower_model(m: zoo.ModelDef, out_dir: str, *, quiet: bool = False) -> dict:
    os.makedirs(os.path.join(out_dir, m.name), exist_ok=True)
    params, x, y, lr = zoo.example_args(m)
    entry = {
        "task": m.task,
        "batch": m.batch,
        "eval_batch": m.eval_batch,
        "x_shape": list(m.x_shape),
        "x_dtype": m.x_dtype,
        "num_classes": m.num_classes,
        "seq_len": m.seq_len,
        "total_params": m.total_params,
        "chunk": zoo.CHUNK,
        "lanes": zoo.BATCH_LANES,
        "params": [
            {"name": s.name, "shape": list(s.shape), "size": s.size} for s in m.specs
        ],
        "ratios": [],
        "eval_artifact": f"{m.name}/eval.hlo.txt",
        "init_artifact": f"{m.name}/init.hlo.txt",
    }

    del x, y, lr  # single-step shapes unused: the train artifact is chunked
    cparams, xs, ys, clr, n_steps = zoo.chunk_example_args(m)
    assert cparams == params
    bparams, bxs, bys, blr, bn_steps = zoo.chunk_batched_example_args(m)
    for r in zoo.RATIOS:
        t0 = time.time()
        step = zoo.make_train_chunk(m, r)
        lowered = jax.jit(step).lower(*cparams, xs, ys, clr, n_steps)
        rel = f"{m.name}/train_{ratio_tag(r)}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(to_hlo_text(lowered))
        # Batched-execution variant: BATCH_LANES independent clients per
        # dispatch (rust `batch_exec=on`); optional in the manifest so old
        # artifact sets keep loading.
        bstep = zoo.make_train_chunk_batched(m, r)
        blowered = jax.jit(bstep).lower(*bparams, bxs, bys, blr, bn_steps)
        brel = f"{m.name}/train_{ratio_tag(r)}_b{zoo.BATCH_LANES}.hlo.txt"
        with open(os.path.join(out_dir, brel), "w") as f:
            f.write(to_hlo_text(blowered))
        entry["ratios"].append(
            {
                "ratio": r,
                "boundary": m.ratio_boundary(r),
                "trainable_fraction": m.trainable_fraction(r),
                "artifact": rel,
                "batched_artifact": brel,
            }
        )
        if not quiet:
            print(f"  {rel} + {brel} ({time.time() - t0:.1f}s)")

    eparams, ex, ey, _ = zoo.example_args(m, for_eval=True)
    lowered = jax.jit(zoo.make_eval_step(m)).lower(*eparams, ex, ey)
    with open(os.path.join(out_dir, entry["eval_artifact"]), "w") as f:
        f.write(to_hlo_text(lowered))

    seed = jax.ShapeDtypeStruct((), jax.numpy.int32)
    lowered = jax.jit(zoo.make_init(m)).lower(seed)
    with open(os.path.join(out_dir, entry["init_artifact"]), "w") as f:
        f.write(to_hlo_text(lowered))

    if not quiet:
        print(f"  {m.name}: eval + init done ({m.total_params} params)")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default=",".join(zoo.MODELS),
        help="comma-separated subset of the model zoo",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"ratios": list(zoo.RATIOS), "models": {}}
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in zoo.MODELS:
            raise SystemExit(f"unknown model {name!r}; have {list(zoo.MODELS)}")
        print(f"lowering {name} ...")
        manifest["models"][name] = lower_model(zoo.MODELS[name], args.out, quiet=args.quiet)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
