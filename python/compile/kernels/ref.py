"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has an oracle here with an identical signature;
``python/tests/test_kernel.py`` sweeps shapes/dtypes with hypothesis and
asserts allclose between kernel and oracle (forward AND gradients).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .dense import apply_activation


def fused_dense_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "none"
) -> jax.Array:
    """Oracle for ``dense.fused_dense``: plain ``act(x @ w + b)`` in jnp."""
    return apply_activation(jnp.dot(x, w) + b, activation)
