"""Layer-1 Pallas kernel: fused dense layer ``act(x @ W + b)``.

This is the compute hot-spot of every train-step in the model zoo (all
models are built from dense blocks; see ``model.py``). The kernel is written
TPU-idiomatically — MXU-shaped tiles expressed through ``BlockSpec`` and the
contraction (K) axis as the innermost grid dimension so each (i, j) output
tile stays resident while it is revisited ``nk`` times as an accumulator.

It is executed with ``interpret=True`` everywhere: the CPU PJRT plugin used
by the rust runtime cannot run Mosaic custom-calls, and interpret-mode
lowers the kernel to plain HLO ops that any backend executes. Correctness is
pinned against the pure-jnp oracle in ``ref.py`` (pytest + hypothesis).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's clients
are mobile SoCs running MNN; the per-client training hot loop is GEMM-bound
there as well. We tile for VMEM (scratchpad) rather than CUDA shared memory
and target the MXU systolic array shape (128x128) rather than WMMA tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles. 128 is the systolic-array edge; the second-minor
# tiling constraint (8 sublanes x 128 lanes for f32) is satisfied by any
# multiple of 8 in the M dimension.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128

ACTIVATIONS = ("none", "relu", "gelu", "tanh")


def apply_activation(x: jax.Array, activation: str) -> jax.Array:
    """Epilogue activation shared by the kernel and the jnp oracle."""
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "tanh":
        return jnp.tanh(x)
    raise ValueError(f"unknown activation {activation!r}")


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """Grid = (M/bm, N/bn, K/bk); K innermost, o_ref doubles as accumulator.

    All model weights are f32, so the output tile itself is a valid f32
    accumulator — this keeps the kernel portable between the Mosaic and
    interpret paths without a VMEM scratch allocation.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU matmul on the current (bm, bk) x (bk, bn) tile pair; accumulate at
    # f32 (preferred_element_type pins the MXU accumulator precision).
    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = apply_activation(out, activation).astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _fused_dense_raw(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """The pallas_call itself (no autodiff rule).

    Arbitrary (M, K) x (K, N) shapes are supported by zero-padding up to the
    tile grid and slicing the result back; zero padding is exact because the
    padded rows/cols are discarded before any downstream op sees them and a
    zero K-extension contributes nothing to the contraction.
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"activation must be one of {ACTIVATIONS}")
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} w{w.shape} b{b.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape[0] != n:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    # Clamp tiles to the (padded) problem so tiny layers do not blow up to a
    # full 128x128 grid cell per element of work.
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 128))
    bk = min(block_k, _ceil_to(k, 128))

    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))

    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    out = pl.pallas_call(
        functools.partial(_fused_dense_kernel, nk=nk, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain tiled matmul through the same Pallas kernel (zero bias)."""
    return _fused_dense_raw(a, b, jnp.zeros((b.shape[1],), a.dtype), "none")


# ---------------------------------------------------------------------------
# Autodiff: jax cannot JVP through a pallas_call that uses program_id, so the
# backward pass is supplied explicitly — and itself runs on the Pallas matmul
# kernel, keeping the whole train-step GEMM-bound on the L1 kernel.
#
#   u  = x @ w + b            (pre-activation, recomputed in bwd: remat)
#   dy_pre = dy * act'(u)     (exact, via jax.vjp of the epilogue)
#   dx = dy_pre @ w.T ;  dw = x.T @ dy_pre ;  db = sum(dy_pre)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused_dense(x, w, b, activation):
    return _fused_dense_raw(x, w, b, activation)


def _fused_dense_fwd(x, w, b, activation):
    return _fused_dense_raw(x, w, b, activation), (x, w, b)


def _fused_dense_bwd(activation, res, dy):
    x, w, b = res
    if activation == "none":
        dy_pre = dy
    else:
        u = _fused_dense_raw(x, w, b, "none")  # remat the pre-activation
        _, epilogue_vjp = jax.vjp(lambda t: apply_activation(t, activation), u)
        (dy_pre,) = epilogue_vjp(dy)
    dx = matmul(dy_pre, w.T)
    dw = matmul(x.T, dy_pre)
    db = jnp.sum(dy_pre, axis=0)
    return dx, dw, db


_fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)


def fused_dense(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "none"
) -> jax.Array:
    """Fused ``act(x @ w + b)`` as a Pallas kernel, differentiable.

    Public entry point used by every dense block in ``model.py``. Forward and
    backward both execute on the tiled Pallas kernel; the activation
    derivative is exact (``jax.vjp`` of the same epilogue function).
    """
    return _fused_dense(x, w, b, activation)
