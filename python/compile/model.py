"""Layer-2: the model zoo — JAX forward/backward train-step graphs.

Paper mapping (DESIGN.md §3: every real dataset/model is substituted by a
synthetic equivalent exercising the same code path):

================  ===========================  ==============================
zoo name          paper model / dataset         ours
================  ===========================  ==============================
``vision``        ResNet-20 on CIFAR-10         PatchCNN on 24x24x3 synthetic
                                                10-class Gaussian clusters
``speech``        VGG11 on Google Speech        frame-dense + temporal pool on
                                                32x40 synthetic spectrograms,
                                                35 classes
``text``          ALBERT on Reddit (next word)  2-layer causal transformer LM,
                                                vocab 512, seq 32
``kws_lite``      lightweight KWS net [33]      ~80k-param dense KWS net
``e2e_lm``        (end-to-end driver)           6-layer transformer LM,
                                                d=256, vocab 4096, seq 64
================  ===========================  ==============================

Each model exposes fixed-shape jittable functions that ``aot.py`` lowers to
HLO text, one artifact per partial-training ratio:

- ``train_step(*params, x, y, lr) -> (*new_params, loss)`` — one SGD step.
  For ratio r < 1 the parameter *prefix* (input-side layers) is frozen: it
  still runs the forward pass but ``stop_gradient`` + identity pass-through
  means XLA dead-code-eliminates its backward graph, mirroring the paper's
  partial model training (§3.2.2: only a suffix of consecutive output-side
  layers is trained).
- ``eval_step(*params, x, y) -> (loss_sum, correct)`` (classification) or
  ``-> (nll_sum, token_count)`` (LM; perplexity = exp(nll_sum/token_count)).
- ``init(seed) -> (*params,)``.

Parameters are a flat, positionally-ordered list (see ``nn.py``): the rust
runtime addresses them by index using ``artifacts/manifest.json``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import nn
from .nn import Cursor, ParamSpec

# Partial-training ratios compiled AOT. The scheduler (rust) rounds its
# continuous alpha down to the nearest entry, guaranteeing the client still
# finishes within the aggregation interval.
RATIOS = (0.125, 0.25, 0.5, 0.75, 1.0)

# SGD steps fused into ONE PJRT call (lax.scan over stacked batches).
# Padding slots beyond the dynamic ``n_steps`` operand are masked out, so
# the rust trainer issues ceil(total_steps / CHUNK) executions per client
# round instead of one per minibatch — the L2 perf optimisation recorded in
# EXPERIMENTS.md §Perf (the per-execute host<->device roundtrip dominates on
# CPU-PJRT).
CHUNK = 8

# Client lanes fused into ONE PJRT call (lax.map over per-lane train
# chunks): the batched-execution artifact stacks BATCH_LANES independent
# clients' chunks — each with its own params, minibatches and dynamic
# ``n_steps`` — so the rust engine issues one dispatch per aggregation
# point instead of one per client (``batch_exec=on``). ``lax.map`` (not
# ``vmap``) on purpose: every lane runs the *same* scan body the
# single-lane artifact runs, so per-lane results are independent of which
# lanes share a dispatch — the bit-identity the equivalence suite locks.
BATCH_LANES = 8


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """Everything aot.py / the manifest needs to know about one model."""

    name: str
    task: str  # "classify" | "lm"
    specs: Tuple[ParamSpec, ...]
    forward: Callable[[Sequence[jax.Array], jax.Array], jax.Array]
    batch: int
    eval_batch: int
    x_shape: Tuple[int, ...]  # per-example feature shape (flattened f32) or (T,) int32
    x_dtype: str  # "f32" | "i32"
    num_classes: int  # classes (classify) or vocab (lm)
    seq_len: int = 0  # lm only

    @property
    def total_params(self) -> int:
        return sum(s.size for s in self.specs)

    def ratio_boundary(self, ratio: float) -> int:
        """First trainable param index for a partial ratio.

        Largest suffix of consecutive output-side tensors whose parameter
        count is <= ratio * total, but never empty (the classifier head is
        always trainable) — paper §3.2.2.
        """
        total = self.total_params
        budget = ratio * total
        acc = 0
        boundary = len(self.specs)  # exclusive start; move left while it fits
        for i in range(len(self.specs) - 1, -1, -1):
            if acc + self.specs[i].size > budget and boundary < len(self.specs):
                break
            acc += self.specs[i].size
            boundary = i
        return min(boundary, len(self.specs) - 2 if len(self.specs) >= 2 else 0)

    def trainable_fraction(self, ratio: float) -> float:
        b = self.ratio_boundary(ratio)
        return sum(s.size for s in self.specs[b:]) / self.total_params


# ---------------------------------------------------------------------------
# vision — PatchCNN (ResNet-20 / CIFAR-10 stand-in)
# ---------------------------------------------------------------------------

VISION_IMG = 24  # 24x24x3 synthetic images, 4x4 grid of 6x6 patches
VISION_PATCH = 6
VISION_DIM = VISION_IMG * VISION_IMG * 3


def _vision_specs() -> List[ParamSpec]:
    p = VISION_PATCH * VISION_PATCH * 3  # 108
    specs = nn.dense_specs("patch", p, 64)
    # Binary-tree patch merging: 16 -> 8 -> 4 -> 2 -> 1 tokens, each stage a
    # shared dense(128 -> 64). Conv-like receptive-field growth with layers
    # of near-uniform parameter count, so partial-training ratios map to
    # distinct trainable suffixes (paper §3.2.2 needs layer granularity).
    for i in range(4):
        specs += nn.dense_specs(f"merge{i}", 128, 64)
    specs += nn.dense_specs("trunk", 64, 128)
    specs += nn.dense_specs("head", 128, 10)
    return specs


def _vision_forward(params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """x: (B, 1728) f32 -> logits (B, 10)."""
    cur = Cursor(params)
    b = x.shape[0]
    g = VISION_IMG // VISION_PATCH
    img = x.reshape(b, g, VISION_PATCH, g, VISION_PATCH, 3)
    patches = img.transpose(0, 1, 3, 2, 4, 5).reshape(b * g * g, -1)  # (B*16,108)
    h = nn.dense(cur, patches, activation="relu").reshape(b, g * g, 64)
    for _ in range(4):  # 16 -> 8 -> 4 -> 2 -> 1
        t = h.shape[1]
        pairs = h.reshape(b * (t // 2), 2 * 64)
        h = nn.dense(cur, pairs, activation="relu").reshape(b, t // 2, 64)
    h = nn.dense(cur, h.reshape(b, 64), activation="relu")
    logits = nn.dense(cur, h)
    cur.done()
    return logits


# ---------------------------------------------------------------------------
# speech — frame-dense + temporal pooling (VGG11 / Google Speech stand-in)
# ---------------------------------------------------------------------------

SPEECH_FRAMES = 32
SPEECH_MELS = 40
SPEECH_DIM = SPEECH_FRAMES * SPEECH_MELS


def _speech_specs() -> List[ParamSpec]:
    specs = nn.dense_specs("frame", SPEECH_MELS, 64)
    # Binary-tree temporal merging: 32 -> 16 -> 8 -> 4 -> 2 -> 1 frames (a
    # dilated-conv / pooling-pyramid analogue of VGG11's conv stack) with
    # near-uniform per-stage parameter counts for partial-ratio granularity.
    for i in range(5):
        specs += nn.dense_specs(f"merge{i}", 128, 64)
    specs += nn.dense_specs("trunk", 64, 128)
    specs += nn.dense_specs("head", 128, 35)
    return specs


def _speech_forward(params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """x: (B, 1280) f32 spectrogram -> logits (B, 35)."""
    cur = Cursor(params)
    b = x.shape[0]
    frames = x.reshape(b * SPEECH_FRAMES, SPEECH_MELS)
    h = nn.dense(cur, frames, activation="relu").reshape(b, SPEECH_FRAMES, 64)
    for _ in range(5):  # 32 -> 16 -> 8 -> 4 -> 2 -> 1
        t = h.shape[1]
        pairs = h.reshape(b * (t // 2), 2 * 64)
        h = nn.dense(cur, pairs, activation="relu").reshape(b, t // 2, 64)
    h = nn.dense(cur, h.reshape(b, 64), activation="relu")
    logits = nn.dense(cur, h)
    cur.done()
    return logits


# ---------------------------------------------------------------------------
# kws_lite — ~80k-param keyword-spotting net (paper §4.3 lightweight model)
# ---------------------------------------------------------------------------


def _kws_specs() -> List[ParamSpec]:
    return (
        nn.dense_specs("frame", SPEECH_MELS, 80)
        + nn.dense_specs("mix", 80, 320)
        + nn.dense_specs("trunk", 320, 144)
        + nn.dense_specs("head", 144, 35)
    )


def _kws_forward(params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    cur = Cursor(params)
    b = x.shape[0]
    frames = x.reshape(b * SPEECH_FRAMES, SPEECH_MELS)
    h = nn.dense(cur, frames, activation="relu").reshape(b, SPEECH_FRAMES, 80)
    h = h.mean(axis=1)
    h = nn.dense(cur, h, activation="relu")
    h = nn.dense(cur, h, activation="relu")
    logits = nn.dense(cur, h)
    cur.done()
    return logits


# ---------------------------------------------------------------------------
# Transformer LMs — text (ALBERT/Reddit stand-in) and e2e_lm (driver model)
# ---------------------------------------------------------------------------


def _lm_specs(vocab: int, seq: int, d: int, d_ff: int, layers: int) -> List[ParamSpec]:
    specs = [ParamSpec("tok_emb", (vocab, d)), ParamSpec("pos_emb", (seq, d))]
    for i in range(layers):
        specs += nn.block_specs(f"blk{i}", d, d_ff)
    specs += nn.layernorm_specs("lnf", d)
    specs += nn.dense_specs("head", d, vocab)
    return specs


def _lm_forward_factory(
    vocab: int, seq: int, d: int, layers: int, heads: int
) -> Callable[[Sequence[jax.Array], jax.Array], jax.Array]:
    def forward(params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
        """x: (B, T) int32 tokens -> logits (B, T, vocab)."""
        cur = Cursor(params)
        tok_emb, pos_emb = cur.take(2)
        b, t = x.shape
        h = tok_emb[x] + pos_emb[None, :t]
        for _ in range(layers):
            h = nn.transformer_block(cur, h, n_heads=heads)
        h = nn.layernorm(cur, h)
        logits = nn.dense(cur, h.reshape(b * t, d)).reshape(b, t, vocab)
        cur.done()
        return logits

    return forward


TEXT_VOCAB, TEXT_SEQ, TEXT_D, TEXT_LAYERS, TEXT_HEADS = 512, 32, 64, 2, 4
E2E_VOCAB, E2E_SEQ, E2E_D, E2E_LAYERS, E2E_HEADS = 4096, 64, 256, 6, 8


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def _registry() -> Dict[str, ModelDef]:
    models = {
        "vision": ModelDef(
            name="vision",
            task="classify",
            specs=tuple(_vision_specs()),
            forward=_vision_forward,
            batch=8,
            eval_batch=64,
            x_shape=(VISION_DIM,),
            x_dtype="f32",
            num_classes=10,
        ),
        "speech": ModelDef(
            name="speech",
            task="classify",
            specs=tuple(_speech_specs()),
            forward=_speech_forward,
            batch=16,
            eval_batch=64,
            x_shape=(SPEECH_DIM,),
            x_dtype="f32",
            num_classes=35,
        ),
        "kws_lite": ModelDef(
            name="kws_lite",
            task="classify",
            specs=tuple(_kws_specs()),
            forward=_kws_forward,
            batch=16,
            eval_batch=64,
            x_shape=(SPEECH_DIM,),
            x_dtype="f32",
            num_classes=35,
        ),
        "text": ModelDef(
            name="text",
            task="lm",
            specs=tuple(_lm_specs(TEXT_VOCAB, TEXT_SEQ, TEXT_D, 4 * TEXT_D, TEXT_LAYERS)),
            forward=_lm_forward_factory(TEXT_VOCAB, TEXT_SEQ, TEXT_D, TEXT_LAYERS, TEXT_HEADS),
            batch=8,
            eval_batch=32,
            x_shape=(TEXT_SEQ,),
            x_dtype="i32",
            num_classes=TEXT_VOCAB,
            seq_len=TEXT_SEQ,
        ),
        "e2e_lm": ModelDef(
            name="e2e_lm",
            task="lm",
            specs=tuple(_lm_specs(E2E_VOCAB, E2E_SEQ, E2E_D, 4 * E2E_D, E2E_LAYERS)),
            forward=_lm_forward_factory(E2E_VOCAB, E2E_SEQ, E2E_D, E2E_LAYERS, E2E_HEADS),
            batch=8,
            eval_batch=16,
            x_shape=(E2E_SEQ,),
            x_dtype="i32",
            num_classes=E2E_VOCAB,
            seq_len=E2E_SEQ,
        ),
    }
    return models


MODELS = _registry()


# ---------------------------------------------------------------------------
# Train / eval / init graph builders (what aot.py lowers)
# ---------------------------------------------------------------------------


def loss_fn(model: ModelDef, params: Sequence[jax.Array], x: jax.Array, y: jax.Array) -> jax.Array:
    logits = model.forward(params, x)
    if model.task == "classify":
        return nn.softmax_xent(logits, y)
    return nn.softmax_xent(logits.reshape(-1, model.num_classes), y.reshape(-1))


def make_train_step(model: ModelDef, ratio: float):
    """SGD train-step with the prefix [0, boundary) frozen."""
    boundary = model.ratio_boundary(ratio)

    def train_step(*args):
        n = len(model.specs)
        params, x, y, lr = list(args[:n]), args[n], args[n + 1], args[n + 2]
        frozen, trainable = params[:boundary], params[boundary:]

        def partial_loss(trainable_params):
            full = [jax.lax.stop_gradient(p) for p in frozen] + list(trainable_params)
            return loss_fn(model, full, x, y)

        loss, grads = jax.value_and_grad(partial_loss)(trainable)
        new_trainable = [p - lr * g for p, g in zip(trainable, grads)]
        return tuple(frozen) + tuple(new_trainable) + (loss,)

    return train_step


def make_train_chunk(model: ModelDef, ratio: float, chunk: int = CHUNK):
    """Fused multi-step SGD train graph (the AOT'd hot path).

    Signature::

        (*params, xs[S, B, ...], ys[S, ...], lr, n_steps:i32)
            -> (*new_params, loss_sum)

    Runs ``lax.scan`` over ``S = chunk`` stacked minibatches; slots with
    index >= ``n_steps`` are masked (zero effective learning rate, zero loss
    contribution), so callers pad the tail of the stack with any valid batch.
    ``loss_sum`` is the sum of the executed steps' (pre-update) losses —
    divide by ``n_steps`` host-side for the mean.

    Numerically identical to ``n_steps`` sequential ``make_train_step``
    executions (asserted by ``tests/test_model.py``).
    """
    boundary = model.ratio_boundary(ratio)

    def train_chunk(*args):
        n = len(model.specs)
        params = list(args[:n])
        xs, ys, lr, n_steps = args[n], args[n + 1], args[n + 2], args[n + 3]
        frozen = [jax.lax.stop_gradient(p) for p in params[:boundary]]
        trainable = list(params[boundary:])

        def body(carry, inp):
            cur, loss_sum = carry
            i, x, y = inp

            def partial_loss(tp):
                return loss_fn(model, frozen + list(tp), x, y)

            loss, grads = jax.value_and_grad(partial_loss)(tuple(cur))
            active = jnp.where(i < n_steps, jnp.float32(1), jnp.float32(0))
            new_cur = [p - lr * active * g for p, g in zip(cur, grads)]
            return (new_cur, loss_sum + active * loss), None

        idx = jnp.arange(chunk, dtype=jnp.int32)
        (new_trainable, loss_sum), _ = jax.lax.scan(
            body, (trainable, jnp.float32(0)), (idx, xs, ys)
        )
        return tuple(params[:boundary]) + tuple(new_trainable) + (loss_sum,)

    return train_chunk


def make_train_chunk_batched(
    model: ModelDef, ratio: float, lanes: int = BATCH_LANES, chunk: int = CHUNK
):
    """Multi-client train graph: ``lanes`` independent chunks, one dispatch.

    Signature::

        (*params[L, ...], xs[L, S, B, ...], ys[L, S, ...], lr, n_steps[L]:i32)
            -> (*new_params[L, ...], loss_sum[L])

    Lane ``l`` runs exactly ``make_train_chunk`` on its own parameter set and
    batch stack, masked to its own ``n_steps[l]``; a lane with ``n_steps[l]
    == 0`` passes its params through untouched (zero loss), which is how the
    rust trainer pads short lanes. ``lr`` is shared (one global client_lr).
    """
    step = make_train_chunk(model, ratio, chunk)

    def train_chunk_batched(*args):
        n = len(model.specs)
        params = tuple(args[:n])
        xs, ys, lr, n_steps = args[n], args[n + 1], args[n + 2], args[n + 3]

        def lane(inp):
            lane_params, lane_xs, lane_ys, lane_n = inp
            return step(*lane_params, lane_xs, lane_ys, lr, lane_n)

        return jax.lax.map(lane, (params, xs, ys, n_steps))

    return train_chunk_batched


def make_eval_step(model: ModelDef):
    def eval_step(*args):
        n = len(model.specs)
        params, x, y = list(args[:n]), args[n], args[n + 1]
        logits = model.forward(params, x)
        if model.task == "classify":
            return nn.xent_sum_and_correct(logits, y)
        nll_sum, _ = nn.xent_sum_and_correct(
            logits.reshape(-1, model.num_classes), y.reshape(-1)
        )
        count = jnp.float32(y.size)
        return nll_sum, count

    return eval_step


def make_init(model: ModelDef):
    def init(seed):
        key = jax.random.PRNGKey(seed)
        return tuple(nn.init_params(model.specs, key))

    return init


def example_args(model: ModelDef, *, for_eval: bool = False):
    """ShapeDtypeStructs for jax.jit(...).lower()."""
    b = model.eval_batch if for_eval else model.batch
    params = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.specs]
    xd = jnp.float32 if model.x_dtype == "f32" else jnp.int32
    x = jax.ShapeDtypeStruct((b, *model.x_shape), xd)
    if model.task == "classify":
        y = jax.ShapeDtypeStruct((b,), jnp.int32)
    else:
        y = jax.ShapeDtypeStruct((b, model.seq_len), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    return params, x, y, lr


def chunk_example_args(model: ModelDef, chunk: int = CHUNK):
    """ShapeDtypeStructs for jax.jit(make_train_chunk(...)).lower()."""
    params, x, y, lr = example_args(model)
    xs = jax.ShapeDtypeStruct((chunk, *x.shape), x.dtype)
    ys = jax.ShapeDtypeStruct((chunk, *y.shape), y.dtype)
    n_steps = jax.ShapeDtypeStruct((), jnp.int32)
    return params, xs, ys, lr, n_steps


def chunk_batched_example_args(model: ModelDef, lanes: int = BATCH_LANES, chunk: int = CHUNK):
    """ShapeDtypeStructs for jax.jit(make_train_chunk_batched(...)).lower()."""
    params, xs, ys, lr, _ = chunk_example_args(model, chunk)
    bparams = [jax.ShapeDtypeStruct((lanes, *p.shape), p.dtype) for p in params]
    bxs = jax.ShapeDtypeStruct((lanes, *xs.shape), xs.dtype)
    bys = jax.ShapeDtypeStruct((lanes, *ys.shape), ys.dtype)
    n_steps = jax.ShapeDtypeStruct((lanes,), jnp.int32)
    return bparams, bxs, bys, lr, n_steps
