"""Layer-1 correctness: the Pallas fused-dense kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and activations; forward AND backward must agree.
This is the core correctness signal of the compile path — everything the
rust runtime executes flows through this kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dense import ACTIVATIONS, fused_dense, matmul
from compile.kernels.ref import fused_dense_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize("activation", ACTIVATIONS)
@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 108, 64),      # vision patch layer
        (128, 128, 128),   # exactly one MXU tile
        (256, 256, 128),   # multi-tile M and K
        (5, 7, 3),         # tiny, fully padded
        (33, 200, 35),     # ragged everything
        (1, 1, 1),         # degenerate
    ],
)
def test_forward_matches_ref(m, k, n, activation):
    x, w, b = _rand(1, m, k), _rand(2, k, n) * 0.2, _rand(3, n)
    got = fused_dense(x, w, b, activation=activation)
    ref = fused_dense_ref(x, w, b, activation=activation)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("activation", ACTIVATIONS)
def test_gradients_match_ref(activation):
    m, k, n = 16, 96, 40
    x, w, b = _rand(4, m, k), _rand(5, k, n) * 0.2, _rand(6, n)

    def loss_kernel(x, w, b):
        return (fused_dense(x, w, b, activation=activation) ** 2).sum()

    def loss_ref(x, w, b):
        return (fused_dense_ref(x, w, b, activation=activation) ** 2).sum()

    g1 = jax.grad(loss_kernel, (0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, (0, 1, 2))(x, w, b)
    for a, r in zip(g1, g2):
        np.testing.assert_allclose(a, r, rtol=3e-4, atol=3e-4)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 160),
    n=st.integers(1, 80),
    activation=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(m, k, n, activation, seed):
    key = jax.random.PRNGKey(seed)
    kx, kw, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.3
    b = jax.random.normal(kb, (n,), jnp.float32)
    got = fused_dense(x, w, b, activation=activation)
    ref = fused_dense_ref(x, w, b, activation=activation)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, ref, rtol=3e-5, atol=3e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 96),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_gradient_sweep(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    kx, kw, kb, kc = jax.random.split(key, 4)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.3
    b = jax.random.normal(kb, (n,), jnp.float32)
    cot = jax.random.normal(kc, (m, n), jnp.float32)

    def f(fn):
        def loss(x, w, b):
            return (fn(x, w, b, activation="relu") * cot).sum()

        return jax.grad(loss, (0, 1, 2))(x, w, b)

    for a, r in zip(f(fused_dense), f(fused_dense_ref)):
        np.testing.assert_allclose(a, r, rtol=5e-4, atol=5e-4)


def test_matmul_helper():
    a, b = _rand(7, 30, 50), _rand(8, 50, 20)
    np.testing.assert_allclose(matmul(a, b), a @ b, rtol=2e-5, atol=2e-5)


def test_bad_shapes_raise():
    with pytest.raises(ValueError):
        fused_dense(_rand(1, 4, 5), _rand(2, 6, 3), _rand(3, 3))
    with pytest.raises(ValueError):
        fused_dense(_rand(1, 4, 5), _rand(2, 5, 3), _rand(3, 7))
    with pytest.raises(ValueError):
        fused_dense(_rand(1, 4, 5), _rand(2, 5, 3), _rand(3, 3), activation="swish")


def test_f32_accumulation_precision():
    # Large-K contraction: naive f16-style accumulation would drift; the
    # kernel accumulates at f32 and must stay close to the f64 ground truth.
    m, k, n = 8, 1024, 8
    x, w = _rand(9, m, k), _rand(10, k, n)
    b = jnp.zeros((n,), jnp.float32)
    got = np.asarray(fused_dense(x, w, b), np.float64)
    truth = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
    np.testing.assert_allclose(got, truth, rtol=1e-4, atol=1e-4)
