"""Layer-2 correctness: model zoo shapes, partial-training semantics, and
train-step behaviour (pre-AOT — the same functions aot.py lowers)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as zoo
from compile import nn

jax.config.update("jax_platform_name", "cpu")

SMALL_MODELS = ["vision", "speech", "kws_lite", "text"]  # e2e_lm is slow; covered by aot


def _init(m, seed=0):
    return list(zoo.make_init(m)(jnp.int32(seed)))


def _batch(m, rng, batch=None):
    b = batch or m.batch
    if m.x_dtype == "f32":
        x = rng.standard_normal((b, *m.x_shape), np.float32)
        y = rng.integers(0, m.num_classes, (b,), np.int32)
    else:
        x = rng.integers(0, m.num_classes, (b, *m.x_shape), np.int32)
        y = rng.integers(0, m.num_classes, (b, m.seq_len), np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_init_matches_specs(name):
    m = zoo.MODELS[name]
    params = _init(m)
    assert len(params) == len(m.specs)
    for p, s in zip(params, m.specs):
        assert p.shape == s.shape, s.name
        assert bool(jnp.all(jnp.isfinite(p)))
    assert sum(int(np.prod(p.shape)) for p in params) == m.total_params


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_forward_shapes(name):
    m = zoo.MODELS[name]
    params = _init(m)
    rng = np.random.default_rng(0)
    x, _ = _batch(m, rng)
    logits = m.forward(params, x)
    if m.task == "classify":
        assert logits.shape == (m.batch, m.num_classes)
    else:
        assert logits.shape == (m.batch, m.seq_len, m.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_full_train_step_reduces_loss(name):
    m = zoo.MODELS[name]
    step = jax.jit(zoo.make_train_step(m, 1.0))
    params = _init(m)
    rng = np.random.default_rng(1)
    x, y = _batch(m, rng)  # overfit one fixed batch
    first = last = None
    for _ in range(25):
        out = step(*params, x, y, jnp.float32(0.1))
        params, loss = list(out[:-1]), float(out[-1])
        first = loss if first is None else first
        last = loss
    assert last < 0.7 * first, f"{name}: {first} -> {last}"


@pytest.mark.parametrize("name", SMALL_MODELS)
@pytest.mark.parametrize("ratio", [0.25, 0.5])
def test_partial_step_freezes_prefix(name, ratio):
    m = zoo.MODELS[name]
    boundary = m.ratio_boundary(ratio)
    assert 0 < boundary < len(m.specs)
    step = jax.jit(zoo.make_train_step(m, ratio))
    params = _init(m)
    rng = np.random.default_rng(2)
    x, y = _batch(m, rng)
    out = step(*params, x, y, jnp.float32(0.1))
    new_params = list(out[:-1])
    for i in range(boundary):
        np.testing.assert_array_equal(params[i], new_params[i]), m.specs[i].name
    moved = any(
        not np.array_equal(params[i], new_params[i])
        for i in range(boundary, len(params))
    )
    assert moved, "no trainable tensor moved"


def test_ratio_boundaries_monotone():
    for m in zoo.MODELS.values():
        bounds = [m.ratio_boundary(r) for r in zoo.RATIOS]
        assert bounds == sorted(bounds, reverse=True), (m.name, bounds)
        assert m.ratio_boundary(1.0) == 0
        # trainable fraction never exceeds requested ratio (rounded down to
        # a layer boundary), except that the classifier head (the minimal
        # mandatory suffix) is always trainable even when it alone exceeds
        # the ratio budget.
        n = len(m.specs)
        min_boundary = min(n - 2 if n >= 2 else 0, n - 1)
        min_fraction = sum(s.size for s in m.specs[min_boundary:]) / m.total_params
        for r in zoo.RATIOS:
            assert m.trainable_fraction(r) <= max(r, min_fraction) + 1e-9
            assert m.trainable_fraction(r) > 0


@pytest.mark.parametrize("name", ["vision", "kws_lite"])
@pytest.mark.parametrize("ratio", [0.5, 1.0])
def test_chunk_matches_sequential_steps(name, ratio):
    """The fused scan train-chunk is numerically identical to repeated
    single train-steps (the §Perf optimisation must not change semantics)."""
    m = zoo.MODELS[name]
    chunk = 4
    n_steps = 3  # exercise tail-slot masking too
    step = jax.jit(zoo.make_train_step(m, ratio))
    fused = jax.jit(zoo.make_train_chunk(m, ratio, chunk))
    params = _init(m, seed=5)
    rng = np.random.default_rng(7)
    batches = [_batch(m, rng) for _ in range(chunk)]
    lr = jnp.float32(0.05)

    seq = list(params)
    losses = []
    for i in range(n_steps):
        out = step(*seq, batches[i][0], batches[i][1], lr)
        seq, losses = list(out[:-1]), losses + [float(out[-1])]

    xs = jnp.stack([b[0] for b in batches])
    ys = jnp.stack([b[1] for b in batches])
    out = fused(*params, xs, ys, lr, jnp.int32(n_steps))
    fused_params, loss_sum = list(out[:-1]), float(out[-1])

    np.testing.assert_allclose(loss_sum, sum(losses), rtol=1e-5)
    for a, b, spec in zip(seq, fused_params, m.specs):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6, err_msg=spec.name)


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_eval_step_counts(name):
    m = zoo.MODELS[name]
    ev = jax.jit(zoo.make_eval_step(m))
    params = _init(m)
    rng = np.random.default_rng(3)
    x, y = _batch(m, rng, batch=m.eval_batch)
    loss_sum, second = ev(*params, x, y)
    n = m.eval_batch if m.task == "classify" else m.eval_batch * m.seq_len
    # untrained mean loss should be near ln(num_classes)
    mean = float(loss_sum) / n
    assert abs(mean - np.log(m.num_classes)) < 1.0
    if m.task == "classify":
        assert 0 <= float(second) <= m.eval_batch
    else:
        assert float(second) == n


def test_softmax_xent_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 0.0, 0.0]])
    labels = jnp.asarray([0, 2])
    got = float(nn.softmax_xent(logits, labels))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    manual = (-np.log(p0) - np.log(1 / 3)) / 2
    assert abs(got - manual) < 1e-5


def test_layernorm_normalizes():
    cur = nn.Cursor([jnp.ones((8,)), jnp.zeros((8,))])
    x = jnp.asarray(np.random.default_rng(4).standard_normal((4, 8)) * 5 + 3, jnp.float32)
    out = nn.layernorm(cur, x)
    np.testing.assert_allclose(np.mean(out, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(out, -1), 1.0, atol=1e-3)


def test_transformer_block_causal():
    # Changing a future token must not change past positions' outputs.
    d, heads, seq = 32, 4, 8
    specs = nn.block_specs("b", d, 2 * d)
    params = nn.init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, seq, d))
    out1 = nn.transformer_block(nn.Cursor(params), x, n_heads=heads)
    x2 = x.at[0, -1].set(99.0)
    out2 = nn.transformer_block(nn.Cursor(params), x2, n_heads=heads)
    np.testing.assert_allclose(out1[0, :-1], out2[0, :-1], atol=1e-5)
    assert not np.allclose(out1[0, -1], out2[0, -1])
