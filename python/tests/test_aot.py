"""Compile-path checks: HLO text emission + manifest consistency."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as zoo

jax.config.update("jax_platform_name", "cpu")


def test_ratio_tag_stable():
    assert aot.ratio_tag(0.125) == "r0125"
    assert aot.ratio_tag(0.5) == "r0500"
    assert aot.ratio_tag(1.0) == "r1000"


def test_hlo_text_emission_smoke(tmp_path):
    # Lower the smallest model's eval graph only (fast) and sanity-check the
    # HLO text: ENTRY, tuple root, parameters.
    m = zoo.MODELS["kws_lite"]
    params, x, y, _ = zoo.example_args(m, for_eval=True)
    lowered = jax.jit(zoo.make_eval_step(m)).lower(*params, x, y)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "parameter(0)" in text
    # eval returns 2-tuple
    assert "tuple(" in text or "ROOT" in text


def test_lower_model_writes_all_artifacts(tmp_path):
    m = zoo.MODELS["kws_lite"]
    entry = aot.lower_model(m, str(tmp_path), quiet=True)
    for r in entry["ratios"]:
        assert (tmp_path / r["artifact"]).exists()
        assert r["boundary"] == m.ratio_boundary(r["ratio"])
    assert (tmp_path / entry["eval_artifact"]).exists()
    assert (tmp_path / entry["init_artifact"]).exists()
    sizes = [p["size"] for p in entry["params"]]
    assert sum(sizes) == m.total_params


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="run `make artifacts` first",
)
def test_existing_manifest_consistent_with_zoo():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    assert set(manifest["ratios"]) == set(zoo.RATIOS)
    for name, entry in manifest["models"].items():
        m = zoo.MODELS[name]
        assert entry["total_params"] == m.total_params, name
        assert len(entry["params"]) == len(m.specs), name
        for spec, p in zip(m.specs, entry["params"]):
            assert p["name"] == spec.name
            assert tuple(p["shape"]) == spec.shape
        for r in entry["ratios"]:
            assert r["boundary"] == m.ratio_boundary(r["ratio"]), (name, r)
