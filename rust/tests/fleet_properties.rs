//! Artifact-free property suite for the fleet subsystem (runs in
//! `scripts/check.sh`, no PJRT artifacts needed).
//!
//! Three layers of properties:
//! - **OnlineSetIndex** — randomized churn against a linear-scan reference
//!   set, plus twin-RNG proofs that indexed sampling consumes the exact
//!   draw sequence of the historical pool-indexing paths;
//! - **LazyAvailability** — the on-demand transition sweep against the
//!   eager O(n) scans, across every stochastic availability process and
//!   under adversarial (coarse/fine/jittered) sweep schedules;
//! - **Hierarchy** — the public aggregation algebra: single-group
//!   bit-exactness, fan-in invariance of the weighted mean, and the
//!   uniform policy's deliberate divergence.
//!
//! `tests/fleet_equivalence.rs` proves the same contracts end-to-end
//! through real simulations; this suite pins them at the unit seam so a
//! violation names the broken structure directly.

use timelyfl::availability::{AvailabilityConfig, AvailabilityKind, AvailabilityModel};
use timelyfl::config::parse::{apply_cli, KNOWN_KEYS};
use timelyfl::config::RunConfig;
use timelyfl::fleet::{
    ClockMode, FleetCore, ForwardPolicy, HierarchyConfig, LazyAvailability, OnlineSetIndex,
    RegionClock, Topology,
};
use timelyfl::util::rng::Rng;
use timelyfl::util::stats::gini;

// ---------------------------------------------------------------- index

/// Linear-scan reference: the set an `OnlineSetIndex` claims to be.
fn reference(idx: &OnlineSetIndex) -> Vec<usize> {
    (0..idx.capacity()).filter(|&i| idx.contains(i)).collect()
}

#[test]
fn index_tracks_a_reference_set_under_random_churn() {
    // Capacities straddling word boundaries (64-bit words) are the spots a
    // bitset + Fenwick implementation gets wrong.
    for capacity in [1, 63, 64, 65, 128, 130, 1000] {
        let mut idx = OnlineSetIndex::new(capacity);
        let mut rng = Rng::seed_from(0xF1EE7 ^ capacity as u64);
        for step in 0..1500 {
            let id = rng.usize_below(capacity);
            if rng.f64() < 0.5 {
                idx.insert(id);
            } else {
                idx.remove(id);
            }
            if step % 97 == 0 || step > 1400 {
                let want = reference(&idx);
                assert_eq!(idx.len(), want.len(), "cap {capacity} step {step}");
                assert_eq!(idx.to_vec(), want, "cap {capacity} step {step}");
                for (k, &member) in want.iter().enumerate() {
                    assert_eq!(idx.select(k), member, "cap {capacity} rank {k}");
                }
            }
        }
    }
}

#[test]
fn indexed_sampling_replays_the_pool_indexing_rng_stream() {
    // The byte-identity of the lazy sim core rests on exactly this: the
    // index must consume the SAME RNG draws, in the SAME order, as the
    // historical `pool[rng.usize_below(len)]` / `sample_without_replacement`
    // paths over the ascending materialized pool.
    let mut idx = OnlineSetIndex::new(777);
    let mut churn = Rng::seed_from(31);
    for _ in 0..400 {
        idx.insert(churn.usize_below(777));
    }
    for _ in 0..60 {
        idx.remove(churn.usize_below(777));
    }
    let pool = idx.to_vec();

    let mut a = Rng::seed_from(0xABCD);
    let mut b = a.clone();
    for _ in 0..300 {
        assert_eq!(idx.sample_one(&mut a), pool[b.usize_below(pool.len())]);
    }
    for want in [0, 1, 7, pool.len() / 3, pool.len()] {
        let got = idx.sample_distinct(&mut a, want);
        let expect: Vec<usize> = b
            .sample_without_replacement(pool.len(), want)
            .into_iter()
            .map(|i| pool[i])
            .collect();
        assert_eq!(got, expect, "want = {want}");
        // Draw-order equality, not just set equality.
        assert_eq!(got.len(), want);
    }
    assert_eq!(a.next_u64(), b.next_u64(), "RNG streams must stay in sync");
}

// ----------------------------------------------------------- lazy sweep

fn churny_model(kind: AvailabilityKind, population: usize, seed: u64) -> AvailabilityModel {
    let cfg = AvailabilityConfig {
        kind,
        mean_online_secs: 600.0,
        mean_offline_secs: 200.0,
        regions: 3,
        region_mtbf_secs: 500.0,
        region_outage_secs: 250.0,
        degrade_window_secs: 120.0,
        ..AvailabilityConfig::default()
    };
    AvailabilityModel::build(&cfg, population, seed).unwrap()
}

#[test]
fn lazy_sweep_equals_eager_scans_for_every_process() {
    // Twin models on the same seed (queries lazily extend Markov timelines,
    // so the two access patterns must not share one model). After each
    // sweep the lazy online set — in ascending order — must equal the eager
    // linear scan, and the agenda head must equal the eager O(n)
    // earliest-transition scan. Diurnal is closed-form, trace-free; all
    // stochastic kinds plus always-on are covered.
    for kind in [
        AvailabilityKind::AlwaysOn,
        AvailabilityKind::Markov,
        AvailabilityKind::Diurnal,
        AvailabilityKind::Correlated,
    ] {
        let mut lazy_model = churny_model(kind, 50, 0xBEEF);
        let mut eager_model = churny_model(kind, 50, 0xBEEF);
        let mut lazy = LazyAvailability::new(&mut lazy_model);
        let mut jitter = Rng::seed_from(2);
        let mut now = 0.0;
        for _ in 0..300 {
            // Adversarial schedule: mostly small hops, occasional leaps —
            // sweeps that pop zero, one, and many transitions at once.
            now += if jitter.f64() < 0.1 {
                jitter.range(500.0, 2500.0)
            } else {
                jitter.range(0.0, 40.0)
            };
            lazy.advance_to(&mut lazy_model, now);
            assert_eq!(
                lazy.online().to_vec(),
                eager_model.online_clients(now),
                "{kind:?}: online set diverged at t={now}"
            );
            assert_eq!(
                lazy.earliest_transition(),
                eager_model.earliest_transition(now),
                "{kind:?}: earliest transition diverged at t={now}"
            );
        }
    }
}

#[test]
fn lazy_sweep_is_insensitive_to_sweep_granularity() {
    // Sweeping in many small steps and sweeping straight to the horizon
    // must land on the same final set: pops are chained per client, so no
    // transition can be skipped by a coarse sweep.
    let mut fine_model = churny_model(AvailabilityKind::Correlated, 40, 99);
    let mut coarse_model = churny_model(AvailabilityKind::Correlated, 40, 99);
    let mut fine = LazyAvailability::new(&mut fine_model);
    let mut coarse = LazyAvailability::new(&mut coarse_model);
    let horizon = 5000.0;
    let mut t = 0.0;
    while t < horizon {
        t += 13.0;
        fine.advance_to(&mut fine_model, t.min(horizon));
    }
    coarse.advance_to(&mut coarse_model, horizon);
    assert_eq!(fine.online().to_vec(), coarse.online().to_vec());
    assert_eq!(fine.earliest_transition(), coarse.earliest_transition());
}

// ------------------------------------------------------------ hierarchy

#[test]
fn hierarchy_config_surface_round_trips_through_overrides() {
    // The `--set` surface and the typed config agree; unknown values get
    // catalogued errors (the satellite-b contract, pinned here from the
    // public API side).
    let mut cfg = RunConfig::default();
    assert_eq!(cfg.fleet_core, FleetCore::Eager, "eager must stay the default");
    assert!(!cfg.hierarchy.is_tiered(), "flat must stay the default");
    for (k, v) in [
        ("fleet_core", "lazy"),
        ("hierarchy", "two-tier"),
        ("hier_regions", "16"),
        ("hier_fan_in", "8"),
        ("hier_forward", "uniform"),
        ("hier_depth", "3"),
        ("hier_clock", "region"),
        ("hier_flush_secs", "90"),
        ("hier_uplink", "priced"),
        ("hier_up_ratio", "0.5"),
    ] {
        assert!(KNOWN_KEYS.contains(&k), "{k} missing from KNOWN_KEYS");
        apply_cli(&mut cfg, &format!("{k}={v}")).unwrap();
    }
    assert_eq!(cfg.fleet_core, FleetCore::Lazy);
    assert_eq!(
        cfg.hierarchy,
        HierarchyConfig {
            topology: Topology::Tree,
            regions: 16,
            fan_in: 8,
            forward: ForwardPolicy::Uniform,
            depth: 3,
            clock: ClockMode::Region,
            flush_secs: 90.0,
            flush_auto: false,
            uplink: "priced".into(),
            up_ratio: 0.5,
        }
    );
    cfg.validate().unwrap();
    // `auto` flips the calibration flag without clobbering the number.
    apply_cli(&mut cfg, "hier_flush_secs=auto").unwrap();
    assert!(cfg.hierarchy.flush_auto);
    assert_eq!(cfg.hierarchy.flush_secs, 90.0);
    cfg.validate().unwrap();

    let err = format!("{:#}", apply_cli(&mut cfg, "fleet_kore=lazy").unwrap_err());
    assert!(err.contains("fleet_core"), "unknown-key error lists fleet_core: {err}");
    assert!(err.contains("hier_fan_in"), "unknown-key error lists hier_fan_in: {err}");
    assert!(err.contains("hier_clock"), "unknown-key error lists hier_clock: {err}");

    // Region clocks without a tier — or without any flush window — are
    // contradictions caught at validate, not at parse.
    let mut bad = RunConfig::default();
    apply_cli(&mut bad, "hier_clock=region").unwrap();
    assert!(bad.validate().is_err(), "region clocks on a flat topology must fail");
    apply_cli(&mut bad, "hierarchy=tree").unwrap();
    assert!(bad.validate().is_err(), "region clocks without a flush window must fail");
    apply_cli(&mut bad, "hier_flush_secs=30").unwrap();
    bad.validate().unwrap();
}

#[test]
fn scale_scenarios_resolve_and_validate() {
    // The shipped fleet scenarios stay materialisable without artifacts:
    // resolving + validating exercises the whole config surface at the
    // million-client setting.
    use timelyfl::experiment::scenario;
    for (name, population) in [
        ("fleet_1m", 1_000_000),
        ("fleet_50k", 50_000),
        ("fleet_tree", 50_000),
    ] {
        let spec = scenario::resolve(name).unwrap();
        let cfg = spec.config().unwrap();
        assert_eq!(cfg.population, population, "{name}");
        assert_eq!(cfg.fleet_core, FleetCore::Lazy, "{name}");
        assert!(cfg.hierarchy.is_tiered(), "{name}");
    }
    // Only the edge-clock testbed runs region-clocked; the scale scenarios
    // keep the lockstep (byte-identity) default.
    assert!(!scenario::resolve("fleet_1m").unwrap().config().unwrap().hierarchy.region_clocked());
    let tree = scenario::resolve("fleet_tree").unwrap().config().unwrap();
    assert!(tree.hierarchy.region_clocked());
    assert_eq!(tree.hierarchy.depth, 3);
}

#[test]
fn region_clock_deadline_algebra_from_the_public_api() {
    // The engine-facing lifecycle, artifact-free: absorb opens + arms once
    // per window, ripeness is deadline-gated, flush disarms + hands back
    // the merged partial, stale alarm generations stop matching, and the
    // `auto` interval calibrates from the region's own flush cadence.
    use timelyfl::fleet::PartialAggregate;
    let part = |v: f32| PartialAggregate { sums: vec![vec![v]], wsums: vec![1.0] };

    let mut rc = RegionClock::new();
    assert!(!rc.holds());
    assert_eq!(rc.deadline(), None);
    let armed = rc.absorb(part(1.0), 1000.0, 120.0, false);
    assert_eq!(armed, Some(1120.0), "first absorb arms now + interval");
    let gen = rc.gen();
    assert!(rc.alarm_matches(gen));
    assert_eq!(rc.absorb(part(2.0), 1100.0, 120.0, false), None, "merge, no re-arm");
    assert_eq!(rc.deadline(), Some(1120.0), "deadline untouched by later absorbs");
    assert!(!rc.ripe(1119.9));
    assert!(rc.ripe(1120.0));
    let flushed = rc.flush(1120.0).expect("held partial");
    assert_eq!(flushed.sums[0][0], 3.0);
    assert_eq!(flushed.wsums[0], 2.0);
    assert!(!rc.holds());
    assert!(!rc.alarm_matches(gen), "flushed window invalidates its alarm");
    assert!(rc.flush(1200.0).is_none(), "double flush is a no-op");

    // Auto calibration: intervals derive from realized flush-to-flush
    // spacing, per region, falling back to the fixed value until observed.
    let mut auto = RegionClock::new();
    assert_eq!(auto.interval(60.0, true), 60.0, "no estimate yet: fallback");
    auto.absorb(part(1.0), 0.0, 60.0, true);
    auto.flush(60.0);
    auto.absorb(part(1.0), 80.0, 60.0, true);
    auto.flush(140.0);
    assert_eq!(auto.interval(60.0, true), 80.0, "first inter-flush interval");
    assert_eq!(auto.absorb(part(1.0), 200.0, 60.0, true), Some(280.0));
}

#[test]
fn gini_is_a_sane_dispersion_measure_for_participation_vectors() {
    // Randomized sanity for the report metric: bounded, scale-invariant,
    // zero at equality, and monotone under a concentrating transfer.
    let mut rng = Rng::seed_from(5);
    for _ in 0..200 {
        let n = 2 + rng.usize_below(64);
        let xs: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let g = gini(&xs);
        assert!((0.0..=1.0).contains(&g), "gini {g} out of [0, 1]");
        let scaled: Vec<f64> = xs.iter().map(|x| x * 17.0).collect();
        assert!((gini(&scaled) - g).abs() < 1e-9, "scale invariance");
    }
    assert_eq!(gini(&vec![0.25; 10]), 0.0);
    // Transfer from the poorest to the richest strictly increases G.
    let before = vec![0.2, 0.4, 0.9];
    let after = vec![0.1, 0.4, 1.0];
    assert!(gini(&after) > gini(&before));
    // Poisoned vectors degrade to the neutral 0.0 — never a panic from the
    // sort, never NaN in a report (the NaN-safety satellite).
    for poison in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        let mut xs = vec![0.2, 0.4, 0.9];
        xs.push(poison);
        assert_eq!(gini(&xs), 0.0, "{poison:?}");
        assert_eq!(gini(&[poison]), 0.0, "{poison:?}");
    }
    assert_eq!(gini(&[f64::NAN, f64::INFINITY, f64::NEG_INFINITY]), 0.0);
}
