//! Integration tests: every registered strategy over the REAL compiled
//! artifacts (kws_lite — the cheapest zoo model — keeps each run fast).
//!
//! These assert coordinator-level invariants the unit tests cannot see:
//! determinism across identical seeds, participation accounting, partial
//! training actually engaging, dropout injection behaving, the
//! cross-strategy ordering the paper's story depends on, and (post
//! engine/registry refactor) that registry dispatch, the run-event stream,
//! and the golden report fingerprints all agree.

use timelyfl::config::RunConfig;
use timelyfl::coordinator::Simulation;
use timelyfl::metrics::RunReport;

// PjRtClient is not Sync, so each test builds its own simulation (kws_lite
// compiles in ~a second; tests stay independent and parallelisable).
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn tiny_cfg(strategy: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.population = 12;
    cfg.concurrency = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 3.2e5;
    cfg
}

fn run(cfg: RunConfig) -> RunReport {
    Simulation::new(cfg, ARTIFACTS)
        .expect("build simulation (run `make artifacts` first)")
        .run()
        .expect("run simulation")
}

fn assert_report_sane(r: &RunReport, cfg: &RunConfig) {
    assert!(r.total_rounds > 0 && r.total_rounds <= cfg.rounds);
    assert_eq!(r.rounds.len(), r.total_rounds);
    assert!(!r.eval_points.is_empty(), "no evaluations recorded");
    assert_eq!(r.participation.len(), cfg.population);
    for &p in &r.participation {
        assert!((0.0..=1.0).contains(&p), "participation {p} out of range");
    }
    for p in &r.eval_points {
        assert!(p.mean_loss.is_finite());
        assert!(p.metric.is_finite());
        assert!(p.sim_secs >= 0.0);
    }
    for w in r.rounds.windows(2) {
        assert!(w[1].sim_secs >= w[0].sim_secs, "sim time went backwards");
    }
    for round in &r.rounds {
        // (Buffered event-driven strategies accumulate drop counts — and,
        // for deadline-gated windows, fast clients' repeat updates —
        // between flushes, so only the population bounds participants here;
        // the round-stepped strategies get the tighter bound below.)
        assert!(round.participants <= cfg.population);
        match round.mean_train_loss {
            Some(l) => {
                assert!(l.is_finite());
                assert!(round.participants > 0, "loss reported with no participants");
            }
            None => assert_eq!(round.participants, 0, "participants but no loss"),
        }
    }
    assert_eq!(r.online_fraction.len(), cfg.population);
    for &f in &r.online_fraction {
        assert!((0.0..=1.0).contains(&f), "online fraction {f} out of range");
    }
    assert!(r.events_processed > 0, "no simulation events processed");
    assert!(r.real_train_steps > 0, "no real PJRT training happened");
    assert!(r.trainings_executed > 0, "no client dispatch ever executed");
    // (Ledger settlement — executed + avoided == dispatched — is asserted
    // against the engine's own counters in Recorder::finish and against an
    // independent baseline in deferred_equivalence.rs; the report only
    // carries the two settled legs.)
}

/// Round-stepped strategies (TimelyFL / SyncFL) sample once per round, so
/// participants + all drops are bounded by the concurrency.
fn assert_round_drops_bounded(r: &RunReport, cfg: &RunConfig) {
    for round in &r.rounds {
        assert!(
            round.participants + round.dropped + round.avail_dropped <= cfg.concurrency,
            "round {}: {} + {} + {} > concurrency",
            round.round,
            round.participants,
            round.dropped,
            round.avail_dropped
        );
    }
}

#[test]
fn timelyfl_runs_and_is_sane() {
    let cfg = tiny_cfg("TimelyFL");
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    assert_round_drops_bounded(&r, &cfg);
    assert_eq!(r.strategy, "TimelyFL");
    // Always-on default: every client online the whole run, no churn drops.
    assert!(r.online_fraction.iter().all(|&f| f == 1.0));
    assert_eq!(r.total_avail_drops(), 0);
}

#[test]
fn fedbuff_runs_and_is_sane() {
    let cfg = tiny_cfg("FedBuff");
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    // FedBuff aggregates exactly k updates per round.
    let k = cfg.k_target();
    for round in &r.rounds {
        assert!(round.participants >= k, "buffer flushed below the goal");
    }
}

#[test]
fn syncfl_runs_and_is_sane() {
    let cfg = tiny_cfg("SyncFL");
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    assert_round_drops_bounded(&r, &cfg);
    // Without dropout every sampled client participates: mean rate is
    // exactly concurrency / population.
    let expected = cfg.concurrency as f64 / cfg.population as f64;
    assert!(
        (r.mean_participation() - expected).abs() < 1e-9,
        "syncfl mean {} != {expected}",
        r.mean_participation()
    );
}

#[test]
fn identical_seeds_identical_reports() {
    let cfg = tiny_cfg("TimelyFL");
    let a = run(cfg.clone());
    let b = run(cfg);
    assert_eq!(a.total_rounds, b.total_rounds);
    assert_eq!(a.participation, b.participation);
    let am: Vec<f64> = a.eval_points.iter().map(|p| p.metric).collect();
    let bm: Vec<f64> = b.eval_points.iter().map(|p| p.metric).collect();
    assert_eq!(am, bm, "same seed must reproduce the same learning curve");
    assert!((a.sim_secs - b.sim_secs).abs() < 1e-9);
}

#[test]
fn different_seeds_differ() {
    let cfg = tiny_cfg("TimelyFL");
    let mut cfg2 = cfg.clone();
    cfg2.seed ^= 0xDEAD;
    let a = run(cfg);
    let b = run(cfg2);
    assert_ne!(
        a.participation, b.participation,
        "fleet/sampling must depend on the seed"
    );
}

#[test]
fn timelyfl_includes_more_than_fedbuff() {
    // The paper's core claim at the smallest scale that shows it: with a
    // heterogeneous fleet, TimelyFL's mean participation rate beats
    // FedBuff's (which only ever aggregates the k fastest arrivals).
    let mut t_cfg = tiny_cfg("TimelyFL");
    t_cfg.rounds = 12;
    let mut f_cfg = tiny_cfg("FedBuff");
    f_cfg.rounds = 12;
    let t = run(t_cfg);
    let f = run(f_cfg);
    assert!(
        t.mean_participation() > f.mean_participation(),
        "TimelyFL {} <= FedBuff {}",
        t.mean_participation(),
        f.mean_participation()
    );
}

#[test]
fn adaptive_ablation_path_runs() {
    let mut cfg = tiny_cfg("TimelyFL");
    cfg.adaptive = false;
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
}

#[test]
fn partial_training_engages_on_tight_intervals() {
    // Squeeze k so T_k is the FASTEST client's unit time: everyone slower
    // must train partially (or miss). Loss must still be finite and some
    // training must happen.
    let mut cfg = tiny_cfg("TimelyFL");
    cfg.k_fraction = 0.2;
    cfg.fleet.compute_spread = 13.3;
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    // With T_k at the 20th percentile, most rounds should still include
    // >= k clients thanks to partial training (the paper's mechanism).
    let k = cfg.k_target();
    let ok_rounds = r.rounds.iter().filter(|x| x.participants >= k).count();
    assert!(
        ok_rounds * 2 >= r.rounds.len(),
        "partial training failed to keep clients inside the interval"
    );
}

#[test]
fn dropout_injection_registers_losses() {
    let mut cfg = tiny_cfg("TimelyFL");
    cfg.dropout_prob = 0.5;
    cfg.rounds = 10;
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    let dropped: usize = r.rounds.iter().map(|x| x.dropped).sum();
    assert!(dropped > 0, "dropout injection never dropped anyone");

    // Control: no dropout -> (near) no drops beyond deadline misses.
    let mut base = tiny_cfg("TimelyFL");
    base.rounds = 10;
    let rb = run(base);
    let base_dropped: usize = rb.rounds.iter().map(|x| x.dropped).sum();
    assert!(
        dropped > base_dropped,
        "dropout=0.5 should drop more than dropout=0"
    );
}

#[test]
fn dropout_syncfl_still_aggregates() {
    let mut cfg = tiny_cfg("SyncFL");
    cfg.dropout_prob = 0.4;
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    assert!(r.mean_participation() < cfg.concurrency as f64 / cfg.population as f64);
}

#[test]
fn fedbuff_staleness_cap_drops_updates() {
    let mut strict = tiny_cfg("FedBuff");
    strict.max_staleness = Some(0); // only perfectly fresh updates
    strict.rounds = 10;
    let r = run(strict.clone());
    // The run must complete even while discarding most slow updates.
    assert_report_sane(&r, &strict);
    let relaxed = {
        let mut c = tiny_cfg("FedBuff");
        c.rounds = 10;
        run(c)
    };
    assert!(
        r.mean_participation() <= relaxed.mean_participation() + 1e-9,
        "staleness cap cannot increase participation"
    );
}

#[test]
fn fedopt_adam_server_converges_on_vision() {
    use timelyfl::aggregation::ServerOptKind;
    let mut cfg = tiny_cfg("TimelyFL");
    cfg.model = "vision".into();
    cfg.server_opt = ServerOptKind::Adam;
    cfg.server_lr = 0.001;
    cfg.rounds = 20;
    cfg.eval_every = 4;
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    // Adam's first bias-corrected steps are large and noisy at this tiny
    // scale; the invariant is boundedness (no blow-up), not fast descent —
    // convergence speed is covered by the table benches.
    let first = r.eval_points.first().unwrap().mean_loss;
    for p in &r.eval_points {
        assert!(
            p.mean_loss.is_finite() && p.mean_loss <= first * 2.0,
            "vision+Adam blew up: {first} -> {}",
            p.mean_loss
        );
    }
}

fn markov_availability(mean_online: f64, mean_offline: f64) -> timelyfl::availability::AvailabilityConfig {
    use timelyfl::availability::{AvailabilityConfig, AvailabilityKind};
    AvailabilityConfig {
        kind: AvailabilityKind::Markov,
        mean_online_secs: mean_online,
        mean_offline_secs: mean_offline,
        dwell_sigma: 0.5,
        ..AvailabilityConfig::default()
    }
}

#[test]
fn markov_churn_reduces_participation() {
    // ~25% steady-state availability with dwells comparable to round times:
    // participation must fall well below the always-on baseline, and the
    // loss must be attributed to availability, not deadlines.
    let base = {
        let mut c = tiny_cfg("TimelyFL");
        c.rounds = 10;
        c
    };
    let churn = {
        let mut c = base.clone();
        c.availability = markov_availability(200.0, 600.0);
        c
    };
    let rb = run(base.clone());
    let rc = run(churn.clone());
    assert_report_sane(&rc, &churn);
    assert_round_drops_bounded(&rc, &churn);
    assert!(
        rc.mean_online_fraction() < 0.6,
        "online fraction {} not reduced by churn",
        rc.mean_online_fraction()
    );
    assert!(
        rc.mean_participation() < rb.mean_participation(),
        "churn {} should reduce participation vs always-on {}",
        rc.mean_participation(),
        rb.mean_participation()
    );
}

#[test]
fn fedbuff_churn_still_aggregates() {
    let mut cfg = tiny_cfg("FedBuff");
    cfg.rounds = 10;
    // Short online dwells relative to FedBuff round times: clients churn
    // out mid-training often enough to register.
    cfg.availability = markov_availability(120.0, 240.0);
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    assert!(
        r.mean_online_fraction() < 0.8,
        "online fraction {} not reduced",
        r.mean_online_fraction()
    );
    // The run must still aggregate k updates per round despite churn.
    let k = cfg.k_target();
    for round in &r.rounds {
        assert!(round.participants >= k, "buffer flushed below the goal");
    }
}

#[test]
fn diurnal_availability_runs_all_strategies() {
    use timelyfl::availability::AvailabilityKind;
    for strat in ["TimelyFL", "FedBuff", "SyncFL", "SemiAsync"] {
        let mut cfg = tiny_cfg(strat);
        cfg.rounds = 6;
        cfg.availability.kind = AvailabilityKind::Diurnal;
        cfg.availability.diurnal_period_secs = 2000.0;
        cfg.availability.diurnal_duty = 0.5;
        cfg.availability.diurnal_shards = 4;
        let r = run(cfg.clone());
        assert_report_sane(&r, &cfg);
        // Over whole periods the population-mean online fraction tracks the
        // duty cycle; runs end mid-period, so keep the bracket loose.
        let f = r.mean_online_fraction();
        assert!(
            (0.2..=0.85).contains(&f),
            "{strat}: diurnal online fraction {f} implausible for duty 0.5",
        );
    }
}

#[test]
fn churn_determinism_by_seed() {
    let mut cfg = tiny_cfg("TimelyFL");
    cfg.rounds = 6;
    cfg.availability = markov_availability(300.0, 300.0);
    let a = run(cfg.clone());
    let b = run(cfg);
    assert_eq!(a.participation, b.participation);
    assert_eq!(a.online_fraction, b.online_fraction);
    assert_eq!(a.total_avail_drops(), b.total_avail_drops());
    assert!((a.sim_secs - b.sim_secs).abs() < 1e-9);
}

#[test]
fn lm_model_reports_perplexity() {
    let mut cfg = tiny_cfg("TimelyFL");
    cfg.model = "text".into();
    cfg.rounds = 4;
    cfg.eval_every = 2;
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    for p in &r.eval_points {
        // ppl = exp(mean nll): must be > 1 and consistent with the loss
        assert!(p.metric > 1.0);
        assert!((p.metric - p.mean_loss.exp()).abs() < 1e-6 * p.metric.max(1.0));
    }
}

// ---------------------------------------------------------------------------
// Registry, engine, and run-event-stream coverage (engine/registry refactor)
// ---------------------------------------------------------------------------

use timelyfl::coordinator::{registry, SimEngine};
use timelyfl::metrics::events::{self, CollectSink, RunEvent};

#[test]
fn every_registered_strategy_builds_and_runs() {
    for info in registry::STRATEGIES {
        let mut cfg = tiny_cfg(info.name);
        cfg.rounds = 4;
        let r = run(cfg.clone());
        assert_report_sane(&r, &cfg);
        assert_eq!(r.strategy, info.name, "report name mismatches registry");
    }
}

#[test]
fn registry_dispatch_equals_direct_engine_drive() {
    // `Simulation::run` (registry resolution + event-sink plumbing) must
    // add nothing on top of hand-driving the engine; alias lookup must
    // resolve to the same constructor.
    let cfg = tiny_cfg("TimelyFL");
    let sim = Simulation::new(cfg, ARTIFACTS).expect("build simulation");
    let via_registry = sim.run().expect("registry run");
    let direct = {
        let info = registry::resolve("timely").expect("alias resolves");
        let mut strategy = (info.build)(&sim).expect("construct strategy");
        let mut eng = SimEngine::new(&sim, None).expect("build engine");
        strategy.run(&mut eng).expect("drive engine");
        eng.finish(strategy.name())
    };
    assert_eq!(via_registry.strategy, direct.strategy);
    assert_eq!(via_registry.total_rounds, direct.total_rounds);
    assert_eq!(via_registry.participation, direct.participation);
    assert_eq!(via_registry.sim_secs, direct.sim_secs);
    assert_eq!(via_registry.events_processed, direct.events_processed);
    let am: Vec<f64> = via_registry.eval_points.iter().map(|p| p.metric).collect();
    let bm: Vec<f64> = direct.eval_points.iter().map(|p| p.metric).collect();
    assert_eq!(am, bm);
}

#[test]
fn every_strategy_is_seed_deterministic() {
    for info in registry::STRATEGIES {
        let mut cfg = tiny_cfg(info.name);
        cfg.rounds = 5;
        let a = run(cfg.clone());
        let b = run(cfg);
        assert_eq!(a.participation, b.participation, "{} not deterministic", info.name);
        assert_eq!(a.total_rounds, b.total_rounds);
        assert!((a.sim_secs - b.sim_secs).abs() < 1e-9);
    }
}

#[test]
fn event_stream_matches_report() {
    let mut cfg = tiny_cfg("FedBuff");
    cfg.rounds = 6;
    let sim = Simulation::new(cfg, ARTIFACTS).expect("build simulation");
    let mut sink = CollectSink::default();
    let report = sim.run_with_sink(&mut sink).expect("run with sink");

    let rounds: Vec<_> = sink
        .events
        .iter()
        .filter_map(|e| match e {
            RunEvent::RoundComplete {
                round,
                participants,
                dropped,
                avail_dropped,
                ..
            } => Some((*round, *participants, *dropped, *avail_dropped)),
            _ => None,
        })
        .collect();
    assert_eq!(rounds.len(), report.total_rounds, "one round-complete per round");
    for (rec, &(round, participants, dropped, avail_dropped)) in
        report.rounds.iter().zip(&rounds)
    {
        assert_eq!(rec.round, round);
        assert_eq!(rec.participants, participants);
        assert_eq!(rec.dropped, dropped);
        assert_eq!(rec.avail_dropped, avail_dropped);
    }
    let evals = sink
        .events
        .iter()
        .filter(|e| matches!(e, RunEvent::EvalPoint { .. }))
        .count();
    assert_eq!(evals, report.eval_points.len(), "one eval-point per evaluation");

    // The stream round-trips through the JSONL writer/parser (util::json).
    let text = events::write_jsonl(&sink.events);
    assert_eq!(events::parse_jsonl(&text).unwrap(), sink.events);
}

#[test]
fn round_complete_events_carry_workloads() {
    // Round-stepped strategies settle eligibility before training, so each
    // round-complete record's workload list is exactly its participants'
    // Alg. 3 assignments (E_c >= 1, alpha_c in (0, 1]).
    let mut cfg = tiny_cfg("TimelyFL");
    cfg.rounds = 6;
    let sim = Simulation::new(cfg, ARTIFACTS).expect("build simulation");
    let mut sink = CollectSink::default();
    let report = sim.run_with_sink(&mut sink).expect("run with sink");
    let mut assignments = 0usize;
    for e in &sink.events {
        if let RunEvent::RoundComplete { participants, workloads, .. } = e {
            assert_eq!(
                workloads.len(),
                *participants,
                "round-stepped workload list must match its participants"
            );
            for w in workloads {
                assert!(w.epochs >= 1, "Alg. 3 line 2 guarantees E_c >= 1");
                assert!(w.alpha > 0.0 && w.alpha <= 1.0, "alpha {} out of range", w.alpha);
            }
            assignments += workloads.len();
        }
    }
    assert!(assignments > 0, "no workload assignments recorded");
    assert_eq!(
        assignments as u64,
        report.trainings_executed,
        "TimelyFL records one workload per executed training"
    );
}

#[test]
fn drop_events_match_attribution_totals() {
    let mut cfg = tiny_cfg("TimelyFL");
    cfg.dropout_prob = 0.5;
    cfg.rounds = 8;
    cfg.availability = markov_availability(300.0, 300.0);
    let sim = Simulation::new(cfg, ARTIFACTS).expect("build simulation");
    let mut sink = CollectSink::default();
    let report = sim.run_with_sink(&mut sink).expect("run with sink");

    use timelyfl::metrics::events::DropCause;
    let (mut avail_ev, mut deadline_ev) = (0usize, 0usize);
    for e in &sink.events {
        if let RunEvent::ClientDropped { cause, .. } = e {
            match cause {
                DropCause::Availability => avail_ev += 1,
                DropCause::Deadline => deadline_ev += 1,
            }
        }
    }
    assert_eq!(avail_ev, report.total_avail_drops(), "churn drop events");
    assert_eq!(deadline_ev, report.total_deadline_drops(), "deadline drop events");
    assert!(deadline_ev > 0, "dropout=0.5 produced no deadline drops");
}

#[test]
fn semiasync_runs_and_is_sane() {
    let mut cfg = tiny_cfg("SemiAsync");
    cfg.rounds = 8;
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    assert_eq!(r.strategy, "SemiAsync");
    // Deadline-gated flushes only fire on non-empty buffers, and
    // participant lists are deduped per window.
    for round in &r.rounds {
        assert!(round.participants >= 1, "flushed an empty window");
        assert!(round.participants <= cfg.population);
    }
    for &p in &r.participation {
        assert!((0.0..=1.0).contains(&p));
    }
}

#[test]
fn semiasync_survives_churn() {
    let mut cfg = tiny_cfg("SemiAsync");
    cfg.rounds = 8;
    cfg.availability = markov_availability(200.0, 400.0);
    let r = run(cfg.clone());
    assert_report_sane(&r, &cfg);
    assert!(r.mean_online_fraction() < 0.8, "churn not engaged");
}

/// Compact, fully-precise fingerprint of everything the golden compares.
fn fingerprint(r: &RunReport) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    writeln!(s, "strategy={}", r.strategy).unwrap();
    writeln!(s, "total_rounds={}", r.total_rounds).unwrap();
    writeln!(s, "events_processed={}", r.events_processed).unwrap();
    writeln!(s, "sim_secs={:?}", r.sim_secs).unwrap();
    // Dissemination counters: exactly 0/0 for every `network = free` case,
    // so the goldens also lock the free path's bit-identity contract.
    writeln!(s, "downlink_wait_secs={:?}", r.downlink_wait_secs).unwrap();
    writeln!(s, "stale_starts={}", r.stale_starts).unwrap();
    writeln!(s, "participation={:?}", r.participation).unwrap();
    for p in &r.eval_points {
        writeln!(
            s,
            "eval round={} sim_secs={:?} loss={:?} metric={:?}",
            p.round, p.sim_secs, p.mean_loss, p.metric
        )
        .unwrap();
    }
    for rr in &r.rounds {
        writeln!(
            s,
            "round {} sim_secs={:?} participants={} dropped={} avail_dropped={} loss={:?}",
            rr.round, rr.sim_secs, rr.participants, rr.dropped, rr.avail_dropped, rr.mean_train_loss
        )
        .unwrap();
    }
    s
}

/// Golden lock on the ported drivers: the refactor onto SimEngine preserved
/// the pre-refactor RNG draw order and event schedule by construction (and
/// the deferred-dispatch split preserves it again — batch plans are drawn
/// eagerly, so RNG stream positions never move); this test freezes the
/// resulting reports bit-for-bit so any FUTURE engine change that perturbs
/// them fails loudly. Regenerate (only for an intentional behaviour change)
/// with TIMELYFL_WRITE_GOLDENS=1. Absent goldens are a skip-with-warning on
/// dev checkouts but a hard failure when TIMELYFL_REQUIRE_GOLDENS is set —
/// the CI release lane records them first and then runs with the gate armed
/// (see .github/workflows/check.yml and tests/goldens/README.md).
#[test]
fn golden_reports_bit_identical() {
    // Canonical location is rust/tests/goldens/ (committed there; CI's
    // release-smoke lane uploads exactly that path). Resolve it whether
    // the Cargo manifest sits at the repo root ([lib] path = rust/src/...)
    // or inside rust/.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let dir = if root.join("rust/tests").is_dir() {
        root.join("rust/tests/goldens")
    } else {
        root.join("tests/goldens")
    };
    let write = std::env::var("TIMELYFL_WRITE_GOLDENS").is_ok();
    let require = std::env::var("TIMELYFL_REQUIRE_GOLDENS").is_ok();
    // Every registered strategy under the always-on default, plus one
    // sampler × correlated-churn configuration so the committed-goldens
    // gate also protects the sampling subsystem and the correlated
    // process (any RNG-order or schedule change there shows up here).
    let mut cases: Vec<(String, RunConfig)> = ["TimelyFL", "FedBuff", "SyncFL", "SemiAsync"]
        .iter()
        .map(|&name| (name.to_lowercase(), tiny_cfg(name)))
        .collect();
    let mut regional = tiny_cfg("TimelyFL");
    regional.sampler = "stay-prob".into();
    regional.sampler_horizon_secs = 200.0;
    {
        use timelyfl::availability::AvailabilityKind;
        let a = &mut regional.availability;
        a.kind = AvailabilityKind::Correlated;
        a.regions = 3;
        a.region_mtbf_secs = 500.0;
        a.region_outage_secs = 250.0;
        a.mean_online_secs = 600.0;
        a.mean_offline_secs = 200.0;
        a.degrade_window_secs = 120.0;
    }
    cases.push(("timelyfl_stayprob_correlated".into(), regional.clone()));
    // And the network subsystem: priced dissemination under the same
    // correlated churn (uniform sampler isolates the network axis). The
    // fingerprint's downlink/stale lines make dissemination drift visible
    // even when the schedule happens to survive.
    let mut priced = regional.clone();
    priced.sampler = "uniform".into();
    priced.network.model = "priced".into();
    priced.network.down_ratio = 0.25;
    cases.push(("timelyfl_priced_correlated".into(), priced));
    // And the scheduling subsystem: the sched-joint aggregation weigher
    // under the same correlated churn (uniform sampler isolates the weigher
    // axis). Non-uniform weights bend only the learning curve, so the
    // fingerprint's eval lines are where drift in the weigher algebra or
    // the drop-ledger plumbing becomes visible.
    let mut weighted = regional;
    weighted.sampler = "uniform".into();
    weighted.scheduling.weigher = "sched-joint".into();
    cases.push(("timelyfl_weighted".into(), weighted));
    // And the hot-path execution config: batched dispatch + chunk-parallel
    // aggregation must fingerprint IDENTICALLY to the serial `timelyfl`
    // golden (batched_equivalence.rs proves the full-report equality; this
    // pins it against the committed bytes too). Recorded as its own stem so
    // the record/verify cycle exercises the batched code path end to end.
    let mut batched = tiny_cfg("TimelyFL");
    batched.batch_exec = true;
    batched.agg_jobs = 2;
    // (Skipped on artifact sets recorded before the batched graphs —
    // everything else in this test still runs there.)
    if std::fs::read_to_string(std::path::Path::new(ARTIFACTS).join("manifest.json"))
        .is_ok_and(|m| m.contains("batched_artifact"))
    {
        cases.push(("timelyfl_batched".into(), batched));
    } else {
        eprintln!("timelyfl_batched golden skipped: artifact set has no batched graphs");
    }
    for (stem, cfg) in cases {
        let r = run(cfg);
        let fp = fingerprint(&r);
        let path = dir.join(format!("{stem}.golden.txt"));
        if write {
            std::fs::create_dir_all(&dir).expect("create goldens dir");
            std::fs::write(&path, &fp).expect("write golden");
            eprintln!("wrote {path:?}");
            continue;
        }
        match std::fs::read_to_string(&path) {
            Ok(want) => assert_eq!(
                fp, want,
                "{stem}: report diverged from its golden — an engine change broke \
                 seed-identity (regenerate with TIMELYFL_WRITE_GOLDENS=1 only if intentional)"
            ),
            Err(_) if require => panic!(
                "golden {path:?} missing with TIMELYFL_REQUIRE_GOLDENS set — record with \
                 TIMELYFL_WRITE_GOLDENS=1 and commit it (see tests/goldens/README.md)"
            ),
            Err(_) => eprintln!(
                "golden {path:?} not recorded yet; run with TIMELYFL_WRITE_GOLDENS=1 to create it"
            ),
        }
    }
}
