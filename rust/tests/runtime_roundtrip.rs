//! Integration: the AOT artifacts load, compile, and train for real.
//!
//! Requires `make artifacts` to have run (skipped with a message otherwise,
//! so `cargo test` stays green on a fresh checkout).

use timelyfl::model::ParamVec;
use timelyfl::runtime::{Batch, Manifest, ModelRuntime, Task};
use timelyfl::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

/// Gaussian-cluster toy batch: class c has mean direction derived from c.
fn toy_vision_batch(rng: &mut Rng, x_len: usize, batch: usize, classes: usize) -> Batch {
    let mut x = Vec::with_capacity(batch * x_len);
    let mut y = Vec::with_capacity(batch);
    for _ in 0..batch {
        let c = rng.usize_below(classes);
        y.push(c as i32);
        let mut feat = Rng::seed_from(c as u64 * 7919 + 13);
        for _ in 0..x_len {
            let center = feat.normal() as f32; // class-specific, fixed
            x.push(center + 0.3 * rng.normal() as f32);
        }
    }
    Batch::F32 { x, y }
}

#[test]
fn init_is_deterministic_and_finite() {
    let dir = require_artifacts!();
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "vision").unwrap();
    let a = rt.init_params(7).unwrap();
    let b = rt.init_params(7).unwrap();
    let c = rt.init_params(8).unwrap();
    assert_eq!(a, b, "same seed must give identical params");
    assert_ne!(a, c, "different seeds must differ");
    assert!(a.all_finite());
    assert_eq!(a.num_params(), rt.meta.total_params);
}

#[test]
fn vision_training_reduces_loss() {
    let dir = require_artifacts!();
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "vision").unwrap();

    let full = rt.meta.ratio_exact(1.0).unwrap().clone();
    let mut params = rt.init_params(0).unwrap();
    let mut rng = Rng::seed_from(42);

    let mut first = None;
    let mut last = 0.0;
    for step in 0..60 {
        let batch = toy_vision_batch(&mut rng, rt.meta.x_len(), rt.meta.batch, 10);
        let (new_params, loss) = rt.train_step(&full, &params, &batch, 0.05).unwrap();
        assert!(loss.is_finite(), "loss diverged at step {step}");
        params = new_params;
        if first.is_none() {
            first = Some(loss);
        }
        last = loss as f64;
    }
    let first = first.unwrap() as f64;
    assert!(
        last < 0.6 * first,
        "loss did not drop: first {first}, last {last}"
    );
}

#[test]
fn partial_ratio_freezes_prefix() {
    let dir = require_artifacts!();
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "vision").unwrap();

    let partial = rt.meta.quantize_ratio(0.25).clone();
    assert!(partial.boundary > 0, "0.25 ratio should freeze a prefix");

    let params = rt.init_params(1).unwrap();
    let mut rng = Rng::seed_from(9);
    let batch = toy_vision_batch(&mut rng, rt.meta.x_len(), rt.meta.batch, 10);
    let (new_params, _) = rt.train_step(&partial, &params, &batch, 0.1).unwrap();

    // Frozen prefix must be bit-identical; trainable suffix must move.
    for i in 0..partial.boundary {
        assert_eq!(
            params.tensors[i], new_params.tensors[i],
            "frozen tensor {i} changed"
        );
    }
    let moved = (partial.boundary..params.tensors.len())
        .any(|i| params.tensors[i] != new_params.tensors[i]);
    assert!(moved, "no trainable tensor changed");

    // And the partial update is the suffix only.
    let upd = new_params.delta_from(&params, partial.boundary);
    assert!(upd.bytes() < rt.meta.full_model_bytes());
}

#[test]
fn eval_returns_sane_metrics() {
    let dir = require_artifacts!();
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "vision").unwrap();
    let params = rt.init_params(3).unwrap();

    let mut rng = Rng::seed_from(5);
    let batches: Vec<Batch> = (0..4)
        .map(|_| toy_vision_batch(&mut rng, rt.meta.x_len(), rt.meta.eval_batch, 10))
        .collect();
    let res = rt.evaluate(&params, &batches).unwrap();
    assert_eq!(res.examples, 4 * rt.meta.eval_batch);
    // Untrained 10-class model: loss near ln(10), accuracy near chance.
    assert!(res.mean_loss > 1.5 && res.mean_loss < 4.0, "{res:?}");
    assert!(res.metric < 0.5, "{res:?}");
}

#[test]
fn lm_round_trip_and_ppl() {
    let dir = require_artifacts!();
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "text").unwrap();
    assert_eq!(rt.meta.task, Task::Lm);

    let mut params = rt.init_params(0).unwrap();
    let mut rng = Rng::seed_from(1);
    let full = rt.meta.ratio_exact(1.0).unwrap().clone();
    let vocab = rt.meta.num_classes;

    // Highly predictable stream: token t+1 = (token t + 1) % 16.
    let make_batch = |rng: &mut Rng, n: usize| {
        let seq = rt.meta.seq_len;
        let mut x = Vec::with_capacity(n * seq);
        let mut y = Vec::with_capacity(n * seq);
        for _ in 0..n {
            let start = rng.usize_below(16) as i32;
            for t in 0..seq as i32 {
                x.push((start + t) % 16);
                y.push((start + t + 1) % 16);
            }
        }
        let _ = vocab;
        Batch::I32 { x, y }
    };

    let mut losses = Vec::new();
    for _ in 0..30 {
        let b = make_batch(&mut rng, rt.meta.batch);
        let (p, loss) = rt.train_step(&full, &params, &b, 0.05).unwrap();
        params = p;
        losses.push(loss as f64);
    }
    assert!(
        losses[29] < 0.5 * losses[0],
        "LM loss did not drop: {:?}",
        &losses[..3]
    );

    let eb = make_batch(&mut rng, rt.meta.eval_batch);
    let res = rt.evaluate(&params, &[eb]).unwrap();
    assert!(res.metric > 1.0, "ppl must exceed 1, got {}", res.metric);
    assert!(res.metric < 100.0, "ppl should have dropped, got {}", res.metric);
}

#[test]
fn rejects_mismatched_params() {
    let dir = require_artifacts!();
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "vision").unwrap();
    let bad = ParamVec {
        tensors: vec![vec![0.0; 3]],
    };
    assert!(bad.check(&rt.meta).is_err());
}
