//! Seeded config fuzz-lite: random-but-valid override sets over the
//! network × availability × sampler × scheduling axes, pushed through the
//! real `config::parse` path.
//!
//! Not a coverage-guided fuzzer — a fixed-seed sweep of ~64 generated
//! configs that must all parse, validate, canonicalize (aliases collapse
//! to registry names), and re-apply deterministically. A smaller
//! artifact-gated group actually RUNS a handful of fuzzed configs on tiny
//! fleets and checks the global invariants no knob combination may break
//! (free networks price nothing; counters stay finite; repeat runs are
//! byte-identical). The artifact-free groups are wired into
//! `scripts/check.sh`.

use timelyfl::config::{parse as cfgparse, RunConfig};
use timelyfl::coordinator::Simulation;
use timelyfl::metrics::RunReport;
use timelyfl::util::rng::Rng;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn artifacts_present() -> bool {
    std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn pick<'a>(rng: &mut Rng, xs: &[&'a str]) -> &'a str {
    xs[rng.usize_below(xs.len())]
}

/// One random-but-valid override set over the axes this fuzz targets.
/// Every value is drawn from the spellings the parser documents (aliases,
/// mixed case, bool synonyms) or from numeric ranges `validate()` accepts.
fn random_overrides(rng: &mut Rng) -> Vec<(String, String)> {
    let mut o: Vec<(String, String)> = Vec::new();
    let mut push = |k: &str, v: String| o.push((k.to_string(), v));
    push(
        "network",
        pick(rng, &["free", "priced", "instant", "downlink", "asym", "FREE", "Priced"]).into(),
    );
    push("net_down_ratio", format!("{:.3}", rng.f64() * 4.0));
    push(
        "net_stale_correction",
        pick(rng, &["none", "delta-replay", "delta_replay", "replay", "NONE"]).into(),
    );
    push("net_rebalance", pick(rng, &["true", "false", "1", "0", "yes", "no"]).into());
    push(
        "availability",
        pick(rng, &["always-on", "always_on", "markov", "correlated", "regional"]).into(),
    );
    push("avail_regions", format!("{}", 1 + rng.usize_below(8)));
    push("avail_region_mtbf_secs", format!("{:.1}", 100.0 + rng.f64() * 2000.0));
    push("avail_region_outage_secs", format!("{:.1}", 50.0 + rng.f64() * 500.0));
    push("avail_mean_online_secs", format!("{:.1}", 200.0 + rng.f64() * 2000.0));
    push("avail_mean_offline_secs", format!("{:.1}", 50.0 + rng.f64() * 800.0));
    push("avail_degrade_window_secs", format!("{:.1}", rng.f64() * 300.0));
    push("avail_degrade_floor", format!("{:.2}", 0.05 + rng.f64() * 0.9));
    push(
        "sampler",
        pick(
            rng,
            &["uniform", "stay-prob", "drop-aware", "survival", "DROP_AWARE", "fair-cap", "fair_cap", "FAIRCAP"],
        )
        .into(),
    );
    push("sampler_horizon_secs", format!("{:.1}", 50.0 + rng.f64() * 500.0));
    // Scheduling axes: the weigher registry, its knobs, and the calibrated
    // horizon (`auto` flips EWMA mode; a number pins the fixed horizon).
    push(
        "weigher",
        pick(rng, &["uniform", "staleness", "sched-joint", "flat", "poly", "CSMA", "JOINT"]).into(),
    );
    push("weigher_staleness_exp", format!("{:.2}", 0.25 + rng.f64() * 2.5));
    push("fair_cap", format!("{}", 1 + rng.usize_below(8)));
    push("fair_explore", format!("{:.2}", rng.f64() * 2.0));
    push(
        "sampler_horizon",
        if rng.usize_below(2) == 0 {
            "auto".into()
        } else {
            format!("{:.1}", 50.0 + rng.f64() * 500.0)
        },
    );
    push(
        "strategy",
        pick(rng, &["TimelyFL", "timelyfl", "fedbuff", "sync", "seafl"]).into(),
    );
    // Hot-path execution axes: batching and aggregation workers compose
    // with every other knob (both are proven semantics-invisible, so any
    // combination must parse, validate, and run).
    push("batch_exec", pick(rng, &["true", "false", "1", "0", "yes", "no"]).into());
    push("agg_jobs", format!("{}", 1 + rng.usize_below(8)));
    push("seed", format!("{}", rng.usize_below(1_000_000)));
    o
}

fn apply_all(cfg: &mut RunConfig, overrides: &[(String, String)]) {
    for (k, v) in overrides {
        cfgparse::apply_cli(cfg, &format!("{k}={v}"))
            .unwrap_or_else(|e| panic!("override {k}={v} rejected: {e:#}"));
    }
}

#[test]
fn sixty_four_fuzzed_configs_parse_validate_and_canonicalize() {
    for seed in 0..64u64 {
        let mut rng = Rng::seed_from(0xC0F6 ^ (seed * 7919));
        let overrides = random_overrides(&mut rng);
        let mut cfg = RunConfig::default();
        apply_all(&mut cfg, &overrides);
        cfg.validate()
            .unwrap_or_else(|e| panic!("seed {seed}: fuzzed config invalid: {e:#}\n{overrides:?}"));
        // Aliases and case collapse to canonical registry names.
        assert!(
            ["free", "priced"].contains(&cfg.network.model.as_str()),
            "seed {seed}: network not canonical: {}",
            cfg.network.model
        );
        assert!(
            ["uniform", "stay-prob", "drop-aware", "fair-cap"].contains(&cfg.sampler.as_str()),
            "seed {seed}: sampler not canonical: {}",
            cfg.sampler
        );
        assert!(
            ["uniform", "staleness", "sched-joint"].contains(&cfg.scheduling.weigher.as_str()),
            "seed {seed}: weigher not canonical: {}",
            cfg.scheduling.weigher
        );
        assert!(
            ["TimelyFL", "FedBuff", "SyncFL", "SemiAsync"].contains(&cfg.strategy.as_str()),
            "seed {seed}: strategy not canonical: {}",
            cfg.strategy
        );
        assert!(cfg.network.down_ratio.is_finite() && cfg.network.down_ratio >= 0.0);
        // Re-applying the same overrides to a fresh default is a pure
        // function of the override list.
        let mut again = RunConfig::default();
        apply_all(&mut again, &overrides);
        assert_eq!(
            format!("{cfg:?}"),
            format!("{again:?}"),
            "seed {seed}: override application not deterministic"
        );
    }
}

#[test]
fn fuzz_rejects_the_bad_values_it_must() {
    let mut cfg = RunConfig::default();
    assert!(cfgparse::apply_cli(&mut cfg, "network=bogus").is_err());
    assert!(cfgparse::apply_cli(&mut cfg, "net_stale_correction=rewind").is_err());
    assert!(cfgparse::apply_cli(&mut cfg, "net_rebalance=maybe").is_err());
    assert!(cfgparse::apply_cli(&mut cfg, "batch_exec=maybe").is_err());
    // usize parse rejects signs and garbage outright.
    assert!(cfgparse::apply_cli(&mut cfg, "agg_jobs=-1").is_err());
    assert!(cfgparse::apply_cli(&mut cfg, "agg_jobs=x").is_err());
    // Values the PARSER accepts but validate() must catch: a negative or
    // non-finite downlink ratio prices time travel.
    for bad in ["-1.0", "nan", "inf"] {
        let mut cfg = RunConfig::default();
        cfgparse::apply_cli(&mut cfg, &format!("net_down_ratio={bad}")).unwrap();
        assert!(cfg.validate().is_err(), "net_down_ratio={bad} validated");
    }
    // agg_jobs=0 parses (it is a count) but zero workers is nonsense.
    let mut cfg = RunConfig::default();
    cfgparse::apply_cli(&mut cfg, "agg_jobs=0").unwrap();
    assert!(cfg.validate().is_err(), "agg_jobs=0 validated");
    // Scheduling axes: an unknown weigher and a non-numeric, non-`auto`
    // horizon are parse errors; a negative staleness exponent and a zero
    // fair-share cap parse but must die in validate().
    let mut cfg = RunConfig::default();
    assert!(cfgparse::apply_cli(&mut cfg, "weigher=bogus").is_err());
    assert!(cfgparse::apply_cli(&mut cfg, "sampler_horizon=soonish").is_err());
    let mut cfg = RunConfig::default();
    cfgparse::apply_cli(&mut cfg, "weigher_staleness_exp=-1").unwrap();
    assert!(cfg.validate().is_err(), "weigher_staleness_exp=-1 validated");
    let mut cfg = RunConfig::default();
    cfgparse::apply_cli(&mut cfg, "fair_cap=0").unwrap();
    assert!(cfg.validate().is_err(), "fair_cap=0 validated");
}

// ---------------------------------------------------------------------------
// Artifact-gated: a handful of fuzzed configs actually run.
// ---------------------------------------------------------------------------

fn semantic_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.to_json().to_string()
}

#[test]
fn fuzzed_tiny_fleets_run_and_hold_global_invariants() {
    require_artifacts!();
    for seed in 0..8u64 {
        let mut rng = Rng::seed_from(0xF1E1D ^ (seed * 104_729));
        let overrides = random_overrides(&mut rng);
        let mut cfg = RunConfig::default();
        apply_all(&mut cfg, &overrides);
        // Shrink to a tiny fleet the PJRT path can afford; the fuzzed
        // network/availability/sampler/strategy axes stay as drawn.
        cfg.model = "kws_lite".into();
        cfg.population = 12;
        cfg.concurrency = 6;
        cfg.rounds = 4;
        cfg.eval_every = 2;
        cfg.eval_batches = 1;
        cfg.steps_per_epoch = 1;
        cfg.max_local_epochs = 2;
        cfg.sim_model_bytes = 3.2e5;
        // A drawn batch_exec=true needs the batched graphs; an artifact set
        // recorded before them still serves every other axis combination.
        if !std::fs::read_to_string(std::path::Path::new(ARTIFACTS).join("manifest.json"))
            .is_ok_and(|m| m.contains("batched_artifact"))
        {
            cfg.batch_exec = false;
        }
        cfg.validate().unwrap();
        let sim = Simulation::new(cfg.clone(), ARTIFACTS)
            .expect("build simulation (run `make artifacts` first)");
        let report = sim.run().unwrap_or_else(|e| {
            panic!("seed {seed}: fuzzed run failed: {e:#}\n{overrides:?}")
        });
        assert!(report.downlink_wait_secs.is_finite() && report.downlink_wait_secs >= 0.0);
        if cfg.network.model == "free" {
            assert_eq!(report.downlink_wait_secs, 0.0, "seed {seed}: free run paid downlink");
            assert_eq!(report.stale_starts, 0, "seed {seed}: free run stale-started");
        }
        assert!(report.total_rounds <= cfg.rounds, "seed {seed}");
        assert_eq!(report.participation.len(), cfg.population, "seed {seed}");
        for p in &report.eval_points {
            assert!(p.mean_loss.is_finite() && p.metric.is_finite(), "seed {seed}");
        }
        // Same config, same bytes.
        let again = Simulation::new(cfg, ARTIFACTS).unwrap().run().unwrap();
        assert_eq!(
            semantic_json(&report),
            semantic_json(&again),
            "seed {seed}: fuzzed run not reproducible"
        );
    }
}
