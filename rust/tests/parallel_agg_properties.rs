//! Artifact-free properties of the chunk-parallel aggregation fold
//! (`agg_jobs=` config key): `aggregation::average_delta_jobs` and the
//! `ServerOpt` worker fan-out must be **bit-identical** to the serial
//! paths for every thread count — f32 addition is non-associative, so this
//! only holds because the parallel fold partitions the OUTPUT tensor index
//! space and reduces each tensor in the exact serial contribution order.
//! This suite is the proof; `scripts/check.sh` runs it on artifact-less
//! checkouts (no PJRT anywhere below).
//!
//! Inputs are adversarial on purpose: partial-update boundaries, zero and
//! fractional weights, staleness discounts, negative zeros and denormals —
//! the values where "close enough" floating-point refactors drift first.

use timelyfl::aggregation::{
    average_delta, average_delta_chunked, average_delta_jobs, Contribution, ServerOpt,
    ServerOptKind,
};
use timelyfl::model::{ParamVec, Update};
use timelyfl::util::rng::Rng;

/// Tensor shapes shared by every random case: mixed sizes, including a
/// zero-length tensor (legal — a bias-free layer) to hit the degenerate
/// inner loop.
const SHAPE: [usize; 6] = [7, 1, 0, 33, 4, 12];

fn template() -> ParamVec {
    ParamVec {
        tensors: SHAPE.iter().map(|&n| vec![0.0f32; n]).collect(),
    }
}

/// A hostile f32: mostly ordinary values, with -0.0, denormals, and large
/// magnitudes mixed in (all cases where bitwise equality is strictly
/// stronger than numeric equality).
fn hostile_f32(rng: &mut Rng) -> f32 {
    match rng.below(10) {
        0 => -0.0,
        1 => f32::from_bits(rng.below(1 << 23) as u32), // positive denormal
        2 => -f32::from_bits(1),                        // smallest-magnitude negative
        3 => rng.range(-1e6, 1e6) as f32,
        _ => rng.range(-2.0, 2.0) as f32,
    }
}

/// Random contribution set: random suffix boundaries (partial updates),
/// weights including exact zeros (the skip rule), random staleness.
fn random_contributions(rng: &mut Rng, n: usize) -> Vec<Contribution> {
    (0..n)
        .map(|i| {
            let boundary = rng.usize_below(SHAPE.len());
            let tensors = SHAPE[boundary..]
                .iter()
                .map(|&len| (0..len).map(|_| hostile_f32(rng)).collect())
                .collect();
            let weight = match rng.below(8) {
                0 => 0.0, // must be skipped identically on every path
                1 => rng.range(2.0, 5.0),
                _ => rng.range(0.1, 1.5),
            };
            Contribution {
                client_id: i,
                update: Update { boundary, tensors },
                weight,
                staleness: rng.below(9),
            }
        })
        .collect()
}

fn assert_bit_identical(label: &str, a: &Update, b: &Update) {
    assert_eq!(a.boundary, b.boundary, "{label}: boundary");
    assert_eq!(a.tensors.len(), b.tensors.len(), "{label}: tensor count");
    for (j, (x, y)) in a.tensors.iter().zip(&b.tensors).enumerate() {
        assert_eq!(x.len(), y.len(), "{label}: tensor {j} len");
        for (i, (p, q)) in x.iter().zip(y).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "{label}: tensor {j}[{i}]: {p:?} vs {q:?}"
            );
        }
    }
}

#[test]
fn parallel_fold_matches_serial_bitwise_on_random_inputs() {
    let mut rng = Rng::seed_from(0xA66);
    let template = template();
    for case in 0..40 {
        let n = 1 + rng.usize_below(24);
        let cs = random_contributions(&mut rng, n);
        for discount in [false, true] {
            let serial = average_delta(&template, &cs, discount);
            for jobs in [1usize, 2, 7] {
                let par = average_delta_jobs(&template, &cs, discount, jobs);
                assert_bit_identical(
                    &format!("case {case} n={n} discount={discount} jobs={jobs}"),
                    &par,
                    &serial,
                );
            }
        }
    }
}

#[test]
fn chunk_size_is_irrelevant_to_the_result() {
    // Each output tensor is reduced independently in serial order, so the
    // unit size can only change scheduling — never bits. 0 is clamped to 1.
    let mut rng = Rng::seed_from(0xC44);
    let template = template();
    let cs = random_contributions(&mut rng, 17);
    for discount in [false, true] {
        let serial = average_delta(&template, &cs, discount);
        for chunk in [0usize, 1, 2, 3, 5, 64, 1024] {
            let par = average_delta_chunked(&template, &cs, discount, 3, chunk);
            assert_bit_identical(&format!("chunk={chunk} discount={discount}"), &par, &serial);
        }
    }
}

#[test]
fn degenerate_sets_are_exact() {
    let template = template();
    // Empty: zero delta, on every path.
    for jobs in [1usize, 2, 7] {
        let avg = average_delta_jobs(&template, &[], true, jobs);
        assert_bit_identical(
            &format!("empty jobs={jobs}"),
            &avg,
            &average_delta(&template, &[], true),
        );
        for t in &avg.tensors {
            assert!(t.iter().all(|v| v.to_bits() == 0), "empty set must give +0.0");
        }
    }
    // Single contribution with weight 1 and staleness 0: the mean IS the
    // update over its covered suffix, bit-for-bit.
    let mut rng = Rng::seed_from(0x51);
    let one = random_contributions(&mut rng, 1);
    let serial = average_delta(&template, &one, false);
    for jobs in [2usize, 7] {
        assert_bit_identical(
            &format!("single jobs={jobs}"),
            &average_delta_jobs(&template, &one, false, jobs),
            &serial,
        );
    }
    // All-skipped (every weight exactly 0): identical to empty.
    let dead: Vec<Contribution> = random_contributions(&mut rng, 5)
        .into_iter()
        .map(|mut c| {
            c.weight = 0.0;
            c
        })
        .collect();
    for jobs in [1usize, 2, 7] {
        assert_bit_identical(
            &format!("all-skipped jobs={jobs}"),
            &average_delta_jobs(&template, &dead, true, jobs),
            &average_delta(&template, &[], true),
        );
    }
}

#[test]
fn server_opt_fanout_matches_serial_bitwise_over_random_trajectories() {
    // Stateful half of the parallel hot path: every optimizer kind, several
    // steps deep (moments accumulate, so one drifted bit would compound and
    // show), workers 2 and 7 against the serial loops.
    let mut rng = Rng::seed_from(0x0F7);
    for kind in [
        ServerOptKind::FedAvg,
        ServerOptKind::SgdM,
        ServerOptKind::Adam,
        ServerOptKind::Yogi,
    ] {
        for jobs in [2usize, 7] {
            let mut serial = ServerOpt::new(kind, 0.05);
            let mut fanned = ServerOpt::new(kind, 0.05).with_jobs(jobs);
            let mut gs = template();
            let mut gf = template();
            for step in 0..6 {
                let delta = Update {
                    boundary: 0,
                    tensors: SHAPE
                        .iter()
                        .map(|&len| (0..len).map(|_| hostile_f32(&mut rng)).collect())
                        .collect(),
                };
                serial.apply(&mut gs, &delta);
                fanned.apply(&mut gf, &delta);
                for (j, (a, b)) in gs.tensors.iter().zip(&gf.tensors).enumerate() {
                    for (i, (x, y)) in a.iter().zip(b).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{kind:?} jobs={jobs} step {step}: tensor {j}[{i}]"
                        );
                    }
                }
            }
            assert_eq!(serial.steps_taken(), fanned.steps_taken());
        }
    }
}
