//! The fleet subsystem's headline suite: the lazy, indexed sim core
//! (`fleet_core = lazy`) must produce **byte-identical** semantic
//! `RunReport` JSON to the historical eager core — for every registered
//! strategy, every sampling policy, and both stochastic availability
//! processes (Markov and correlated-regional). The lazy core replays the
//! exact RNG draw sequence of the eager paths (indexed sampling consumes
//! the same `usize_below` draws; the round drivers' agenda sweep never
//! touches the main event queue), so any divergence is a determinism bug
//! in the fleet seam, not an accuracy trade-off.
//!
//! A second group anchors the aggregation tier end-to-end: `two-tier` with
//! one region and unbounded fan-in routes every contribution through a
//! single edge whose partial the root *moves* (never re-accumulates), so
//! the run is bit-exact to flat; and a genuinely regional tier (2 regions)
//! stays seed-deterministic while producing finite learning curves.
//!
//! Needs the AOT artifacts (real PJRT training), like
//! `strategies_integration.rs`.

use timelyfl::availability::AvailabilityKind;
use timelyfl::config::RunConfig;
use timelyfl::coordinator::{registry, Simulation};
use timelyfl::fleet::{FleetCore, ForwardPolicy, Topology};
use timelyfl::metrics::RunReport;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn tiny_cfg(strategy: &str, sampler_name: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.sampler = sampler_name.to_string();
    cfg.population = 12;
    cfg.concurrency = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 3.2e5;
    cfg
}

fn churn_cfg(strategy: &str, sampler_name: &str, kind: AvailabilityKind) -> RunConfig {
    let mut cfg = tiny_cfg(strategy, sampler_name);
    cfg.availability.kind = kind;
    cfg.availability.regions = 3;
    cfg.availability.region_mtbf_secs = 500.0;
    cfg.availability.region_outage_secs = 250.0;
    cfg.availability.mean_online_secs = 600.0;
    cfg.availability.mean_offline_secs = 200.0;
    cfg.availability.degrade_window_secs = 120.0;
    cfg.sampler_horizon_secs = 200.0;
    cfg
}

fn run(cfg: RunConfig) -> RunReport {
    Simulation::new(cfg, ARTIFACTS)
        .expect("build simulation (run `make artifacts` first)")
        .run()
        .expect("run simulation")
}

/// Report JSON with the only legitimately nondeterministic field zeroed.
/// Everything else — round schedule, participants, drops, learning curve,
/// simulated clock, event counts, wasted-work ledger — participates in the
/// byte-for-byte comparison.
fn semantic_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.to_json().to_string()
}

#[test]
fn lazy_core_is_byte_identical_to_eager_for_every_strategy_and_sampler() {
    // The acceptance criterion: 4 strategies × 3 samplers × always-on +
    // two stochastic availability processes, each compared byte-for-byte.
    for info in registry::STRATEGIES {
        for policy in ["uniform", "stay-prob", "drop-aware"] {
            for kind in [
                AvailabilityKind::AlwaysOn,
                AvailabilityKind::Markov,
                AvailabilityKind::Correlated,
            ] {
                let mut eager = churn_cfg(info.name, policy, kind);
                eager.fleet_core = FleetCore::Eager;
                let mut lazy = eager.clone();
                lazy.fleet_core = FleetCore::Lazy;
                assert_eq!(
                    semantic_json(&run(lazy)),
                    semantic_json(&run(eager)),
                    "{} + {policy} + {kind:?}: lazy core diverged from eager",
                    info.name
                );
            }
        }
    }
}

#[test]
fn single_region_two_tier_is_bit_exact_to_flat_for_every_strategy() {
    // 1 region + unbounded fan-in: one edge partial, moved (not re-added)
    // into the root accumulator — f32-for-f32 the flat reduction. Run under
    // churn so staleness discounting is exercised on the event strategies.
    for info in registry::STRATEGIES {
        let mut flat = churn_cfg(info.name, "uniform", AvailabilityKind::Markov);
        flat.hierarchy.topology = Topology::Flat;
        let mut tiered = flat.clone();
        tiered.hierarchy.topology = Topology::TwoTier;
        tiered.hierarchy.regions = 1;
        tiered.hierarchy.fan_in = 0;
        tiered.hierarchy.forward = ForwardPolicy::Weighted;
        assert_eq!(
            semantic_json(&run(tiered)),
            semantic_json(&run(flat)),
            "{}: single-region two-tier is not bit-exact to flat",
            info.name
        );
    }
}

#[test]
fn regional_two_tier_runs_are_seed_deterministic_and_finite() {
    // A real tier (2 regions) reorders float accumulation, so it is NOT
    // bit-compared against flat; what it must be is reproducible and sane.
    for info in registry::STRATEGIES {
        let mut cfg = churn_cfg(info.name, "uniform", AvailabilityKind::Correlated);
        cfg.fleet_core = FleetCore::Lazy;
        cfg.hierarchy.topology = Topology::TwoTier;
        cfg.hierarchy.regions = 2;
        cfg.hierarchy.fan_in = 3;
        let a = run(cfg.clone());
        let b = run(cfg.clone());
        assert_eq!(
            semantic_json(&a),
            semantic_json(&b),
            "{}: hierarchical run not reproducible",
            info.name
        );
        assert!(a.total_rounds > 0, "{}: no rounds completed", info.name);
        assert_eq!(a.participation.len(), cfg.population);
        for p in &a.eval_points {
            assert!(p.mean_loss.is_finite() && p.metric.is_finite(), "{}", info.name);
        }
        // The dispersion metric rides along on every report.
        let g = a.participation_gini();
        assert!((0.0..=1.0).contains(&g), "{}: gini {g} out of range", info.name);
    }
}

#[test]
fn uniform_forward_policy_changes_the_model_but_not_the_schedule() {
    // `hier_forward = uniform` weights each edge equally regardless of how
    // many clients it buffered — deliberately different aggregation
    // semantics. The event schedule (clock, participants, drops) must stay
    // identical; only the learning curve may move.
    let mut weighted = churn_cfg("TimelyFL", "uniform", AvailabilityKind::Markov);
    weighted.hierarchy.topology = Topology::TwoTier;
    weighted.hierarchy.regions = 2;
    weighted.hierarchy.forward = ForwardPolicy::Weighted;
    let mut uniform = weighted.clone();
    uniform.hierarchy.forward = ForwardPolicy::Uniform;
    let w = run(weighted);
    let u = run(uniform);
    assert_eq!(w.total_rounds, u.total_rounds);
    assert_eq!(w.events_processed, u.events_processed);
    assert_eq!(w.participation, u.participation);
    assert_eq!(w.sim_secs, u.sim_secs);
}
