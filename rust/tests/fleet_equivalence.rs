//! The fleet subsystem's headline suite: the lazy, indexed sim core
//! (`fleet_core = lazy`) must produce **byte-identical** semantic
//! `RunReport` JSON to the historical eager core — for every registered
//! strategy, every sampling policy, and both stochastic availability
//! processes (Markov and correlated-regional). The lazy core replays the
//! exact RNG draw sequence of the eager paths (indexed sampling consumes
//! the same `usize_below` draws; the round drivers' agenda sweep never
//! touches the main event queue), so any divergence is a determinism bug
//! in the fleet seam, not an accuracy trade-off.
//!
//! A second group anchors the aggregation tier end-to-end: a tree with
//! one region and unbounded fan-in routes every contribution through a
//! single edge whose partial the root *moves* (never re-accumulates), so
//! the run is bit-exact to flat; and a genuinely regional tier (2 regions)
//! stays seed-deterministic while producing finite learning curves.
//!
//! A third group anchors the edge-aggregator clocks: under the default
//! `hier_clock = shared` the region-clock machinery must be completely
//! inert (edge counters exactly zero, lazy ≡ eager byte-for-byte on the
//! tree, the historical `two-tier` spelling ≡ the depth-2 tree), while
//! `hier_clock = region` stays core-independent and seed-deterministic
//! with a free uplink waiting zero seconds and a priced one paying real
//! simulated time.
//!
//! Needs the AOT artifacts (real PJRT training), like
//! `strategies_integration.rs`.

use timelyfl::availability::AvailabilityKind;
use timelyfl::config::RunConfig;
use timelyfl::coordinator::{registry, Simulation};
use timelyfl::fleet::{FleetCore, ForwardPolicy, Topology};
use timelyfl::metrics::RunReport;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn tiny_cfg(strategy: &str, sampler_name: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.sampler = sampler_name.to_string();
    cfg.population = 12;
    cfg.concurrency = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 3.2e5;
    cfg
}

fn churn_cfg(strategy: &str, sampler_name: &str, kind: AvailabilityKind) -> RunConfig {
    let mut cfg = tiny_cfg(strategy, sampler_name);
    cfg.availability.kind = kind;
    cfg.availability.regions = 3;
    cfg.availability.region_mtbf_secs = 500.0;
    cfg.availability.region_outage_secs = 250.0;
    cfg.availability.mean_online_secs = 600.0;
    cfg.availability.mean_offline_secs = 200.0;
    cfg.availability.degrade_window_secs = 120.0;
    cfg.sampler_horizon_secs = 200.0;
    cfg
}

fn run(cfg: RunConfig) -> RunReport {
    Simulation::new(cfg, ARTIFACTS)
        .expect("build simulation (run `make artifacts` first)")
        .run()
        .expect("run simulation")
}

/// Report JSON with the only legitimately nondeterministic field zeroed.
/// Everything else — round schedule, participants, drops, learning curve,
/// simulated clock, event counts, wasted-work ledger — participates in the
/// byte-for-byte comparison.
fn semantic_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.to_json().to_string()
}

#[test]
fn lazy_core_is_byte_identical_to_eager_for_every_strategy_and_sampler() {
    // The acceptance criterion: 4 strategies × 3 samplers × always-on +
    // two stochastic availability processes, each compared byte-for-byte.
    for info in registry::STRATEGIES {
        for policy in ["uniform", "stay-prob", "drop-aware"] {
            for kind in [
                AvailabilityKind::AlwaysOn,
                AvailabilityKind::Markov,
                AvailabilityKind::Correlated,
            ] {
                let mut eager = churn_cfg(info.name, policy, kind);
                eager.fleet_core = FleetCore::Eager;
                let mut lazy = eager.clone();
                lazy.fleet_core = FleetCore::Lazy;
                assert_eq!(
                    semantic_json(&run(lazy)),
                    semantic_json(&run(eager)),
                    "{} + {policy} + {kind:?}: lazy core diverged from eager",
                    info.name
                );
            }
        }
    }
}

#[test]
fn single_region_two_tier_is_bit_exact_to_flat_for_every_strategy() {
    // 1 region + unbounded fan-in: one edge partial, moved (not re-added)
    // into the root accumulator — f32-for-f32 the flat reduction. Run under
    // churn so staleness discounting is exercised on the event strategies.
    for info in registry::STRATEGIES {
        let mut flat = churn_cfg(info.name, "uniform", AvailabilityKind::Markov);
        flat.hierarchy.topology = Topology::Flat;
        let mut tiered = flat.clone();
        tiered.hierarchy.topology = Topology::Tree;
        tiered.hierarchy.regions = 1;
        tiered.hierarchy.fan_in = 0;
        tiered.hierarchy.forward = ForwardPolicy::Weighted;
        assert_eq!(
            semantic_json(&run(tiered)),
            semantic_json(&run(flat)),
            "{}: single-region two-tier is not bit-exact to flat",
            info.name
        );
    }
}

#[test]
fn regional_two_tier_runs_are_seed_deterministic_and_finite() {
    // A real tier (2 regions) reorders float accumulation, so it is NOT
    // bit-compared against flat; what it must be is reproducible and sane.
    for info in registry::STRATEGIES {
        let mut cfg = churn_cfg(info.name, "uniform", AvailabilityKind::Correlated);
        cfg.fleet_core = FleetCore::Lazy;
        cfg.hierarchy.topology = Topology::Tree;
        cfg.hierarchy.regions = 2;
        cfg.hierarchy.fan_in = 3;
        let a = run(cfg.clone());
        let b = run(cfg.clone());
        assert_eq!(
            semantic_json(&a),
            semantic_json(&b),
            "{}: hierarchical run not reproducible",
            info.name
        );
        assert!(a.total_rounds > 0, "{}: no rounds completed", info.name);
        assert_eq!(a.participation.len(), cfg.population);
        for p in &a.eval_points {
            assert!(p.mean_loss.is_finite() && p.metric.is_finite(), "{}", info.name);
        }
        // The dispersion metric rides along on every report.
        let g = a.participation_gini();
        assert!((0.0..=1.0).contains(&g), "{}: gini {g} out of range", info.name);
    }
}

#[test]
fn uniform_forward_policy_changes_the_model_but_not_the_schedule() {
    // `hier_forward = uniform` weights each edge equally regardless of how
    // many clients it buffered — deliberately different aggregation
    // semantics. The event schedule (clock, participants, drops) must stay
    // identical; only the learning curve may move.
    let mut weighted = churn_cfg("TimelyFL", "uniform", AvailabilityKind::Markov);
    weighted.hierarchy.topology = Topology::Tree;
    weighted.hierarchy.regions = 2;
    weighted.hierarchy.forward = ForwardPolicy::Weighted;
    let mut uniform = weighted.clone();
    uniform.hierarchy.forward = ForwardPolicy::Uniform;
    let w = run(weighted);
    let u = run(uniform);
    assert_eq!(w.total_rounds, u.total_rounds);
    assert_eq!(w.events_processed, u.events_processed);
    assert_eq!(w.participation, u.participation);
    assert_eq!(w.sim_secs, u.sim_secs);
}

/// A regional tree config under churn, `hier_clock = shared` (the
/// default): the region-clock machinery must be dead code on this path.
fn tree_cfg(strategy: &str, depth: usize) -> RunConfig {
    let mut cfg = churn_cfg(strategy, "uniform", AvailabilityKind::Markov);
    cfg.hierarchy.topology = Topology::Tree;
    cfg.hierarchy.regions = 2;
    cfg.hierarchy.fan_in = 3;
    cfg.hierarchy.depth = depth;
    cfg
}

#[test]
fn shared_clock_tree_is_byte_identical_across_cores_for_every_strategy() {
    // The lockstep anchor at both depths: lazy ≡ eager byte-for-byte on
    // the tree, and the edge-clock counters are exactly zero — the
    // RegionClock layer must be completely inert under the default
    // `hier_clock = shared`.
    for info in registry::STRATEGIES {
        for depth in [2, 3] {
            let mut eager = tree_cfg(info.name, depth);
            eager.fleet_core = FleetCore::Eager;
            let mut lazy = eager.clone();
            lazy.fleet_core = FleetCore::Lazy;
            let e = run(eager);
            let l = run(lazy);
            assert_eq!(
                semantic_json(&l),
                semantic_json(&e),
                "{} depth {depth}: lazy diverged from eager on the shared-clock tree",
                info.name
            );
            assert_eq!(e.edge_flushes, 0, "{}: shared clock flushed", info.name);
            assert_eq!(e.edge_uplink_wait_secs, 0.0, "{}", info.name);
            assert_eq!(e.edge_root_merges, 0, "{}", info.name);
        }
    }
}

#[test]
fn depth_two_tree_is_byte_identical_to_the_historical_two_tier_spelling() {
    // `hierarchy = two-tier` parses as the depth-2 tree; the configs must
    // be identical and so must the runs (zero `collapse_level` passes).
    use timelyfl::config::parse::apply_override;
    for info in registry::STRATEGIES {
        let mut spelled = churn_cfg(info.name, "uniform", AvailabilityKind::Markov);
        apply_override(&mut spelled, "hierarchy", "two-tier").unwrap();
        spelled.hierarchy.regions = 2;
        spelled.hierarchy.fan_in = 3;
        let mut tree = churn_cfg(info.name, "uniform", AvailabilityKind::Markov);
        apply_override(&mut tree, "hierarchy", "tree").unwrap();
        apply_override(&mut tree, "hier_depth", "2").unwrap();
        tree.hierarchy.regions = 2;
        tree.hierarchy.fan_in = 3;
        assert_eq!(spelled.hierarchy.topology, tree.hierarchy.topology);
        assert_eq!(spelled.hierarchy.depth, tree.hierarchy.depth);
        assert_eq!(
            semantic_json(&run(tree)),
            semantic_json(&run(spelled)),
            "{}: depth-2 tree diverged from the two-tier spelling",
            info.name
        );
    }
}

#[test]
fn region_clocks_are_core_independent_and_price_only_the_priced_uplink() {
    // `hier_clock = region` with a positive flush window: the run holds
    // partials at the edges and (a) stays byte-identical across sim cores
    // — the clock layer lives in the shared engine, not in either core —
    // (b) reports flushes, and (c) waits on the uplink ONLY when the
    // edge->root leg is priced.
    for info in registry::STRATEGIES {
        let mut cfg = tree_cfg(info.name, 2);
        cfg.hierarchy.clock = timelyfl::fleet::ClockMode::Region;
        cfg.hierarchy.flush_secs = 50.0;
        cfg.hierarchy.uplink = "free".into();
        cfg.validate().expect("region-clock config validates");

        let mut eager = cfg.clone();
        eager.fleet_core = FleetCore::Eager;
        let mut lazy = cfg.clone();
        lazy.fleet_core = FleetCore::Lazy;
        let free = run(eager);
        assert_eq!(
            semantic_json(&run(lazy)),
            semantic_json(&free),
            "{}: region clocks diverged across sim cores",
            info.name
        );
        assert!(free.edge_flushes > 0, "{}: no region ever flushed", info.name);
        // Free uplink: arrivals are instantaneous — zero priced wait.
        assert_eq!(
            free.edge_uplink_wait_secs, 0.0,
            "{}: free uplink charged wait time",
            info.name
        );
        assert!(
            free.edge_root_merges <= free.edge_flushes,
            "{}: more root merges than flushes",
            info.name
        );

        let mut priced = cfg.clone();
        priced.hierarchy.uplink = "priced".into();
        priced.hierarchy.up_ratio = 0.25;
        let p = run(priced.clone());
        assert_eq!(
            semantic_json(&p),
            semantic_json(&run(priced)),
            "{}: priced region-clock run not reproducible",
            info.name
        );
        assert!(p.edge_flushes > 0, "{}", info.name);
        assert!(
            p.edge_uplink_wait_secs > 0.0,
            "{}: priced uplink reported zero wait",
            info.name
        );
        for pt in &p.eval_points {
            assert!(pt.mean_loss.is_finite() && pt.metric.is_finite(), "{}", info.name);
        }
    }
}
