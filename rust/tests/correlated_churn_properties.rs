//! Artifact-free properties of the correlated-churn availability process
//! (`availability/correlated.rs`) — pure process logic, no PJRT, wired
//! into `scripts/check.sh` alongside the other property suites.
//!
//! Locked here:
//! - **flip-together**: during every regional outage window, every client
//!   of that region is offline — the whole point of correlated churn;
//! - **marginal calibration**: each client's long-run online fraction
//!   tracks (personal Markov steady state) × (region uptime) within
//!   tolerance, and the population mean tracks it tightly;
//! - **seeded determinism**: same seed ⇒ identical schedules, different
//!   seed ⇒ different schedules, through the public facade;
//! - **degrade-before-drop**: the bandwidth factor ramps monotonically
//!   down into an outage, never leaves `[floor, 1]`, is exactly 1.0
//!   outside the window, and is exactly 1.0 for every OTHER process kind
//!   (the strictly-additive contract).

use timelyfl::availability::{
    AvailabilityConfig, AvailabilityKind, AvailabilityModel, CorrelatedModel,
};

fn cfg() -> AvailabilityConfig {
    AvailabilityConfig {
        kind: AvailabilityKind::Correlated,
        mean_online_secs: 1200.0,
        mean_offline_secs: 400.0,
        dwell_sigma: 0.4,
        regions: 4,
        region_mtbf_secs: 2000.0,
        region_outage_secs: 500.0,
        degrade_window_secs: 300.0,
        degrade_floor: 0.25,
        ..AvailabilityConfig::default()
    }
}

#[test]
fn all_clients_in_a_region_flip_together_on_outages() {
    let population = 16;
    let mut direct = CorrelatedModel::build(&cfg(), population, 77);
    let mut facade = AvailabilityModel::build(&cfg(), population, 77).unwrap();
    let horizon = 60_000.0;
    let mut outages_seen = 0;
    for r in 0..4 {
        let windows = direct.outage_windows(r, horizon);
        assert!(!windows.is_empty(), "region {r} never failed over {horizon}s");
        outages_seen += windows.len();
        for &(start, end) in &windows {
            assert!(end > start, "degenerate window [{start}, {end})");
            // Sample through the window: every client of the region must be
            // offline through BOTH surfaces (direct model and facade).
            for i in 0..5 {
                let t = start + (end - start) * (2 * i + 1) as f64 / 10.0;
                for c in (0..population).filter(|&c| c % 4 == r) {
                    assert!(!direct.is_available(c, t), "client {c} up in outage at {t}");
                    assert!(!facade.is_available(c, t), "facade disagrees at {t}");
                }
            }
        }
    }
    assert!(outages_seen >= 8, "only {outages_seen} outages — config too calm to test");
}

#[test]
fn marginal_online_fraction_tracks_the_configured_target() {
    let c = cfg();
    let population = 32;
    let mut m = AvailabilityModel::build(&c, population, 3).unwrap();
    let horizon = 400_000.0;
    let region_up = c.region_mtbf_secs / (c.region_mtbf_secs + c.region_outage_secs);
    let expected = c.markov_steady_state() * region_up;
    let fractions: Vec<f64> = (0..population).map(|cl| m.online_fraction(cl, horizon)).collect();
    for (cl, &f) in fractions.iter().enumerate() {
        assert!(
            (f - expected).abs() < 0.15,
            "client {cl}: fraction {f} vs expected {expected}"
        );
    }
    let mean = fractions.iter().sum::<f64>() / population as f64;
    assert!(
        (mean - expected).abs() < 0.05,
        "population mean {mean} vs expected {expected}"
    );
}

#[test]
fn facade_schedules_are_seed_deterministic() {
    let mut a = AvailabilityModel::build(&cfg(), 8, 123).unwrap();
    let mut b = AvailabilityModel::build(&cfg(), 8, 123).unwrap();
    for c in 0..8 {
        let mut t = 0.0;
        for _ in 0..60 {
            let ta = a.next_transition(c, t).expect("correlated keeps flipping");
            let tb = b.next_transition(c, t).unwrap();
            assert_eq!(ta, tb, "same seed must give identical schedules");
            assert_eq!(a.is_available(c, ta), b.is_available(c, ta));
            assert_eq!(a.bandwidth_factor(c, t), b.bandwidth_factor(c, t));
            assert_eq!(a.survival_prob(c, t, 300.0), b.survival_prob(c, t, 300.0));
            t = ta;
        }
    }
    let mut other = AvailabilityModel::build(&cfg(), 8, 124).unwrap();
    assert_ne!(
        a.next_transition(0, 0.0),
        other.next_transition(0, 0.0),
        "different seeds must differ"
    );
}

#[test]
fn degrade_before_drop_is_monotone_and_bounded() {
    let c = cfg();
    let mut direct = CorrelatedModel::build(&c, 8, 55);
    let mut checked = 0;
    for r in 0..4 {
        let windows = direct.outage_windows(r, 120_000.0);
        // Only outages whose preceding up-gap covers the whole ramp give a
        // clean monotone approach (otherwise the earlier outage's own
        // degradation overlaps).
        for w in windows.windows(2) {
            let gap = w[1].0 - w[0].1;
            if gap <= c.degrade_window_secs + 50.0 {
                continue;
            }
            let start = w[1].0;
            let mut prev = f64::INFINITY;
            for i in 0..=30 {
                let t = start - c.degrade_window_secs + i as f64 * (c.degrade_window_secs / 30.0)
                    - 1e-6;
                let f = direct.bandwidth_factor(r, t); // client r sits in region r
                assert!(
                    (c.degrade_floor..=1.0).contains(&f),
                    "factor {f} outside [floor, 1]"
                );
                assert!(f <= prev + 1e-12, "factor recovered approaching the outage");
                prev = f;
            }
            assert_eq!(
                direct.bandwidth_factor(r, start - c.degrade_window_secs - 10.0),
                1.0,
                "factor must be exactly 1.0 outside the window"
            );
            assert!(
                direct.bandwidth_factor(r, start - 1.0) < c.degrade_floor + 0.05,
                "factor must approach the floor at the outage edge"
            );
            checked += 1;
        }
    }
    assert!(checked >= 3, "only {checked} clean approaches found — config too noisy");
}

#[test]
fn bandwidth_factor_is_exactly_one_for_every_other_process() {
    let kinds = [
        AvailabilityConfig::default(), // always-on
        AvailabilityConfig {
            kind: AvailabilityKind::Markov,
            ..AvailabilityConfig::default()
        },
        AvailabilityConfig {
            kind: AvailabilityKind::Diurnal,
            ..AvailabilityConfig::default()
        },
    ];
    for c in kinds {
        let mut m = AvailabilityModel::build(&c, 4, 1).unwrap();
        for client in 0..4 {
            for t in [0.0, 1234.5, 98_765.0] {
                assert_eq!(
                    m.bandwidth_factor(client, t),
                    1.0,
                    "{:?}: degrade coupling must be correlated-only",
                    c.kind
                );
            }
        }
    }
}

#[test]
fn composite_survival_is_zero_when_offline_and_interior_when_stochastic() {
    let mut m = AvailabilityModel::build(&cfg(), 16, 9).unwrap();
    let mut interior = 0;
    for c in 0..16 {
        let s = m.survival_prob(c, 0.0, 300.0);
        assert!((0.0..=1.0).contains(&s));
        if m.is_available(c, 0.0) {
            assert!(s > 0.0, "online client with zero survival estimate");
            if s < 1.0 {
                interior += 1;
            }
        } else {
            assert_eq!(s, 0.0, "offline client must have zero survival");
        }
    }
    assert!(
        interior > 0,
        "every survival estimate was 0/1 — the correlated predictor is an oracle"
    );
}
