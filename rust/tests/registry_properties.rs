//! Artifact-free properties of the strategy registry, config resolution,
//! and the run-event JSONL schema — everything here runs without the AOT
//! artifacts or PJRT (the other half of the registry contract, actually
//! constructing and driving strategies, lives in
//! `strategies_integration.rs`).

use timelyfl::config::{parse as cfgparse, RunConfig};
use timelyfl::coordinator::{registry, sampler};
use timelyfl::metrics::events::{self, AggWeight, ClientWorkload, DropCause, RunEvent};

#[test]
fn every_registered_sampler_is_listed_and_canonicalizes_through_config() {
    assert!(sampler::SAMPLERS.len() >= 3, "uniform + stay-prob + drop-aware");
    for info in sampler::SAMPLERS {
        let mut cfg = RunConfig::default();
        cfgparse::apply_cli(&mut cfg, &format!("sampler={}", info.name)).unwrap();
        assert_eq!(cfg.sampler, info.name);
        cfg.validate().unwrap();
        for alias in info.aliases {
            cfgparse::apply_cli(&mut cfg, &format!("sampler={alias}")).unwrap();
            assert_eq!(cfg.sampler, info.name, "alias {alias} not canonicalized");
        }
    }
    // Unknown samplers fail at parse AND at validate.
    let mut cfg = RunConfig::default();
    assert!(cfgparse::apply_cli(&mut cfg, "sampler=roulette").is_err());
    cfg.sampler = "roulette".into();
    assert!(cfg.validate().is_err());
}

#[test]
fn every_registered_strategy_is_listed_and_resolvable() {
    assert!(registry::STRATEGIES.len() >= 4, "paper trio + semi-async");
    for info in registry::STRATEGIES {
        assert!(!info.name.is_empty() && !info.summary.is_empty());
        assert_eq!(registry::resolve(info.name).unwrap().name, info.name);
        for alias in info.aliases {
            assert_eq!(
                registry::resolve(alias).unwrap().name,
                info.name,
                "alias {alias} must resolve to {}",
                info.name
            );
        }
    }
}

#[test]
fn config_round_trips_every_strategy_name_and_alias() {
    for info in registry::STRATEGIES {
        let mut cfg = RunConfig::default();
        cfgparse::apply_cli(&mut cfg, &format!("strategy={}", info.name)).unwrap();
        assert_eq!(cfg.strategy, info.name);
        cfg.validate().unwrap();
        for alias in info.aliases {
            cfgparse::apply_cli(&mut cfg, &format!("strategy={alias}")).unwrap();
            assert_eq!(cfg.strategy, info.name, "alias {alias} not canonicalized");
        }
    }
    // Unknown strategies fail at parse AND at validate (belt and braces for
    // configs constructed programmatically).
    let mut cfg = RunConfig::default();
    assert!(cfgparse::apply_cli(&mut cfg, "strategy=adaptivefl").is_err());
    cfg.strategy = "adaptivefl".into();
    assert!(cfg.validate().is_err());
}

#[test]
fn default_config_resolves_through_registry() {
    let cfg = RunConfig::default();
    assert_eq!(registry::resolve(&cfg.strategy).unwrap().name, "TimelyFL");
    cfg.validate().unwrap();
}

#[test]
fn event_schema_round_trips_through_util_json() {
    let events = vec![
        RunEvent::RoundComplete {
            round: 0,
            sim_secs: 60.0,
            participants: 3,
            dropped: 0,
            avail_dropped: 1,
            downlink_wait_secs: 4.5,
            stale_starts: 1,
            mean_train_loss: Some(2.5),
            workloads: vec![
                ClientWorkload { client: 0, epochs: 3, alpha: 1.0, stay_prob: 1.0 },
                ClientWorkload { client: 5, epochs: 1, alpha: 0.5, stay_prob: 0.75 },
            ],
            agg_weights: vec![AggWeight { client: 0, weight: 1.0 }],
        },
        RunEvent::RoundComplete {
            round: 1,
            sim_secs: 120.0,
            participants: 0,
            dropped: 2,
            avail_dropped: 0,
            downlink_wait_secs: 0.0,
            stale_starts: 0,
            mean_train_loss: None,
            workloads: vec![],
            agg_weights: vec![],
        },
        RunEvent::EvalPoint {
            round: 1,
            sim_secs: 120.0,
            mean_loss: 2.25,
            metric: 0.31,
        },
        RunEvent::ClientDropped {
            client: 7,
            sim_secs: 90.5,
            cause: DropCause::Deadline,
            execution_avoided: false,
        },
        RunEvent::ClientDropped {
            client: 9,
            sim_secs: 91.0,
            cause: DropCause::Availability,
            execution_avoided: true,
        },
        RunEvent::AvailabilityTransition {
            client: 2,
            sim_secs: 88.0,
            online: true,
        },
    ];
    let text = events::write_jsonl(&events);
    // One line per record, each a self-contained JSON object.
    assert_eq!(text.lines().count(), events.len());
    assert_eq!(events::parse_jsonl(&text).unwrap(), events);
}

#[test]
fn event_reasons_are_the_documented_set() {
    // docs/architecture.md documents exactly these reason strings; adding a
    // kind means updating the doc (and this list).
    let ev = [
        RunEvent::RoundComplete {
            round: 0,
            sim_secs: 0.0,
            participants: 0,
            dropped: 0,
            avail_dropped: 0,
            downlink_wait_secs: 0.0,
            stale_starts: 0,
            mean_train_loss: None,
            workloads: vec![],
            agg_weights: vec![],
        },
        RunEvent::EvalPoint {
            round: 0,
            sim_secs: 0.0,
            mean_loss: 0.0,
            metric: 0.0,
        },
        RunEvent::ClientDropped {
            client: 0,
            sim_secs: 0.0,
            cause: DropCause::Availability,
            execution_avoided: false,
        },
        RunEvent::AvailabilityTransition {
            client: 0,
            sim_secs: 0.0,
            online: false,
        },
    ];
    let got: Vec<&str> = ev.iter().map(|e| e.reason()).collect();
    assert_eq!(
        got,
        vec![
            "round-complete",
            "eval-point",
            "client-dropped",
            "availability-transition"
        ]
    );
}
