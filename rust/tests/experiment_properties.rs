//! Artifact-free properties of the experiment subsystem: scenario registry
//! resolution, grid expansion (cross/zip counts, deterministic ordering,
//! config/parse round-trips), and the parallel runner's determinism —
//! everything here runs without the AOT artifacts or PJRT. (The other half
//! of the contract — `ExperimentRunner::run` against real artifacts — is
//! exercised by the CI sweep smoke in `.github/workflows/check.yml`.)

use timelyfl::availability::AvailabilityKind;
use timelyfl::config::RunConfig;
use timelyfl::coordinator::registry;
use timelyfl::experiment::{
    runner::{assemble, cell_jobs, run_queue},
    scenario,
    summary::parse_sweep_manifest,
    CellSummary, SweepGrid,
};
use timelyfl::metrics::{EvalPoint, RunReport};

// ---------------------------------------------------------------------------
// Scenario registry
// ---------------------------------------------------------------------------

#[test]
fn every_scenario_materialises_and_is_listed() {
    assert!(scenario::SCENARIOS.len() >= 10, "paper presets + variants");
    for s in scenario::SCENARIOS {
        let cfg = s.config().unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
        cfg.validate().unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
        assert_eq!(scenario::resolve(s.name).unwrap().name, s.name);
        for a in s.aliases {
            assert_eq!(scenario::resolve(a).unwrap().name, s.name);
        }
    }
    let err = scenario::resolve("bogus").unwrap_err().to_string();
    assert!(err.contains("kws_smoke"), "error lists scenarios: {err}");
}

#[test]
fn scenario_overrides_go_through_config_parse() {
    // cifar_churn's overrides are plain key=value strings — the same
    // validation surface as a config file.
    let churn = scenario::resolve("cifar_churn").unwrap().config().unwrap();
    assert_eq!(churn.availability.kind, AvailabilityKind::Markov);
    assert_eq!(churn.availability.mean_online_secs, 400.0);
    // The smoke scenario is really tiny (CI budget).
    let smoke = scenario::resolve("kws_smoke").unwrap().config().unwrap();
    assert!(smoke.population <= 16 && smoke.rounds <= 8);
}

// ---------------------------------------------------------------------------
// Grid expansion
// ---------------------------------------------------------------------------

#[test]
fn cross_expansion_counts_multiply() {
    let grid = SweepGrid::new(RunConfig::default())
        .axis("avail_frac", &["1.0", "0.8", "0.5", "0.3"])
        .strategy_axis_all();
    assert_eq!(grid.len(), 4 * registry::STRATEGIES.len());
    assert_eq!(grid.cells().unwrap().len(), grid.len());
    assert_eq!(grid.axis_keys(), vec!["avail_frac", "strategy"]);
}

#[test]
fn zip_expansion_counts_do_not_multiply() {
    let grid = SweepGrid::new(RunConfig::default())
        .zip(
            &["rounds", "target_metric"],
            &[&["10", "0.4"], &["20", "0.5"], &["30", "0.6"]],
        )
        .axis("strategy", &["TimelyFL", "FedBuff"]);
    assert_eq!(grid.len(), 3 * 2, "zip contributes its row count, not a product");
    let cells = grid.cells().unwrap();
    assert_eq!(cells[0].cfg.rounds, 10);
    assert_eq!(cells[0].cfg.target_metric, Some(0.4));
    assert_eq!(cells[5].cfg.rounds, 30);
    assert_eq!(cells[5].cfg.strategy, "FedBuff");
}

#[test]
fn cell_configs_round_trip_through_config_parse() {
    // Every cell's settings, re-applied onto a fresh base via the public
    // parse API, reproduce the cell's config (the materialisation IS
    // config/parse — no second code path).
    let base = scenario::resolve("cifar").unwrap().config().unwrap();
    let grid = SweepGrid::new(base.clone())
        .axis("avail_frac", &["1.0", "0.5"])
        .axis("strategy", &["timely", "seafl"]); // aliases canonicalize
    for cell in grid.cells().unwrap() {
        let mut replay = base.clone();
        for (k, v) in &cell.settings {
            timelyfl::config::parse::apply_override(&mut replay, k, v).unwrap();
        }
        replay.validate().unwrap();
        assert_eq!(replay.strategy, cell.cfg.strategy);
        assert_eq!(replay.availability.kind, cell.cfg.availability.kind);
        assert_eq!(
            replay.availability.mean_online_secs,
            cell.cfg.availability.mean_online_secs
        );
        // Alias canonicalization happened (registry resolution).
        assert!(["TimelyFL", "SemiAsync"].contains(&cell.cfg.strategy.as_str()));
    }
}

#[test]
fn sampler_axis_expands_and_canonicalizes() {
    // The sampler axis goes through the same config/parse + registry
    // canonicalization as strategies, so aliases land canonical in cells.
    let grid = SweepGrid::new(RunConfig::default())
        .axis("sampler", &["uniform", "survival", "drop_aware"]);
    let cells = grid.cells().unwrap();
    let names: Vec<&str> = cells.iter().map(|c| c.cfg.sampler.as_str()).collect();
    assert_eq!(names, ["uniform", "stay-prob", "drop-aware"]);
    assert_eq!(cells[1].label(), "sampler=survival", "labels keep the declared spelling");
    // The packaged correlated-churn scenario composes with the axis.
    let regional = scenario::resolve("cifar_regional").unwrap().config().unwrap();
    assert_eq!(regional.availability.kind, AvailabilityKind::Correlated);
    let grid = SweepGrid::new(regional).axis("sampler", &["uniform", "stay-prob"]);
    assert_eq!(grid.cells().unwrap().len(), 2);
}

#[test]
fn invalid_cells_fail_with_cell_context() {
    let err = format!(
        "{:#}",
        SweepGrid::new(RunConfig::default())
            .axis("rounds", &["10", "0"]) // rounds = 0 fails validate()
            .cells()
            .unwrap_err()
    );
    assert!(err.contains("grid cell 1"), "offending cell not named: {err}");
}

#[test]
fn cell_order_is_deterministic_and_first_axis_outermost() {
    let labels = |grid: &SweepGrid| -> Vec<String> {
        grid.cells().unwrap().iter().map(|c| c.label()).collect()
    };
    let grid = SweepGrid::new(RunConfig::default())
        .axis("avail_frac", &["1.0", "0.5"])
        .axis("strategy", &["TimelyFL", "FedBuff"]);
    let got = labels(&grid);
    assert_eq!(
        got,
        vec![
            "avail_frac=1.0,strategy=TimelyFL",
            "avail_frac=1.0,strategy=FedBuff",
            "avail_frac=0.5,strategy=TimelyFL",
            "avail_frac=0.5,strategy=FedBuff",
        ]
    );
    assert_eq!(got, labels(&grid), "re-expansion must be identical");
}

// ---------------------------------------------------------------------------
// Parallel runner determinism (synthetic executor — no PJRT)
// ---------------------------------------------------------------------------

/// A deterministic fake run: everything derives from the config alone, the
/// way a real seeded simulation's report does.
fn fake_report(cfg: &RunConfig) -> RunReport {
    let s = cfg.seed as f64;
    RunReport {
        strategy: cfg.strategy.clone(),
        model: cfg.model.clone(),
        eval_points: vec![EvalPoint {
            round: cfg.rounds - 1,
            sim_secs: 3600.0 + s,
            mean_loss: 2.0 - 0.01 * s,
            metric: 0.3 + 0.001 * s,
        }],
        rounds: vec![],
        participation: vec![0.25, 0.75],
        online_fraction: vec![1.0, 1.0],
        sim_secs: 3600.0 + s,
        // Wall-clock varies run to run in reality; make it non-deterministic
        // here to PROVE it cannot reach summaries or the manifest.
        wall_secs: std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_secs_f64(),
        total_rounds: cfg.rounds,
        events_processed: 3,
        real_train_steps: 10,
        trainings_executed: 7,
        trainings_avoided: 1,
        tail_dropped: 0,
        tail_avail_dropped: 0,
        downlink_wait_secs: 0.0,
        stale_starts: 0,
        edge_flushes: 0,
        edge_uplink_wait_secs: 0.0,
        edge_root_merges: 0,
    }
}

#[test]
fn seed_replicates_derive_from_the_cell_seed() {
    let grid = SweepGrid::new(RunConfig::default()).axis("strategy", &["TimelyFL"]);
    let cells = grid.cells().unwrap();
    let jobs = cell_jobs(&cells, 3);
    assert_eq!(jobs.len(), 3);
    let base_seed = RunConfig::default().seed;
    for (k, job) in jobs.iter().enumerate() {
        assert_eq!(job.seed_index, k);
        assert_eq!(job.seed, base_seed + k as u64);
    }
}

#[test]
fn parallel_and_serial_runs_produce_identical_summaries_and_manifest() {
    let make_grid = || {
        SweepGrid::new(RunConfig::default())
            .axis("avail_frac", &["1.0", "0.5"])
            .axis("strategy", &["TimelyFL", "FedBuff", "SyncFL"])
    };
    let seeds = 3;
    let run_at = |jobs: usize| -> (Vec<CellSummary>, String) {
        let grid = make_grid();
        let cells = grid.cells().unwrap();
        let job_list = cell_jobs(&cells, seeds);
        let flat: Vec<RunReport> = run_queue(jobs, &job_list, || Ok(()), |_, job| {
            let mut cfg = job.cell.cfg.clone();
            cfg.seed = job.seed;
            Ok(fake_report(&cfg))
        })
        .unwrap();
        let result = assemble(cells, flat, seeds, &|_| true);
        let manifest = result.manifest(Some("test"), &grid.axis_keys());
        (result.summaries(), manifest)
    };
    let (serial_sums, serial_manifest) = run_at(1);
    let (par_sums, par_manifest) = run_at(4);
    assert_eq!(serial_sums, par_sums, "summaries must not depend on --jobs");
    assert_eq!(
        serial_manifest, par_manifest,
        "sweep manifest must be byte-identical across --jobs"
    );
    assert_eq!(serial_sums.len(), 6);
    for s in &serial_sums {
        assert_eq!(s.seeds, seeds);
        // Metric mean over seeds s, s+1, s+2 — nonzero spread proves the
        // replicates really ran at distinct seeds.
        assert!(s.final_metric.unwrap().std > 0.0);
    }
    // Manifest parses back to the same summaries (downstream tooling).
    assert_eq!(parse_sweep_manifest(&serial_manifest).unwrap(), serial_sums);
}

#[test]
fn warm_ledger_parallel_sweep_is_byte_identical_to_serial() {
    // Mirrors `ExperimentRunner::run`'s warm-ledger path with a synthetic
    // executor: cells are a barrier, every replicate of a cell seeds from
    // the same cumulative snapshot, the replicates run under REAL thread
    // parallelism through `run_queue`, and their increments fold back in
    // seed order via `WarmLedger::fold_delta`. The resulting summaries and
    // manifest must be byte-identical for any worker count — the contract
    // that let `timelyfl sweep --warm-ledger` drop its forced `--jobs 1`.
    use timelyfl::scheduling::WarmLedger;
    let seeds = 3;
    let run_at = |jobs: usize| -> (Vec<CellSummary>, String) {
        let grid = SweepGrid::new(RunConfig::default())
            .axis("avail_frac", &["1.0", "0.5"])
            .axis("strategy", &["TimelyFL", "FedBuff"]);
        let cells = grid.cells().unwrap();
        let job_list = cell_jobs(&cells, seeds);
        let mut cumulative = WarmLedger::default();
        let mut flat: Vec<RunReport> = Vec::with_capacity(job_list.len());
        for chunk in job_list.chunks(seeds) {
            let snapshot = cumulative.clone();
            let outcomes = run_queue(jobs, chunk, || Ok(()), |_, job| {
                let mut cfg = job.cell.cfg.clone();
                cfg.seed = job.seed;
                // Synthetic warm run: seed the tables from the snapshot,
                // make seed-dependent deliveries, harvest — and surface the
                // warm totals in the report, so any fold nondeterminism
                // would corrupt the manifest bytes.
                let mut delivered = vec![0u32; 4];
                let mut churned = vec![0u32; 4];
                snapshot.seed_into(&mut delivered, &mut churned);
                delivered[(cfg.seed as usize) % 4] += 1 + (cfg.seed % 3) as u32;
                churned[(cfg.seed as usize + 1) % 4] += 1;
                let mut local = WarmLedger::default();
                local.harvest(&delivered, &churned);
                let mut report = fake_report(&cfg);
                report.participation = delivered.iter().map(|&d| d as f64).collect();
                Ok((report, local))
            })
            .unwrap();
            for (report, harvest) in outcomes {
                cumulative.fold_delta(&snapshot, &harvest);
                flat.push(report);
            }
        }
        let result = assemble(cells, flat, seeds, &|_| true);
        let manifest = result.manifest(Some("warm"), &grid.axis_keys());
        (result.summaries(), manifest)
    };
    let (serial_sums, serial_manifest) = run_at(1);
    for jobs in [2, 4] {
        let (par_sums, par_manifest) = run_at(jobs);
        assert_eq!(serial_sums, par_sums, "--jobs {jobs}: summaries diverged");
        assert_eq!(
            serial_manifest, par_manifest,
            "--jobs {jobs}: warm-ledger manifest must be byte-identical to serial"
        );
    }
    // The ledger really carried: a later cell's replicates see deliveries
    // accumulated by earlier cells, so mean participation grows cell over
    // cell — proof this is a warm sweep, not four cold ones.
    assert!(
        serial_sums.last().unwrap().mean_participation.mean
            > serial_sums.first().unwrap().mean_participation.mean,
        "warm ledger failed to carry across cells"
    );
}

#[test]
fn summaries_are_wall_clock_free() {
    // Two runs of the same grid at different wall times must summarise
    // identically (fake_report stamps real wall-clock into RunReport).
    let run_once = || {
        let grid = SweepGrid::new(RunConfig::default()).axis("strategy", &["TimelyFL"]);
        let cells = grid.cells().unwrap();
        let jobs = cell_jobs(&cells, 2);
        let flat: Vec<RunReport> = run_queue(1, &jobs, || Ok(()), |_, job| {
            let mut cfg = job.cell.cfg.clone();
            cfg.seed = job.seed;
            Ok(fake_report(&cfg))
        })
        .unwrap();
        assemble(cells, flat, 2, &|_| true).manifest(None, &["strategy".to_string()])
    };
    let a = run_once();
    std::thread::sleep(std::time::Duration::from_millis(5));
    let b = run_once();
    assert_eq!(a, b, "wall-clock leaked into the sweep manifest");
}
