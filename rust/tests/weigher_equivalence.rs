//! The weigher seam's headline suite — the scheduling subsystem's
//! acceptance anchors:
//!
//! - `weigher = uniform` (the default, and every alias spelling) scores
//!   each delivered update at exactly 1.0, the value the strategies already
//!   initialise, so a run with the weigher seam engaged MUST be
//!   byte-identical to the default config — for every registered strategy,
//!   every sampler, and both sim cores, under real correlated churn. Any
//!   divergence means the seam leaked into RNG order, the clock, or the
//!   ledger.
//! - Round-synchronous strategies (TimelyFL, SyncFL) aggregate with zero
//!   staleness, so the `staleness` weigher's polynomial discount is exactly
//!   1.0 there: byte-identity again, by construction (the zero-lag
//!   invariance of the ISSUE).
//! - Non-uniform weighers may only bend the learning curve. Clocks,
//!   cohorts, participation, and the drop ledger are computed before the
//!   weigher runs and must not move.
//!
//! The sim-running groups need the AOT artifacts (real PJRT training) and
//! self-skip without them; the weight-algebra group at the bottom is
//! artifact-free and always runs (wired into `scripts/check.sh`).

use timelyfl::availability::AvailabilityKind;
use timelyfl::config::{parse as cfgparse, RunConfig};
use timelyfl::coordinator::{registry, Simulation};
use timelyfl::fleet::FleetCore;
use timelyfl::metrics::RunReport;
use timelyfl::scheduling;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn artifacts_present() -> bool {
    std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn tiny_cfg(strategy: &str, sampler_name: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.sampler = sampler_name.to_string();
    cfg.population = 12;
    cfg.concurrency = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 3.2e5;
    cfg
}

fn regional_cfg(strategy: &str, sampler_name: &str) -> RunConfig {
    let mut cfg = tiny_cfg(strategy, sampler_name);
    cfg.availability.kind = AvailabilityKind::Correlated;
    cfg.availability.regions = 3;
    cfg.availability.region_mtbf_secs = 500.0;
    cfg.availability.region_outage_secs = 250.0;
    cfg.availability.mean_online_secs = 600.0;
    cfg.availability.mean_offline_secs = 200.0;
    cfg.availability.degrade_window_secs = 120.0;
    cfg.sampler_horizon_secs = 200.0;
    cfg
}

fn run(cfg: RunConfig) -> RunReport {
    Simulation::new(cfg, ARTIFACTS)
        .expect("build simulation (run `make artifacts` first)")
        .run()
        .expect("run simulation")
}

/// Report JSON with the only legitimately nondeterministic field zeroed.
fn semantic_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.to_json().to_string()
}

#[test]
fn uniform_weigher_is_bit_identical_to_default_everywhere() {
    require_artifacts!();
    // Every strategy × every sampler × both sim cores, under correlated
    // churn. The `weigher=flat` spelling goes through the CLI-override
    // path, so registry canonicalization is exercised end to end.
    for info in registry::STRATEGIES {
        for policy in ["uniform", "stay-prob", "drop-aware"] {
            for core in [FleetCore::Eager, FleetCore::Lazy] {
                let mut reference = regional_cfg(info.name, policy);
                reference.fleet_core = core;
                let mut cfg = reference.clone();
                cfgparse::apply_cli(&mut cfg, "weigher=flat").unwrap();
                assert_eq!(cfg.scheduling.weigher, "uniform", "alias canonicalization");
                assert_eq!(
                    semantic_json(&run(cfg)),
                    semantic_json(&run(reference)),
                    "{} + {policy} + {core:?}: weigher=uniform diverged from the \
                     default — the weigher seam is not inert",
                    info.name
                );
            }
        }
    }
}

#[test]
fn staleness_weigher_is_inert_for_round_synchronous_strategies() {
    require_artifacts!();
    // TimelyFL and SyncFL aggregate the round they dispatched: staleness is
    // zero for every contribution, so 1/(1+0)^p == 1.0 exactly and the run
    // must not move a byte (the zero-lag invariance criterion).
    for strategy in ["TimelyFL", "SyncFL"] {
        let reference = semantic_json(&run(regional_cfg(strategy, "uniform")));
        let mut cfg = regional_cfg(strategy, "uniform");
        cfg.scheduling.weigher = "staleness".into();
        assert_eq!(
            semantic_json(&run(cfg)),
            reference,
            "{strategy}: staleness weigher moved a zero-lag run"
        );
    }
}

#[test]
fn nonuniform_weighers_are_seed_deterministic_under_churn() {
    require_artifacts!();
    for weigher in ["staleness", "sched-joint"] {
        let mut cfg = regional_cfg("FedBuff", "uniform");
        cfg.scheduling.weigher = weigher.into();
        let a = run(cfg.clone());
        let b = run(cfg);
        assert_eq!(
            semantic_json(&a),
            semantic_json(&b),
            "{weigher}: correlated-churn run not reproducible"
        );
    }
}

#[test]
fn nonuniform_weighers_change_only_the_learning_curve() {
    require_artifacts!();
    // FedBuff under churn has genuinely stale contributions, so sched-joint
    // produces non-unit weights — but weights touch only the aggregated
    // delta. Clocks, round schedule, cohorts, participation, and the drop
    // ledger are all computed before the weigher runs.
    let reference = run(regional_cfg("FedBuff", "uniform"));
    let mut cfg = regional_cfg("FedBuff", "uniform");
    cfg.scheduling.weigher = "sched-joint".into();
    let weighted = run(cfg);
    assert_eq!(weighted.total_rounds, reference.total_rounds, "round schedule moved");
    assert_eq!(weighted.sim_secs, reference.sim_secs, "simulated clock moved");
    assert_eq!(weighted.participation, reference.participation, "cohorts moved");
    assert_eq!(weighted.online_fraction, reference.online_fraction);
    assert_eq!(
        weighted.total_avail_drops(),
        reference.total_avail_drops(),
        "availability drop ledger moved"
    );
    assert_eq!(
        weighted.total_deadline_drops(),
        reference.total_deadline_drops(),
        "deadline drop ledger moved"
    );
    assert_eq!(weighted.events_processed, reference.events_processed);
    assert_eq!(weighted.trainings_executed, reference.trainings_executed);
}

#[test]
fn fair_cap_sampler_survives_every_strategy_under_churn() {
    require_artifacts!();
    for info in registry::STRATEGIES {
        let cfg = regional_cfg(info.name, "fair-cap");
        let r = run(cfg.clone());
        assert!(r.total_rounds > 0, "{} + fair-cap: no rounds", info.name);
        assert_eq!(r.participation.len(), cfg.population);
        for &p in &r.participation {
            assert!((0.0..=1.0).contains(&p));
        }
        for p in &r.eval_points {
            assert!(p.mean_loss.is_finite() && p.metric.is_finite());
        }
    }
}

#[test]
fn calibrated_horizon_is_seed_deterministic() {
    require_artifacts!();
    // `sampler_horizon=auto` replaces the fixed horizon with the EWMA of
    // realized aggregation intervals — pure arithmetic over the simulated
    // clock, so the run stays reproducible.
    let mut cfg = regional_cfg("TimelyFL", "stay-prob");
    cfg.scheduling.horizon_auto = true;
    let a = run(cfg.clone());
    let b = run(cfg);
    assert_eq!(semantic_json(&a), semantic_json(&b));
}

// ---------------------------------------------------------------------------
// Artifact-free weight algebra (always runs; see scripts/check.sh).
// ---------------------------------------------------------------------------

#[test]
fn weight_algebra_holds_for_every_registered_weigher() {
    for info in scheduling::WEIGHERS {
        let mut cfg = timelyfl::scheduling::SchedulingConfig::default();
        cfg.weigher = info.name.to_string();
        let w = cfg.build().unwrap();
        assert_eq!(w.name(), info.name);
        for staleness in [0u64, 1, 3, 50] {
            for (delivered, churned) in [(0u32, 0u32), (5, 0), (0, 5), (7, 3)] {
                let x = w.weight(staleness, delivered, churned);
                assert!(
                    x.is_finite() && x > 0.0 && x <= 1.0 + 1e-12,
                    "{}: weight({staleness}, {delivered}, {churned}) = {x} out of (0, 1]",
                    info.name
                );
                // Monotone non-increasing in staleness.
                assert!(
                    w.weight(staleness + 1, delivered, churned) <= x + 1e-12,
                    "{}: weight increased with staleness",
                    info.name
                );
            }
        }
        // Zero lag, clean ledger: every weigher must sit at exactly 1.0 —
        // the algebraic root of the byte-identity suite above.
        assert_eq!(w.weight(0, 0, 0), 1.0, "{}: fresh weight != 1.0", info.name);
    }
}

#[test]
fn uniform_weigher_is_exactly_one_everywhere() {
    let cfg = timelyfl::scheduling::SchedulingConfig::default();
    let w = cfg.build().unwrap();
    for staleness in [0u64, 9, 1_000] {
        for (d, c) in [(0u32, 0u32), (1_000, 0), (0, 1_000)] {
            assert_eq!(w.weight(staleness, d, c), 1.0);
        }
    }
}

#[test]
fn sched_joint_discounts_both_lag_and_flakiness() {
    let mut cfg = timelyfl::scheduling::SchedulingConfig::default();
    cfg.weigher = "sched-joint".into();
    let w = cfg.build().unwrap();
    // More churn evidence at equal staleness => strictly smaller weight.
    assert!(w.weight(2, 5, 5) < w.weight(2, 5, 0));
    // More staleness at an equal ledger => strictly smaller weight.
    assert!(w.weight(5, 5, 2) < w.weight(1, 5, 2));
    // And it never beats the pure-staleness weigher (posterior <= 1).
    cfg.weigher = "staleness".into();
    let s = cfg.build().unwrap();
    assert!(w.weight(3, 4, 2) <= s.weight(3, 4, 2));
}
