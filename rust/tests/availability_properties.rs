//! Property tests for the availability subsystem over its PUBLIC api —
//! no PJRT / artifacts involved, so these always run. The per-process
//! invariants: determinism by seed, dwell-time calibration, diurnal
//! periodicity, trace round-tripping (including through a real file and
//! `AvailabilityModel::build`), and the event-driven contract
//! (`next_transition` is exactly where `is_available` flips).

use timelyfl::availability::{
    parse_trace, write_trace, AvailabilityConfig, AvailabilityKind, AvailabilityModel, TraceEvent,
};
use timelyfl::util::rng::Rng;

fn markov_cfg() -> AvailabilityConfig {
    AvailabilityConfig {
        kind: AvailabilityKind::Markov,
        mean_online_secs: 900.0,
        mean_offline_secs: 450.0,
        dwell_sigma: 0.6,
        ..AvailabilityConfig::default()
    }
}

/// Walk a client's transition schedule for `n` steps.
fn schedule(model: &mut AvailabilityModel, client: usize, n: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0;
    for _ in 0..n {
        match model.next_transition(client, t) {
            Some(next) => {
                assert!(next > t, "transition must be strictly after the query");
                out.push(next);
                t = next;
            }
            None => break,
        }
    }
    out
}

#[test]
fn same_seed_identical_transition_sequences() {
    let mut a = AvailabilityModel::build(&markov_cfg(), 8, 1234).unwrap();
    let mut b = AvailabilityModel::build(&markov_cfg(), 8, 1234).unwrap();
    for c in 0..8 {
        assert_eq!(
            schedule(&mut a, c, 300),
            schedule(&mut b, c, 300),
            "client {c}: same seed must give an identical schedule"
        );
    }
}

#[test]
fn different_seeds_different_sequences() {
    let mut a = AvailabilityModel::build(&markov_cfg(), 4, 1).unwrap();
    let mut b = AvailabilityModel::build(&markov_cfg(), 4, 2).unwrap();
    let sa: Vec<Vec<f64>> = (0..4).map(|c| schedule(&mut a, c, 20)).collect();
    let sb: Vec<Vec<f64>> = (0..4).map(|c| schedule(&mut b, c, 20)).collect();
    assert_ne!(sa, sb, "seeds must matter");
}

#[test]
fn clients_have_independent_streams() {
    let mut m = AvailabilityModel::build(&markov_cfg(), 2, 5).unwrap();
    assert_ne!(
        schedule(&mut m, 0, 20),
        schedule(&mut m, 1, 20),
        "per-client schedules must differ"
    );
}

#[test]
fn markov_dwell_means_calibrated() {
    // Collect on/off dwells across a large population and compare the
    // sample means to the configured means.
    let cfg = markov_cfg();
    let mut m = AvailabilityModel::build(&cfg, 128, 42).unwrap();
    let (mut on_sum, mut on_n, mut off_sum, mut off_n) = (0.0f64, 0u64, 0.0f64, 0u64);
    for c in 0..128 {
        let mut t = 0.0;
        for _ in 0..60 {
            let online = m.is_available(c, t);
            let next = m.next_transition(c, t).unwrap();
            if online {
                on_sum += next - t;
                on_n += 1;
            } else {
                off_sum += next - t;
                off_n += 1;
            }
            t = next;
        }
    }
    let on_mean = on_sum / on_n as f64;
    let off_mean = off_sum / off_n as f64;
    assert!(
        (on_mean - cfg.mean_online_secs).abs() < 0.1 * cfg.mean_online_secs,
        "online dwell mean {on_mean}, want ~{}",
        cfg.mean_online_secs
    );
    assert!(
        (off_mean - cfg.mean_offline_secs).abs() < 0.1 * cfg.mean_offline_secs,
        "offline dwell mean {off_mean}, want ~{}",
        cfg.mean_offline_secs
    );
}

#[test]
fn markov_long_run_fraction_tracks_steady_state() {
    let cfg = markov_cfg(); // steady state = 900 / 1350 = 2/3
    let mut m = AvailabilityModel::build(&cfg, 64, 7).unwrap();
    let horizon = 400_000.0; // ~300 cycles
    let mean: f64 =
        (0..64).map(|c| m.online_fraction(c, horizon)).sum::<f64>() / 64.0;
    assert!(
        (mean - cfg.markov_steady_state()).abs() < 0.05,
        "mean online fraction {mean} vs steady state {}",
        cfg.markov_steady_state()
    );
}

#[test]
fn transitions_are_exactly_where_state_flips() {
    // The event-driven contract: between consecutive transitions the state
    // is constant, and it differs across each transition.
    let mut m = AvailabilityModel::build(&markov_cfg(), 4, 99).unwrap();
    for c in 0..4 {
        let mut t = 0.0;
        for _ in 0..100 {
            let next = m.next_transition(c, t).unwrap();
            let before = m.is_available(c, t);
            let mid = m.is_available(c, (t + next) / 2.0);
            let after = m.is_available(c, next);
            assert_eq!(before, mid, "state changed without a transition");
            assert_ne!(mid, after, "transition without a state change");
            t = next;
        }
    }
}

#[test]
fn diurnal_schedule_has_the_configured_period() {
    let cfg = AvailabilityConfig {
        kind: AvailabilityKind::Diurnal,
        diurnal_period_secs: 5000.0,
        diurnal_duty: 0.3,
        diurnal_shards: 3,
        ..AvailabilityConfig::default()
    };
    let mut m = AvailabilityModel::build(&cfg, 3, 0).unwrap();
    for c in 0..3 {
        let s = schedule(&mut m, c, 9);
        assert_eq!(s.len(), 9, "diurnal must keep transitioning");
        // Same-type boundaries (every second transition) are one period
        // apart; the on/off split inside a period follows the duty cycle.
        for w in s.windows(3).step_by(2) {
            assert!(
                (w[2] - w[0] - 5000.0).abs() < 1e-6,
                "client {c}: period broken: {w:?}"
            );
        }
        let frac = m.online_fraction(c, 20.0 * 5000.0);
        assert!(
            (frac - 0.3).abs() < 1e-6,
            "client {c}: duty 0.3 but fraction {frac}"
        );
    }
}

#[test]
fn trace_jsonl_round_trips_through_a_file() {
    // Build a synthetic trace, write it to disk, load it back through the
    // full AvailabilityModel::build path, and check both the parsed events
    // and the resulting schedule.
    let mut rng = Rng::seed_from(77);
    let mut events = Vec::new();
    for client in 0..6usize {
        let mut t = 0.0;
        let mut online = true;
        for _ in 0..20 {
            t += 50.0 + rng.f64() * 500.0;
            online = !online;
            events.push(TraceEvent { at: t, client, online });
        }
    }
    let text = write_trace(&events);
    assert_eq!(parse_trace(&text).unwrap(), events, "write -> parse identity");

    let path = std::env::temp_dir().join(format!(
        "timelyfl_avail_trace_{}.jsonl",
        std::process::id()
    ));
    std::fs::write(&path, &text).unwrap();
    let cfg = AvailabilityConfig {
        kind: AvailabilityKind::Trace,
        trace_path: Some(path.to_string_lossy().into_owned()),
        ..AvailabilityConfig::default()
    };
    let mut a = AvailabilityModel::build(&cfg, 8, 0).unwrap();
    let mut b = AvailabilityModel::build(&cfg, 8, 12345).unwrap(); // seed-free
    for c in 0..8 {
        assert_eq!(
            schedule(&mut a, c, 64),
            schedule(&mut b, c, 64),
            "trace schedules are seed-independent"
        );
    }
    // Clients 6 and 7 have no events: always online.
    assert!(a.is_available(6, 1e9));
    assert_eq!(a.next_transition(7, 0.0), None);
    // Client 0's schedule replays its (already alternating) event times.
    let want: Vec<f64> = events.iter().filter(|e| e.client == 0).map(|e| e.at).collect();
    assert_eq!(schedule(&mut a, 0, 64), want);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn build_rejects_bad_configs() {
    let mut cfg = markov_cfg();
    cfg.mean_online_secs = 0.0;
    assert!(AvailabilityModel::build(&cfg, 4, 0).is_err());
    let cfg = AvailabilityConfig {
        kind: AvailabilityKind::Trace,
        trace_path: None,
        ..AvailabilityConfig::default()
    };
    assert!(AvailabilityModel::build(&cfg, 4, 0).is_err());
    let cfg = AvailabilityConfig {
        kind: AvailabilityKind::Trace,
        trace_path: Some("/nonexistent/availability.jsonl".into()),
        ..AvailabilityConfig::default()
    };
    assert!(AvailabilityModel::build(&cfg, 4, 0).is_err());
}
