//! The headline test for deferred dispatch execution: every registered
//! strategy, run at a fixed seed under Markov churn, must produce the SAME
//! `RunReport` whether client training executes eagerly at dispatch time
//! (`cfg.eager_train = true`, the historical behaviour) or deferred to the
//! generation-validated finish event (the default).
//!
//! "Same" is byte-identical report JSON after zeroing the fields that are
//! *supposed* to differ between the two paths:
//!
//! - `wall_secs` — real elapsed time, nondeterministic by nature;
//! - `real_train_steps` — the point of deferral is that the deferred path
//!   executes FEWER real PJRT steps under churn;
//! - `trainings_executed` / `trainings_avoided` — the wasted-work ledger
//!   measuring exactly that difference.
//!
//! Everything semantic — round schedule, participants, drop attribution,
//! per-client participation, learning curve, simulated clock, event counts
//! — must match bit-for-bit (exact f64 equality via the JSON rendering).
//! Needs the AOT artifacts (real PJRT training), like
//! `strategies_integration.rs`.

use timelyfl::availability::{AvailabilityConfig, AvailabilityKind};
use timelyfl::config::RunConfig;
use timelyfl::coordinator::{registry, Simulation};
use timelyfl::metrics::RunReport;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

/// Strategies driven through `SimEngine::drive_events` (the deferred
/// dispatch path); round-stepped strategies train synchronously and must
/// be byte-identical trivially (avoided == 0 in both modes).
const EVENT_STRATEGIES: &[&str] = &["FedBuff", "SemiAsync"];

fn churn_cfg(strategy: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.population = 12;
    cfg.concurrency = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 3.2e5;
    // Online dwells comparable to round times: mid-training churn-outs are
    // frequent enough that the deferred path demonstrably skips work.
    cfg.availability = AvailabilityConfig {
        kind: AvailabilityKind::Markov,
        mean_online_secs: 150.0,
        mean_offline_secs: 300.0,
        dwell_sigma: 0.5,
        ..AvailabilityConfig::default()
    };
    cfg
}

fn run(mut cfg: RunConfig, eager: bool) -> RunReport {
    cfg.eager_train = eager;
    Simulation::new(cfg, ARTIFACTS)
        .expect("build simulation (run `make artifacts` first)")
        .run()
        .expect("run simulation")
}

/// Report JSON with the intentionally-divergent perf-accounting fields
/// zeroed; every remaining byte participates in the equivalence check.
fn semantic_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.real_train_steps = 0;
    r.trainings_executed = 0;
    r.trainings_avoided = 0;
    r.to_json().to_string()
}

#[test]
fn every_strategy_is_bit_identical_eager_vs_deferred_under_churn() {
    for info in registry::STRATEGIES {
        let deferred = run(churn_cfg(info.name), false);
        let eager = run(churn_cfg(info.name), true);
        assert_eq!(
            semantic_json(&deferred),
            semantic_json(&eager),
            "{}: deferred execution changed the run's semantics",
            info.name
        );
    }
}

#[test]
fn every_strategy_is_bit_identical_eager_vs_deferred_always_on() {
    // The always-on control: deferral must also be invisible when nothing
    // is ever cancelled (this is the configuration the committed goldens
    // fingerprint, so it doubles as golden-compatibility insurance).
    for info in registry::STRATEGIES {
        let mut cfg = churn_cfg(info.name);
        cfg.availability = AvailabilityConfig::default();
        let deferred = run(cfg.clone(), false);
        let eager = run(cfg, true);
        assert_eq!(
            semantic_json(&deferred),
            semantic_json(&eager),
            "{}: deferred execution visible under always-on availability",
            info.name
        );
    }
}

#[test]
fn deferred_event_strategies_skip_real_work_under_churn() {
    // The acceptance criterion's perf half: under churn the deferred path
    // must avoid dispatches (cancelled or tail-pending plans) and execute
    // strictly fewer real PJRT train steps than eager.
    for &name in EVENT_STRATEGIES {
        let deferred = run(churn_cfg(name), false);
        let eager = run(churn_cfg(name), true);
        assert!(
            deferred.trainings_avoided > 0,
            "{name}: churn-heavy run avoided nothing"
        );
        assert_eq!(
            eager.trainings_avoided, 0,
            "{name}: eager mode must never avoid work"
        );
        assert_eq!(
            deferred.total_train_dispatches(),
            eager.total_train_dispatches(),
            "{name}: dispatch schedules must match between modes"
        );
        assert!(
            deferred.trainings_executed < eager.trainings_executed,
            "{name}: deferred executed {} !< eager {}",
            deferred.trainings_executed,
            eager.trainings_executed
        );
        assert!(
            deferred.real_train_steps < eager.real_train_steps,
            "{name}: deferred PJRT steps {} !< eager {}",
            deferred.real_train_steps,
            eager.real_train_steps
        );
        let ratio = deferred.trainings_avoided_ratio();
        assert!((0.0..=1.0).contains(&ratio));
    }
}

#[test]
fn wasted_work_ledger_settles_for_every_strategy() {
    // executed + avoided == total dispatches, over real runs, measured
    // against an INDEPENDENT baseline: the eager run executes every
    // dispatch at dispatch time, and both modes make bit-identical
    // dispatch decisions (proven above), so eager's executed count IS the
    // true dispatch count the deferred ledger must settle to. (The pure
    // ledger algebra is property-tested in wasted_work_properties.rs;
    // Recorder::finish debug-asserts zero residue on every run.)
    for info in registry::STRATEGIES {
        let deferred = run(churn_cfg(info.name), false);
        let eager = run(churn_cfg(info.name), true);
        assert_eq!(eager.trainings_avoided, 0, "{}: eager avoided", info.name);
        assert_eq!(
            deferred.trainings_executed + deferred.trainings_avoided,
            eager.trainings_executed,
            "{}: deferred ledger did not settle to the true dispatch count",
            info.name
        );
    }
}

#[test]
fn round_strategies_never_avoid_work() {
    // TimelyFL/SyncFL decide eligibility before training, so even the
    // deferred default leaves their ledger all-executed.
    for name in ["TimelyFL", "SyncFL"] {
        let r = run(churn_cfg(name), false);
        assert_eq!(r.trainings_avoided, 0, "{name}: round strategy avoided");
        assert!(r.trainings_executed > 0, "{name}: nothing trained");
    }
}
