//! Property-based tests (in-tree `testkit` harness) over the coordinator's
//! pure logic: workload scheduling, interval selection, aggregation
//! algebra, update bookkeeping, the event queue, and the device model.
//! These are the invariants Algorithm 1-3 rely on for correctness, checked
//! over thousands of random cases — no PJRT involved, so they run in
//! milliseconds.

use timelyfl::aggregation::{average_delta, staleness_discount, Contribution};
use timelyfl::coordinator::local_time::TimeEstimate;
use timelyfl::coordinator::scheduler::{aggregation_interval, schedule};
use timelyfl::devices::{Fleet, FleetConfig};
use timelyfl::model::{ParamVec, Update};
use timelyfl::simtime::EventQueue;
use timelyfl::util::rng::Rng;
use timelyfl::util::testkit::{check, gen};

fn rand_estimate(rng: &mut Rng) -> TimeEstimate {
    TimeEstimate {
        t_cmp: gen::positive_time(rng) * 100.0,
        t_com: gen::positive_time(rng) * 10.0,
    }
}

#[test]
fn prop_schedule_outputs_always_valid() {
    check("schedule validity", 5000, |rng| {
        let est = rand_estimate(rng);
        let t_k = gen::positive_time(rng) * 100.0;
        let max_epochs = 1 + rng.usize_below(32);
        let w = schedule(t_k, &est, max_epochs);
        assert!(w.epochs >= 1 && w.epochs <= max_epochs, "epochs {}", w.epochs);
        assert!(w.alpha > 0.0 && w.alpha <= 1.0, "alpha {}", w.alpha);
        assert!(w.t_rpt <= t_k + 1e-9, "report deadline after interval");
    });
}

#[test]
fn prop_scheduled_workload_fits_interval() {
    // Alg. 3 guarantee: with exact estimates, the assigned workload's
    // predicted duration never exceeds T_k (the paper's timeliness claim).
    check("workload fits interval", 5000, |rng| {
        let est = rand_estimate(rng);
        let t_k = gen::positive_time(rng) * 100.0;
        let w = schedule(t_k, &est, 64);
        let predicted = if w.alpha < 1.0 {
            (est.t_cmp + est.t_com) * w.alpha
        } else {
            est.t_cmp * w.epochs as f64 + est.t_com
        };
        // A fast client (E >= 1 fits) or a partial client both fit.
        if predicted > t_k + 1e-9 {
            // The only legal violation: even one epoch at the smallest
            // alpha cannot fit — then E = 1, alpha < 1 is still assigned
            // (the client trains its best effort). alpha*total <= t_k must
            // hold by construction of line 3.
            assert!(
                w.alpha * (est.t_cmp + est.t_com) <= t_k + 1e-9,
                "alpha rule violated: {} * {} > {t_k}",
                w.alpha,
                est.t_cmp + est.t_com
            );
        }
    });
}

#[test]
fn prop_interval_is_order_statistic() {
    check("T_k order statistic", 2000, |rng| {
        let totals = gen::f64_vec(rng, 1, 64, 1.0)
            .into_iter()
            .map(f64::abs)
            .collect::<Vec<_>>();
        let k = 1 + rng.usize_below(totals.len());
        let t_k = aggregation_interval(&totals, k);
        let below = totals.iter().filter(|&&t| t <= t_k + 1e-12).count();
        assert!(below >= k, "fewer than k totals fit inside T_k");
        assert!(totals.contains(&t_k), "T_k must be one of the estimates");
    });
}

#[test]
fn prop_average_delta_bounded_by_extremes() {
    // With uniform weights and full updates, every aggregated element lies
    // within [min, max] of the contributions' elements.
    check("average within extremes", 800, |rng| {
        let n_tensors = 1 + rng.usize_below(4);
        let sizes: Vec<usize> = (0..n_tensors).map(|_| 1 + rng.usize_below(16)).collect();
        let template = ParamVec {
            tensors: sizes.iter().map(|&s| vec![0.0; s]).collect(),
        };
        let n_clients = 1 + rng.usize_below(8);
        let contributions: Vec<Contribution> = (0..n_clients)
            .map(|i| Contribution {
                client_id: i,
                update: Update {
                    boundary: 0,
                    tensors: sizes.iter().map(|&s| gen::f32_vec(rng, s, 2.0)).collect(),
                },
                weight: 1.0,
                staleness: 0,
            })
            .collect();
        let avg = average_delta(&template, &contributions, false);
        for t in 0..n_tensors {
            for j in 0..sizes[t] {
                let vals: Vec<f32> = contributions
                    .iter()
                    .map(|c| c.update.tensors[t][j])
                    .collect();
                let lo = vals.iter().cloned().fold(f32::MAX, f32::min);
                let hi = vals.iter().cloned().fold(f32::MIN, f32::max);
                let got = avg.tensors[t][j];
                assert!(
                    got >= lo - 1e-4 && got <= hi + 1e-4,
                    "avg {got} outside [{lo}, {hi}]"
                );
            }
        }
    });
}

#[test]
fn prop_partial_contributions_never_leak_across_boundary() {
    // A client that trained only the suffix must have zero influence on
    // prefix tensors, whatever the mix of boundaries in the cohort.
    check("boundary isolation", 800, |rng| {
        let sizes = [4usize, 3, 5];
        let template = ParamVec {
            tensors: sizes.iter().map(|&s| vec![0.0; s]).collect(),
        };
        // One full client with known values, one partial client.
        let full_tensors: Vec<Vec<f32>> =
            sizes.iter().map(|&s| gen::f32_vec(rng, s, 1.0)).collect();
        let boundary = 1 + rng.usize_below(2);
        let partial_tensors: Vec<Vec<f32>> = sizes[boundary..]
            .iter()
            .map(|&s| gen::f32_vec(rng, s, 1.0))
            .collect();
        let contributions = vec![
            Contribution {
                client_id: 0,
                update: Update {
                    boundary: 0,
                    tensors: full_tensors.clone(),
                },
                weight: 1.0,
                staleness: 0,
            },
            Contribution {
                client_id: 1,
                update: Update {
                    boundary,
                    tensors: partial_tensors,
                },
                weight: 1.0,
                staleness: 0,
            },
        ];
        let avg = average_delta(&template, &contributions, false);
        // Prefix tensors: only the full client contributed -> exact match.
        for t in 0..boundary {
            assert_eq!(avg.tensors[t], full_tensors[t], "prefix diluted");
        }
    });
}

#[test]
fn prop_staleness_discount_decreasing_in_tau() {
    check("staleness monotone", 1000, |rng| {
        let tau = rng.usize_below(100) as u64;
        let d1 = staleness_discount(tau);
        let d2 = staleness_discount(tau + 1 + rng.usize_below(10) as u64);
        assert!(d1 > d2, "discount must strictly decrease");
        assert!(d1 <= 1.0 && d2 > 0.0);
    });
}

#[test]
fn prop_delta_apply_roundtrip() {
    check("delta/apply inverse", 1000, |rng| {
        let n = 1 + rng.usize_below(4);
        let sizes: Vec<usize> = (0..n).map(|_| 1 + rng.usize_below(12)).collect();
        let base = ParamVec {
            tensors: sizes.iter().map(|&s| gen::f32_vec(rng, s, 5.0)).collect(),
        };
        let new = ParamVec {
            tensors: sizes.iter().map(|&s| gen::f32_vec(rng, s, 5.0)).collect(),
        };
        let boundary = rng.usize_below(n);
        let delta = new.delta_from(&base, boundary);
        let mut rebuilt = base.clone();
        rebuilt.apply(&delta, 1.0);
        // prefix untouched, suffix == new
        for t in 0..boundary {
            assert_eq!(rebuilt.tensors[t], base.tensors[t]);
        }
        for t in boundary..n {
            for (a, b) in rebuilt.tensors[t].iter().zip(&new.tensors[t]) {
                assert!((a - b).abs() < 1e-4, "suffix mismatch");
            }
        }
        assert_eq!(delta.bytes(), delta.num_params() * 4);
    });
}

#[test]
fn prop_event_queue_pops_sorted() {
    check("event queue order", 500, |rng| {
        let mut q: EventQueue<u64> = EventQueue::new();
        let n = 1 + rng.usize_below(200);
        for i in 0..n {
            q.schedule_in(gen::positive_time(rng), i as u64);
        }
        let mut last = 0.0f64;
        let mut popped = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last, "time went backwards");
            last = t;
            popped += 1;
        }
        assert_eq!(popped, n);
        assert_eq!(q.events_processed(), n as u64);
    });
}

#[test]
fn prop_fleet_spread_always_within_calibration() {
    check("fleet spread", 100, |rng| {
        let spread = 1.5 + rng.f64() * 40.0;
        let cfg = FleetConfig {
            compute_spread: spread,
            ..FleetConfig::default()
        };
        let fleet = Fleet::generate(64, cfg, rng);
        let times: Vec<f64> = fleet.devices.iter().map(|d| d.base_epoch_secs).collect();
        let max = times.iter().cloned().fold(f64::MIN, f64::max);
        let min = times.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min <= spread + 1e-9, "spread violated");
        assert!(times.iter().all(|&t| t > 0.0));
    });
}

#[test]
fn prop_disturbance_in_paper_bounds() {
    // Eq. 2: w is clipped to [1, 1.3].
    check("disturbance eq2", 5000, |rng| {
        let w = timelyfl::devices::disturbance_coefficient(rng);
        assert!((1.0..=1.3).contains(&w), "w = {w} outside [1, 1.3]");
    });
}
