//! The headline test for batched plan execution (`batch_exec=` config
//! key): every registered strategy, at a fixed seed, must produce the SAME
//! `RunReport` whether resolve-ready train plans execute one PJRT dispatch
//! at a time (the serial anchor) or coalesced into stacked multi-lane
//! dispatches drained at each aggregation boundary.
//!
//! Against the serial DEFERRED path the comparison is total: every report
//! field except `wall_secs` — including the wasted-work ledger and
//! `real_train_steps` — because batching changes only how many PJRT
//! *executions* carry the work (`RuntimeStats::train_execs`, asserted in
//! `benches/hotpath_criterion.rs`), never which plans run or how many
//! logical SGD steps they take. Against EAGER execution the usual
//! perf-accounting fields are zeroed first (eager runs churn-cancelled
//! work that both deferred modes skip — `deferred_equivalence.rs`).
//!
//! The batched lanes also run `agg_jobs >= 2`, so this suite doubles as
//! the end-to-end proof that chunk-parallel aggregation is invisible in
//! full runs (the pure fold is property-tested in
//! `parallel_agg_properties.rs`).
//!
//! Needs AOT artifacts WITH batched graphs (`make artifacts` on this
//! tree); both gates self-skip with a hint otherwise.

use timelyfl::availability::{AvailabilityConfig, AvailabilityKind};
use timelyfl::config::RunConfig;
use timelyfl::coordinator::{registry, Simulation};
use timelyfl::metrics::RunReport;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn batched_artifacts_present() -> bool {
    // Manifest predating the batched graphs parses fine (lanes = 0) but
    // cannot serve `batch_exec=on`; skip rather than demand a re-record.
    std::fs::read_to_string(std::path::Path::new(ARTIFACTS).join("manifest.json"))
        .is_ok_and(|m| m.contains("batched_artifact"))
}

macro_rules! require_batched_artifacts {
    () => {
        if !batched_artifacts_present() {
            eprintln!("skipping: run `make artifacts` first (need batched graphs)");
            return;
        }
    };
}

/// Tiny churn-heavy fleet (the `deferred_equivalence.rs` shape): round
/// times comparable to online dwells, so plans are cancelled mid-flight
/// often enough that the batched queue demonstrably skips them too.
fn base_cfg(strategy: &str, churn: AvailabilityKind) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.population = 12;
    cfg.concurrency = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 3.2e5;
    cfg.availability = match churn {
        AvailabilityKind::AlwaysOn => AvailabilityConfig::default(),
        AvailabilityKind::Markov => AvailabilityConfig {
            kind: AvailabilityKind::Markov,
            mean_online_secs: 150.0,
            mean_offline_secs: 300.0,
            dwell_sigma: 0.5,
            ..AvailabilityConfig::default()
        },
        _ => AvailabilityConfig {
            kind: AvailabilityKind::Correlated,
            mean_online_secs: 150.0,
            mean_offline_secs: 300.0,
            dwell_sigma: 0.5,
            regions: 3,
            region_mtbf_secs: 500.0,
            region_outage_secs: 250.0,
            degrade_window_secs: 120.0,
            ..AvailabilityConfig::default()
        },
    };
    cfg
}

fn run(mut cfg: RunConfig, batched: bool, agg_jobs: usize) -> RunReport {
    cfg.batch_exec = batched;
    cfg.agg_jobs = agg_jobs;
    Simulation::new(cfg, ARTIFACTS)
        .expect("build simulation (run `make artifacts` first)")
        .run()
        .expect("run simulation")
}

/// Full-fidelity comparison key: only real elapsed time may differ.
fn full_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.to_json().to_string()
}

/// The eager-comparison key (`deferred_equivalence.rs` idiom): zero the
/// perf-accounting fields the two dispatch disciplines are ALLOWED to
/// disagree on.
fn semantic_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.real_train_steps = 0;
    r.trainings_executed = 0;
    r.trainings_avoided = 0;
    r.to_json().to_string()
}

const CHURNS: &[(&str, AvailabilityKind)] = &[
    ("always-on", AvailabilityKind::AlwaysOn),
    ("markov", AvailabilityKind::Markov),
    ("correlated", AvailabilityKind::Correlated),
];

#[test]
fn every_strategy_batched_is_bit_identical_to_serial_under_every_churn() {
    require_batched_artifacts!();
    for &(churn_name, churn) in CHURNS {
        for info in registry::STRATEGIES {
            let serial = run(base_cfg(info.name, churn), false, 1);
            let batched = run(base_cfg(info.name, churn), true, 2);
            assert_eq!(
                full_json(&serial),
                full_json(&batched),
                "{} / {churn_name}: batched execution changed the report",
                info.name
            );
        }
    }
}

#[test]
fn batched_is_insensitive_to_agg_jobs() {
    // The acceptance criterion's "at every agg_jobs" clause: odd worker
    // counts that do not divide the tensor count, against the same serial
    // anchor. One strategy per family keeps the PJRT budget sane — the
    // fold itself is jobs-blind by construction (parallel_agg_properties).
    require_batched_artifacts!();
    for name in ["TimelyFL", "FedBuff"] {
        let serial = run(base_cfg(name, AvailabilityKind::Markov), false, 1);
        for jobs in [1usize, 3, 7] {
            let batched = run(base_cfg(name, AvailabilityKind::Markov), true, jobs);
            assert_eq!(
                full_json(&serial),
                full_json(&batched),
                "{name}: agg_jobs={jobs} changed the report"
            );
        }
    }
}

#[test]
fn batched_matches_eager_semantics_under_churn() {
    // Transitivity check against the OTHER execution discipline: batched
    // deferred vs eager-at-dispatch agree on everything semantic.
    require_batched_artifacts!();
    for info in registry::STRATEGIES {
        let mut eager_cfg = base_cfg(info.name, AvailabilityKind::Markov);
        eager_cfg.eager_train = true;
        let eager = run(eager_cfg, false, 1);
        let batched = run(base_cfg(info.name, AvailabilityKind::Markov), true, 2);
        assert_eq!(
            semantic_json(&eager),
            semantic_json(&batched),
            "{}: batched vs eager semantic drift",
            info.name
        );
    }
}

#[test]
fn cancelled_tickets_never_poison_round_losses() {
    // Placeholder-loss hygiene regression: a ticketed `ClientFinish` from
    // the batched queue carries `mean_loss = NaN` until the flush patches
    // it. A client cancelled by churn BETWEEN enqueue and drain leaves its
    // placeholder unpatched forever; before the `complete_round` /
    // `Recorder` guards, one such leak turned a round's `mean_train_loss`
    // (and every downstream golden) into NaN. Under this churn-heavy fleet
    // the avoided counter proves such cancellations happened, so every
    // recorded loss must still be finite-or-null — and identical to the
    // serial run, which never mints placeholders at all.
    require_batched_artifacts!();
    for &(churn_name, churn) in CHURNS[1..].iter() {
        for info in registry::STRATEGIES {
            let batched = run(base_cfg(info.name, churn), true, 2);
            assert!(
                batched.trainings_avoided > 0,
                "{} / {churn_name}: no ticket was cancelled between enqueue and drain",
                info.name
            );
            for r in &batched.rounds {
                assert!(
                    r.mean_train_loss.map_or(true, |l| l.is_finite()),
                    "{} / {churn_name}: round {} carries a non-finite loss {:?}",
                    info.name,
                    r.round,
                    r.mean_train_loss
                );
            }
            assert!(
                !full_json(&batched).contains("NaN"),
                "{} / {churn_name}: NaN leaked into the serialized report",
                info.name
            );
            let serial = run(base_cfg(info.name, churn), false, 1);
            let losses = |r: &RunReport| -> Vec<Option<f64>> {
                r.rounds.iter().map(|rr| rr.mean_train_loss).collect()
            };
            assert_eq!(
                losses(&serial),
                losses(&batched),
                "{} / {churn_name}: placeholder handling changed the loss series",
                info.name
            );
        }
    }
}

#[test]
fn batched_never_executes_cancelled_plans() {
    // The ledger half: under churn the batched queue must avoid exactly
    // what serial deferral avoids — cancelled plans never reach a stacked
    // dispatch — and the ledger settles to the true dispatch count
    // (executed + avoided == eager's executed; eager trains every
    // dispatch at dispatch time, so its executed count IS the total).
    require_batched_artifacts!();
    for name in ["FedBuff", "SemiAsync"] {
        let serial = run(base_cfg(name, AvailabilityKind::Markov), false, 1);
        let batched = run(base_cfg(name, AvailabilityKind::Markov), true, 2);
        let mut eager_cfg = base_cfg(name, AvailabilityKind::Markov);
        eager_cfg.eager_train = true;
        let eager = run(eager_cfg, false, 1);

        assert!(batched.trainings_avoided > 0, "{name}: churn avoided nothing");
        assert_eq!(
            batched.trainings_executed, serial.trainings_executed,
            "{name}: batched executed a different plan set than serial"
        );
        assert_eq!(
            batched.trainings_avoided, serial.trainings_avoided,
            "{name}: batched avoided a different plan set than serial"
        );
        assert_eq!(
            batched.trainings_executed + batched.trainings_avoided,
            eager.trainings_executed,
            "{name}: batched ledger did not settle to the dispatch count"
        );
        assert_eq!(
            batched.real_train_steps, serial.real_train_steps,
            "{name}: batching changed the logical step count"
        );
    }
}
