//! The network subsystem's headline suite.
//!
//! Contract under test (`crate::network`): the default `network = free`
//! model is **byte-identical** to the pre-subsystem behaviour — zero extra
//! RNG draws, every downlink priced at exactly 0.0, all dissemination
//! bookkeeping gated on a strictly positive transfer — for every registered
//! strategy, every sampling policy, and both sim cores. `network = priced`
//! then makes dissemination a first-class cost: every dispatch pays a
//! downlink leg, the run-level counters go nonzero under correlated churn,
//! and the event-driven strategies record stale starts when a newer global
//! version overtakes an in-flight transfer.
//!
//! The byte-identity group needs the AOT artifacts (real PJRT training,
//! like `fleet_equivalence.rs`); the pure-logic properties at the bottom
//! run on any checkout and are wired into `scripts/check.sh`.

use timelyfl::availability::AvailabilityKind;
use timelyfl::config::RunConfig;
use timelyfl::coordinator::local_time::TimeEstimate;
use timelyfl::coordinator::scheduler::schedule;
use timelyfl::coordinator::{registry, sampler, Simulation};
use timelyfl::fleet::FleetCore;
use timelyfl::metrics::events::{CollectSink, RunEvent};
use timelyfl::metrics::RunReport;
use timelyfl::network::{self, NetworkModel, PricedNetwork, StaleCorrection};

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn tiny_cfg(strategy: &str, sampler_name: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.sampler = sampler_name.to_string();
    cfg.population = 12;
    cfg.concurrency = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 3.2e5;
    cfg
}

fn churn_cfg(strategy: &str, sampler_name: &str) -> RunConfig {
    let mut cfg = tiny_cfg(strategy, sampler_name);
    cfg.availability.kind = AvailabilityKind::Correlated;
    cfg.availability.regions = 3;
    cfg.availability.region_mtbf_secs = 500.0;
    cfg.availability.region_outage_secs = 250.0;
    cfg.availability.mean_online_secs = 600.0;
    cfg.availability.mean_offline_secs = 200.0;
    cfg.availability.degrade_window_secs = 120.0;
    cfg.sampler_horizon_secs = 200.0;
    cfg
}

fn run(cfg: RunConfig) -> RunReport {
    Simulation::new(cfg, ARTIFACTS)
        .expect("build simulation (run `make artifacts` first)")
        .run()
        .expect("run simulation")
}

fn run_with_events(cfg: RunConfig) -> (RunReport, Vec<RunEvent>) {
    let sim = Simulation::new(cfg, ARTIFACTS).expect("build simulation (run `make artifacts` first)");
    let mut sink = CollectSink::default();
    let report = sim.run_with_sink(&mut sink).expect("run simulation");
    (report, sink.events)
}

/// Report JSON with the only legitimately nondeterministic field zeroed.
fn semantic_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.to_json().to_string()
}

fn artifacts_present() -> bool {
    std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

// ---------------------------------------------------------------------------
// Artifact-gated: byte-identity + priced-counter behaviour end-to-end.
// ---------------------------------------------------------------------------

#[test]
fn free_network_is_byte_identical_to_default_everywhere() {
    require_artifacts!();
    // The acceptance criterion: explicit `network = free` — even with every
    // other net knob set to wild values — reproduces the default config
    // byte-for-byte across 4 strategies × 3 samplers × both sim cores,
    // under correlated churn. `down_ratio` and `stale_correction` are dead
    // weight under `free` (no transfer to price, no transfer to overtake).
    // `net_rebalance` is deliberately NOT flipped here: it is an
    // independent *scheduling* axis (Alg. 3 against the effective
    // timeline) that changes behaviour under any network model.
    for info in registry::STRATEGIES {
        for policy in ["uniform", "stay-prob", "drop-aware"] {
            for core in [FleetCore::Lazy, FleetCore::Eager] {
                let mut baseline = churn_cfg(info.name, policy);
                baseline.fleet_core = core;
                let mut explicit = baseline.clone();
                explicit.network.model = "free".into();
                explicit.network.down_ratio = 7.5;
                explicit.network.stale_correction = StaleCorrection::DeltaReplay;
                assert_eq!(
                    semantic_json(&run(explicit)),
                    semantic_json(&run(baseline)),
                    "{} + {policy} + {core:?}: explicit network=free diverged from default",
                    info.name
                );
            }
        }
    }
}

#[test]
fn free_runs_record_zero_dissemination_counters() {
    require_artifacts!();
    for info in registry::STRATEGIES {
        let (report, events) = run_with_events(churn_cfg(info.name, "uniform"));
        assert_eq!(report.downlink_wait_secs, 0.0, "{}", info.name);
        assert_eq!(report.stale_starts, 0, "{}", info.name);
        for ev in &events {
            if let RunEvent::RoundComplete { downlink_wait_secs, stale_starts, .. } = ev {
                assert_eq!(*downlink_wait_secs, 0.0, "{}", info.name);
                assert_eq!(*stale_starts, 0, "{}", info.name);
            }
        }
    }
}

#[test]
fn priced_network_pays_downlink_and_event_strategies_stale_start() {
    require_artifacts!();
    // Long transfers (the model costs 4x its upload time to receive) under
    // correlated churn: every strategy pays a nonzero downlink, and the
    // event-driven protocols — whose in-flight transfers newer globals can
    // overtake — record stale starts between them. Per-round event counters
    // must never exceed the run totals (the tail fold is run-level only).
    let mut stale_total = 0u64;
    for info in registry::STRATEGIES {
        let mut cfg = churn_cfg(info.name, "uniform");
        cfg.rounds = 10;
        cfg.network.model = "priced".into();
        cfg.network.down_ratio = 4.0;
        let (report, events) = run_with_events(cfg);
        assert!(
            report.downlink_wait_secs > 0.0,
            "{}: priced run paid no downlink",
            info.name
        );
        let mut event_wait = 0.0;
        let mut event_stale = 0u64;
        for ev in &events {
            if let RunEvent::RoundComplete { downlink_wait_secs, stale_starts, .. } = ev {
                event_wait += downlink_wait_secs;
                event_stale += stale_starts;
            }
        }
        assert!(
            event_wait <= report.downlink_wait_secs + 1e-9,
            "{}: per-round downlink exceeds the run total",
            info.name
        );
        assert!(event_stale <= report.stale_starts, "{}", info.name);
        // Round-stepped strategies settle eligibility before training (no
        // versioned in-flight window), so stale starts are event-only.
        if matches!(info.name, "TimelyFL" | "SyncFL") {
            assert_eq!(report.stale_starts, 0, "{}", info.name);
        }
        stale_total += report.stale_starts;
    }
    assert!(
        stale_total > 0,
        "no event-driven strategy recorded a stale start under 4x transfers"
    );
}

#[test]
fn delta_replay_changes_the_model_but_not_the_schedule() {
    require_artifacts!();
    // `net_stale_correction = delta-replay` rewrites the *staleness
    // accounting* of an overtaken dispatch (its contribution is weighted as
    // if rebased on the version that overtook it) — it must not move the
    // clock, the cohorts, or the counters, only the learning curve.
    let mut none = churn_cfg("FedBuff", "uniform");
    none.rounds = 10;
    none.network.model = "priced".into();
    none.network.down_ratio = 4.0;
    let mut replay = none.clone();
    replay.network.stale_correction = StaleCorrection::DeltaReplay;
    let n = run(none);
    let r = run(replay);
    assert_eq!(n.total_rounds, r.total_rounds);
    assert_eq!(n.events_processed, r.events_processed);
    assert_eq!(n.sim_secs, r.sim_secs);
    assert_eq!(n.participation, r.participation);
    assert_eq!(n.stale_starts, r.stale_starts);
    assert_eq!(n.downlink_wait_secs, r.downlink_wait_secs);
}

#[test]
fn rebalancing_never_assigns_more_than_the_nominal_schedule() {
    require_artifacts!();
    // TimelyFL + priced + rebalance: Alg. 3 against the degraded timeline.
    // The bandwidth signal is a cached deterministic read (no RNG draws),
    // so each round's cohort, probes, and T_k are identical across the two
    // runs — but WHO lands can differ (shrunk workloads survive deadlines
    // the nominal schedule misses), and round-stepped workload records
    // cover only clients that trained. So compare per (round, client) over
    // the intersection: for any dispatch present in both runs, the
    // rebalanced assignment must never EXCEED the nominal one
    // (`degraded()` only stretches the comm term; Alg. 3 is monotone in
    // the estimate). The strict shrink on degraded clients is demonstrated
    // by `benches/network_dissemination.rs`.
    let mut nominal = churn_cfg("TimelyFL", "uniform");
    nominal.rounds = 10;
    nominal.max_local_epochs = 4;
    nominal.network.model = "priced".into();
    nominal.network.down_ratio = 1.0;
    let mut rebalanced = nominal.clone();
    rebalanced.network.rebalance = true;
    let (_, ev_nom) = run_with_events(nominal);
    let (_, ev_reb) = run_with_events(rebalanced);
    let workload_map = |events: &[RunEvent]| {
        let mut out = std::collections::BTreeMap::new();
        for ev in events {
            if let RunEvent::RoundComplete { round, workloads, .. } = ev {
                for w in workloads {
                    out.insert((*round, w.client), (w.epochs, w.alpha));
                }
            }
        }
        out
    };
    let nom = workload_map(&ev_nom);
    let reb = workload_map(&ev_reb);
    let mut compared = 0usize;
    for (key, (n_epochs, n_alpha)) in &nom {
        let Some((r_epochs, r_alpha)) = reb.get(key) else {
            continue;
        };
        compared += 1;
        assert!(
            r_epochs <= n_epochs,
            "round {} client {}: rebalance RAISED epochs {n_epochs} -> {r_epochs}",
            key.0,
            key.1
        );
        assert!(
            *r_alpha <= n_alpha + 1e-12,
            "round {} client {}: rebalance RAISED alpha {n_alpha} -> {r_alpha}",
            key.0,
            key.1
        );
    }
    assert!(compared > 0, "no dispatch appeared in both runs");
}

#[test]
fn priced_runs_are_seed_deterministic() {
    require_artifacts!();
    for info in registry::STRATEGIES {
        let mut cfg = churn_cfg(info.name, "stay-prob");
        cfg.network.model = "priced".into();
        cfg.network.down_ratio = 1.0;
        cfg.network.rebalance = true;
        cfg.network.stale_correction = StaleCorrection::DeltaReplay;
        let a = run(cfg.clone());
        let b = run(cfg);
        assert_eq!(
            semantic_json(&a),
            semantic_json(&b),
            "{}: priced run not reproducible",
            info.name
        );
    }
}

// ---------------------------------------------------------------------------
// Artifact-free properties (wired into scripts/check.sh).
// ---------------------------------------------------------------------------

#[test]
fn priced_downlink_is_monotone_in_bandwidth_degradation() {
    // The engine feeds the network model the EFFECTIVE upload time
    // (nominal / bandwidth_factor), so composing with `degraded()` must
    // make the downlink monotone non-increasing in the factor.
    let net = PricedNetwork { down_ratio: 0.25 };
    let nominal = TimeEstimate { t_cmp: 100.0, t_com: 8.0 };
    let mut prev = f64::INFINITY;
    for i in 1..=20 {
        let factor = i as f64 / 20.0;
        let down = net.downlink_secs(nominal.degraded(factor).t_com);
        assert!(down > 0.0 && down.is_finite());
        assert!(
            down <= prev,
            "downlink not monotone: factor {factor} gave {down} > {prev}"
        );
        prev = down;
    }
    // Anchor the undegraded price itself.
    assert!((net.downlink_secs(8.0) - 2.0).abs() < 1e-12);
}

#[test]
fn stale_start_detection_algebra() {
    use std::collections::BTreeMap;
    let mut born = BTreeMap::new();
    born.insert(3u64, 10.0);
    born.insert(4u64, 20.0);
    born.insert(5u64, 30.0);
    // A free transfer (zero seconds on the wire) can never be overtaken.
    assert_eq!(network::overtaken_by(0.0, 3, 100.0, &born), None);
    // Overtaken by the NEWEST version born while the bits were in flight.
    assert_eq!(network::overtaken_by(5.0, 3, 25.0, &born), Some(4));
    assert_eq!(network::overtaken_by(5.0, 3, 30.0, &born), Some(5));
    // Versions at or below the dispatch's own base never count.
    assert_eq!(network::overtaken_by(5.0, 5, 100.0, &born), None);
    // Nothing newer had been born by arrival.
    assert_eq!(network::overtaken_by(5.0, 3, 15.0, &born), None);
}

#[test]
fn rebalanced_schedule_is_monotone_under_degradation() {
    // Alg. 3 on the degraded estimate never assigns MORE work than on the
    // nominal one, for a grid of timelines and factors — the pure-logic
    // core of the rebalancing acceptance criterion.
    for (t_cmp, t_com) in [(10.0, 2.0), (40.0, 15.0), (100.0, 8.0), (5.0, 30.0)] {
        let est = TimeEstimate { t_cmp, t_com };
        let t_k = 2.0 * est.t_total();
        let nominal = schedule(t_k, &est, 8);
        for i in 1..=10 {
            let factor = i as f64 / 10.0;
            let w = schedule(t_k, &est.degraded(factor), 8);
            assert!(
                w.epochs <= nominal.epochs,
                "factor {factor}: epochs {} > nominal {}",
                w.epochs,
                nominal.epochs
            );
            assert!(
                w.alpha <= nominal.alpha + 1e-12,
                "factor {factor}: alpha {} > nominal {}",
                w.alpha,
                nominal.alpha
            );
            assert!(w.epochs >= 1 && w.alpha > 0.0, "workload degenerate");
        }
    }
}

#[test]
fn default_config_resolves_the_free_model() {
    let cfg = RunConfig::default();
    assert_eq!(cfg.network.model, "free");
    let net = cfg.network.build().unwrap();
    assert_eq!(net.name(), "free");
    // And it prices EVERY transfer at exactly 0.0 — the bit-identity hook.
    for up in [0.0, 1e-9, 1.0, 3600.0, 1e12] {
        assert_eq!(net.downlink_secs(up), 0.0);
    }
}

#[test]
fn every_registered_model_builds_and_self_reports() {
    for info in network::NETWORKS {
        let mut cfg = RunConfig::default();
        cfg.network.model = info.name.to_string();
        cfg.network.validate().unwrap();
        let net = cfg.network.build().unwrap();
        assert_eq!(net.name(), info.name);
        for alias in info.aliases {
            assert_eq!(network::resolve(alias).unwrap().name, info.name);
        }
    }
    // Samplers and strategies resolve too — the three registries share the
    // resolve idiom, and a network name must never shadow either.
    for info in network::NETWORKS {
        assert!(registry::resolve(info.name).is_err());
        assert!(sampler::resolve(info.name).is_err());
    }
}
