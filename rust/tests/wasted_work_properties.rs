//! Artifact-free properties of the wasted-work ledger behind deferred
//! dispatch execution (`SimEngine::dispatch` / `metrics::WastedWork`).
//!
//! The engine's bookkeeping contract, modelled here without PJRT:
//!
//! - every dispatch is counted once (`on_dispatch`), at plan time;
//! - eager mode (`cfg.eager_train`) executes at dispatch, so cancellation
//!   cannot avoid anything;
//! - deferred mode executes at a generation-valid finish; a churn
//!   cancellation — or a plan still pending when the run ends — skips the
//!   execution and counts as avoided.
//!
//! The same invariants over REAL strategy runs (with PJRT) are asserted in
//! `rust/tests/deferred_equivalence.rs`; this suite is the pure-logic half
//! that `scripts/check.sh` runs on artifact-less checkouts.

use timelyfl::metrics::{RunReport, WastedWork};
use timelyfl::util::json::Json;
use timelyfl::util::rng::Rng;

/// Minimal model of the engine's dispatch bookkeeping: one pending slot
/// per in-flight dispatch, resolved by finish or cancel, drained at run
/// end exactly as `SimEngine::finish` drains its pending table.
struct DispatchModel {
    eager: bool,
    ledger: WastedWork,
    /// In-flight dispatches; `true` = still holds an unexecuted plan.
    in_flight: Vec<bool>,
}

impl DispatchModel {
    fn new(eager: bool) -> Self {
        DispatchModel {
            eager,
            ledger: WastedWork::default(),
            in_flight: Vec::new(),
        }
    }

    fn dispatch(&mut self) {
        self.ledger.on_dispatch();
        if self.eager {
            self.ledger.on_execute(); // trains at dispatch time
            self.in_flight.push(false);
        } else {
            self.in_flight.push(true); // plan stashed, accelerator untouched
        }
    }

    fn finish(&mut self, idx: usize) {
        if self.in_flight.swap_remove(idx) {
            self.ledger.on_execute(); // deferred plan runs now
        }
    }

    fn cancel(&mut self, idx: usize) {
        if self.in_flight.swap_remove(idx) {
            self.ledger.on_avoid(); // deferred plan dies unexecuted
        }
    }

    /// Run-end settlement: plans still pending were never executed.
    fn drain(&mut self) {
        for planned in self.in_flight.drain(..) {
            if planned {
                self.ledger.on_avoid();
            }
        }
    }
}

/// Drive a random dispatch/finish/cancel schedule. `cancel_weight` = 0
/// models always-on availability (churn never cancels anything).
fn random_run(seed: u64, eager: bool, cancel_weight: u64, ops: usize) -> (WastedWork, u64) {
    let mut rng = Rng::seed_from(seed);
    let mut m = DispatchModel::new(eager);
    let mut cancels = 0u64;
    for _ in 0..ops {
        let have = !m.in_flight.is_empty();
        match rng.below(10 + cancel_weight) {
            0..=3 => m.dispatch(),
            4..=9 if have => m.finish(rng.usize_below(m.in_flight.len())),
            _ if have => {
                m.cancel(rng.usize_below(m.in_flight.len()));
                cancels += 1;
            }
            _ => m.dispatch(),
        }
        // Mid-run: the unresolved count is exactly the in-flight set.
        assert_eq!(m.ledger.pending(), m.in_flight.len() as u64);
        let r = m.ledger.avoided_ratio();
        assert!((0.0..=1.0).contains(&r), "ratio {r} out of range");
    }
    m.drain();
    (m.ledger, cancels)
}

#[test]
fn executed_plus_avoided_equals_total_dispatches() {
    // The headline conservation law, for event strategies in both modes:
    // after settlement every dispatch resolved exactly one way.
    for seed in 0..40u64 {
        for eager in [false, true] {
            let (w, _) = random_run(seed, eager, 6, 400);
            assert_eq!(
                w.executed + w.avoided,
                w.dispatched,
                "seed {seed} eager {eager}: ledger did not settle ({w:?})"
            );
            assert_eq!(w.pending(), 0);
        }
    }
}

#[test]
fn eager_mode_never_avoids_anything() {
    // --eager-train is the historical behaviour: churn-cancelled work was
    // already burned, so avoided stays 0 under ANY cancellation pressure.
    for seed in 0..40u64 {
        let (w, cancels) = random_run(seed, true, 20, 400);
        assert_eq!(w.avoided, 0, "seed {seed}: eager run avoided work");
        assert_eq!(w.executed, w.dispatched);
        assert!(cancels > 0, "seed {seed}: churn model never cancelled");
    }
}

#[test]
fn always_on_deferred_avoids_nothing_once_finishes_land() {
    // Always-on availability: no cancellations ever, and every dispatch's
    // finish event eventually validates — the deferred path then executes
    // exactly what eager would have.
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from(seed ^ 0xA1105E);
        let mut m = DispatchModel::new(false);
        for _ in 0..200 {
            if m.in_flight.is_empty() || rng.below(2) == 0 {
                m.dispatch();
            } else {
                m.finish(rng.usize_below(m.in_flight.len()));
            }
        }
        // Let every outstanding finish land (the queue running dry).
        while !m.in_flight.is_empty() {
            m.finish(m.in_flight.len() - 1);
        }
        m.drain();
        assert_eq!(m.ledger.avoided, 0, "seed {seed}: no-churn run avoided work");
        assert_eq!(m.ledger.executed, m.ledger.dispatched);
    }
}

#[test]
fn churned_deferred_runs_strictly_beat_eager_on_executions() {
    // Same op schedule, both modes: deferred executes strictly less once
    // at least one dispatch was cancelled or left pending.
    for seed in 0..20u64 {
        let (deferred, cancels) = random_run(seed, false, 8, 300);
        let (eager, _) = random_run(seed, true, 8, 300);
        assert_eq!(deferred.dispatched, eager.dispatched, "same schedule");
        if cancels > 0 {
            assert!(
                deferred.executed < eager.executed,
                "seed {seed}: deferred {deferred:?} did not beat eager {eager:?}"
            );
            assert!(deferred.avoided > 0);
        }
    }
}

#[test]
fn counters_render_into_report_json() {
    let mut report = RunReport {
        strategy: "FedBuff".into(),
        model: "kws_lite".into(),
        eval_points: vec![],
        rounds: vec![],
        participation: vec![],
        online_fraction: vec![],
        sim_secs: 10.0,
        wall_secs: 0.5,
        total_rounds: 2,
        events_processed: 9,
        real_train_steps: 40,
        trainings_executed: 11,
        trainings_avoided: 4,
        tail_dropped: 0,
        tail_avail_dropped: 0,
        downlink_wait_secs: 0.0,
        stale_starts: 0,
        edge_flushes: 0,
        edge_uplink_wait_secs: 0.0,
        edge_root_merges: 0,
    };
    assert_eq!(report.total_train_dispatches(), 15);
    assert!((report.trainings_avoided_ratio() - 4.0 / 15.0).abs() < 1e-12);

    let parsed = Json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(
        parsed.get("trainings_executed").unwrap().as_f64().unwrap(),
        11.0
    );
    assert_eq!(
        parsed.get("trainings_avoided").unwrap().as_f64().unwrap(),
        4.0
    );

    // An eager (or always-on-drained) report renders avoided as 0, not as
    // a missing key — consumers can rely on the field's presence.
    report.trainings_avoided = 0;
    let parsed = Json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(
        parsed.get("trainings_avoided").unwrap().as_f64().unwrap(),
        0.0
    );
    assert_eq!(report.trainings_avoided_ratio(), 0.0);
}
