//! The sampler seam's headline suite: under `always-on` availability every
//! client's survival probability is trivially 1.0 and the drop ledger
//! never records a churn loss, so the `stay-prob` and `drop-aware`
//! policies MUST take the uniform code path — same RNG calls, same order —
//! and produce byte-identical semantic `RunReport` JSON to
//! `sampler = uniform`, for every registered strategy. Any divergence is
//! an RNG-ordering bug in the seam, not a policy difference.
//!
//! A second group locks the seam under real correlated churn: weighted
//! sampling must stay seed-deterministic and sane (the *benefit* of the
//! policies is measured by `benches/sampler_regional_churn.rs`, not
//! asserted here — a property test should not encode a tuning claim).
//!
//! Needs the AOT artifacts (real PJRT training), like
//! `strategies_integration.rs`.

use timelyfl::availability::AvailabilityKind;
use timelyfl::config::RunConfig;
use timelyfl::coordinator::{registry, sampler, Simulation};
use timelyfl::metrics::RunReport;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn tiny_cfg(strategy: &str, sampler_name: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "kws_lite".into();
    cfg.strategy = strategy.to_string();
    cfg.sampler = sampler_name.to_string();
    cfg.population = 12;
    cfg.concurrency = 6;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.eval_batches = 1;
    cfg.steps_per_epoch = 1;
    cfg.max_local_epochs = 2;
    cfg.sim_model_bytes = 3.2e5;
    cfg
}

fn regional_cfg(strategy: &str, sampler_name: &str) -> RunConfig {
    let mut cfg = tiny_cfg(strategy, sampler_name);
    cfg.availability.kind = AvailabilityKind::Correlated;
    cfg.availability.regions = 3;
    cfg.availability.region_mtbf_secs = 500.0;
    cfg.availability.region_outage_secs = 250.0;
    cfg.availability.mean_online_secs = 600.0;
    cfg.availability.mean_offline_secs = 200.0;
    cfg.availability.degrade_window_secs = 120.0;
    cfg.sampler_horizon_secs = 200.0;
    cfg
}

fn run(cfg: RunConfig) -> RunReport {
    Simulation::new(cfg, ARTIFACTS)
        .expect("build simulation (run `make artifacts` first)")
        .run()
        .expect("run simulation")
}

/// Report JSON with the only legitimately nondeterministic field zeroed.
/// Everything else — round schedule, participants, drops, learning curve,
/// simulated clock, event counts, wasted-work ledger — participates in the
/// byte-for-byte comparison.
fn semantic_json(r: &RunReport) -> String {
    let mut r = r.clone();
    r.wall_secs = 0.0;
    r.to_json().to_string()
}

#[test]
fn weighted_samplers_are_bit_identical_to_uniform_under_always_on() {
    for info in registry::STRATEGIES {
        let reference = semantic_json(&run(tiny_cfg(info.name, "uniform")));
        for policy in ["stay-prob", "drop-aware"] {
            let got = semantic_json(&run(tiny_cfg(info.name, policy)));
            assert_eq!(
                got, reference,
                "{} + {policy}: always-on run diverged from uniform — \
                 an RNG-ordering bug in the sampler seam",
                info.name
            );
        }
    }
}

#[test]
fn sampler_aliases_resolve_to_the_same_run() {
    // `survival` is an alias of `stay-prob`: same canonical policy, same
    // bytes (exercises the registry canonicalization end to end).
    let canonical = semantic_json(&run(tiny_cfg("TimelyFL", "stay-prob")));
    let mut cfg = tiny_cfg("TimelyFL", "uniform");
    cfg.sampler = sampler::resolve("survival").unwrap().name.to_string();
    assert_eq!(semantic_json(&run(cfg)), canonical);
}

#[test]
fn weighted_samplers_are_seed_deterministic_under_correlated_churn() {
    for policy in ["uniform", "stay-prob", "drop-aware"] {
        let a = run(regional_cfg("TimelyFL", policy));
        let b = run(regional_cfg("TimelyFL", policy));
        assert_eq!(
            semantic_json(&a),
            semantic_json(&b),
            "{policy}: correlated-churn run not reproducible"
        );
    }
}

#[test]
fn every_strategy_survives_correlated_churn_with_every_sampler() {
    for info in registry::STRATEGIES {
        for policy in ["uniform", "stay-prob", "drop-aware"] {
            let cfg = regional_cfg(info.name, policy);
            let r = run(cfg.clone());
            assert!(r.total_rounds > 0, "{} + {policy}: no rounds", info.name);
            assert_eq!(r.participation.len(), cfg.population);
            for &p in &r.participation {
                assert!((0.0..=1.0).contains(&p));
            }
            assert!(
                r.mean_online_fraction() < 1.0,
                "{} + {policy}: correlated churn never engaged",
                info.name
            );
            for p in &r.eval_points {
                assert!(p.mean_loss.is_finite() && p.metric.is_finite());
            }
        }
    }
}

#[test]
fn stay_prob_under_correlated_churn_diverges_from_uniform() {
    // The opposite anchor of the always-on equivalence: once survival
    // probabilities actually differ, the weighted policy must make
    // different choices at the same seed (otherwise the seam is wired to
    // the degenerate path unconditionally). Participation vectors are the
    // most sensitive observable.
    let uniform = run(regional_cfg("TimelyFL", "uniform"));
    let weighted = run(regional_cfg("TimelyFL", "stay-prob"));
    assert_ne!(
        uniform.participation, weighted.participation,
        "stay-prob made identical choices to uniform under heavy correlated churn"
    );
}
