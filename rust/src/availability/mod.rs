//! Client availability & churn subsystem.
//!
//! The paper's premise is that "the availability of each client to join the
//! training is highly variable over time due to system heterogeneities and
//! intermittent connectivity" (§1) — production cross-device FL (Papaya,
//! Huba et al. 2022) is dominated by exactly this churn. The seed fleet
//! model only covered compute/bandwidth heterogeneity; this module adds the
//! missing dimension: per-client online/offline *processes* over simulated
//! time.
//!
//! Five process kinds, all behind one [`AvailabilityModel`] facade:
//!
//! - **always-on** — the seed behaviour and the default; strictly additive
//!   (runs are bit-identical to the pre-subsystem code).
//! - **markov** — seeded on/off alternating renewal process with log-normal
//!   dwell times (mean online / offline dwell configurable).
//! - **diurnal** — deterministic sine-gated availability with a configurable
//!   period, duty cycle and timezone sharding (clients in different shards
//!   are phase-shifted copies of each other).
//! - **trace** — replayed from a JSONL event file (`{"at": .., "client": ..,
//!   "online": ..}` records; see `docs/availability.md`).
//! - **correlated** — region-sharded correlated churn: a seeded regional
//!   outage process flips whole regions together, layered over per-client
//!   Markov dwells, with bandwidth degrading before the drop
//!   ([`correlated`]).
//!
//! Every process answers two queries — `is_available(client, t)` and
//! `next_transition(client, t)` (first state flip strictly after `t`) — so
//! availability integrates with the coordinator *event-driven*: transitions
//! become [`crate::simtime::EventQueue`] events instead of per-round
//! Bernoulli coin flips. Two further queries feed availability-aware client
//! sampling (`coordinator::sampler`): `survival_prob(client, now, horizon)`
//! (the stay-prob policy's ranking signal) and `bandwidth_factor(client, t)`
//! (the correlated process's degrade-before-drop coupling; exactly 1.0
//! elsewhere).
//!
//! The bandwidth coupling is also exported as the [`BandwidthSignal`]
//! trait so consumers outside the engine's private `truth_at` — the
//! network subsystem's downlink pricing and the workload-rebalancing seam
//! (`crate::network`) — share ONE signal instead of each re-deriving
//! per-client link quality.

pub mod correlated;
pub mod process;
pub mod trace;

use crate::simtime::SimTime;

pub use correlated::CorrelatedModel;
pub use process::{AvailabilityConfig, AvailabilityKind, AvailabilityModel, SEED_SALT};
pub use trace::{parse_trace, write_trace, TraceEvent};

/// The shared per-client link-quality signal: a multiplicative factor in
/// `(0, 1]` applied to a client's bandwidth at simulated time `t` (1.0 =
/// nominal; the correlated-churn process ramps it toward its configured
/// floor while a region degrades). Uplink pricing (`SimEngine::truth_at`),
/// downlink pricing (`crate::network`), and bandwidth-aware workload
/// rebalancing all consume this one trait, so every leg of a dispatch sees
/// the same degraded link.
pub trait BandwidthSignal {
    fn bandwidth_factor(&mut self, client: usize, t: SimTime) -> f64;
}

impl BandwidthSignal for AvailabilityModel {
    fn bandwidth_factor(&mut self, client: usize, t: SimTime) -> f64 {
        // Delegates to the inherent facade method (which takes precedence
        // at call sites, so this cannot recurse).
        AvailabilityModel::bandwidth_factor(self, client, t)
    }
}
