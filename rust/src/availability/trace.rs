//! Trace-driven availability: JSONL event records (one JSON object per
//! line, in the machine-message idiom of cargo's `machine_message.rs`).
//!
//! Schema (documented in `docs/availability.md`):
//!
//! ```text
//! {"at":120.0,"client":3,"online":false}
//! {"at":540.5,"client":3,"online":true}
//! ```
//!
//! - `at`      — simulated seconds since experiment start (finite, >= 0);
//! - `client`  — client index in `[0, population)`;
//! - `online`  — the state the client *enters* at `at`.
//!
//! Clients with no records are always online; every client is online before
//! its first record (matching the always-on default). Records may appear in
//! any order — the loader sorts per client — and records that restate the
//! current state are ignored (no transition).

use std::fmt::Write as _;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One availability transition observed in a trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Simulated seconds since experiment start.
    pub at: f64,
    /// Client index.
    pub client: usize,
    /// The state the client enters at `at`.
    pub online: bool,
}

/// Serialize events to the JSONL trace format.
pub fn write_trace(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        let line = Json::obj(vec![
            ("at", Json::num(e.at)),
            ("client", Json::num(e.client as f64)),
            ("online", Json::Bool(e.online)),
        ]);
        let _ = writeln!(out, "{line}");
    }
    out
}

/// Parse a JSONL trace. Blank lines are skipped; any malformed line is an
/// error with its line number.
pub fn parse_trace(text: &str) -> Result<Vec<TraceEvent>> {
    let mut events = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let parse_line = || -> Result<TraceEvent> {
            let v = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
            let at = v.expect("at")?.as_f64()?;
            anyhow::ensure!(at.is_finite() && at >= 0.0, "at must be finite and >= 0, got {at}");
            let client = v.expect("client")?.as_usize()?;
            let online = v.expect("online")?.as_bool()?;
            Ok(TraceEvent { at, client, online })
        };
        events.push(parse_line().with_context(|| format!("trace line {}", lineno + 1))?);
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let events = vec![
            TraceEvent { at: 0.0, client: 0, online: false },
            TraceEvent { at: 120.5, client: 0, online: true },
            TraceEvent { at: 60.0, client: 3, online: false },
        ];
        let text = write_trace(&events);
        let back = parse_trace(&text).unwrap();
        assert_eq!(back, events);
    }

    #[test]
    fn skips_blank_lines() {
        let back = parse_trace("\n{\"at\":1.0,\"client\":2,\"online\":true}\n\n").unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].client, 2);
        assert!(back[0].online);
    }

    #[test]
    fn rejects_malformed_lines_with_lineno() {
        let err = parse_trace("{\"at\":1.0,\"client\":0,\"online\":true}\nnot json\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"));
        // missing field
        assert!(parse_trace("{\"at\":1.0,\"client\":0}\n").is_err());
        // negative / non-finite time
        assert!(parse_trace("{\"at\":-1.0,\"client\":0,\"online\":true}\n").is_err());
        // wrong type
        assert!(parse_trace("{\"at\":1.0,\"client\":0,\"online\":1}\n").is_err());
    }
}
