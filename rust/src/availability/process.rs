//! Availability processes: always-on, seeded Markov on/off, diurnal
//! sine-gated, and trace-replayed — unified behind [`AvailabilityModel`].
//!
//! The Markov and trace processes materialise per-client *timelines*
//! (strictly increasing transition timestamps; the state flips at each).
//! Markov timelines are generated lazily from a per-client forked RNG, so
//! queries are deterministic in the seed regardless of query order pattern
//! within a monotone simulation. The diurnal process is closed-form — no
//! state at all — and always-on answers without allocating.

use std::f64::consts::PI;

use anyhow::{Context, Result};

use super::correlated::CorrelatedModel;
use super::trace::{self, TraceEvent};
use crate::simtime::SimTime;
use crate::util::rng::Rng;
use crate::util::stats::lognormal_survival;

const TWO_PI: f64 = 2.0 * PI;

/// Salt XORed into `RunConfig::seed` to derive the availability RNG stream,
/// so availability draws never perturb the fleet/sampling streams (the
/// always-on default must stay bit-identical to the pre-subsystem code).
pub const SEED_SALT: u64 = 0xA7A1_1AB1_E5EE_D001;

/// Which availability process drives the population.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AvailabilityKind {
    /// Every client reachable at all times (seed behaviour, default).
    AlwaysOn,
    /// Alternating on/off renewal process with log-normal dwell times.
    Markov,
    /// Deterministic sine-gated availability, timezone-sharded.
    Diurnal,
    /// Replay a JSONL trace file (see `docs/availability.md`).
    Trace,
    /// Region-sharded correlated churn: a seeded regional outage process
    /// flips whole regions together, layered over per-client Markov
    /// dwells, with bandwidth degrading before the drop
    /// (`availability::correlated`).
    Correlated,
}

impl AvailabilityKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "always_on" | "always-on" | "always" | "on" => AvailabilityKind::AlwaysOn,
            "markov" => AvailabilityKind::Markov,
            "diurnal" => AvailabilityKind::Diurnal,
            "trace" => AvailabilityKind::Trace,
            "correlated" | "regional" => AvailabilityKind::Correlated,
            other => anyhow::bail!("unknown availability kind {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AvailabilityKind::AlwaysOn => "always_on",
            AvailabilityKind::Markov => "markov",
            AvailabilityKind::Diurnal => "diurnal",
            AvailabilityKind::Trace => "trace",
            AvailabilityKind::Correlated => "correlated",
        }
    }
}

/// Calibration of the availability process (threaded through `RunConfig`).
#[derive(Clone, Debug)]
pub struct AvailabilityConfig {
    pub kind: AvailabilityKind,
    /// Markov: mean online dwell in simulated seconds.
    pub mean_online_secs: f64,
    /// Markov: mean offline dwell in simulated seconds.
    pub mean_offline_secs: f64,
    /// Markov: log-normal sigma of both dwell distributions (0 = exact
    /// means, deterministic dwells).
    pub dwell_sigma: f64,
    /// Diurnal: period of the availability wave (default: 24 h).
    pub diurnal_period_secs: f64,
    /// Diurnal: fraction of each period a client is online, in (0, 1].
    pub diurnal_duty: f64,
    /// Diurnal: number of timezone shards; client `c` sits in shard
    /// `c % shards`, phase-shifted by `shard / shards` of a period.
    pub diurnal_shards: usize,
    /// Trace: path to the JSONL event file (required for `kind = trace`).
    pub trace_path: Option<String>,
    /// Correlated: number of regions; client `c` sits in region
    /// `c % regions` and the whole region flips together on outages.
    pub regions: usize,
    /// Correlated: mean up-time between regional outages (seconds).
    pub region_mtbf_secs: f64,
    /// Correlated: mean regional outage duration (seconds).
    pub region_outage_secs: f64,
    /// Correlated: bandwidth starts degrading this many seconds before a
    /// regional outage begins (0 disables the coupling).
    pub degrade_window_secs: f64,
    /// Correlated: effective-throughput floor reached at the outage edge,
    /// in (0, 1].
    pub degrade_floor: f64,
}

impl Default for AvailabilityConfig {
    fn default() -> Self {
        AvailabilityConfig {
            kind: AvailabilityKind::AlwaysOn,
            mean_online_secs: 3600.0,
            mean_offline_secs: 1800.0,
            dwell_sigma: 0.5,
            diurnal_period_secs: 86_400.0,
            diurnal_duty: 0.5,
            diurnal_shards: 4,
            trace_path: None,
            regions: 4,
            region_mtbf_secs: 7200.0,
            region_outage_secs: 900.0,
            degrade_window_secs: 600.0,
            degrade_floor: 0.25,
        }
    }
}

impl AvailabilityConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.mean_online_secs > 0.0 && self.mean_online_secs.is_finite(),
            "avail_mean_online_secs must be positive"
        );
        anyhow::ensure!(
            self.mean_offline_secs > 0.0 && self.mean_offline_secs.is_finite(),
            "avail_mean_offline_secs must be positive"
        );
        anyhow::ensure!(
            self.dwell_sigma >= 0.0 && self.dwell_sigma.is_finite(),
            "avail_dwell_sigma must be >= 0"
        );
        anyhow::ensure!(
            self.diurnal_period_secs > 0.0 && self.diurnal_period_secs.is_finite(),
            "avail_diurnal_period_secs must be positive"
        );
        anyhow::ensure!(
            self.diurnal_duty > 0.0 && self.diurnal_duty <= 1.0,
            "avail_diurnal_duty must be in (0, 1]"
        );
        anyhow::ensure!(self.diurnal_shards >= 1, "avail_diurnal_shards must be >= 1");
        anyhow::ensure!(self.regions >= 1, "avail_regions must be >= 1");
        anyhow::ensure!(
            self.region_mtbf_secs > 0.0 && self.region_mtbf_secs.is_finite(),
            "avail_region_mtbf_secs must be positive"
        );
        anyhow::ensure!(
            self.region_outage_secs > 0.0 && self.region_outage_secs.is_finite(),
            "avail_region_outage_secs must be positive"
        );
        anyhow::ensure!(
            self.degrade_window_secs >= 0.0 && self.degrade_window_secs.is_finite(),
            "avail_degrade_window_secs must be >= 0"
        );
        anyhow::ensure!(
            self.degrade_floor > 0.0 && self.degrade_floor <= 1.0,
            "avail_degrade_floor must be in (0, 1]"
        );
        if self.kind == AvailabilityKind::Trace {
            anyhow::ensure!(
                self.trace_path.is_some(),
                "kind = trace requires avail_trace_path"
            );
        }
        Ok(())
    }

    /// Steady-state online probability of the Markov process.
    pub fn markov_steady_state(&self) -> f64 {
        self.mean_online_secs / (self.mean_online_secs + self.mean_offline_secs)
    }
}

/// Lazy dwell-time generator backing a Markov timeline.
#[derive(Clone, Debug)]
pub(super) struct MarkovGen {
    rng: Rng,
    /// Log-normal mu for online dwells: ln(mean) - sigma^2/2, so the dwell
    /// MEAN equals the configured mean (E[lognormal] = exp(mu + sigma^2/2)).
    mu_on: f64,
    mu_off: f64,
    sigma: f64,
}

impl MarkovGen {
    /// Build a generator whose dwell MEANS equal the given means.
    pub(super) fn with_means(rng: Rng, mean_on: f64, mean_off: f64, sigma: f64) -> MarkovGen {
        MarkovGen {
            rng,
            mu_on: mean_on.ln() - sigma * sigma / 2.0,
            mu_off: mean_off.ln() - sigma * sigma / 2.0,
            sigma,
        }
    }
}

/// One client's transition history: the state flips at each timestamp in
/// `transitions`; the state on `[transitions[i-1], transitions[i])` is
/// `initial_online ^ (i is odd)`. `covered` is the horizon up to which the
/// timeline is final; Markov timelines extend it on demand, static (trace)
/// timelines set it to infinity.
#[derive(Clone, Debug)]
pub(super) struct Timeline {
    initial_online: bool,
    transitions: Vec<f64>,
    covered: f64,
    gen: Option<MarkovGen>,
}

impl Timeline {
    pub(super) fn fixed(initial_online: bool, transitions: Vec<f64>) -> Timeline {
        debug_assert!(transitions.windows(2).all(|w| w[0] < w[1]));
        Timeline {
            initial_online,
            transitions,
            covered: f64::INFINITY,
            gen: None,
        }
    }

    pub(super) fn markov(initial_online: bool, gen: MarkovGen) -> Timeline {
        Timeline {
            initial_online,
            transitions: Vec::new(),
            covered: 0.0,
            gen: Some(gen),
        }
    }

    /// Generate dwells until the timeline is final strictly past `t`.
    fn extend_to(&mut self, t: f64) {
        let Some(g) = self.gen.as_mut() else { return };
        while self.covered <= t {
            let online_now = self.initial_online ^ (self.transitions.len() % 2 == 1);
            let mu = if online_now { g.mu_on } else { g.mu_off };
            let dwell = g.rng.lognormal(mu, g.sigma).max(1e-6);
            self.covered += dwell;
            self.transitions.push(self.covered);
        }
    }

    pub(super) fn state_at(&mut self, t: f64) -> bool {
        self.extend_to(t);
        let flips = self.transitions.partition_point(|&x| x <= t);
        self.initial_online ^ (flips % 2 == 1)
    }

    /// First transition strictly after `t` (None for a static timeline with
    /// no further events).
    pub(super) fn next_after(&mut self, t: f64) -> Option<f64> {
        self.extend_to(t);
        let idx = self.transitions.partition_point(|&x| x <= t);
        self.transitions.get(idx).copied()
    }

    /// Start of the dwell segment containing `t` (0.0 inside the first).
    fn segment_start(&mut self, t: f64) -> f64 {
        self.extend_to(t);
        let idx = self.transitions.partition_point(|&x| x <= t);
        if idx == 0 {
            0.0
        } else {
            self.transitions[idx - 1]
        }
    }

    /// Probability the timeline stays "on" through `[now, now + horizon]`,
    /// given what an observer at `now` can see. For a generated (Markov)
    /// timeline this is the analytic residual-dwell survival from the
    /// process parameters and the observed session age — NOT an oracle
    /// peek at the realized schedule: `P(D >= age + h | D > age)` for the
    /// log-normal dwell `D`. For a static (trace) timeline the schedule is
    /// recorded data, so the answer is the exact 0/1.
    pub(super) fn survival_prob(&mut self, now: f64, horizon: f64) -> f64 {
        if !self.state_at(now) {
            return 0.0;
        }
        if horizon <= 0.0 {
            return 1.0;
        }
        if self.gen.is_none() {
            // Static (trace) timeline: the schedule is recorded data.
            return match self.next_after(now) {
                Some(t) if t < now + horizon => 0.0,
                _ => 1.0,
            };
        }
        let age = (now - self.segment_start(now)).max(0.0);
        let g = self.gen.as_ref().expect("generated timeline");
        let s_age = lognormal_survival(age, g.mu_on, g.sigma);
        if s_age <= 0.0 {
            return 0.0;
        }
        (lognormal_survival(age + horizon, g.mu_on, g.sigma) / s_age).clamp(0.0, 1.0)
    }
}

/// Closed-form diurnal process: client `c` is online iff
/// `sin(2*pi*t/period + phase(c)) >= cos(pi*duty)` — the threshold is chosen
/// so exactly `duty` of each period is online.
#[derive(Clone, Copy, Debug)]
struct Diurnal {
    period: f64,
    duty: f64,
    /// cos(pi * duty): sin(theta) >= threshold holds on an arc of measure
    /// 2*pi*duty per period.
    threshold: f64,
    shards: usize,
}

impl Diurnal {
    fn phase(&self, client: usize) -> f64 {
        TWO_PI * (client % self.shards) as f64 / self.shards as f64
    }

    fn online(&self, client: usize, t: f64) -> bool {
        if self.duty >= 1.0 {
            return true;
        }
        (TWO_PI * t / self.period + self.phase(client)).sin() >= self.threshold
    }

    fn next_transition(&self, client: usize, t: f64) -> Option<f64> {
        if self.duty >= 1.0 {
            return None;
        }
        // Online arc in angle space: [a, pi - a] with a = asin(threshold).
        let a = self.threshold.asin();
        let theta = (TWO_PI * t / self.period + self.phase(client)).rem_euclid(TWO_PI);
        // Distance (in angle) to each boundary, strictly ahead of theta.
        let ahead = |boundary: f64| -> f64 {
            let d = (boundary - theta).rem_euclid(TWO_PI);
            if d < 1e-9 {
                d + TWO_PI
            } else {
                d
            }
        };
        let d = ahead(a).min(ahead(PI - a));
        let next = t + d * self.period / TWO_PI;
        // Floating-point guard: never report a transition at or before `t`.
        if next <= t {
            None
        } else {
            Some(next)
        }
    }
}

enum ModelKind {
    AlwaysOn,
    Timelines(Vec<Timeline>),
    Diurnal(Diurnal),
    Correlated(CorrelatedModel),
}

/// Facade over the population's availability processes.
pub struct AvailabilityModel {
    population: usize,
    kind: ModelKind,
}

impl AvailabilityModel {
    /// The seed behaviour: everyone reachable forever.
    pub fn always_on(population: usize) -> AvailabilityModel {
        AvailabilityModel {
            population,
            kind: ModelKind::AlwaysOn,
        }
    }

    /// Build the configured process for a population. Deterministic in
    /// `seed` (which should already be salted with [`SEED_SALT`]).
    pub fn build(cfg: &AvailabilityConfig, population: usize, seed: u64) -> Result<AvailabilityModel> {
        cfg.validate()?;
        let kind = match cfg.kind {
            AvailabilityKind::AlwaysOn => ModelKind::AlwaysOn,
            AvailabilityKind::Markov => {
                let sigma = cfg.dwell_sigma;
                let mu_on = cfg.mean_online_secs.ln() - sigma * sigma / 2.0;
                let mu_off = cfg.mean_offline_secs.ln() - sigma * sigma / 2.0;
                let p_on = cfg.markov_steady_state();
                let mut master = Rng::seed_from(seed);
                let timelines = (0..population)
                    .map(|c| {
                        let mut rng = master.fork(c as u64);
                        let initial_online = rng.f64() < p_on;
                        Timeline::markov(
                            initial_online,
                            MarkovGen {
                                rng,
                                mu_on,
                                mu_off,
                                sigma,
                            },
                        )
                    })
                    .collect();
                ModelKind::Timelines(timelines)
            }
            AvailabilityKind::Diurnal => ModelKind::Diurnal(Diurnal {
                period: cfg.diurnal_period_secs,
                duty: cfg.diurnal_duty,
                threshold: (PI * cfg.diurnal_duty).cos(),
                shards: cfg.diurnal_shards,
            }),
            AvailabilityKind::Trace => {
                let path = cfg.trace_path.as_ref().expect("validated above");
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading availability trace {path}"))?;
                let events = trace::parse_trace(&text)
                    .with_context(|| format!("parsing availability trace {path}"))?;
                ModelKind::Timelines(Self::timelines_from_trace(&events, population)?)
            }
            AvailabilityKind::Correlated => {
                ModelKind::Correlated(CorrelatedModel::build(cfg, population, seed))
            }
        };
        Ok(AvailabilityModel { population, kind })
    }

    /// Fold trace events into per-client timelines. Clients with no events
    /// are always online; events restating the current state are dropped.
    fn timelines_from_trace(events: &[TraceEvent], population: usize) -> Result<Vec<Timeline>> {
        let mut per_client: Vec<Vec<(f64, bool)>> = vec![Vec::new(); population];
        for e in events {
            anyhow::ensure!(
                e.client < population,
                "trace client {} out of range (population {population})",
                e.client
            );
            per_client[e.client].push((e.at, e.online));
        }
        Ok(per_client
            .into_iter()
            .map(|mut evs| {
                evs.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite trace times"));
                let initial_online = true;
                let mut state = initial_online;
                let mut transitions = Vec::new();
                for (at, online) in evs {
                    if online != state {
                        // Coincident flip-flops collapse to the last state.
                        if transitions.last() == Some(&at) {
                            transitions.pop();
                        } else {
                            transitions.push(at);
                        }
                        state = online;
                    }
                }
                Timeline::fixed(initial_online, transitions)
            })
            .collect())
    }

    pub fn population(&self) -> usize {
        self.population
    }

    /// True when the model can never drop anyone (fast paths + reporting).
    pub fn is_always_on(&self) -> bool {
        matches!(self.kind, ModelKind::AlwaysOn)
    }

    /// Is `client` online at simulated time `t`? At a transition timestamp
    /// the *post*-transition state holds.
    pub fn is_available(&mut self, client: usize, t: SimTime) -> bool {
        debug_assert!(client < self.population, "client {client} out of range");
        match &mut self.kind {
            ModelKind::AlwaysOn => true,
            ModelKind::Timelines(ts) => ts[client].state_at(t),
            ModelKind::Diurnal(d) => d.online(client, t),
            ModelKind::Correlated(c) => c.is_available(client, t),
        }
    }

    /// First state flip strictly after `t` (None = no further transitions).
    pub fn next_transition(&mut self, client: usize, t: SimTime) -> Option<SimTime> {
        debug_assert!(client < self.population, "client {client} out of range");
        match &mut self.kind {
            ModelKind::AlwaysOn => None,
            ModelKind::Timelines(ts) => ts[client].next_after(t),
            ModelKind::Diurnal(d) => d.next_transition(client, t),
            ModelKind::Correlated(c) => c.next_transition(client, t),
        }
    }

    /// Probability that `client` stays online through `[now, now + horizon]`
    /// given what the server can observe at `now` — the prediction the
    /// `stay-prob` sampler ranks by. Per process:
    ///
    /// - **always-on**: 1.0 (trivially — the sampler-equivalence anchor);
    /// - **markov**: analytic residual-dwell survival from the process
    ///   parameters and the observed session age (no oracle peek);
    /// - **diurnal**: the process is deterministic, so the exact 0/1;
    /// - **trace**: the schedule is recorded data, so the exact 0/1;
    /// - **correlated**: product of the region-uptime and personal-layer
    ///   survivals (both analytic).
    pub fn survival_prob(&mut self, client: usize, now: SimTime, horizon: f64) -> f64 {
        debug_assert!(client < self.population, "client {client} out of range");
        match &mut self.kind {
            ModelKind::AlwaysOn => 1.0,
            ModelKind::Timelines(ts) => ts[client].survival_prob(now, horizon),
            ModelKind::Diurnal(d) => {
                if !d.online(client, now) {
                    0.0
                } else {
                    match d.next_transition(client, now) {
                        Some(t) if t < now + horizon => 0.0,
                        _ => 1.0,
                    }
                }
            }
            ModelKind::Correlated(c) => c.survival_prob(client, now, horizon),
        }
    }

    /// Effective-throughput multiplier in (0, 1] for `client` at `t` — the
    /// degrade-before-drop coupling of the correlated process (a client's
    /// bandwidth decays as its region approaches an outage). Exactly 1.0
    /// for every other process, so the coupling is strictly additive.
    pub fn bandwidth_factor(&mut self, client: usize, t: SimTime) -> f64 {
        debug_assert!(client < self.population, "client {client} out of range");
        match &mut self.kind {
            ModelKind::Correlated(c) => c.bandwidth_factor(client, t),
            _ => 1.0,
        }
    }

    /// Client ids online at `t`, ascending. When everyone is online this is
    /// exactly `0..population` — index-sampling from it is then identical
    /// to sampling the whole population (the always-on bit-compat path).
    pub fn online_clients(&mut self, t: SimTime) -> Vec<usize> {
        let n = self.population;
        (0..n).filter(|&c| self.is_available(c, t)).collect()
    }

    /// Does `client` stay online for the whole of `[t0, t1]`?
    pub fn online_through(&mut self, client: usize, t0: SimTime, t1: SimTime) -> bool {
        self.is_available(client, t0)
            && self.next_transition(client, t0).map_or(true, |t| t >= t1)
    }

    /// Earliest transition of ANY client strictly after `t` (the wake-up
    /// time when the whole population is momentarily offline).
    pub fn earliest_transition(&mut self, t: SimTime) -> Option<SimTime> {
        let n = self.population;
        let mut best: Option<f64> = None;
        for c in 0..n {
            if let Some(x) = self.next_transition(c, t) {
                best = Some(best.map_or(x, |b: f64| b.min(x)));
            }
        }
        best
    }

    /// Fraction of `[0, horizon]` the client was online (1.0 for a zero
    /// horizon — nothing has elapsed to be offline for).
    pub fn online_fraction(&mut self, client: usize, horizon: SimTime) -> f64 {
        if horizon <= 0.0 {
            return 1.0;
        }
        let mut cur = 0.0;
        let mut acc = 0.0;
        while cur < horizon {
            let next = self.next_transition(client, cur).unwrap_or(f64::INFINITY);
            if next <= cur {
                break; // floating-point guard; cannot regress
            }
            let seg_end = next.min(horizon);
            // Sample the state at the segment MIDPOINT: the state is
            // constant on the open segment, and midpoints dodge the
            // ulp-level ambiguity of evaluating the diurnal gate exactly
            // at a boundary instant.
            if self.is_available(client, (cur + seg_end) / 2.0) {
                acc += seg_end - cur;
            }
            if next >= horizon {
                break;
            }
            cur = next;
        }
        (acc / horizon).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn markov_cfg() -> AvailabilityConfig {
        AvailabilityConfig {
            kind: AvailabilityKind::Markov,
            mean_online_secs: 600.0,
            mean_offline_secs: 300.0,
            dwell_sigma: 0.4,
            ..AvailabilityConfig::default()
        }
    }

    #[test]
    fn always_on_is_trivial() {
        let mut m = AvailabilityModel::always_on(5);
        assert!(m.is_always_on());
        for c in 0..5 {
            assert!(m.is_available(c, 0.0));
            assert!(m.is_available(c, 1e9));
            assert_eq!(m.next_transition(c, 0.0), None);
            assert_eq!(m.online_fraction(c, 1e6), 1.0);
        }
        assert_eq!(m.online_clients(42.0), vec![0, 1, 2, 3, 4]);
        assert_eq!(m.earliest_transition(0.0), None);
    }

    #[test]
    fn default_config_builds_always_on() {
        let cfg = AvailabilityConfig::default();
        let m = AvailabilityModel::build(&cfg, 8, 1).unwrap();
        assert!(m.is_always_on());
    }

    #[test]
    fn markov_transitions_alternate_states() {
        let mut m = AvailabilityModel::build(&markov_cfg(), 4, 7).unwrap();
        for c in 0..4 {
            let mut t = 0.0;
            let mut state = m.is_available(c, t);
            for _ in 0..50 {
                let next = m.next_transition(c, t).expect("markov always transitions");
                assert!(next > t, "transition must move forward");
                // state holds right up to the transition...
                assert_eq!(m.is_available(c, (t + next) / 2.0), state);
                // ...and flips at it.
                let after = m.is_available(c, next);
                assert_ne!(after, state, "state must flip at a transition");
                t = next;
                state = after;
            }
        }
    }

    #[test]
    fn markov_deterministic_by_seed() {
        let mut a = AvailabilityModel::build(&markov_cfg(), 6, 99).unwrap();
        let mut b = AvailabilityModel::build(&markov_cfg(), 6, 99).unwrap();
        for c in 0..6 {
            let mut t = 0.0;
            for _ in 0..200 {
                let ta = a.next_transition(c, t).unwrap();
                let tb = b.next_transition(c, t).unwrap();
                assert_eq!(ta, tb, "same seed must give identical schedules");
                assert_eq!(a.is_available(c, ta), b.is_available(c, ta));
                t = ta;
            }
        }
    }

    #[test]
    fn markov_seeds_differ() {
        let mut a = AvailabilityModel::build(&markov_cfg(), 1, 1).unwrap();
        let mut b = AvailabilityModel::build(&markov_cfg(), 1, 2).unwrap();
        assert_ne!(a.next_transition(0, 0.0), b.next_transition(0, 0.0));
    }

    #[test]
    fn markov_query_order_does_not_change_schedule() {
        // Lazy extension must not depend on the interleaving of queries.
        let mut a = AvailabilityModel::build(&markov_cfg(), 2, 5).unwrap();
        let mut b = AvailabilityModel::build(&markov_cfg(), 2, 5).unwrap();
        let far = a.next_transition(0, 50_000.0); // forces a long extension
        let mut t = 0.0;
        let mut last = None;
        while t < 50_000.0 {
            last = b.next_transition(0, t);
            t = last.unwrap();
        }
        assert_eq!(far, last);
    }

    #[test]
    fn markov_dwell_means_within_tolerance() {
        let mut cfg = markov_cfg();
        cfg.mean_online_secs = 500.0;
        cfg.mean_offline_secs = 250.0;
        cfg.dwell_sigma = 0.5;
        let mut m = AvailabilityModel::build(&cfg, 64, 3).unwrap();
        let (mut on_sum, mut on_n, mut off_sum, mut off_n) = (0.0, 0u32, 0.0, 0u32);
        for c in 0..64 {
            let mut t = 0.0;
            for _ in 0..100 {
                let online = m.is_available(c, t);
                let next = m.next_transition(c, t).unwrap();
                // The first dwell is truncated by t=0 only for the initial
                // state draw; we include it anyway — bias is negligible at
                // this sample size because t starts at 0 (no inspection
                // paradox: we take whole dwells, not residuals).
                if online {
                    on_sum += next - t;
                    on_n += 1;
                } else {
                    off_sum += next - t;
                    off_n += 1;
                }
                t = next;
            }
        }
        let on_mean = on_sum / on_n as f64;
        let off_mean = off_sum / off_n as f64;
        assert!(
            (on_mean - 500.0).abs() < 0.1 * 500.0,
            "online dwell mean {on_mean} != 500 +- 10%"
        );
        assert!(
            (off_mean - 250.0).abs() < 0.1 * 250.0,
            "offline dwell mean {off_mean} != 250 +- 10%"
        );
    }

    #[test]
    fn markov_zero_sigma_gives_exact_dwells() {
        let mut cfg = markov_cfg();
        cfg.dwell_sigma = 0.0;
        let mut m = AvailabilityModel::build(&cfg, 1, 11).unwrap();
        let t1 = m.next_transition(0, 0.0).unwrap();
        let t2 = m.next_transition(0, t1).unwrap();
        let d1 = t1;
        let d2 = t2 - t1;
        // Alternating exact dwells of 600 and 300 (order depends on the
        // initial state draw).
        let mut pair = [d1, d2];
        pair.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((pair[0] - 300.0).abs() < 1e-6, "dwells {pair:?}");
        assert!((pair[1] - 600.0).abs() < 1e-6, "dwells {pair:?}");
    }

    fn diurnal_cfg(duty: f64, shards: usize) -> AvailabilityConfig {
        AvailabilityConfig {
            kind: AvailabilityKind::Diurnal,
            diurnal_period_secs: 1000.0,
            diurnal_duty: duty,
            diurnal_shards: shards,
            ..AvailabilityConfig::default()
        }
    }

    #[test]
    fn diurnal_period_correct() {
        let mut m = AvailabilityModel::build(&diurnal_cfg(0.5, 1), 1, 0).unwrap();
        // Transitions alternate on/off boundaries; boundaries of the SAME
        // type are exactly one period apart.
        let t1 = m.next_transition(0, 0.0).unwrap();
        let t2 = m.next_transition(0, t1).unwrap();
        let t3 = m.next_transition(0, t2).unwrap();
        let t4 = m.next_transition(0, t3).unwrap();
        assert!((t3 - t1 - 1000.0).abs() < 1e-6, "period {t1} {t3}");
        assert!((t4 - t2 - 1000.0).abs() < 1e-6, "period {t2} {t4}");
        // Duty 0.5: the online stretch of each period is half of it.
        let online_span = if m.is_available(0, (t1 + t2) / 2.0) {
            t2 - t1
        } else {
            t3 - t2
        };
        assert!((online_span - 500.0).abs() < 1e-6, "duty span {online_span}");
    }

    #[test]
    fn diurnal_duty_sets_online_fraction() {
        for duty in [0.25, 0.5, 0.75] {
            let mut m = AvailabilityModel::build(&diurnal_cfg(duty, 1), 1, 0).unwrap();
            // Integrate over many whole periods: fraction == duty.
            let f = m.online_fraction(0, 100.0 * 1000.0);
            assert!((f - duty).abs() < 1e-6, "duty {duty} got fraction {f}");
        }
    }

    #[test]
    fn diurnal_shards_phase_shift() {
        let mut m = AvailabilityModel::build(&diurnal_cfg(0.5, 4), 8, 0).unwrap();
        // Same shard => identical schedule; different shard => shifted by
        // period * shard_delta / shards.
        let a0 = m.next_transition(0, 0.0).unwrap();
        let a4 = m.next_transition(4, 0.0).unwrap();
        assert_eq!(a0, a4, "clients 0 and 4 share shard 0");
        for t in [0.0, 137.0, 800.0] {
            let s1 = m.is_available(1, t);
            let s0 = m.is_available(0, t + 250.0); // shard 1 leads by P/4
            assert_eq!(s0, s1, "shard phase shift broken at t={t}");
        }
    }

    #[test]
    fn diurnal_full_duty_never_transitions() {
        let mut m = AvailabilityModel::build(&diurnal_cfg(1.0, 3), 3, 0).unwrap();
        for c in 0..3 {
            assert!(m.is_available(c, 123.0));
            assert_eq!(m.next_transition(c, 123.0), None);
        }
    }

    #[test]
    fn trace_semantics() {
        let events = vec![
            TraceEvent { at: 10.0, client: 0, online: false },
            TraceEvent { at: 20.0, client: 0, online: true },
            TraceEvent { at: 5.0, client: 2, online: false },
        ];
        let timelines = AvailabilityModel::timelines_from_trace(&events, 3).unwrap();
        let mut m = AvailabilityModel {
            population: 3,
            kind: ModelKind::Timelines(timelines),
        };
        // Client 0: on until 10, off on [10, 20), on after.
        assert!(m.is_available(0, 0.0));
        assert!(m.is_available(0, 9.999));
        assert!(!m.is_available(0, 10.0));
        assert!(!m.is_available(0, 15.0));
        assert!(m.is_available(0, 20.0));
        assert_eq!(m.next_transition(0, 0.0), Some(10.0));
        assert_eq!(m.next_transition(0, 10.0), Some(20.0));
        assert_eq!(m.next_transition(0, 20.0), None);
        // Client 1: no events => always online.
        assert!(m.is_available(1, 1e9));
        assert_eq!(m.next_transition(1, 0.0), None);
        // Client 2: off forever after 5.
        assert!(!m.is_available(2, 6.0));
        assert_eq!(m.next_transition(2, 5.0), None);
        // Online fraction of client 0 over [0, 40]: 30/40.
        assert!((m.online_fraction(0, 40.0) - 0.75).abs() < 1e-12);
        // Redundant restatements are ignored.
        let noisy = vec![
            TraceEvent { at: 1.0, client: 0, online: true }, // already online
            TraceEvent { at: 2.0, client: 0, online: false },
            TraceEvent { at: 3.0, client: 0, online: false }, // restated
        ];
        let tl = AvailabilityModel::timelines_from_trace(&noisy, 1).unwrap();
        let mut m2 = AvailabilityModel {
            population: 1,
            kind: ModelKind::Timelines(tl),
        };
        assert!(m2.is_available(0, 1.5));
        assert!(!m2.is_available(0, 2.5));
        assert!(!m2.is_available(0, 3.5));
    }

    #[test]
    fn trace_rejects_out_of_range_client() {
        let events = vec![TraceEvent { at: 1.0, client: 9, online: false }];
        assert!(AvailabilityModel::timelines_from_trace(&events, 3).is_err());
    }

    #[test]
    fn online_through_detects_mid_window_dropout() {
        let events = vec![
            TraceEvent { at: 50.0, client: 0, online: false },
            TraceEvent { at: 60.0, client: 0, online: true },
        ];
        let tl = AvailabilityModel::timelines_from_trace(&events, 1).unwrap();
        let mut m = AvailabilityModel {
            population: 1,
            kind: ModelKind::Timelines(tl),
        };
        assert!(m.online_through(0, 0.0, 49.0));
        assert!(m.online_through(0, 0.0, 50.0)); // transition exactly at end
        assert!(!m.online_through(0, 0.0, 51.0));
        assert!(!m.online_through(0, 55.0, 56.0)); // starts offline
        assert!(m.online_through(0, 60.0, 1e9));
    }

    #[test]
    fn config_validation() {
        let mut c = AvailabilityConfig::default();
        c.validate().unwrap();
        c.kind = AvailabilityKind::Trace;
        assert!(c.validate().is_err(), "trace without path must fail");
        c.trace_path = Some("x.jsonl".into());
        c.validate().unwrap();
        c.diurnal_duty = 0.0;
        assert!(c.validate().is_err());
        c.diurnal_duty = 0.5;
        c.mean_online_secs = -1.0;
        assert!(c.validate().is_err());
        c.mean_online_secs = 3600.0;
        c.regions = 0;
        assert!(c.validate().is_err(), "zero regions must fail");
        c.regions = 4;
        c.region_mtbf_secs = 0.0;
        assert!(c.validate().is_err());
        c.region_mtbf_secs = 7200.0;
        c.degrade_floor = 0.0;
        assert!(c.validate().is_err(), "degrade floor must be positive");
        c.degrade_floor = 1.5;
        assert!(c.validate().is_err(), "degrade floor must be <= 1");
        c.degrade_floor = 0.25;
        c.degrade_window_secs = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            AvailabilityKind::AlwaysOn,
            AvailabilityKind::Markov,
            AvailabilityKind::Diurnal,
            AvailabilityKind::Trace,
            AvailabilityKind::Correlated,
        ] {
            assert_eq!(AvailabilityKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(
            AvailabilityKind::parse("regional").unwrap(),
            AvailabilityKind::Correlated
        );
        assert!(AvailabilityKind::parse("sometimes").is_err());
    }

    #[test]
    fn survival_prob_always_on_is_one() {
        let mut m = AvailabilityModel::always_on(3);
        for c in 0..3 {
            assert_eq!(m.survival_prob(c, 0.0, 1e9), 1.0);
            assert_eq!(m.bandwidth_factor(c, 123.0), 1.0);
        }
    }

    #[test]
    fn survival_prob_markov_is_a_probability_and_decreases() {
        let mut m = AvailabilityModel::build(&markov_cfg(), 8, 21).unwrap();
        for c in 0..8 {
            let online = m.is_available(c, 0.0);
            let s = m.survival_prob(c, 0.0, 100.0);
            if !online {
                assert_eq!(s, 0.0, "offline client must have zero survival");
                continue;
            }
            assert!(s > 0.0 && s <= 1.0, "survival {s} out of range");
            // Zero horizon is a sure thing; longer horizons never help.
            assert_eq!(m.survival_prob(c, 0.0, 0.0), 1.0);
            let mut prev = 1.0;
            for h in [10.0, 100.0, 1000.0, 10_000.0] {
                let s = m.survival_prob(c, 0.0, h);
                assert!(s <= prev + 1e-12, "survival must decrease in horizon");
                prev = s;
            }
        }
    }

    #[test]
    fn survival_prob_markov_is_not_an_oracle() {
        // The analytic estimate must be strictly interior for a stochastic
        // dwell at a modest horizon — 0/1 answers here would mean we peeked
        // at the realized schedule.
        let mut m = AvailabilityModel::build(&markov_cfg(), 16, 5).unwrap();
        let interior = (0..16)
            .filter(|&c| m.is_available(c, 0.0))
            .map(|c| m.survival_prob(c, 0.0, 300.0))
            .filter(|&s| s > 0.0 && s < 1.0)
            .count();
        assert!(interior > 0, "markov survival collapsed to 0/1 everywhere");
    }

    #[test]
    fn survival_prob_diurnal_and_trace_are_exact() {
        let mut d = AvailabilityModel::build(&diurnal_cfg(0.5, 1), 1, 0).unwrap();
        let t1 = d.next_transition(0, 0.0).unwrap();
        let online = d.is_available(0, 0.0);
        // Whole horizon inside the current arc: survival matches the state.
        let expect = if online { 1.0 } else { 0.0 };
        assert_eq!(d.survival_prob(0, 0.0, (t1 - 0.0) / 2.0), expect);
        // Horizon crossing the boundary: an online client surely flips.
        if online {
            assert_eq!(d.survival_prob(0, 0.0, t1 + 1.0), 0.0);
        }

        let events = vec![TraceEvent { at: 50.0, client: 0, online: false }];
        let tl = AvailabilityModel::timelines_from_trace(&events, 1).unwrap();
        let mut m = AvailabilityModel {
            population: 1,
            kind: ModelKind::Timelines(tl),
        };
        assert_eq!(m.survival_prob(0, 0.0, 40.0), 1.0);
        assert_eq!(m.survival_prob(0, 0.0, 60.0), 0.0);
        assert_eq!(m.survival_prob(0, 60.0, 1e9), 0.0, "offline forever");
    }
}
