//! Correlated churn: region-sharded clients flipped together by a seeded
//! regional outage process, layered over per-client Markov dwells, with
//! bandwidth degrading before the drop.
//!
//! Production fleets do not churn independently (Papaya, Huba et al. 2022):
//! a cell tower hiccup, an ISP maintenance window, or an evening power cut
//! takes a whole *region* of devices down at once, and connectivity
//! usually degrades before it dies. Two layers compose per client:
//!
//! - **Region layer** — client `c` sits in region `c % regions`; each
//!   region runs a seeded up/down alternating renewal process (log-normal
//!   dwells with means `region_mtbf_secs` / `region_outage_secs`). When a
//!   region goes down, every client in it is offline, simultaneously.
//! - **Personal layer** — an independent per-client Markov on/off process
//!   (the PR-1 machinery, same `mean_online_secs` / `mean_offline_secs` /
//!   `dwell_sigma` calibration) modelling individual behaviour inside an
//!   up region.
//!
//! A client is online iff its region is up AND its personal state is on,
//! so the marginal online fraction is (region uptime) × (personal Markov
//! steady state) — the property suite in
//! `rust/tests/correlated_churn_properties.rs` locks exactly that.
//!
//! **Degrade-before-drop coupling**: inside the last `degrade_window_secs`
//! before a region's next outage, every client in that region sees its
//! effective throughput scaled by a factor that ramps linearly from 1.0
//! down to `degrade_floor` at the outage edge
//! ([`CorrelatedModel::bandwidth_factor`]; the coordinator divides upload
//! times by it). The
//! factor is monotone non-increasing as the outage approaches and exactly
//! 1.0 outside the window, so uncoupled configurations are bit-identical.
//! The same ramp is the backing signal of the shared
//! [`super::BandwidthSignal`] trait the network subsystem reads — priced
//! model dissemination and TimelyFL's bandwidth-aware rebalancing consume
//! it without touching this module (`crate::network`).

use crate::simtime::SimTime;
use crate::util::rng::Rng;

use super::process::{AvailabilityConfig, MarkovGen, Timeline};

/// Stream-id offset separating region forks from client forks of the
/// availability master RNG (regions and clients must never share streams,
/// whatever the population size).
const REGION_STREAM_SALT: u64 = 0x5E61_0000_0000_0000;

/// The composed two-layer process (wrapped by `AvailabilityModel`; tests
/// build it directly to reach the per-layer queries).
pub struct CorrelatedModel {
    /// Per-region up/down timelines ("online" = region up).
    region_tl: Vec<Timeline>,
    /// Per-client personal Markov timelines.
    client_tl: Vec<Timeline>,
    regions: usize,
    degrade_window: f64,
    degrade_floor: f64,
}

impl CorrelatedModel {
    /// Deterministic in `seed` (already salted with
    /// [`super::SEED_SALT`] by the caller). Region streams fork first, in
    /// region order, then client streams in client order, so schedules are
    /// stable under population growth of a fixed region count.
    pub fn build(cfg: &AvailabilityConfig, population: usize, seed: u64) -> CorrelatedModel {
        let mut master = Rng::seed_from(seed);
        let region_p_up =
            cfg.region_mtbf_secs / (cfg.region_mtbf_secs + cfg.region_outage_secs);
        let region_tl = (0..cfg.regions)
            .map(|r| {
                let mut rng = master.fork(REGION_STREAM_SALT | r as u64);
                let initially_up = rng.f64() < region_p_up;
                Timeline::markov(
                    initially_up,
                    MarkovGen::with_means(
                        rng,
                        cfg.region_mtbf_secs,
                        cfg.region_outage_secs,
                        cfg.dwell_sigma,
                    ),
                )
            })
            .collect();
        let personal_p_on = cfg.markov_steady_state();
        let client_tl = (0..population)
            .map(|c| {
                let mut rng = master.fork(c as u64);
                let initially_on = rng.f64() < personal_p_on;
                Timeline::markov(
                    initially_on,
                    MarkovGen::with_means(
                        rng,
                        cfg.mean_online_secs,
                        cfg.mean_offline_secs,
                        cfg.dwell_sigma,
                    ),
                )
            })
            .collect();
        CorrelatedModel {
            region_tl,
            client_tl,
            regions: cfg.regions,
            degrade_window: cfg.degrade_window_secs,
            degrade_floor: cfg.degrade_floor,
        }
    }

    /// Which region `client` belongs to.
    pub fn region_of(&self, client: usize) -> usize {
        client % self.regions
    }

    /// Is `region` up at `t`?
    pub fn region_up(&mut self, region: usize, t: SimTime) -> bool {
        self.region_tl[region].state_at(t)
    }

    /// The region's outage windows `[start, end)` intersecting
    /// `[0, horizon]`, in order (an outage still open at the horizon is
    /// truncated to it). Test surface for the flip-together property.
    pub fn outage_windows(&mut self, region: usize, horizon: f64) -> Vec<(f64, f64)> {
        let tl = &mut self.region_tl[region];
        let mut windows = Vec::new();
        let mut cur = 0.0;
        let mut up = tl.state_at(0.0);
        if !up {
            // Outage already open at t = 0.
            let end = tl.next_after(0.0).map_or(horizon, |t| t.min(horizon));
            windows.push((0.0, end));
        }
        while cur < horizon {
            let Some(next) = tl.next_after(cur) else { break };
            if next >= horizon {
                break;
            }
            up = !up;
            if !up {
                let end = tl.next_after(next).map_or(horizon, |t| t.min(horizon));
                windows.push((next, end));
            }
            cur = next;
        }
        windows
    }

    pub fn is_available(&mut self, client: usize, t: SimTime) -> bool {
        let r = self.region_of(client);
        self.region_tl[r].state_at(t) && self.client_tl[client].state_at(t)
    }

    /// First flip of the COMPOSED state strictly after `t`: walk the
    /// merged region/personal transition stream until the AND of the two
    /// layers changes (personal flips during an outage, and region flips
    /// while the personal layer is off, don't change the composite).
    pub fn next_transition(&mut self, client: usize, t: SimTime) -> Option<SimTime> {
        let r = self.region_of(client);
        let cur = self.is_available(client, t);
        let mut s = t;
        loop {
            let rn = self.region_tl[r].next_after(s);
            let cn = self.client_tl[client].next_after(s);
            let next = match (rn, cn) {
                (Some(a), Some(b)) => a.min(b),
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => return None,
            };
            if self.is_available(client, next) != cur {
                return Some(next);
            }
            s = next;
        }
    }

    /// Survival through `[now, now + horizon]`: both layers must hold, and
    /// they are independent by construction, so the probabilities multiply
    /// (each layer's estimate is the analytic residual-dwell survival —
    /// see `Timeline::survival_prob`).
    pub fn survival_prob(&mut self, client: usize, now: SimTime, horizon: f64) -> f64 {
        let r = self.region_of(client);
        self.region_tl[r].survival_prob(now, horizon)
            * self.client_tl[client].survival_prob(now, horizon)
    }

    /// Degrade-before-drop: effective-throughput multiplier in
    /// `[degrade_floor, 1.0]`. Ramps linearly from 1.0 at
    /// `degrade_window` seconds before the region's next outage down to
    /// the floor at the outage edge; 1.0 outside the window or when the
    /// coupling is disabled (`degrade_window == 0`). During an outage the
    /// client is offline anyway; the floor is reported for consistency.
    pub fn bandwidth_factor(&mut self, client: usize, t: SimTime) -> f64 {
        if self.degrade_window <= 0.0 {
            return 1.0;
        }
        let r = self.region_of(client);
        if !self.region_tl[r].state_at(t) {
            return self.degrade_floor;
        }
        let Some(outage_at) = self.region_tl[r].next_after(t) else {
            return 1.0;
        };
        let remaining = outage_at - t;
        if remaining >= self.degrade_window {
            1.0
        } else {
            self.degrade_floor
                + (1.0 - self.degrade_floor) * (remaining / self.degrade_window).clamp(0.0, 1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::process::AvailabilityKind;
    use super::*;

    fn cfg() -> AvailabilityConfig {
        AvailabilityConfig {
            kind: AvailabilityKind::Correlated,
            mean_online_secs: 1200.0,
            mean_offline_secs: 400.0,
            dwell_sigma: 0.4,
            regions: 3,
            region_mtbf_secs: 2000.0,
            region_outage_secs: 500.0,
            degrade_window_secs: 300.0,
            degrade_floor: 0.25,
            ..AvailabilityConfig::default()
        }
    }

    #[test]
    fn regions_shard_by_modulo() {
        let m = CorrelatedModel::build(&cfg(), 9, 1);
        for c in 0..9 {
            assert_eq!(m.region_of(c), c % 3);
        }
    }

    #[test]
    fn outage_takes_down_every_client_in_the_region() {
        let mut m = CorrelatedModel::build(&cfg(), 12, 7);
        let horizon = 40_000.0;
        for r in 0..3 {
            let windows = m.outage_windows(r, horizon);
            assert!(!windows.is_empty(), "region {r} never failed in {horizon}s");
            for &(start, end) in &windows {
                assert!(end > start, "degenerate outage window");
                let mid = (start + end) / 2.0;
                for c in (0..12).filter(|&c| c % 3 == r) {
                    assert!(
                        !m.is_available(c, mid),
                        "client {c} online during region {r} outage at {mid}"
                    );
                }
            }
        }
    }

    #[test]
    fn composite_transitions_flip_the_composite_state() {
        let mut m = CorrelatedModel::build(&cfg(), 6, 3);
        for c in 0..6 {
            let mut t = 0.0;
            let mut state = m.is_available(c, t);
            for _ in 0..40 {
                let next = m.next_transition(c, t).expect("both layers keep flipping");
                assert!(next > t);
                // The composite state is constant until the transition...
                assert_eq!(m.is_available(c, (t + next) / 2.0), state);
                // ...and actually changes at it.
                let after = m.is_available(c, next);
                assert_ne!(after, state, "reported transition changed nothing");
                t = next;
                state = after;
            }
        }
    }

    #[test]
    fn determinism_and_seed_sensitivity() {
        let mut a = CorrelatedModel::build(&cfg(), 6, 42);
        let mut b = CorrelatedModel::build(&cfg(), 6, 42);
        for c in 0..6 {
            let mut t = 0.0;
            for _ in 0..50 {
                let ta = a.next_transition(c, t).unwrap();
                let tb = b.next_transition(c, t).unwrap();
                assert_eq!(ta, tb, "same seed must give identical schedules");
                t = ta;
            }
        }
        let mut c2 = CorrelatedModel::build(&cfg(), 6, 43);
        assert_ne!(
            a.next_transition(0, 0.0),
            c2.next_transition(0, 0.0),
            "different seeds must differ"
        );
    }

    #[test]
    fn bandwidth_degrades_monotonically_into_the_outage() {
        let mut m = CorrelatedModel::build(&cfg(), 3, 11);
        let windows = m.outage_windows(0, 200_000.0);
        // Pick an outage whose preceding up-gap covers the whole approach,
        // so the region is up throughout the ramp we sample.
        let start = windows
            .windows(2)
            .find(|w| w[1].0 - w[0].1 > 400.0)
            .map(|w| w[1].0)
            .expect("an outage preceded by a long-enough up dwell");
        // Approach the outage from one window out: the factor starts at
        // exactly 1.0 and never increases on the way in.
        let mut prev = f64::INFINITY;
        for i in 0..=20 {
            let t = start - 300.0 + i as f64 * (300.0 / 20.0) - 1e-6;
            let f = m.bandwidth_factor(0, t);
            assert!((0.25..=1.0).contains(&f), "factor {f} out of range");
            assert!(f <= prev + 1e-12, "factor must not recover approaching an outage");
            prev = f;
        }
        assert_eq!(m.bandwidth_factor(0, start - 301.0), 1.0, "outside the window");
        assert!(m.bandwidth_factor(0, start - 1.0) < 0.3, "near the edge -> near floor");
    }

    #[test]
    fn zero_window_disables_the_coupling() {
        let mut c = cfg();
        c.degrade_window_secs = 0.0;
        let mut m = CorrelatedModel::build(&c, 3, 11);
        for t in [0.0, 500.0, 5000.0, 50_000.0] {
            assert_eq!(m.bandwidth_factor(0, t), 1.0);
        }
    }

    #[test]
    fn survival_multiplies_the_layers() {
        let mut m = CorrelatedModel::build(&cfg(), 6, 9);
        for c in 0..6 {
            let s = m.survival_prob(c, 0.0, 200.0);
            assert!((0.0..=1.0).contains(&s));
            if !m.is_available(c, 0.0) {
                assert_eq!(s, 0.0, "offline composite must have zero survival");
            } else {
                // Composite survival can never beat either layer alone.
                let r = m.region_of(c);
                let region_s = m.region_tl[r].survival_prob(0.0, 200.0);
                let personal_s = m.client_tl[c].survival_prob(0.0, 200.0);
                assert!(s <= region_s + 1e-12 && s <= personal_s + 1e-12);
            }
        }
    }
}
