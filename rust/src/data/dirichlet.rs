//! Dirichlet non-iid partitioner.
//!
//! Following FedBuff/FedML practice (and the paper's CIFAR-10 setup,
//! Dirichlet alpha = 0.1 over 128 clusters), each client's label
//! distribution is an independent draw p_c ~ Dirichlet(alpha * 1_K). Small
//! alpha concentrates each client on few classes (highly non-iid); large
//! alpha approaches iid.

use crate::util::rng::Rng;

/// Per-client class distributions: `n_clients` rows, each a length-`classes`
/// probability vector.
pub fn client_class_distributions(
    n_clients: usize,
    classes: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<f64>> {
    assert!(alpha > 0.0, "dirichlet alpha must be positive");
    (0..n_clients).map(|_| rng.dirichlet(alpha, classes)).collect()
}

/// Measure of non-iid-ness actually achieved: mean total-variation distance
/// between client distributions and uniform. 0 = iid, -> (K-1)/K as alpha->0.
pub fn mean_tv_from_uniform(dists: &[Vec<f64>]) -> f64 {
    if dists.is_empty() {
        return 0.0;
    }
    let k = dists[0].len() as f64;
    let uniform = 1.0 / k;
    let tv: f64 = dists
        .iter()
        .map(|p| 0.5 * p.iter().map(|&x| (x - uniform).abs()).sum::<f64>())
        .sum();
    tv / dists.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_distributions() {
        let mut rng = Rng::seed_from(31);
        let d = client_class_distributions(64, 10, 0.1, &mut rng);
        assert_eq!(d.len(), 64);
        for row in &d {
            assert_eq!(row.len(), 10);
            assert!((row.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn alpha_controls_noniidness() {
        let mut rng = Rng::seed_from(32);
        let skewed = client_class_distributions(200, 10, 0.1, &mut rng);
        let near_iid = client_class_distributions(200, 10, 100.0, &mut rng);
        let tv_skewed = mean_tv_from_uniform(&skewed);
        let tv_iid = mean_tv_from_uniform(&near_iid);
        assert!(
            tv_skewed > 3.0 * tv_iid,
            "alpha=0.1 tv {tv_skewed} vs alpha=100 tv {tv_iid}"
        );
        assert!(tv_skewed > 0.5);
        assert!(tv_iid < 0.15);
    }
}
