//! Synthetic federated datasets + the Dirichlet non-iid partitioner.
//!
//! The sandbox has no network access, so CIFAR-10 / Google Speech / Reddit
//! are substituted by deterministic synthetic sources that keep the
//! learning dynamics the paper's tables measure (accuracy rises with
//! training; non-iid partitioning slows convergence; LM perplexity falls).
//! See DESIGN.md §3 for the substitution argument.

pub mod dirichlet;
pub mod synthetic;

pub use dirichlet::client_class_distributions;
pub use synthetic::{ClientData, FederatedDataset, SyntheticSpec};
