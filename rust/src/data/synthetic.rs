//! Deterministic synthetic data sources for the three paper workloads.
//!
//! - **classify** (vision / speech / kws): class c has a fixed Gaussian
//!   template t_c; a sample is `scale * t_c + noise`. Labels are drawn from
//!   the client's Dirichlet class distribution (non-iid knob = alpha).
//! - **lm** (text): a near-deterministic Markov source — the next token is
//!   `perm[tok]` with probability `1 - noise` else uniform — whose entropy
//!   floor gives an achievable perplexity of a few, from an untrained
//!   perplexity of |vocab|. Client non-iid-ness skews which region of token
//!   space a client's sequences start in.
//!
//! Everything derives from `dataset_seed`: two runs with the same seed see
//! bit-identical data, on any thread, in any order (generation is
//! counter-based, not stream-based).

use crate::runtime::manifest::{ModelMeta, Task};
use crate::util::rng::Rng;

use super::dirichlet::client_class_distributions;
use crate::runtime::engine::Batch;

/// Tuning knobs of the synthetic source.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub dataset_seed: u64,
    /// Dirichlet alpha for client label skew (paper uses 0.1 for CIFAR-10).
    pub alpha: f64,
    /// classify: template amplitude relative to unit noise. Controls task
    /// difficulty (smaller = harder).
    pub template_scale: f32,
    /// lm: probability the Markov source emits a *random* (unpredictable)
    /// token instead of the deterministic successor.
    pub lm_noise: f64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            dataset_seed: 1234,
            alpha: 0.1,
            template_scale: 0.12,
            lm_noise: 0.1,
        }
    }
}

/// Per-client view handed to the trainer.
#[derive(Clone, Debug)]
pub struct ClientData {
    pub client_id: usize,
    /// Class distribution (classify) or start-bucket distribution (lm).
    pub class_dist: Vec<f64>,
}

/// A fully-specified federated dataset for one model of the zoo.
pub struct FederatedDataset {
    pub spec: SyntheticSpec,
    pub task: Task,
    pub classes: usize,
    pub x_len: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub clients: Vec<ClientData>,
    /// classify: one template per class (classes x x_len).
    templates: Vec<Vec<f32>>,
    /// lm: successor permutation over the vocab.
    perm: Vec<u32>,
}

impl FederatedDataset {
    pub fn new(spec: SyntheticSpec, meta: &ModelMeta, n_clients: usize) -> FederatedDataset {
        let mut rng = Rng::seed_from(spec.dataset_seed);
        let classes = meta.num_classes;
        // For LMs the Dirichlet skew acts over coarse "start buckets" of
        // token space rather than the full vocab.
        let dist_dims = match meta.task {
            Task::Classify => classes,
            Task::Lm => 64.min(classes),
        };
        let dists = client_class_distributions(n_clients, dist_dims, spec.alpha, &mut rng);
        let clients = dists
            .into_iter()
            .enumerate()
            .map(|(client_id, class_dist)| ClientData {
                client_id,
                class_dist,
            })
            .collect();

        let (templates, perm) = match meta.task {
            Task::Classify => {
                let mut t = Vec::with_capacity(classes);
                for c in 0..classes {
                    let mut trng = Rng::seed_from(
                        spec.dataset_seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    t.push((0..meta.x_len()).map(|_| trng.normal() as f32).collect());
                }
                (t, Vec::new())
            }
            Task::Lm => {
                let mut perm: Vec<u32> = (0..classes as u32).collect();
                rng.shuffle(&mut perm);
                (Vec::new(), perm)
            }
        };

        FederatedDataset {
            spec,
            task: meta.task,
            classes,
            x_len: meta.x_len(),
            seq_len: meta.seq_len,
            batch: meta.batch,
            eval_batch: meta.eval_batch,
            clients,
            templates,
            perm,
        }
    }

    pub fn n_clients(&self) -> usize {
        self.clients.len()
    }

    /// One training minibatch for `client`. `rng` is the caller's stream
    /// (per-client, seeded by the coordinator) so data order is
    /// reproducible per run.
    pub fn train_batch(&self, client: usize, rng: &mut Rng) -> Batch {
        let dist = &self.clients[client].class_dist;
        self.sample_batch(self.batch, rng, Some(dist))
    }

    /// Balanced, held-out eval batches (shared by all strategies).
    pub fn eval_batches(&self, n_batches: usize, seed: u64) -> Vec<Batch> {
        let mut rng = Rng::seed_from(self.spec.dataset_seed ^ 0xEA55_EA55 ^ seed);
        (0..n_batches)
            .map(|_| self.sample_batch(self.eval_batch, &mut rng, None))
            .collect()
    }

    fn sample_batch(&self, size: usize, rng: &mut Rng, dist: Option<&[f64]>) -> Batch {
        match self.task {
            Task::Classify => {
                let mut x = Vec::with_capacity(size * self.x_len);
                let mut y = Vec::with_capacity(size);
                for i in 0..size {
                    let c = match dist {
                        Some(d) => rng.categorical(d),
                        None => i % self.classes, // balanced eval
                    };
                    y.push(c as i32);
                    let t = &self.templates[c];
                    let s = self.spec.template_scale;
                    for &tv in t {
                        x.push(s * tv + rng.normal() as f32);
                    }
                }
                Batch::F32 { x, y }
            }
            Task::Lm => {
                let mut x = Vec::with_capacity(size * self.seq_len);
                let mut y = Vec::with_capacity(size * self.seq_len);
                let bucket_width = (self.classes / 64.max(1)).max(1);
                for _ in 0..size {
                    let start = match dist {
                        Some(d) => {
                            let bucket = rng.categorical(d);
                            (bucket * bucket_width + rng.usize_below(bucket_width))
                                .min(self.classes - 1)
                        }
                        None => rng.usize_below(self.classes),
                    };
                    let mut tok = start as u32;
                    for _ in 0..self.seq_len {
                        x.push(tok as i32);
                        let next = if rng.f64() < self.spec.lm_noise {
                            rng.below(self.classes as u64) as u32
                        } else {
                            self.perm[tok as usize]
                        };
                        y.push(next as i32);
                        tok = next;
                    }
                }
                Batch::I32 { x, y }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ParamMeta, XDtype};

    fn classify_meta() -> ModelMeta {
        ModelMeta {
            name: "toy".into(),
            task: Task::Classify,
            batch: 8,
            eval_batch: 16,
            x_shape: vec![12],
            x_dtype: XDtype::F32,
            num_classes: 4,
            seq_len: 0,
            total_params: 1,
            chunk: 8,
            lanes: 0,
            params: vec![ParamMeta {
                name: "w".into(),
                shape: vec![1],
                size: 1,
            }],
            ratios: vec![],
            eval_artifact: String::new(),
            init_artifact: String::new(),
        }
    }

    fn lm_meta() -> ModelMeta {
        ModelMeta {
            task: Task::Lm,
            num_classes: 128,
            seq_len: 8,
            x_shape: vec![8],
            x_dtype: XDtype::I32,
            ..classify_meta()
        }
    }

    #[test]
    fn batches_have_correct_shapes() {
        let ds = FederatedDataset::new(SyntheticSpec::default(), &classify_meta(), 5);
        let mut rng = Rng::seed_from(1);
        match ds.train_batch(2, &mut rng) {
            Batch::F32 { x, y } => {
                assert_eq!(x.len(), 8 * 12);
                assert_eq!(y.len(), 8);
                assert!(y.iter().all(|&c| (0..4).contains(&c)));
            }
            _ => panic!("expected f32 batch"),
        }
    }

    #[test]
    fn labels_follow_client_skew() {
        let spec = SyntheticSpec {
            alpha: 0.05,
            ..Default::default()
        };
        let ds = FederatedDataset::new(spec, &classify_meta(), 3);
        // With alpha=0.05 a client's mode class should dominate its batches.
        let dist = &ds.clients[0].class_dist;
        let mode = dist
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        let mut rng = Rng::seed_from(2);
        let mut mode_count = 0;
        let mut total = 0;
        for _ in 0..50 {
            if let Batch::F32 { y, .. } = ds.train_batch(0, &mut rng) {
                mode_count += y.iter().filter(|&&c| c == mode).count();
                total += y.len();
            }
        }
        assert!(
            mode_count as f64 / total as f64 > dist[mode as usize] * 0.7,
            "skew not reflected"
        );
    }

    #[test]
    fn eval_batches_are_balanced_and_deterministic() {
        let ds = FederatedDataset::new(SyntheticSpec::default(), &classify_meta(), 2);
        let a = ds.eval_batches(3, 0);
        let b = ds.eval_batches(3, 0);
        for (ba, bb) in a.iter().zip(&b) {
            match (ba, bb) {
                (Batch::F32 { x: xa, y: ya }, Batch::F32 { x: xb, y: yb }) => {
                    assert_eq!(xa, xb);
                    assert_eq!(ya, yb);
                    // balanced: each class appears eval_batch/classes times
                    let mut counts = [0; 4];
                    for &c in ya {
                        counts[c as usize] += 1;
                    }
                    assert!(counts.iter().all(|&c| c == 4));
                }
                _ => panic!("expected f32"),
            }
        }
    }

    #[test]
    fn lm_stream_is_mostly_deterministic() {
        let ds = FederatedDataset::new(SyntheticSpec::default(), &lm_meta(), 2);
        let mut rng = Rng::seed_from(3);
        if let Batch::I32 { x, y } = ds.train_batch(0, &mut rng) {
            assert_eq!(x.len(), 8 * 8);
            // count transitions matching the permutation
            let matches = x
                .iter()
                .zip(y.iter())
                .filter(|&(&xt, &yt)| ds.perm[xt as usize] == yt as u32)
                .count();
            let frac = matches as f64 / x.len() as f64;
            assert!(frac > 0.75, "deterministic fraction {frac}");
        } else {
            panic!("expected i32 batch");
        }
    }

    #[test]
    fn different_classes_have_distinct_templates() {
        let ds = FederatedDataset::new(SyntheticSpec::default(), &classify_meta(), 1);
        let d01: f32 = ds.templates[0]
            .iter()
            .zip(&ds.templates[1])
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        assert!(d01 > 1.0, "templates too similar");
    }
}
