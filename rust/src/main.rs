//! `timelyfl` CLI — launcher for simulated federated-learning runs.
//!
//! ```text
//! timelyfl run        --preset cifar_fedavg [--strategy NAME] [--sampler NAME] [--set k=v ...]
//!                     [--events FILE]                # JSONL run-event stream
//!                     [--eager-train]                # A/B: train at dispatch, not at finish
//! timelyfl compare    --preset cifar_fedavg [--set k=v ...]  # every registered strategy
//! timelyfl sweep      --scenario NAME [--axis k=v1,v2]... [--seeds N] [--jobs J]
//!                     [--out FILE]                   # machine-readable sweep manifest
//!                     [--events DIR]                 # per-run JSONL event streams
//!                     [--warm-ledger]                # carry one drop ledger across cells
//! timelyfl report     MANIFEST.jsonl [--csv] [--out FILE]
//!                                                     # render a sweep manifest as a markdown/CSV table
//! timelyfl strategies                                 # dump the strategy registry
//! timelyfl samplers                                   # dump the sampler registry
//! timelyfl weighers                                   # dump the aggregation-weigher registry
//! timelyfl networks                                   # dump the network-model registry
//! timelyfl scenarios                                  # dump the scenario registry
//! timelyfl presets                                    # dump the paper presets
//! timelyfl trace record [--set avail_*=..] [--horizon SECS] [--out FILE]
//!                                                     # dump the availability schedule as a JSONL trace
//! timelyfl inspect    [--artifacts DIR]               # manifest dump
//! ```
//!
//! Strategies resolve through `coordinator::registry`, scenarios through
//! `experiment::scenario` — both accept any registered name or alias.
//! `sweep` expands `--axis` flags into a cross-product grid (every value
//! goes through the `config::parse` validation of a `--set` flag), runs
//! the cell × seed matrix thread-parallel, and prints one summary row per
//! cell; output is byte-identical for every `--jobs` value. Unknown
//! subcommands exit non-zero (shell pipelines depend on it).
//!
//! (Hand-rolled arg parsing: clap is not in the offline vendor set.)

use std::io::Write as _;

use anyhow::{Context, Result};

use timelyfl::availability::{write_trace, AvailabilityModel, TraceEvent, SEED_SALT};
use timelyfl::config::{self, parse as cfgparse, RunConfig};
use timelyfl::coordinator::{registry, sampler, Simulation};
use timelyfl::experiment::{scenario, summary, ExperimentRunner, MeanStd, SweepGrid};
use timelyfl::metrics::events::JsonlSink;
use timelyfl::metrics::report::{fmt_hours, fmt_speedup, participation_table, Table};
use timelyfl::metrics::RunReport;
use timelyfl::network;
use timelyfl::scheduling;
use timelyfl::runtime::{Manifest, Task};
use timelyfl::simtime::hours;

struct Args {
    command: String,
    /// First bare word after the command (e.g. `trace record`).
    subcommand: Option<String>,
    preset: Option<String>,
    strategy: Option<String>,
    /// `--sampler NAME`: client-sampling policy (registry-resolved).
    sampler: Option<String>,
    config_file: Option<String>,
    sets: Vec<String>,
    artifacts: String,
    out: Option<String>,
    target: Option<f64>,
    events: Option<String>,
    horizon: Option<f64>,
    /// `--eager-train`: disable deferred dispatch execution (A/B hatch).
    eager_train: bool,
    /// `--scenario NAME`: base the config on a registered scenario.
    scenario: Option<String>,
    /// `--axis key=v1,v2,...` (repeatable): sweep-grid axes, in order.
    axes: Vec<String>,
    /// `--seeds N`: replicates per sweep cell.
    seeds: Option<usize>,
    /// `--jobs J`: sweep worker threads (default: available parallelism,
    /// capped at 4 — each worker owns a PJRT client).
    jobs: Option<usize>,
    /// `--warm-ledger`: carry one drop ledger across the sweep's cells
    /// (per-cell barrier; parallel within a cell, byte-identical for any
    /// `--jobs`).
    warm_ledger: bool,
    /// `--csv`: `report` emits CSV instead of a markdown table.
    csv: bool,
}

fn parse_args() -> Result<Args> {
    let mut args = Args {
        command: String::new(),
        subcommand: None,
        preset: None,
        strategy: None,
        sampler: None,
        config_file: None,
        sets: Vec::new(),
        artifacts: "artifacts".into(),
        out: None,
        target: None,
        events: None,
        horizon: None,
        eager_train: false,
        scenario: None,
        axes: Vec::new(),
        seeds: None,
        jobs: None,
        warm_ledger: false,
        csv: false,
    };
    let mut it = std::env::args().skip(1);
    args.command = it.next().unwrap_or_else(|| "help".into());
    while let Some(a) = it.next() {
        let mut need = |name: &str| -> Result<String> {
            it.next().with_context(|| format!("{name} needs a value"))
        };
        match a.as_str() {
            "--preset" => args.preset = Some(need("--preset")?),
            "--strategy" => args.strategy = Some(need("--strategy")?),
            "--sampler" => args.sampler = Some(need("--sampler")?),
            "--config" => args.config_file = Some(need("--config")?),
            "--set" => args.sets.push(need("--set")?),
            "--artifacts" => args.artifacts = need("--artifacts")?,
            "--out" => args.out = Some(need("--out")?),
            "--target" => args.target = Some(need("--target")?.parse()?),
            "--events" => args.events = Some(need("--events")?),
            "--horizon" => args.horizon = Some(need("--horizon")?.parse()?),
            "--eager-train" => args.eager_train = true,
            "--scenario" => args.scenario = Some(need("--scenario")?),
            "--axis" => args.axes.push(need("--axis")?),
            "--seeds" => args.seeds = Some(need("--seeds")?.parse()?),
            "--jobs" => args.jobs = Some(need("--jobs")?.parse()?),
            "--warm-ledger" => args.warm_ledger = true,
            "--csv" => args.csv = true,
            "--help" | "-h" => {
                args.command = "help".into();
            }
            other if !other.starts_with('-') && args.subcommand.is_none() => {
                args.subcommand = Some(other.to_string());
            }
            other => anyhow::bail!("unknown flag {other:?}"),
        }
    }
    Ok(args)
}

fn build_config(args: &Args) -> Result<RunConfig> {
    anyhow::ensure!(
        args.scenario.is_none() || args.preset.is_none(),
        "--scenario and --preset are mutually exclusive (a scenario already names its preset)"
    );
    let mut cfg = match (&args.scenario, &args.preset) {
        (Some(s), _) => scenario::resolve(s)?.config()?,
        (None, Some(p)) => RunConfig::preset(p)?,
        (None, None) => RunConfig::default(),
    };
    if let Some(path) = &args.config_file {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        cfgparse::apply_file(&mut cfg, &text)?;
    }
    for kv in &args.sets {
        cfgparse::apply_cli(&mut cfg, kv)?;
    }
    if let Some(s) = &args.strategy {
        cfg.strategy = registry::resolve(s)?.name.to_string();
    }
    if let Some(s) = &args.sampler {
        cfg.sampler = sampler::resolve(s)?.name.to_string();
    }
    if let Some(t) = args.target {
        cfg.target_metric = Some(t);
    }
    if args.eager_train {
        cfg.eager_train = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn print_report(report: &RunReport) {
    let mut t = Table::new(&["round", "sim_hours", "loss", "metric"]);
    for p in &report.eval_points {
        t.row(vec![
            p.round.to_string(),
            format!("{:.3}", hours(p.sim_secs)),
            format!("{:.4}", p.mean_loss),
            format!("{:.4}", p.metric),
        ]);
    }
    println!("{}", t.render());
    println!(
        "rounds={} sim={:.2}h wall={:.1}s steps={} events={} mean_participation={:.3} \
         online_frac={:.3} avail_drops={} deadline_drops={} trainings_executed={} \
         trainings_avoided={}",
        report.total_rounds,
        hours(report.sim_secs),
        report.wall_secs,
        report.real_train_steps,
        report.events_processed,
        report.mean_participation(),
        report.mean_online_fraction(),
        report.total_avail_drops(),
        report.total_deadline_drops(),
        report.trainings_executed,
        report.trainings_avoided
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    eprintln!(
        "run: model={} strategy={} population={} concurrency={} rounds={}",
        cfg.model, cfg.strategy, cfg.population, cfg.concurrency, cfg.rounds
    );
    let sim = Simulation::new(cfg, &args.artifacts)?;
    let report = match &args.events {
        Some(path) => {
            let file = std::fs::File::create(path)
                .with_context(|| format!("creating event stream {path}"))?;
            let mut sink = JsonlSink::new(std::io::BufWriter::new(file));
            let report = sim.run_with_sink(&mut sink)?;
            anyhow::ensure!(sink.errors == 0, "{} event-stream writes failed", sink.errors);
            sink.into_inner().flush()?;
            eprintln!("wrote event stream {path}");
            report
        }
        None => sim.run()?,
    };

    print_report(&report);
    if let Some(out) = &args.out {
        std::fs::write(out, report.to_json().to_string())?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let base = build_config(args)?;
    let manifest = Manifest::load(&args.artifacts)?;
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let higher_better = manifest.model(&base.model)?.task == Task::Classify;

    // Every registered strategy, in registry order — a new strategy shows
    // up here with zero CLI changes.
    let mut reports = Vec::new();
    for info in registry::STRATEGIES {
        let mut cfg = base.clone();
        cfg.strategy = info.name.to_string();
        eprintln!("running {} ...", info.name);
        let sim = Simulation::with_client(cfg, &manifest, &client)?;
        reports.push(sim.run()?);
    }

    let target = base.target_metric;
    let mut t = Table::new(&[
        "strategy",
        "final_metric",
        "time_to_target",
        "speedup_vs",
        "sim_hours",
        "mean_particip",
    ]);
    let tt0 = target.and_then(|tv| reports[0].time_to_target(tv, higher_better));
    for r in &reports {
        let tt = target.and_then(|tv| r.time_to_target(tv, higher_better));
        t.row(vec![
            r.strategy.clone(),
            r.final_metric().map(|m| format!("{m:.4}")).unwrap_or_default(),
            fmt_hours(tt),
            fmt_speedup(tt0, tt),
            format!("{:.2}", hours(r.sim_secs)),
            format!("{:.3}", r.mean_participation()),
        ]);
    }
    println!("{}", t.render());
    // Availability attribution (online-fraction, churn vs deadline drops).
    let rows: Vec<(&str, &RunReport)> =
        reports.iter().map(|r| (r.strategy.as_str(), r)).collect();
    println!("{}", participation_table(&rows).render());
    Ok(())
}

fn cmd_strategies() -> Result<()> {
    let mut t = Table::new(&["name", "aliases", "summary"]);
    for info in registry::STRATEGIES {
        t.row(vec![
            info.name.to_string(),
            info.aliases.join(", "),
            info.summary.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_samplers() -> Result<()> {
    let mut t = Table::new(&["name", "aliases", "summary"]);
    for info in sampler::SAMPLERS {
        t.row(vec![
            info.name.to_string(),
            info.aliases.join(", "),
            info.summary.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_weighers() -> Result<()> {
    let mut t = Table::new(&["name", "aliases", "summary"]);
    for info in scheduling::WEIGHERS {
        t.row(vec![
            info.name.to_string(),
            info.aliases.join(", "),
            info.summary.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_networks() -> Result<()> {
    let mut t = Table::new(&["name", "aliases", "summary"]);
    for info in network::NETWORKS {
        t.row(vec![
            info.name.to_string(),
            info.aliases.join(", "),
            info.summary.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_scenarios() -> Result<()> {
    let mut t = Table::new(&["name", "aliases", "preset", "summary"]);
    for s in scenario::SCENARIOS {
        t.row(vec![
            s.name.to_string(),
            s.aliases.join(", "),
            s.preset.unwrap_or("(default)").to_string(),
            s.summary.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_presets() -> Result<()> {
    let mut t = Table::new(&["name", "summary"]);
    for (name, summary) in config::PRESETS {
        t.row(vec![name.to_string(), summary.to_string()]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `timelyfl sweep`: expand `--axis` flags over a scenario/preset base
/// config and run the cell × seed matrix thread-parallel.
fn cmd_sweep(args: &Args) -> Result<()> {
    let base = build_config(args)?;
    let mut grid = SweepGrid::new(base);
    for spec in &args.axes {
        let (key, values) = spec.split_once('=').with_context(|| {
            format!("--axis {spec:?}: expected key=v1,v2,...")
        })?;
        let values: Vec<&str> = values.split(',').map(str::trim).collect();
        anyhow::ensure!(
            values.iter().all(|v| !v.is_empty()),
            "--axis {spec:?}: empty value"
        );
        grid = grid.axis(key, &values);
    }
    let seeds = args.seeds.unwrap_or(1);
    anyhow::ensure!(seeds >= 1, "--seeds must be >= 1");
    let jobs = match args.jobs {
        Some(j) => {
            anyhow::ensure!(j >= 1, "--jobs must be >= 1");
            j
        }
        // Default mirrors benchkit's policy: each worker owns a PJRT client
        // + full executable set, so past ~4 workers the CPU client only
        // oversubscribes. --jobs overrides for bigger machines.
        None => std::thread::available_parallelism().map_or(1, |n| n.get().min(4)),
    };
    eprintln!(
        "sweep: {} cells x {} seeds over axes [{}] ({} jobs{})",
        grid.len(),
        seeds,
        grid.axis_keys().join(", "),
        jobs,
        if args.warm_ledger { ", warm ledger" } else { "" }
    );

    let mut runner = ExperimentRunner::new(&args.artifacts)
        .seeds(seeds)
        .jobs(jobs)
        .warm_ledger(args.warm_ledger);
    if let Some(dir) = &args.events {
        runner = runner.events_dir(dir);
    }
    let result = runner.run(&grid)?;

    let mut t = Table::new(&[
        "cell",
        "final_metric",
        "time_to_target",
        "sim_hours",
        "mean_particip",
        "online_frac",
        "avail_drops",
        "deadline_drops",
        "rounds",
    ]);
    for c in &result.cells {
        let s = &c.summary;
        t.row(vec![
            s.label.clone(),
            s.final_metric.map_or("-".into(), |m| m.fmt(4)),
            match &s.time_to_target {
                None => "-".into(),
                Some(tt) => match &tt.hours {
                    Some(h) => format!("{} hr ({}/{})", h.fmt(2), tt.reached, s.seeds),
                    None => "> budget".into(),
                },
            },
            s.sim_hours.fmt(2),
            s.mean_participation.fmt(3),
            s.mean_online_fraction.fmt(3),
            s.avail_drops.fmt(1),
            s.deadline_drops.fmt(1),
            s.rounds.fmt(1),
        ]);
    }
    println!("{}", t.render());

    if let Some(out) = &args.out {
        let manifest = result.manifest(args.scenario.as_deref(), &grid.axis_keys());
        std::fs::write(out, manifest).with_context(|| format!("writing {out}"))?;
        eprintln!("wrote sweep manifest {out}");
    }
    Ok(())
}

/// `timelyfl report MANIFEST.jsonl [--csv] [--out FILE]`: render a sweep
/// manifest (what `sweep --out` wrote) as an `EXPERIMENTS.md`-style
/// markdown table, or CSV for spreadsheet tooling — result tables in docs
/// get regenerated from the manifest, never hand-edited.
fn cmd_report(args: &Args) -> Result<()> {
    let path = args.subcommand.as_deref().context(
        "usage: timelyfl report MANIFEST.jsonl [--csv] [--out FILE]",
    )?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let summaries = summary::parse_sweep_manifest(&text)?;
    anyhow::ensure!(!summaries.is_empty(), "{path}: no cell records");

    let opt = |m: &Option<MeanStd>, prec: usize| -> String {
        m.as_ref().map_or("-".into(), |m| m.fmt(prec))
    };
    let time_to_target = |s: &summary::CellSummary| -> String {
        match &s.time_to_target {
            None => "-".into(),
            Some(tt) => match &tt.hours {
                Some(h) => format!("{} hr ({}/{})", h.fmt(2), tt.reached, s.seeds),
                None => "> budget".into(),
            },
        }
    };

    let rendered = if args.csv {
        // CSV carries bare means (std is recoverable from the manifest);
        // the label is quoted — `k=v,k=v` labels contain the separator.
        let mut out = String::from(
            "cell,seeds,rounds,final_metric,best_metric,sim_hours,\
             mean_participation,online_fraction,avail_drops,deadline_drops,\
             target_reached,hours_to_target\n",
        );
        let num = |m: &MeanStd| format!("{}", m.mean);
        let optnum =
            |m: &Option<MeanStd>| m.as_ref().map_or(String::new(), |m| format!("{}", m.mean));
        for s in &summaries {
            let (reached, tt_hours) = match &s.time_to_target {
                Some(tt) => (tt.reached.to_string(), optnum(&tt.hours)),
                None => (String::new(), String::new()),
            };
            out.push_str(&format!(
                "\"{}\",{},{},{},{},{},{},{},{},{},{},{}\n",
                s.label.replace('"', "\"\""),
                s.seeds,
                num(&s.rounds),
                optnum(&s.final_metric),
                optnum(&s.best_metric),
                num(&s.sim_hours),
                num(&s.mean_participation),
                num(&s.mean_online_fraction),
                num(&s.avail_drops),
                num(&s.deadline_drops),
                reached,
                tt_hours,
            ));
        }
        out
    } else {
        let mut out = String::from(
            "| cell | seeds | rounds | final_metric | best_metric | sim_hours \
             | particip | online | avail_drops | deadline_drops | time_to_target |\n\
             |---|---|---|---|---|---|---|---|---|---|---|\n",
        );
        for s in &summaries {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n",
                s.label,
                s.seeds,
                s.rounds.fmt(1),
                opt(&s.final_metric, 4),
                opt(&s.best_metric, 4),
                s.sim_hours.fmt(2),
                s.mean_participation.fmt(3),
                s.mean_online_fraction.fmt(3),
                s.avail_drops.fmt(1),
                s.deadline_drops.fmt(1),
                time_to_target(s),
            ));
        }
        out
    };

    match &args.out {
        Some(out) => {
            std::fs::write(out, &rendered).with_context(|| format!("writing {out}"))?;
            eprintln!("wrote {} cells to {out}", summaries.len());
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

/// `timelyfl trace record`: dump the configured availability process's
/// schedule to the JSONL trace format of `docs/availability.md`, so a
/// Markov/diurnal run can be replayed elsewhere with `availability=trace`.
fn cmd_trace(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("record") => {}
        other => anyhow::bail!(
            "usage: timelyfl trace record [--preset P] [--set avail_*=..] \
             [--horizon SECS] [--out FILE] (got {other:?})"
        ),
    }
    let cfg = build_config(args)?;
    let horizon = match args.horizon {
        Some(h) => h,
        None if cfg.sim_time_budget.is_finite() => cfg.sim_time_budget,
        None => 86_400.0, // one simulated day
    };
    anyhow::ensure!(
        horizon > 0.0 && horizon.is_finite(),
        "--horizon must be positive and finite (got {horizon})"
    );
    let mut model =
        AvailabilityModel::build(&cfg.availability, cfg.population, cfg.seed ^ SEED_SALT)?;

    let mut events = Vec::new();
    for client in 0..cfg.population {
        // Trace semantics: clients are online before their first record, so
        // an initially-offline client needs an explicit record at t=0.
        let mut online = model.is_available(client, 0.0);
        if !online {
            events.push(TraceEvent { at: 0.0, client, online: false });
        }
        let mut t = 0.0;
        while let Some(next) = model.next_transition(client, t) {
            if next > horizon {
                break;
            }
            online = !online;
            events.push(TraceEvent { at: next, client, online });
            t = next;
        }
    }
    let text = write_trace(&events);
    match &args.out {
        Some(path) => {
            std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
            eprintln!(
                "wrote {} transitions for {} clients over {horizon}s to {path}",
                events.len(),
                cfg.population
            );
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.artifacts)?;
    let mut t = Table::new(&["model", "task", "params", "tensors", "ratios", "batch"]);
    for (name, m) in &manifest.models {
        t.row(vec![
            name.clone(),
            format!("{:?}", m.task),
            m.total_params.to_string(),
            m.params.len().to_string(),
            m.ratios
                .iter()
                .map(|r| format!("{}", r.ratio))
                .collect::<Vec<_>>()
                .join("/"),
            m.batch.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn usage() -> String {
    format!(
        "usage: timelyfl <run|compare|sweep|report MANIFEST|strategies|samplers|weighers|networks|scenarios|presets|trace record|inspect> \
         [--preset P] [--scenario S] [--strategy S] [--sampler S] [--config FILE] [--set k=v]... \
         [--axis k=v1,v2]... [--seeds N] [--jobs J] [--warm-ledger] [--artifacts DIR] [--out FILE] \
         [--target X] [--events FILE|DIR] [--horizon SECS] [--eager-train] [--csv]\n\
         strategies: {}\n\
         samplers:   {}\n\
         weighers:   {}\n\
         networks:   {}\n\
         scenarios:  {}",
        registry::names().join(", "),
        sampler::names().join(", "),
        scheduling::names().join(", "),
        network::names().join(", "),
        scenario::names().join(", ")
    )
}

fn main() -> Result<()> {
    let args = parse_args()?;
    // Only `trace` (subcommand word) and `report` (positional manifest
    // path) take a bare argument; a stray one anywhere else is a user
    // error (e.g. a forgotten `--`), not something to skip.
    let stray = (args.command != "trace" && args.command != "report")
        .then_some(args.subcommand.as_deref())
        .flatten();
    if let Some(word) = stray {
        eprintln!("{}", usage());
        eprintln!("timelyfl: unexpected argument {word:?}");
        std::process::exit(2);
    }
    match args.command.as_str() {
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "sweep" => cmd_sweep(&args),
        "report" => cmd_report(&args),
        "strategies" => cmd_strategies(),
        "samplers" => cmd_samplers(),
        "weighers" => cmd_weighers(),
        "networks" => cmd_networks(),
        "scenarios" => cmd_scenarios(),
        "presets" => cmd_presets(),
        "trace" => cmd_trace(&args),
        "inspect" => cmd_inspect(&args),
        "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            // Unknown subcommands must fail loudly AND non-zero, or shell
            // pipelines (and scripts/check.sh composition) silently pass.
            eprintln!("{}", usage());
            eprintln!("timelyfl: unknown subcommand {other:?}");
            std::process::exit(2);
        }
    }
}
