//! `timelyfl` CLI — launcher for simulated federated-learning runs.
//!
//! ```text
//! timelyfl run      --preset cifar_fedavg [--strategy timelyfl] [--set k=v ...]
//! timelyfl compare  --preset cifar_fedavg [--set k=v ...]      # all 3 strategies
//! timelyfl inspect  [--artifacts DIR]                           # manifest dump
//! ```
//!
//! (Hand-rolled arg parsing: clap is not in the offline vendor set.)

use anyhow::{Context, Result};

use timelyfl::config::{parse as cfgparse, RunConfig, StrategyKind};
use timelyfl::coordinator::Simulation;
use timelyfl::metrics::report::{fmt_hours, fmt_speedup, participation_table, Table};
use timelyfl::metrics::RunReport;
use timelyfl::runtime::{Manifest, Task};
use timelyfl::simtime::hours;

struct Args {
    command: String,
    preset: Option<String>,
    strategy: Option<String>,
    config_file: Option<String>,
    sets: Vec<String>,
    artifacts: String,
    out: Option<String>,
    target: Option<f64>,
}

fn parse_args() -> Result<Args> {
    let mut args = Args {
        command: String::new(),
        preset: None,
        strategy: None,
        config_file: None,
        sets: Vec::new(),
        artifacts: "artifacts".into(),
        out: None,
        target: None,
    };
    let mut it = std::env::args().skip(1);
    args.command = it.next().unwrap_or_else(|| "help".into());
    while let Some(a) = it.next() {
        let mut need = |name: &str| -> Result<String> {
            it.next().with_context(|| format!("{name} needs a value"))
        };
        match a.as_str() {
            "--preset" => args.preset = Some(need("--preset")?),
            "--strategy" => args.strategy = Some(need("--strategy")?),
            "--config" => args.config_file = Some(need("--config")?),
            "--set" => args.sets.push(need("--set")?),
            "--artifacts" => args.artifacts = need("--artifacts")?,
            "--out" => args.out = Some(need("--out")?),
            "--target" => args.target = Some(need("--target")?.parse()?),
            "--help" | "-h" => {
                args.command = "help".into();
            }
            other => anyhow::bail!("unknown flag {other:?}"),
        }
    }
    Ok(args)
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match &args.preset {
        Some(p) => RunConfig::preset(p)?,
        None => RunConfig::default(),
    };
    if let Some(path) = &args.config_file {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        cfgparse::apply_file(&mut cfg, &text)?;
    }
    for kv in &args.sets {
        cfgparse::apply_cli(&mut cfg, kv)?;
    }
    if let Some(s) = &args.strategy {
        cfg.strategy = StrategyKind::parse(s)?;
    }
    if let Some(t) = args.target {
        cfg.target_metric = Some(t);
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    eprintln!(
        "run: model={} strategy={} population={} concurrency={} rounds={}",
        cfg.model,
        cfg.strategy.name(),
        cfg.population,
        cfg.concurrency,
        cfg.rounds
    );
    let sim = Simulation::new(cfg, &args.artifacts)?;
    let report = sim.run()?;

    let mut t = Table::new(&["round", "sim_hours", "loss", "metric"]);
    for p in &report.eval_points {
        t.row(vec![
            p.round.to_string(),
            format!("{:.3}", hours(p.sim_secs)),
            format!("{:.4}", p.mean_loss),
            format!("{:.4}", p.metric),
        ]);
    }
    println!("{}", t.render());
    println!(
        "rounds={} sim={:.2}h wall={:.1}s steps={} events={} mean_participation={:.3} \
         online_frac={:.3} avail_drops={} deadline_drops={}",
        report.total_rounds,
        hours(report.sim_secs),
        report.wall_secs,
        report.real_train_steps,
        report.events_processed,
        report.mean_participation(),
        report.mean_online_fraction(),
        report.total_avail_drops(),
        report.total_deadline_drops()
    );
    if let Some(out) = &args.out {
        std::fs::write(out, report.to_json().to_string())?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let base = build_config(args)?;
    let manifest = Manifest::load(&args.artifacts)?;
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let higher_better = manifest.model(&base.model)?.task == Task::Classify;

    let mut reports = Vec::new();
    for strat in [StrategyKind::TimelyFl, StrategyKind::FedBuff, StrategyKind::SyncFl] {
        let mut cfg = base.clone();
        cfg.strategy = strat;
        eprintln!("running {} ...", strat.name());
        let sim = Simulation::with_client(cfg, &manifest, &client)?;
        reports.push(sim.run()?);
    }

    let target = base.target_metric;
    let mut t = Table::new(&[
        "strategy",
        "final_metric",
        "time_to_target",
        "speedup_vs",
        "sim_hours",
        "mean_particip",
    ]);
    let tt0 = target.and_then(|tv| reports[0].time_to_target(tv, higher_better));
    for r in &reports {
        let tt = target.and_then(|tv| r.time_to_target(tv, higher_better));
        t.row(vec![
            r.strategy.clone(),
            r.final_metric().map(|m| format!("{m:.4}")).unwrap_or_default(),
            fmt_hours(tt),
            fmt_speedup(tt0, tt),
            format!("{:.2}", hours(r.sim_secs)),
            format!("{:.3}", r.mean_participation()),
        ]);
    }
    println!("{}", t.render());
    // Availability attribution (online-fraction, churn vs deadline drops).
    let rows: Vec<(&str, &RunReport)> =
        reports.iter().map(|r| (r.strategy.as_str(), r)).collect();
    println!("{}", participation_table(&rows).render());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.artifacts)?;
    let mut t = Table::new(&["model", "task", "params", "tensors", "ratios", "batch"]);
    for (name, m) in &manifest.models {
        t.row(vec![
            name.clone(),
            format!("{:?}", m.task),
            m.total_params.to_string(),
            m.params.len().to_string(),
            m.ratios
                .iter()
                .map(|r| format!("{}", r.ratio))
                .collect::<Vec<_>>()
                .join("/"),
            m.batch.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn main() -> Result<()> {
    let args = parse_args()?;
    match args.command.as_str() {
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "inspect" => cmd_inspect(&args),
        _ => {
            eprintln!(
                "usage: timelyfl <run|compare|inspect> [--preset P] [--strategy S] \
                 [--config FILE] [--set k=v]... [--artifacts DIR] [--out FILE] [--target X]"
            );
            Ok(())
        }
    }
}
