//! Compiled-model execution engine: one `ModelRuntime` per zoo model, with
//! one loaded executable per partial-training ratio plus eval and init.

use std::cell::RefCell;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{Manifest, ModelMeta, RatioMeta, XDtype};
use crate::model::ParamVec;

/// A training batch. `x` layout is row-major `(batch, features…)` flattened;
/// labels are one int per example (classify) or per token (lm).
#[derive(Clone, Debug)]
pub enum Batch {
    F32 { x: Vec<f32>, y: Vec<i32> },
    I32 { x: Vec<i32>, y: Vec<i32> },
}

impl Batch {
    pub fn len_x(&self) -> usize {
        match self {
            Batch::F32 { x, .. } => x.len(),
            Batch::I32 { x, .. } => x.len(),
        }
    }
    pub fn y(&self) -> &[i32] {
        match self {
            Batch::F32 { y, .. } | Batch::I32 { y, .. } => y,
        }
    }
}

/// Cumulative wall-clock accounting of real PJRT executions (distinct from
/// the *simulated* device time of the coordinator).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    /// Logical SGD steps (minibatches consumed).
    pub train_steps: u64,
    /// PJRT executions issued for training (chunked: <= train_steps).
    pub train_execs: u64,
    pub train_secs: f64,
    pub eval_batches: u64,
    pub eval_secs: f64,
}

/// Loaded executables for one model.
pub struct ModelRuntime {
    pub meta: ModelMeta,
    client: PjRtClient,
    /// Parallel to `meta.ratios`; compiled lazily on first use — FedBuff
    /// and SyncFL only ever execute ratio 1.0, and TimelyFL touches a
    /// workload-dependent subset, so eager compilation of all five
    /// variants wastes startup time (significant for the 6.9M-param
    /// `e2e_lm`; see EXPERIMENTS.md §Perf).
    train: Vec<once_cell::unsync::OnceCell<PjRtLoadedExecutable>>,
    train_paths: Vec<std::path::PathBuf>,
    /// Batched-execution variants (`meta.lanes` clients per dispatch),
    /// parallel to `meta.ratios`; lazy like `train`, `None` path when the
    /// artifact set predates the batched graphs (`batch_exec=on` then fails
    /// with a re-record hint on first use).
    train_batched: Vec<once_cell::unsync::OnceCell<PjRtLoadedExecutable>>,
    train_batched_paths: Vec<Option<std::path::PathBuf>>,
    eval: PjRtLoadedExecutable,
    init: PjRtLoadedExecutable,
    stats: RefCell<RuntimeStats>,
}

fn compile(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))
}

fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let lit = Literal::vec1(data);
    if dims.len() == 1 && dims[0] == data.len() {
        return Ok(lit);
    }
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow::anyhow!("{e:?}"))
}

fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let lit = Literal::vec1(data);
    if dims.len() == 1 && dims[0] == data.len() {
        return Ok(lit);
    }
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow::anyhow!("{e:?}"))
}

impl ModelRuntime {
    /// Load + compile all artifacts of `name`. Compilation happens once; the
    /// executables are reused for every simulated client across the run.
    pub fn load(client: &PjRtClient, manifest: &Manifest, name: &str) -> Result<ModelRuntime> {
        let meta = manifest.model(name)?.clone();
        let train = (0..meta.ratios.len())
            .map(|_| once_cell::unsync::OnceCell::new())
            .collect();
        let train_paths = meta
            .ratios
            .iter()
            .map(|r| manifest.artifact_path(&r.artifact))
            .collect();
        let train_batched = (0..meta.ratios.len())
            .map(|_| once_cell::unsync::OnceCell::new())
            .collect();
        let train_batched_paths = meta
            .ratios
            .iter()
            .map(|r| {
                r.batched_artifact
                    .as_deref()
                    .map(|rel| manifest.artifact_path(rel))
            })
            .collect();
        let eval = compile(client, &manifest.artifact_path(&meta.eval_artifact))?;
        let init = compile(client, &manifest.artifact_path(&meta.init_artifact))?;
        Ok(ModelRuntime {
            meta,
            client: client.clone(),
            train,
            train_paths,
            train_batched,
            train_batched_paths,
            eval,
            init,
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// The compiled train executable for ratio index `idx` (compiling it on
    /// first use).
    fn train_exe(&self, idx: usize) -> Result<&PjRtLoadedExecutable> {
        if let Some(e) = self.train[idx].get() {
            return Ok(e);
        }
        let e = compile(&self.client, &self.train_paths[idx])?;
        let _ = self.train[idx].set(e);
        Ok(self.train[idx].get().unwrap())
    }

    /// The compiled batched-train executable for ratio index `idx`
    /// (compiling it on first use).
    fn train_batched_exe(&self, idx: usize) -> Result<&PjRtLoadedExecutable> {
        if let Some(e) = self.train_batched[idx].get() {
            return Ok(e);
        }
        let path = self.train_batched_paths[idx].as_ref().with_context(|| {
            format!(
                "model {} ratio {} has no batched artifact — the artifact set \
                 predates batch_exec; re-run `make artifacts`",
                self.meta.name, self.meta.ratios[idx].ratio
            )
        })?;
        let e = compile(&self.client, path)?;
        let _ = self.train_batched[idx].set(e);
        Ok(self.train_batched[idx].get().unwrap())
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    /// Initial global model from the AOT init graph (seeded).
    pub fn init_params(&self, seed: i32) -> Result<ParamVec> {
        let out = self
            .init
            .execute::<Literal>(&[Literal::scalar(seed)])
            .map_err(|e| anyhow::anyhow!("init: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("init fetch: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        anyhow::ensure!(
            parts.len() == self.meta.params.len(),
            "init returned {} tensors, manifest has {}",
            parts.len(),
            self.meta.params.len()
        );
        let tensors = parts
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}")))
            .collect::<Result<Vec<_>>>()?;
        let pv = ParamVec { tensors };
        pv.check(&self.meta)?;
        Ok(pv)
    }

    fn params_to_literals(&self, params: &ParamVec) -> Result<Vec<Literal>> {
        params
            .tensors
            .iter()
            .zip(&self.meta.params)
            .map(|(t, p)| literal_f32(t, &p.shape))
            .collect()
    }

    fn batch_literals(&self, batch: &Batch, batch_size: usize) -> Result<(Literal, Literal)> {
        let mut x_dims = vec![batch_size];
        x_dims.extend_from_slice(&self.meta.x_shape);
        let x_lit = match (batch, self.meta.x_dtype) {
            (Batch::F32 { x, .. }, XDtype::F32) => literal_f32(x, &x_dims)?,
            (Batch::I32 { x, .. }, XDtype::I32) => literal_i32(x, &x_dims)?,
            _ => anyhow::bail!("batch dtype does not match model {}", self.meta.name),
        };
        let y = batch.y();
        let y_lit = match self.meta.task {
            super::manifest::Task::Classify => literal_i32(y, &[batch_size])?,
            super::manifest::Task::Lm => literal_i32(y, &[batch_size, self.meta.seq_len])?,
        };
        Ok((x_lit, y_lit))
    }

    /// Stack up to `meta.chunk` minibatches into the train artifact's
    /// `(xs[S, B, …], ys[S, …])` operands, padding unused tail slots with a
    /// repeat of the first batch (masked out in-graph by `n_steps`).
    fn stacked_batch_literals(&self, batches: &[Batch]) -> Result<(Literal, Literal)> {
        let chunk = self.meta.chunk;
        anyhow::ensure!(
            !batches.is_empty() && batches.len() <= chunk,
            "got {} batches for chunk size {chunk}",
            batches.len()
        );
        let x_per = self.meta.batch * self.meta.x_len();
        let y_per = match self.meta.task {
            super::manifest::Task::Classify => self.meta.batch,
            super::manifest::Task::Lm => self.meta.batch * self.meta.seq_len,
        };
        let mut ys = Vec::with_capacity(chunk * y_per);
        let mut x_dims = vec![chunk, self.meta.batch];
        x_dims.extend_from_slice(&self.meta.x_shape);

        let x_lit = match self.meta.x_dtype {
            XDtype::F32 => {
                let mut xs = Vec::with_capacity(chunk * x_per);
                for i in 0..chunk {
                    let b = &batches[i.min(batches.len() - 1)];
                    let Batch::F32 { x, y } = b else {
                        anyhow::bail!("batch dtype does not match model {}", self.meta.name)
                    };
                    anyhow::ensure!(x.len() == x_per && y.len() == y_per, "bad batch shape");
                    xs.extend_from_slice(x);
                    ys.extend_from_slice(y);
                }
                literal_f32(&xs, &x_dims)?
            }
            XDtype::I32 => {
                let mut xs = Vec::with_capacity(chunk * x_per);
                for i in 0..chunk {
                    let b = &batches[i.min(batches.len() - 1)];
                    let Batch::I32 { x, y } = b else {
                        anyhow::bail!("batch dtype does not match model {}", self.meta.name)
                    };
                    anyhow::ensure!(x.len() == x_per && y.len() == y_per, "bad batch shape");
                    xs.extend_from_slice(x);
                    ys.extend_from_slice(y);
                }
                literal_i32(&xs, &x_dims)?
            }
        };
        let y_dims: Vec<usize> = match self.meta.task {
            super::manifest::Task::Classify => vec![chunk, self.meta.batch],
            super::manifest::Task::Lm => vec![chunk, self.meta.batch, self.meta.seq_len],
        };
        let y_lit = literal_i32(&ys, &y_dims)?;
        Ok((x_lit, y_lit))
    }

    /// Run up to `meta.chunk` consecutive local SGD steps in ONE PJRT
    /// execution (the L2 scan fusion — see EXPERIMENTS.md §Perf). Returns
    /// the updated parameters and the mean (pre-update) minibatch loss over
    /// the executed steps.
    ///
    /// The executable's signature is
    /// `(params…, xs, ys, lr, n_steps) -> (params…, loss_sum)`; frozen
    /// prefix tensors pass through unchanged, so the output is always a
    /// full ParamVec regardless of ratio.
    pub fn train_chunk(
        &self,
        ratio: &RatioMeta,
        params: &ParamVec,
        batches: &[Batch],
        lr: f32,
    ) -> Result<(ParamVec, f32)> {
        let idx = self
            .meta
            .ratios
            .iter()
            .position(|r| (r.ratio - ratio.ratio).abs() < 1e-9)
            .with_context(|| format!("ratio {} not compiled", ratio.ratio))?;
        let t0 = Instant::now();

        let mut args = self.params_to_literals(params)?;
        let (x_lit, y_lit) = self.stacked_batch_literals(batches)?;
        args.push(x_lit);
        args.push(y_lit);
        args.push(Literal::scalar(lr));
        args.push(Literal::scalar(batches.len() as i32));

        let out = self.train_exe(idx)?
            .execute::<Literal>(&args)
            .map_err(|e| anyhow::anyhow!("train_chunk: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("train fetch: {e:?}"))?;
        let mut parts = out.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        anyhow::ensure!(
            parts.len() == self.meta.params.len() + 1,
            "train returned {} outputs",
            parts.len()
        );
        let loss_lit = parts.pop().unwrap();
        let loss_sum: f32 = loss_lit
            .get_first_element()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let tensors = parts
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}")))
            .collect::<Result<Vec<_>>>()?;

        let mut s = self.stats.borrow_mut();
        s.train_steps += batches.len() as u64;
        s.train_execs += 1;
        s.train_secs += t0.elapsed().as_secs_f64();
        Ok((ParamVec { tensors }, loss_sum / batches.len() as f32))
    }

    /// Run up to `meta.lanes` independent clients' train chunks in ONE PJRT
    /// execution (`batch_exec=on`; the dispatch-count optimisation of
    /// docs/architecture.md §Batched execution). Each `(params, batches)`
    /// lane behaves exactly like a [`Self::train_chunk`] call: the batched
    /// artifact is a `lax.map` over the same scan body, so a lane's result
    /// is independent of which lanes share the dispatch (locked bitwise by
    /// `tests/batched_equivalence.rs`). Missing lanes (fewer clients than
    /// `meta.lanes`) are padded internally with `n_steps = 0` pass-through
    /// repeats of the last real lane.
    pub fn train_chunk_batched(
        &self,
        ratio: &RatioMeta,
        lanes: &[(&ParamVec, &[Batch])],
        lr: f32,
    ) -> Result<Vec<(ParamVec, f32)>> {
        let nlanes = self.meta.lanes;
        anyhow::ensure!(
            nlanes >= 1,
            "model {} has no batched artifacts — the artifact set predates \
             batch_exec; re-run `make artifacts`",
            self.meta.name
        );
        anyhow::ensure!(
            !lanes.is_empty() && lanes.len() <= nlanes,
            "got {} lanes for lane count {nlanes}",
            lanes.len()
        );
        let chunk = self.meta.chunk;
        for (_, b) in lanes {
            anyhow::ensure!(
                !b.is_empty() && b.len() <= chunk,
                "got {} batches for chunk size {chunk}",
                b.len()
            );
        }
        let idx = self
            .meta
            .ratios
            .iter()
            .position(|r| (r.ratio - ratio.ratio).abs() < 1e-9)
            .with_context(|| format!("ratio {} not compiled", ratio.ratio))?;
        let t0 = Instant::now();

        // Stacked params: one [L, *shape] operand per tensor.
        let npar = self.meta.params.len();
        let mut args = Vec::with_capacity(npar + 4);
        for (pi, pmeta) in self.meta.params.iter().enumerate() {
            let mut data = Vec::with_capacity(nlanes * pmeta.size);
            for l in 0..nlanes {
                data.extend_from_slice(&lanes[l.min(lanes.len() - 1)].0.tensors[pi]);
            }
            let mut dims = vec![nlanes];
            dims.extend_from_slice(&pmeta.shape);
            args.push(literal_f32(&data, &dims)?);
        }

        // Stacked minibatches: per lane, the same in-chunk tail padding as
        // `stacked_batch_literals` (slots past that lane's n_steps repeat
        // the first batch and are masked in-graph).
        let x_per = self.meta.batch * self.meta.x_len();
        let y_per = match self.meta.task {
            super::manifest::Task::Classify => self.meta.batch,
            super::manifest::Task::Lm => self.meta.batch * self.meta.seq_len,
        };
        let mut ys = Vec::with_capacity(nlanes * chunk * y_per);
        let mut x_dims = vec![nlanes, chunk, self.meta.batch];
        x_dims.extend_from_slice(&self.meta.x_shape);
        let x_lit = match self.meta.x_dtype {
            XDtype::F32 => {
                let mut xs = Vec::with_capacity(nlanes * chunk * x_per);
                for l in 0..nlanes {
                    let batches = lanes[l.min(lanes.len() - 1)].1;
                    for i in 0..chunk {
                        let b = &batches[i.min(batches.len() - 1)];
                        let Batch::F32 { x, y } = b else {
                            anyhow::bail!("batch dtype does not match model {}", self.meta.name)
                        };
                        anyhow::ensure!(x.len() == x_per && y.len() == y_per, "bad batch shape");
                        xs.extend_from_slice(x);
                        ys.extend_from_slice(y);
                    }
                }
                literal_f32(&xs, &x_dims)?
            }
            XDtype::I32 => {
                let mut xs = Vec::with_capacity(nlanes * chunk * x_per);
                for l in 0..nlanes {
                    let batches = lanes[l.min(lanes.len() - 1)].1;
                    for i in 0..chunk {
                        let b = &batches[i.min(batches.len() - 1)];
                        let Batch::I32 { x, y } = b else {
                            anyhow::bail!("batch dtype does not match model {}", self.meta.name)
                        };
                        anyhow::ensure!(x.len() == x_per && y.len() == y_per, "bad batch shape");
                        xs.extend_from_slice(x);
                        ys.extend_from_slice(y);
                    }
                }
                literal_i32(&xs, &x_dims)?
            }
        };
        let y_dims: Vec<usize> = match self.meta.task {
            super::manifest::Task::Classify => vec![nlanes, chunk, self.meta.batch],
            super::manifest::Task::Lm => vec![nlanes, chunk, self.meta.batch, self.meta.seq_len],
        };
        args.push(x_lit);
        args.push(literal_i32(&ys, &y_dims)?);
        args.push(Literal::scalar(lr));
        let n_steps: Vec<i32> = (0..nlanes)
            .map(|l| if l < lanes.len() { lanes[l].1.len() as i32 } else { 0 })
            .collect();
        args.push(literal_i32(&n_steps, &[nlanes])?);

        let out = self.train_batched_exe(idx)?
            .execute::<Literal>(&args)
            .map_err(|e| anyhow::anyhow!("train_chunk_batched: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("train fetch: {e:?}"))?;
        let mut parts = out.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        anyhow::ensure!(
            parts.len() == npar + 1,
            "batched train returned {} outputs",
            parts.len()
        );
        let loss_lit = parts.pop().unwrap();
        let losses = loss_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        anyhow::ensure!(losses.len() == nlanes, "batched train returned {} losses", losses.len());
        let stacked = parts
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}")))
            .collect::<Result<Vec<_>>>()?;
        for (v, p) in stacked.iter().zip(&self.meta.params) {
            anyhow::ensure!(
                v.len() == nlanes * p.size,
                "batched tensor {} has {} elements, want {}",
                p.name,
                v.len(),
                nlanes * p.size
            );
        }
        let outs = lanes
            .iter()
            .enumerate()
            .map(|(l, (_, batches))| {
                let tensors = stacked
                    .iter()
                    .zip(&self.meta.params)
                    .map(|(v, p)| v[l * p.size..(l + 1) * p.size].to_vec())
                    .collect();
                // Same host-side mean as `train_chunk` so per-chunk loss
                // accumulation stays bit-identical to the serial path.
                (ParamVec { tensors }, losses[l] / batches.len() as f32)
            })
            .collect();

        let mut s = self.stats.borrow_mut();
        s.train_steps += lanes.iter().map(|(_, b)| b.len() as u64).sum::<u64>();
        s.train_execs += 1;
        s.train_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// One local SGD step (single-batch convenience wrapper over
    /// [`Self::train_chunk`]; tests and micro-benches use this).
    pub fn train_step(
        &self,
        ratio: &RatioMeta,
        params: &ParamVec,
        batch: &Batch,
        lr: f32,
    ) -> Result<(ParamVec, f32)> {
        self.train_chunk(ratio, params, std::slice::from_ref(batch), lr)
    }

    /// One eval batch: returns `(loss_sum, correct_or_token_count)`.
    pub fn eval_batch(&self, params: &ParamVec, batch: &Batch) -> Result<(f64, f64)> {
        let t0 = Instant::now();
        let mut args = self.params_to_literals(params)?;
        let (x_lit, y_lit) = self.batch_literals(batch, self.meta.eval_batch)?;
        args.push(x_lit);
        args.push(y_lit);
        let out = self
            .eval
            .execute::<Literal>(&args)
            .map_err(|e| anyhow::anyhow!("eval: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("eval fetch: {e:?}"))?;
        let (a, b) = out.to_tuple2().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let loss_sum: f32 = a.get_first_element().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let second: f32 = b.get_first_element().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mut s = self.stats.borrow_mut();
        s.eval_batches += 1;
        s.eval_secs += t0.elapsed().as_secs_f64();
        Ok((loss_sum as f64, second as f64))
    }

    /// Evaluate over a full test set (already shaped into eval batches).
    /// Returns (mean loss, accuracy) for classifiers, (mean nll, ppl) for LMs.
    pub fn evaluate(&self, params: &ParamVec, batches: &[Batch]) -> Result<EvalResult> {
        let mut loss_sum = 0.0;
        let mut second_sum = 0.0;
        let mut examples = 0usize;
        for b in batches {
            let (l, s) = self.eval_batch(params, b)?;
            loss_sum += l;
            second_sum += s;
            examples += match self.meta.task {
                super::manifest::Task::Classify => self.meta.eval_batch,
                super::manifest::Task::Lm => self.meta.eval_batch * self.meta.seq_len,
            };
        }
        let mean_loss = loss_sum / examples.max(1) as f64;
        let metric = match self.meta.task {
            super::manifest::Task::Classify => second_sum / examples.max(1) as f64, // accuracy
            super::manifest::Task::Lm => mean_loss.exp(),                           // perplexity
        };
        Ok(EvalResult {
            mean_loss,
            metric,
            examples,
        })
    }
}

/// Output of `ModelRuntime::evaluate`.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub mean_loss: f64,
    /// Accuracy in [0,1] for classifiers; perplexity for LMs.
    pub metric: f64,
    pub examples: usize,
}
