//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Describes, per model, the positional parameter layout, the
//! fixed batch shapes, and the partial-training ratio -> trainable-boundary
//! mapping (paper §3.2.2: a partial model is a suffix of consecutive
//! output-side tensors).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One parameter tensor's metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
}

/// One compiled partial-training variant.
#[derive(Clone, Debug, PartialEq)]
pub struct RatioMeta {
    /// Nominal ratio requested at AOT time (0 < ratio <= 1).
    pub ratio: f64,
    /// First trainable parameter index; tensors [0, boundary) are frozen.
    pub boundary: usize,
    /// Actual fraction of parameters trainable at this boundary.
    pub trainable_fraction: f64,
    /// HLO text path relative to the artifacts directory.
    pub artifact: String,
    /// Batched-execution variant (`lanes` independent clients per dispatch;
    /// see `ModelMeta::lanes`). `None` on artifact sets recorded before the
    /// batched path existed — `batch_exec=on` then fails with a re-record
    /// hint instead of silently falling back.
    pub batched_artifact: Option<String>,
}

/// Task type of a model in the zoo.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Classify,
    /// Next-token LM: eval returns (nll_sum, token_count); ppl = exp(mean).
    Lm,
}

/// Input element type of the model's `x` operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XDtype {
    F32,
    I32,
}

/// Everything the runtime needs to know about one model.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub task: Task,
    pub batch: usize,
    pub eval_batch: usize,
    pub x_shape: Vec<usize>,
    pub x_dtype: XDtype,
    pub num_classes: usize,
    pub seq_len: usize,
    pub total_params: usize,
    /// SGD steps fused into one train-artifact execution (lax.scan length);
    /// the trainer issues ceil(steps / chunk) calls with tail slots masked
    /// via the `n_steps` operand.
    pub chunk: usize,
    /// Client lanes fused into one batched-train execution (lax.map width);
    /// 0 when the artifact set predates the batched path (no
    /// `batched_artifact` entries either).
    pub lanes: usize,
    pub params: Vec<ParamMeta>,
    pub ratios: Vec<RatioMeta>,
    pub eval_artifact: String,
    pub init_artifact: String,
}

impl ModelMeta {
    /// Per-example feature count of `x`.
    pub fn x_len(&self) -> usize {
        self.x_shape.iter().product()
    }

    /// Bytes of a full model update (f32 params), the `M` of Algorithm 2.
    pub fn full_model_bytes(&self) -> usize {
        self.total_params * 4
    }

    /// The largest compiled ratio <= `alpha` (the scheduler's continuous
    /// alpha is rounded *down* so the client still meets its deadline).
    /// Falls back to the smallest compiled ratio.
    pub fn quantize_ratio(&self, alpha: f64) -> &RatioMeta {
        self.ratios
            .iter()
            .filter(|r| r.ratio <= alpha + 1e-9)
            .max_by(|a, b| a.ratio.partial_cmp(&b.ratio).unwrap())
            .unwrap_or_else(|| {
                self.ratios
                    .iter()
                    .min_by(|a, b| a.ratio.partial_cmp(&b.ratio).unwrap())
                    .expect("model has no compiled ratios")
            })
    }

    /// Ratio metadata for exact nominal ratio (1.0 = full training).
    pub fn ratio_exact(&self, ratio: f64) -> Option<&RatioMeta> {
        self.ratios.iter().find(|r| (r.ratio - ratio).abs() < 1e-9)
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub ratios: Vec<f64>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Self::from_json(root, &json)
    }

    pub fn from_json(root: PathBuf, json: &Json) -> Result<Manifest> {
        let ratios = json
            .expect("ratios")?
            .as_arr()?
            .iter()
            .map(|r| r.as_f64())
            .collect::<Result<Vec<_>>>()?;
        let mut models = BTreeMap::new();
        for (name, m) in json.expect("models")?.as_obj()? {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest {
            root,
            ratios,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name:?} not in manifest ({:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn artifact_path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelMeta> {
    let task = match m.expect("task")?.as_str()? {
        "classify" => Task::Classify,
        "lm" => Task::Lm,
        other => anyhow::bail!("unknown task {other:?}"),
    };
    let x_dtype = match m.expect("x_dtype")?.as_str()? {
        "f32" => XDtype::F32,
        "i32" => XDtype::I32,
        other => anyhow::bail!("unknown x_dtype {other:?}"),
    };
    let params = m
        .expect("params")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamMeta {
                name: p.expect("name")?.as_str()?.to_string(),
                shape: p
                    .expect("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                size: p.expect("size")?.as_usize()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let ratios = m
        .expect("ratios")?
        .as_arr()?
        .iter()
        .map(|r| {
            Ok(RatioMeta {
                ratio: r.expect("ratio")?.as_f64()?,
                boundary: r.expect("boundary")?.as_usize()?,
                trainable_fraction: r.expect("trainable_fraction")?.as_f64()?,
                artifact: r.expect("artifact")?.as_str()?.to_string(),
                batched_artifact: match r.get("batched_artifact") {
                    Some(b) => Some(b.as_str()?.to_string()),
                    None => None,
                },
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let meta = ModelMeta {
        name: name.to_string(),
        task,
        batch: m.expect("batch")?.as_usize()?,
        eval_batch: m.expect("eval_batch")?.as_usize()?,
        x_shape: m
            .expect("x_shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<_>>()?,
        x_dtype,
        num_classes: m.expect("num_classes")?.as_usize()?,
        seq_len: m.expect("seq_len")?.as_usize()?,
        total_params: m.expect("total_params")?.as_usize()?,
        chunk: m.expect("chunk")?.as_usize()?,
        lanes: match m.get("lanes") {
            Some(l) => l.as_usize()?,
            None => 0,
        },
        params,
        ratios,
        eval_artifact: m.expect("eval_artifact")?.as_str()?.to_string(),
        init_artifact: m.expect("init_artifact")?.as_str()?.to_string(),
    };

    // Structural invariants the rest of the runtime relies on.
    let sum: usize = meta.params.iter().map(|p| p.size).sum();
    anyhow::ensure!(
        sum == meta.total_params,
        "{name}: param sizes sum {sum} != total {}",
        meta.total_params
    );
    for p in &meta.params {
        let prod: usize = p.shape.iter().product();
        anyhow::ensure!(prod == p.size, "{name}/{}: shape/size mismatch", p.name);
    }
    for r in &meta.ratios {
        anyhow::ensure!(
            r.boundary < meta.params.len(),
            "{name}: ratio {} boundary out of range",
            r.ratio
        );
        anyhow::ensure!(
            r.batched_artifact.is_none() || meta.lanes >= 1,
            "{name}: ratio {} has a batched artifact but no lane count",
            r.ratio
        );
    }
    Ok(meta)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest_json() -> Json {
        Json::parse(
            r#"{
              "ratios": [0.5, 1.0],
              "models": {
                "m": {
                  "task": "classify", "batch": 4, "eval_batch": 8,
                  "x_shape": [6], "x_dtype": "f32",
                  "num_classes": 3, "seq_len": 0, "total_params": 10,
                  "chunk": 8,
                  "params": [
                    {"name": "a_w", "shape": [2, 3], "size": 6},
                    {"name": "a_b", "shape": [4], "size": 4}
                  ],
                  "ratios": [
                    {"ratio": 0.5, "boundary": 1, "trainable_fraction": 0.4,
                     "artifact": "m/train_r0500.hlo.txt"},
                    {"ratio": 1.0, "boundary": 0, "trainable_fraction": 1.0,
                     "artifact": "m/train_r1000.hlo.txt"}
                  ],
                  "eval_artifact": "m/eval.hlo.txt",
                  "init_artifact": "m/init.hlo.txt"
                }
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_validates() {
        let man = Manifest::from_json(PathBuf::from("/tmp"), &tiny_manifest_json()).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.x_len(), 6);
        assert_eq!(m.full_model_bytes(), 40);
        assert_eq!(m.task, Task::Classify);
    }

    #[test]
    fn quantize_rounds_down() {
        let man = Manifest::from_json(PathBuf::from("/tmp"), &tiny_manifest_json()).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.quantize_ratio(0.9).ratio, 0.5);
        assert_eq!(m.quantize_ratio(1.0).ratio, 1.0);
        assert_eq!(m.quantize_ratio(0.5).ratio, 0.5);
        // below the smallest compiled ratio -> clamp to smallest
        assert_eq!(m.quantize_ratio(0.1).ratio, 0.5);
    }

    #[test]
    fn missing_model_is_error() {
        let man = Manifest::from_json(PathBuf::from("/tmp"), &tiny_manifest_json()).unwrap();
        assert!(man.model("nope").is_err());
    }

    #[test]
    fn pre_batched_manifests_parse_with_zero_lanes() {
        // The tiny fixture has neither `lanes` nor `batched_artifact`: the
        // optional fields must default instead of failing old artifact sets.
        let man = Manifest::from_json(PathBuf::from("/tmp"), &tiny_manifest_json()).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.lanes, 0);
        assert!(m.ratios.iter().all(|r| r.batched_artifact.is_none()));
    }

    #[test]
    fn batched_fields_parse_and_require_lanes() {
        let mut text = r#"{
          "ratios": [1.0],
          "models": {
            "m": {
              "task": "classify", "batch": 4, "eval_batch": 8,
              "x_shape": [6], "x_dtype": "f32",
              "num_classes": 3, "seq_len": 0, "total_params": 10,
              "chunk": 8, "lanes": 8,
              "params": [
                {"name": "a_w", "shape": [2, 3], "size": 6},
                {"name": "a_b", "shape": [4], "size": 4}
              ],
              "ratios": [
                {"ratio": 1.0, "boundary": 0, "trainable_fraction": 1.0,
                 "artifact": "m/train_r1000.hlo.txt",
                 "batched_artifact": "m/train_r1000_b8.hlo.txt"}
              ],
              "eval_artifact": "m/eval.hlo.txt",
              "init_artifact": "m/init.hlo.txt"
            }
          }
        }"#
        .to_string();
        let man =
            Manifest::from_json(PathBuf::from("/tmp"), &Json::parse(&text).unwrap()).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.lanes, 8);
        assert_eq!(
            m.ratios[0].batched_artifact.as_deref(),
            Some("m/train_r1000_b8.hlo.txt")
        );
        // A batched artifact without a lane count is a malformed manifest.
        text = text.replace("\"chunk\": 8, \"lanes\": 8,", "\"chunk\": 8,");
        let err = Manifest::from_json(PathBuf::from("/tmp"), &Json::parse(&text).unwrap());
        assert!(err.is_err(), "batched artifact without lanes must fail");
    }
}
