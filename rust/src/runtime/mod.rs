//! PJRT runtime: loads the HLO-text artifacts produced by `make artifacts`
//! and executes them on the CPU PJRT client. This is the only module that
//! touches the `xla` crate; everything above it works on plain `Vec<f32>`.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* (not serialized
//! proto — xla_extension 0.5.1 rejects jax>=0.5 64-bit instruction ids),
//! `return_tuple=True` on the python side, `to_tuple()` here.

pub mod engine;
pub mod manifest;

pub use engine::{Batch, ModelRuntime, RuntimeStats};
pub use manifest::{Manifest, ModelMeta, RatioMeta, Task, XDtype};
