//! Million-client fleet support: the sim core that survives scale plus the
//! hierarchical aggregation tier.
//!
//! Two halves (see `docs/architecture.md`, "The fleet subsystem"):
//!
//! - **Scale core** — [`OnlineSetIndex`] (O(log n) rank-select sampling
//!   over the online set), [`LazyAvailability`] (per-client next-transition
//!   agenda instead of eager full-schedule scans), and [`ClientTables`]
//!   (compact SoA per-client engine state). Selected by the
//!   `fleet_core = lazy` config override; the default `eager` core keeps
//!   the historical linear-scan paths. Both cores are byte-identical in
//!   `RunReport` JSON (locked by `tests/fleet_equivalence.rs`).
//! - **Aggregation tier** — [`HierarchyConfig`] routes round contributions
//!   through regional edge aggregators ([`PartialAggregate`]) before the
//!   root merge, composing over the strategy registry: all four strategies
//!   run unmodified beneath the tier. Under `hier_clock = region` each
//!   edge additionally owns a [`RegionClock`] — an independent flush
//!   deadline plus a priced edge→root uplink (see
//!   `docs/architecture.md`, "Region clocks").

mod hierarchy;
mod index;
mod lazy;
mod tables;

pub use hierarchy::{
    edge_aggregate, root_merge, ClockMode, ForwardPolicy, HierarchyConfig, PartialAggregate,
    RegionClock, Topology,
};
pub use index::OnlineSetIndex;
pub use lazy::LazyAvailability;
pub use tables::ClientTables;

use anyhow::Result;

/// Which sim-core implementation the engine runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FleetCore {
    /// Historical paths: O(n) online scans, dense per-client state.
    #[default]
    Eager,
    /// Lazy availability + indexed online sets + sparse pending table.
    /// Byte-identical reports, wall-clock independent of idle fleet size.
    Lazy,
}

impl FleetCore {
    pub fn parse(s: &str) -> Result<FleetCore> {
        match s.to_ascii_lowercase().as_str() {
            "eager" => Ok(FleetCore::Eager),
            "lazy" | "indexed" => Ok(FleetCore::Lazy),
            other => anyhow::bail!("unknown fleet core {other:?} (known: eager, lazy)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FleetCore::Eager => "eager",
            FleetCore::Lazy => "lazy",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_core_parse_round_trips() {
        for core in [FleetCore::Eager, FleetCore::Lazy] {
            assert_eq!(FleetCore::parse(core.name()).unwrap(), core);
        }
        assert_eq!(FleetCore::parse("indexed").unwrap(), FleetCore::Lazy);
        assert_eq!(FleetCore::default(), FleetCore::Eager);
        assert!(FleetCore::parse("turbo").is_err());
    }
}
