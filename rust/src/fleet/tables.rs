//! Compact SoA per-client engine state.
//!
//! The engine used to carry five parallel `Vec`s (`sampler_scores`,
//! `delivered`, `churned`, `busy`, `gens`) plus a dense
//! `Vec<Option<PendingDispatch>>`. At 10^6 clients the ledgers dominated
//! resident memory, so the hot per-client state lives here as
//! struct-of-arrays with the narrowest types that cannot overflow in
//! practice (`u32` counters: a client cannot deliver or churn 4 billion
//! times inside any finite sim budget; dispatch generations bump once per
//! churn cancellation). The busy flags pack into a bitset — they are read
//! on every refill. The pending-dispatch table itself moved to a sparse
//! `BTreeMap` in the engine (bounded by in-flight concurrency, not fleet
//! size).

use super::index::OnlineSetIndex;

/// Per-client engine ledgers, struct-of-arrays.
#[derive(Clone, Debug)]
pub struct ClientTables {
    /// Sampler decision scores, 1.0 until a weighted policy scores the
    /// client (stamped onto dispatch records as `stay_prob`).
    pub scores: Vec<f64>,
    /// Updates delivered per client (drop-aware sampler posterior input).
    pub delivered: Vec<u32>,
    /// Churn losses per client (the other posterior input).
    pub churned: Vec<u32>,
    /// In-flight flags, one bit per client (an [`OnlineSetIndex`] used
    /// purely for membership).
    busy: OnlineSetIndex,
    /// Dispatch generation per client; bumped on churn cancellation so a
    /// stale Finish event can be recognised and discarded.
    gens: Vec<u32>,
}

impl ClientTables {
    pub fn new(population: usize) -> ClientTables {
        ClientTables {
            scores: vec![1.0; population],
            delivered: vec![0; population],
            churned: vec![0; population],
            busy: OnlineSetIndex::new(population),
            gens: vec![0; population],
        }
    }

    pub fn is_busy(&self, client: usize) -> bool {
        self.busy.contains(client)
    }

    pub fn set_busy(&mut self, client: usize, busy: bool) {
        if busy {
            self.busy.insert(client);
        } else {
            self.busy.remove(client);
        }
    }

    pub fn gen(&self, client: usize) -> u32 {
        self.gens[client]
    }

    pub fn bump_gen(&mut self, client: usize) {
        self.gens[client] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_flags_and_gens() {
        let mut t = ClientTables::new(100);
        assert!(!t.is_busy(64));
        t.set_busy(64, true);
        assert!(t.is_busy(64));
        t.set_busy(64, false);
        t.set_busy(64, false);
        assert!(!t.is_busy(64));
        assert_eq!(t.gen(99), 0);
        t.bump_gen(99);
        t.bump_gen(99);
        assert_eq!(t.gen(99), 2);
        assert_eq!(t.delivered.len(), 100);
        assert_eq!(t.churned.len(), 100);
        assert_eq!(t.scores.len(), 100);
        assert_eq!(t.scores[0], 1.0, "scores start at the engine's neutral 1.0");
    }
}
