//! Lazy availability: materialise a client's next transition only when the
//! clock actually reaches it, instead of queueing every client's full
//! schedule (or scanning all N clients per idle wait).
//!
//! The structure is a private agenda ([`crate::simtime::Agenda`]) holding
//! **one** chained entry per client — its next pending transition — plus an
//! [`OnlineSetIndex`] of the clients currently online. Advancing to `now`
//! pops only the transitions that actually elapsed; each pop asks the
//! underlying [`AvailabilityModel`] for that client's next transition and
//! re-chains it. Markov timelines already extend themselves on demand from
//! per-client forked RNG streams, so the sweep touches exactly the clients
//! whose state could have changed — per-round cost is O(transitions since
//! last sweep · log n), independent of fleet size.
//!
//! Determinism contract (locked by `tests/fleet_equivalence.rs` and the
//! property suite):
//! - after `advance_to(now)`, [`LazyAvailability::online`] holds exactly
//!   `AvailabilityModel::online_clients(now)` (ascending iteration
//!   reproduces the historical pool byte-for-byte), and
//!   [`LazyAvailability::earliest_transition`] equals the eager O(n)
//!   `AvailabilityModel::earliest_transition(now)` scan;
//! - state at a popped transition is read at the midpoint of the
//!   surrounding segment — the same read the event driver performs — so
//!   correlated transitions that do not flip the effective state stay
//!   no-ops;
//! - the round drivers never enqueue availability transitions into the
//!   main `EventQueue`, so replacing their scans with this sweep leaves
//!   `events_processed` (and therefore the `RunReport` JSON) untouched.
//!
//! In the event-driven mode ([`SimEngine::drive_events`]) the main queue
//! must keep carrying every transition — `events_processed` is part of the
//! report — so the agenda is unused there; the engine instead maintains
//! the index incrementally from the popped Transition/Finish/dispatch
//! events as an idle-online refill pool ([`LazyAvailability::note_event_transition`],
//! [`note_busy`](LazyAvailability::note_busy) /
//! [`note_idle`](LazyAvailability::note_idle)).
//!
//! [`SimEngine::drive_events`]: crate::coordinator::SimEngine

use crate::availability::AvailabilityModel;
use crate::simtime::{Agenda, SimTime};

use super::index::OnlineSetIndex;

/// Incrementally-maintained online set + per-client next-transition agenda.
#[derive(Clone, Debug)]
pub struct LazyAvailability {
    agenda: Agenda<usize>,
    online: OnlineSetIndex,
}

impl LazyAvailability {
    /// One O(n) pass at t = 0 seeds the initial state; everything after is
    /// incremental.
    pub fn new(avail: &mut AvailabilityModel) -> LazyAvailability {
        let n = avail.population();
        let mut online = OnlineSetIndex::new(n);
        let mut agenda = Agenda::new();
        for c in 0..n {
            if avail.is_available(c, 0.0) {
                online.insert(c);
            }
            if let Some(t) = avail.next_transition(c, 0.0) {
                agenda.push(t, c);
            }
        }
        LazyAvailability { agenda, online }
    }

    /// Sweep all transitions with time <= `now` (round-driver mode). Each
    /// popped client re-chains its next transition and flips its index
    /// membership to its state just after the pop — read at the segment
    /// midpoint, exactly like the event driver's Transition arm.
    pub fn advance_to(&mut self, avail: &mut AvailabilityModel, now: SimTime) {
        while let Some((t, c)) = self.agenda.pop_until(now) {
            let next = avail.next_transition(c, t);
            let online_now = match next {
                Some(tn) => avail.is_available(c, (t + tn) / 2.0),
                None => avail.is_available(c, t),
            };
            if let Some(tn) = next {
                self.agenda.push(tn, c);
            }
            if online_now {
                self.online.insert(c);
            } else {
                self.online.remove(c);
            }
        }
    }

    /// The set this structure maintains: all online clients in round-driver
    /// mode (after [`advance_to`](Self::advance_to)), the idle-online
    /// refill pool in event-driver mode.
    pub fn online(&self) -> &OnlineSetIndex {
        &self.online
    }

    /// Earliest pending transition strictly after the last
    /// [`advance_to`](Self::advance_to) sweep — the lazy replacement for
    /// the eager O(n) `AvailabilityModel::earliest_transition` scan in the
    /// round drivers' idle waits.
    pub fn earliest_transition(&self) -> Option<SimTime> {
        self.agenda.peek_time()
    }

    /// Event-driver maintenance: a Transition event for `client` was
    /// popped from the main queue with effective state `online_now`.
    /// Idempotent on purpose — correlated-churn transitions that do not
    /// flip the effective state (e.g. a personal-layer flip while the
    /// region is down) arrive here too.
    pub fn note_event_transition(&mut self, client: usize, online_now: bool, busy: bool) {
        if online_now {
            if !busy {
                self.online.insert(client);
            }
        } else {
            self.online.remove(client);
        }
    }

    /// Event-driver maintenance: `client` was dispatched (left the idle
    /// pool).
    pub fn note_busy(&mut self, client: usize) {
        self.online.remove(client);
    }

    /// Event-driver maintenance: `client` finished with a valid generation
    /// (a gen-valid finish implies it stayed online throughout) and is
    /// idle again.
    pub fn note_idle(&mut self, client: usize) {
        self.online.insert(client);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::availability::{AvailabilityConfig, AvailabilityKind};

    fn model(kind: AvailabilityKind, population: usize) -> AvailabilityModel {
        let cfg = AvailabilityConfig {
            kind,
            mean_online_secs: 600.0,
            mean_offline_secs: 200.0,
            regions: 3,
            region_mtbf_secs: 500.0,
            region_outage_secs: 250.0,
            degrade_window_secs: 120.0,
            ..AvailabilityConfig::default()
        };
        AvailabilityModel::build(&cfg, population, 0xFEED).unwrap()
    }

    #[test]
    fn lazy_sweep_tracks_eager_scans() {
        for kind in [
            AvailabilityKind::AlwaysOn,
            AvailabilityKind::Markov,
            AvailabilityKind::Correlated,
        ] {
            // Twin models on the same seed: one swept lazily, one scanned
            // eagerly. (Queries mutate markov timelines, so twins keep the
            // two access patterns from interleaving.)
            let mut lazy_model = model(kind, 40);
            let mut eager_model = model(kind, 40);
            let mut lazy = LazyAvailability::new(&mut lazy_model);
            for step in 0..200 {
                let now = step as f64 * 37.5;
                lazy.advance_to(&mut lazy_model, now);
                assert_eq!(
                    lazy.online().to_vec(),
                    eager_model.online_clients(now),
                    "{kind:?}: online set diverged at t={now}"
                );
                assert_eq!(
                    lazy.earliest_transition(),
                    eager_model.earliest_transition(now),
                    "{kind:?}: earliest transition diverged at t={now}"
                );
            }
        }
    }

    #[test]
    fn always_on_has_empty_agenda_and_full_index() {
        let mut m = AvailabilityModel::always_on(17);
        let mut lazy = LazyAvailability::new(&mut m);
        assert_eq!(lazy.online().len(), 17);
        assert_eq!(lazy.earliest_transition(), None);
        lazy.advance_to(&mut m, 1e9);
        assert_eq!(lazy.online().len(), 17);
    }

    #[test]
    fn event_notes_are_idempotent() {
        let mut m = AvailabilityModel::always_on(8);
        let mut lazy = LazyAvailability::new(&mut m);
        lazy.note_busy(3);
        lazy.note_busy(3);
        assert!(!lazy.online().contains(3));
        // Non-flip transition while busy must NOT re-insert.
        lazy.note_event_transition(3, true, true);
        assert!(!lazy.online().contains(3));
        lazy.note_idle(3);
        lazy.note_event_transition(3, true, false);
        assert!(lazy.online().contains(3));
        lazy.note_event_transition(3, false, false);
        assert!(!lazy.online().contains(3));
    }
}
