//! O(1)-sample online-set index: a word bitset with a Fenwick tree over
//! per-word popcounts.
//!
//! The engine historically materialised its candidate pools with linear
//! scans (`AvailabilityModel::online_clients`, `idle_online_clients`) and
//! then sampled positions out of the resulting ascending `Vec<usize>`.
//! This index keeps the same *set* incrementally and answers the two
//! queries those pools existed for without ever materialising them:
//!
//! - [`OnlineSetIndex::select`]\(k\) — the k-th smallest member, in
//!   O(log n) via a binary-lifting descent of the Fenwick tree followed by
//!   a popcount walk inside one 64-bit word;
//! - [`OnlineSetIndex::sample_one`] / [`OnlineSetIndex::sample_distinct`]
//!   — uniform draws that consume **exactly the same RNG stream** as
//!   indexing into the ascending pool (`pool[rng.usize_below(pool.len())]`
//!   and `Rng::sample_without_replacement` respectively), which is what
//!   makes the lazy/indexed sim core byte-identical to the eager one.
//!
//! Ascending iteration ([`OnlineSetIndex::iter`] / `to_vec`) reproduces the
//! historical pool ordering for the weighted samplers, which genuinely need
//! to score every candidate.

use std::collections::HashMap;

use crate::util::rng::Rng;

/// A dynamic subset of `[0, capacity)` supporting O(log n) rank-select
/// sampling. Insert/remove are idempotent (important for correlated-churn
/// transition events that do not flip a client's effective state).
#[derive(Clone, Debug)]
pub struct OnlineSetIndex {
    /// Membership bitset, 64 ids per word.
    words: Vec<u64>,
    /// Fenwick tree (1-based) over per-word popcounts.
    fen: Vec<u32>,
    len: usize,
    capacity: usize,
}

impl OnlineSetIndex {
    pub fn new(capacity: usize) -> OnlineSetIndex {
        let nwords = capacity.div_ceil(64);
        OnlineSetIndex {
            words: vec![0; nwords],
            fen: vec![0; nwords + 1],
            len: 0,
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn contains(&self, id: usize) -> bool {
        debug_assert!(id < self.capacity);
        self.words[id >> 6] & (1u64 << (id & 63)) != 0
    }

    /// Add `id`; returns false (and changes nothing) if already a member.
    pub fn insert(&mut self, id: usize) -> bool {
        debug_assert!(id < self.capacity);
        let (w, bit) = (id >> 6, 1u64 << (id & 63));
        if self.words[w] & bit != 0 {
            return false;
        }
        self.words[w] |= bit;
        self.len += 1;
        self.fen_add(w, 1);
        true
    }

    /// Remove `id`; returns false (and changes nothing) if not a member.
    pub fn remove(&mut self, id: usize) -> bool {
        debug_assert!(id < self.capacity);
        let (w, bit) = (id >> 6, 1u64 << (id & 63));
        if self.words[w] & bit == 0 {
            return false;
        }
        self.words[w] &= !bit;
        self.len -= 1;
        self.fen_add(w, -1);
        true
    }

    fn fen_add(&mut self, word: usize, delta: i32) {
        let mut i = word + 1;
        while i < self.fen.len() {
            self.fen[i] = (self.fen[i] as i32 + delta) as u32;
            i += i & i.wrapping_neg();
        }
    }

    /// k-th smallest member (0-based rank). Panics when `k >= len()`.
    pub fn select(&self, k: usize) -> usize {
        assert!(k < self.len, "select({k}) of a {}-member set", self.len);
        let nwords = self.words.len();
        // Binary-lifting descent: largest word-prefix whose popcount <= k.
        let mut rem = k as u32;
        let mut pos = 0usize;
        let mut step = 1usize << (usize::BITS - 1 - nwords.leading_zeros());
        while step != 0 {
            let next = pos + step;
            if next <= nwords && self.fen[next] <= rem {
                pos = next;
                rem -= self.fen[next];
            }
            step >>= 1;
        }
        // `pos` words are fully before the target; clear `rem` low set bits
        // inside the target word to land on the answer.
        let mut w = self.words[pos];
        debug_assert!(rem < w.count_ones());
        for _ in 0..rem {
            w &= w - 1;
        }
        (pos << 6) + w.trailing_zeros() as usize
    }

    /// One uniform member. Consumes the same single `usize_below(len)` draw
    /// as `pool[rng.usize_below(pool.len())]` over the ascending pool.
    pub fn sample_one(&self, rng: &mut Rng) -> usize {
        self.select(rng.usize_below(self.len))
    }

    /// `want` distinct uniform members, in draw order. A sparse partial
    /// Fisher–Yates over ranks: same `usize_below(n - i)` draws, in the
    /// same order, as `Rng::sample_without_replacement(len, want)` mapped
    /// through the ascending pool — but O(want log n) instead of O(len).
    pub fn sample_distinct(&self, rng: &mut Rng, want: usize) -> Vec<usize> {
        let n = self.len;
        assert!(want <= n, "cannot sample {want} from {n}");
        // Displaced ranks of the virtual `(0..n)` array; untouched
        // positions hold their own index. (Only read by key, so HashMap
        // iteration order never matters for determinism.)
        let mut moved: HashMap<usize, usize> = HashMap::new();
        let mut out = Vec::with_capacity(want);
        for i in 0..want {
            let j = i + rng.usize_below(n - i);
            let vi = *moved.get(&i).unwrap_or(&i);
            let vj = *moved.get(&j).unwrap_or(&j);
            moved.insert(i, vj);
            moved.insert(j, vi);
            out.push(self.select(vj));
        }
        out
    }

    /// Members in ascending order — the historical pool ordering.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &word)| {
            let mut w = word;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some((wi << 6) + b)
                }
            })
        })
    }

    /// Materialise the ascending pool (for the weighted samplers, which
    /// score every candidate and so are inherently O(pool)).
    pub fn to_vec(&self) -> Vec<usize> {
        self.iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference(idx: &OnlineSetIndex) -> Vec<usize> {
        (0..idx.capacity()).filter(|&i| idx.contains(i)).collect()
    }

    #[test]
    fn insert_remove_select_match_linear_scan() {
        let mut idx = OnlineSetIndex::new(300);
        let mut rng = Rng::seed_from(11);
        for _ in 0..2000 {
            let id = rng.usize_below(300);
            if rng.f64() < 0.5 {
                idx.insert(id);
            } else {
                idx.remove(id);
            }
            let want = reference(&idx);
            assert_eq!(idx.len(), want.len());
            assert_eq!(idx.to_vec(), want);
            for (k, &id) in want.iter().enumerate() {
                assert_eq!(idx.select(k), id);
            }
        }
    }

    #[test]
    fn insert_and_remove_are_idempotent() {
        let mut idx = OnlineSetIndex::new(70);
        assert!(idx.insert(65));
        assert!(!idx.insert(65));
        assert_eq!(idx.len(), 1);
        assert!(idx.remove(65));
        assert!(!idx.remove(65));
        assert!(idx.is_empty());
        assert!(!idx.remove(3));
    }

    #[test]
    fn sample_one_matches_pool_indexing() {
        let mut idx = OnlineSetIndex::new(200);
        for i in (0..200).step_by(3) {
            idx.insert(i);
        }
        let pool = idx.to_vec();
        let mut a = Rng::seed_from(9);
        let mut b = a.clone();
        for _ in 0..500 {
            assert_eq!(idx.sample_one(&mut a), pool[b.usize_below(pool.len())]);
        }
        assert_eq!(a.next_u64(), b.next_u64(), "RNG streams must stay in sync");
    }

    #[test]
    fn sample_distinct_matches_sample_without_replacement() {
        let mut idx = OnlineSetIndex::new(257);
        let mut seed_rng = Rng::seed_from(4);
        for _ in 0..120 {
            idx.insert(seed_rng.usize_below(257));
        }
        let pool = idx.to_vec();
        for want in [0, 1, 2, pool.len() / 2, pool.len()] {
            let mut a = Rng::seed_from(1000 + want as u64);
            let mut b = a.clone();
            let got = idx.sample_distinct(&mut a, want);
            let expect: Vec<usize> = b
                .sample_without_replacement(pool.len(), want)
                .into_iter()
                .map(|i| pool[i])
                .collect();
            assert_eq!(got, expect);
            assert_eq!(a.next_u64(), b.next_u64(), "RNG streams must stay in sync");
        }
    }
}
