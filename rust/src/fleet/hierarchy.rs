//! Hierarchical aggregation tier: regional/edge aggregators between the
//! clients and the root coordinator (Papaya-style, see PAPERS.md).
//!
//! The tier is a pure composition over the aggregation algebra: strategies
//! keep collecting [`Contribution`]s exactly as before and hand the batch
//! to [`HierarchyConfig::aggregate`] instead of calling
//! [`average_delta`] directly. With the default flat topology that call
//! *is* `average_delta`; with `hierarchy = tree` (the spelling `two-tier`
//! still parses) the contributions are routed through per-region edge
//! aggregators (region = `client_id % regions`, the same assignment
//! correlated churn uses), each edge buffers at most `fan_in` updates into
//! a [`PartialAggregate`], `hier_depth - 2` intermediate levels collapse
//! sibling partials `fan_in` at a time, and the root merges what is left.
//! All four registered strategies run unmodified beneath the tier.
//!
//! Determinism notes:
//! - A **single** edge group (`hier_regions = 1`, `hier_fan_in = 0`)
//!   reduces to flat aggregation **bit-exactly**: the edge accumulation
//!   loop mirrors `average_delta`'s operation order f32-for-f32, and the
//!   root merge of one partial is a move, not a re-accumulation.
//! - `hier_depth = 2` (the default) runs ZERO collapse rounds, so the
//!   generalized tree is bit-exact to the historical two-tier shape.
//! - Two or more groups under the `weighted` forward policy compute the
//!   same per-tensor weighted mean but in a different floating-point
//!   summation order — equal to a few ulps, not bitwise. Extra depth only
//!   re-groups the same additions, so it stays within ulps too.
//! - The `uniform` forward policy is deliberately *different semantics*:
//!   each edge forwards its normalised partial mean and the root averages
//!   the partial means per covered tensor, so every edge counts equally
//!   regardless of how many clients reported through it.
//!
//! # Region clocks (`hier_clock = region`)
//!
//! Under the default `hier_clock = shared` every edge flushes within the
//! round/flush that produced its contributions — aggregation is one
//! synchronous pass and nothing below this paragraph runs (that is the
//! byte-identity anchor, locked by `rust/tests/fleet_equivalence.rs`).
//! With `hier_clock = region` each edge aggregator gets its own clock: a
//! [`RegionClock`] holds the region's merged [`PartialAggregate`] until a
//! per-region flush deadline (`hier_flush_secs`, or `auto` to calibrate
//! each region's interval from its own [`HorizonEstimator`] EWMA), then
//! the flushed partial travels the edge→root leg priced by the
//! [`NetworkModel`] registry (`hier_uplink = free | priced`, ratio
//! `hier_up_ratio` — the `net_down_ratio` idiom pointed up), arriving at
//! the root only after its transfer cost elapses on the shared sim clock.
//! The deadline algebra lives here (artifact-free, tested in
//! `rust/tests/fleet_properties.rs`); the event plumbing (the engine's
//! `EdgeFlush` events and in-transit queue) lives in
//! `coordinator/engine.rs`.

use anyhow::Result;

use crate::aggregation::{average_delta, average_delta_jobs, staleness_discount, Contribution};
use crate::model::{ParamVec, Update};
use crate::network::NetworkModel;
use crate::scheduling::HorizonEstimator;
use crate::simtime::SimTime;

/// Aggregation topology between clients and the root coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Every contribution goes straight to the root (the historical path).
    Flat,
    /// Contributions buffer in per-region edge aggregators whose partial
    /// aggregates climb `hier_depth - 2` intermediate levels (fan-in
    /// reused per level) before the root merge. Depth 2 — the default —
    /// is exactly the historical two-tier shape, and the old `two-tier`
    /// spellings parse to this variant.
    Tree,
}

impl Topology {
    pub fn parse(s: &str) -> Result<Topology> {
        match s.to_ascii_lowercase().as_str() {
            "flat" => Ok(Topology::Flat),
            "tree" | "two-tier" | "two_tier" | "twotier" => Ok(Topology::Tree),
            other => anyhow::bail!("unknown hierarchy topology {other:?} (known: flat, tree)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::Flat => "flat",
            Topology::Tree => "tree",
        }
    }
}

/// How an edge aggregator forwards its buffered updates to the root.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForwardPolicy {
    /// Forward per-tensor weighted sums + weight totals; the root's merge
    /// is mathematically identical to flat aggregation (same weighted
    /// mean, floating-point summation order aside).
    Weighted,
    /// Forward the edge's normalised partial mean; the root averages the
    /// partial means per covered tensor, so each edge counts equally.
    Uniform,
}

impl ForwardPolicy {
    pub fn parse(s: &str) -> Result<ForwardPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "weighted" => Ok(ForwardPolicy::Weighted),
            "uniform" => Ok(ForwardPolicy::Uniform),
            other => {
                anyhow::bail!("unknown hierarchy forward policy {other:?} (known: weighted, uniform)")
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ForwardPolicy::Weighted => "weighted",
            ForwardPolicy::Uniform => "uniform",
        }
    }
}

/// Whose clock an edge aggregator flushes on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClockMode {
    /// Edges flush inside the round/flush that produced their
    /// contributions — the historical synchronous behaviour and the
    /// byte-identity anchor.
    #[default]
    Shared,
    /// Each edge holds its partial until its own flush deadline and ships
    /// it up a priced uplink (Papaya-style independently-clocked
    /// aggregators).
    Region,
}

impl ClockMode {
    pub fn parse(s: &str) -> Result<ClockMode> {
        match s.to_ascii_lowercase().as_str() {
            "shared" => Ok(ClockMode::Shared),
            "region" | "edge" => Ok(ClockMode::Region),
            other => anyhow::bail!("unknown hierarchy clock {other:?} (known: shared, region)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClockMode::Shared => "shared",
            ClockMode::Region => "region",
        }
    }
}

/// Config surface of the aggregation tier (`hierarchy=`, `hier_regions=`,
/// `hier_fan_in=`, `hier_forward=`, `hier_depth=`, `hier_clock=`,
/// `hier_flush_secs=`, `hier_uplink=`, `hier_up_ratio=` overrides).
#[derive(Clone, Debug, PartialEq)]
pub struct HierarchyConfig {
    pub topology: Topology,
    /// Edge aggregator count; a client reports to edge `client_id % regions`.
    pub regions: usize,
    /// Max contributions one edge buffers into a single partial aggregate
    /// before cutting the next one; 0 = unbounded (one partial per edge).
    /// Intermediate tree levels reuse the same fan-in for partials.
    pub fan_in: usize,
    pub forward: ForwardPolicy,
    /// Tree depth counting the leaf-edge level and the root; 2 (default)
    /// is the historical two-tier shape and runs zero collapse rounds.
    pub depth: usize,
    /// Whose clock the edges flush on; `Shared` (default) is the
    /// byte-identity anchor and disables everything region-clocked.
    pub clock: ClockMode,
    /// Fixed per-region flush interval, seconds (`hier_clock = region`
    /// only). Also the fallback interval while `auto` has no estimate.
    pub flush_secs: f64,
    /// `hier_flush_secs = auto`: calibrate each region's interval from its
    /// own realized flush cadence ([`HorizonEstimator`] EWMA).
    pub flush_auto: bool,
    /// Edge→root uplink pricing model, resolved through the
    /// [`crate::network`] registry (`free` | `priced`; canonicalized at
    /// parse time).
    pub uplink: String,
    /// Uplink duration as a fraction of the flushing region's mean
    /// effective upload time (only the `priced` model reads it — the
    /// `net_down_ratio` idiom pointed up the tree).
    pub up_ratio: f64,
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        HierarchyConfig {
            topology: Topology::Flat,
            regions: 4,
            fan_in: 0,
            forward: ForwardPolicy::Weighted,
            depth: 2,
            clock: ClockMode::Shared,
            flush_secs: 0.0,
            flush_auto: false,
            uplink: "free".into(),
            up_ratio: 0.25,
        }
    }
}

impl HierarchyConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.regions >= 1, "hier_regions must be >= 1");
        anyhow::ensure!(self.depth >= 2, "hier_depth must be >= 2 (leaf edges + root)");
        anyhow::ensure!(
            self.flush_secs >= 0.0 && self.flush_secs.is_finite(),
            "hier_flush_secs must be finite and >= 0"
        );
        anyhow::ensure!(
            self.up_ratio >= 0.0 && self.up_ratio.is_finite(),
            "hier_up_ratio must be finite and >= 0"
        );
        crate::network::resolve(&self.uplink)?;
        if self.clock == ClockMode::Region {
            anyhow::ensure!(
                self.is_tiered(),
                "hier_clock = region needs a tiered topology (hierarchy = tree)"
            );
            anyhow::ensure!(
                self.flush_auto || self.flush_secs > 0.0,
                "hier_clock = region needs hier_flush_secs > 0 or hier_flush_secs = auto"
            );
        }
        Ok(())
    }

    pub fn is_tiered(&self) -> bool {
        self.topology == Topology::Tree
    }

    /// True when edges run on their own clocks (the non-default mode; the
    /// engine gates every region-clock structure on this).
    pub fn region_clocked(&self) -> bool {
        self.clock == ClockMode::Region && self.is_tiered()
    }

    /// Build the edge→root uplink pricing model (`hier_uplink` /
    /// `hier_up_ratio` through the shared network registry).
    pub fn uplink_model(&self) -> Result<Box<dyn NetworkModel>> {
        let info = crate::network::resolve(&self.uplink)?;
        let net = crate::network::NetworkConfig {
            model: info.name.into(),
            down_ratio: self.up_ratio,
            ..Default::default()
        };
        Ok((info.build)(&net))
    }

    /// Aggregate a round's contributions through the configured topology.
    /// Flat delegates to [`average_delta`]; tree groups by region, chunks
    /// by fan-in, edge-aggregates each chunk, collapses `depth - 2`
    /// intermediate levels and root-merges the rest. Returns a full-shape
    /// `Update` with `boundary = 0`.
    pub fn aggregate(
        &self,
        template: &ParamVec,
        contributions: &[Contribution],
        discount_staleness: bool,
    ) -> Update {
        self.aggregate_jobs(template, contributions, discount_staleness, 1)
    }

    /// [`HierarchyConfig::aggregate`] with a worker-thread count for the
    /// flat path (`agg_jobs=` config key; bit-identical for any count —
    /// see [`average_delta_jobs`]). The tiered path stays serial: the
    /// edge/root split is already the parallel structure there, and its
    /// per-chunk accumulation order is part of the documented semantics.
    pub fn aggregate_jobs(
        &self,
        template: &ParamVec,
        contributions: &[Contribution],
        discount_staleness: bool,
        jobs: usize,
    ) -> Update {
        if !self.is_tiered() {
            return average_delta_jobs(template, contributions, discount_staleness, jobs);
        }
        // Route every contribution to its edge, preserving arrival order
        // within a region (edges see uploads in the order they landed).
        let regions = self.regions;
        let mut groups: Vec<Vec<&Contribution>> = vec![Vec::new(); regions];
        for c in contributions {
            groups[c.client_id % regions].push(c);
        }
        let mut partials = Vec::new();
        for group in &groups {
            if group.is_empty() {
                continue;
            }
            let chunk_len = if self.fan_in == 0 { group.len() } else { self.fan_in };
            for chunk in group.chunks(chunk_len) {
                partials.push(edge_aggregate(
                    template,
                    chunk,
                    discount_staleness,
                    self.forward,
                ));
            }
        }
        // Intermediate tree levels: depth 2 (the default) runs ZERO
        // collapse rounds, keeping the historical two-tier path bit-exact;
        // each extra level merges `fan_in` sibling partials into one.
        for _ in 2..self.depth {
            partials = collapse_level(partials, self.fan_in);
        }
        root_merge(template, partials)
    }

    /// One merged partial per contributing region, ascending region order —
    /// the region-clock absorb path. Each region's chunk partials (same
    /// chunking as [`HierarchyConfig::aggregate_jobs`]) are summed into a
    /// single [`PartialAggregate`] the region's [`RegionClock`] can hold
    /// across rounds.
    pub fn region_partials(
        &self,
        template: &ParamVec,
        contributions: &[Contribution],
        discount_staleness: bool,
    ) -> Vec<(usize, PartialAggregate)> {
        let regions = self.regions;
        let mut groups: Vec<Vec<&Contribution>> = vec![Vec::new(); regions];
        for c in contributions {
            groups[c.client_id % regions].push(c);
        }
        let mut out = Vec::new();
        for (r, group) in groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let chunk_len = if self.fan_in == 0 { group.len() } else { self.fan_in };
            let mut acc: Option<PartialAggregate> = None;
            for chunk in group.chunks(chunk_len) {
                let p = edge_aggregate(template, chunk, discount_staleness, self.forward);
                match &mut acc {
                    None => acc = Some(p),
                    Some(a) => a.merge(&p),
                }
            }
            out.push((r, acc.expect("non-empty region group yields a partial")));
        }
        out
    }
}

/// One intermediate tree level: merge runs of `fan_in` sibling partials
/// (0 = unbounded, i.e. everything into one) in order — the same
/// deterministic left-to-right f32 accumulation the root merge uses.
fn collapse_level(partials: Vec<PartialAggregate>, fan_in: usize) -> Vec<PartialAggregate> {
    if partials.len() <= 1 {
        return partials;
    }
    let chunk = if fan_in == 0 { partials.len() } else { fan_in };
    let mut out = Vec::new();
    let mut iter = partials.into_iter();
    while let Some(mut acc) = iter.next() {
        for _ in 1..chunk {
            match iter.next() {
                Some(p) => acc.merge(&p),
                None => break,
            }
        }
        out.push(acc);
    }
    out
}

/// What one edge forwards to the root: per-tensor f32 accumulators plus a
/// per-tensor f64 normaliser. Under [`ForwardPolicy::Weighted`] these are
/// weighted sums and weight totals; under [`ForwardPolicy::Uniform`] the
/// sums are already normalised partial means and the normaliser is a
/// coverage count (1.0 per covered tensor). Either way the root's merge is
/// the same: add everything, divide each tensor by its normaliser.
#[derive(Clone, Debug)]
pub struct PartialAggregate {
    pub sums: Vec<Vec<f32>>,
    pub wsums: Vec<f64>,
}

impl PartialAggregate {
    /// Fold `other` into this partial: element-wise add of the f32
    /// accumulators and f64 normalisers — the root merge's accumulation
    /// step, reused by intermediate tree levels and [`RegionClock`] holds.
    pub fn merge(&mut self, other: &PartialAggregate) {
        for (dst, src) in self.sums.iter_mut().zip(&other.sums) {
            debug_assert_eq!(dst.len(), src.len());
            for (a, b) in dst.iter_mut().zip(src) {
                *a += b;
            }
        }
        for (a, b) in self.wsums.iter_mut().zip(&other.wsums) {
            *a += b;
        }
    }
}

/// Buffer one edge chunk into a partial aggregate. The accumulation loop
/// mirrors [`average_delta`] operation-for-operation (same skip rule, same
/// normaliser choice, same f32 multiply-accumulate) so a single-chunk
/// hierarchy reduces to the flat path bit-exactly.
pub fn edge_aggregate(
    template: &ParamVec,
    chunk: &[&Contribution],
    discount_staleness: bool,
    forward: ForwardPolicy,
) -> PartialAggregate {
    let n_tensors = template.tensors.len();
    let mut sums: Vec<Vec<f32>> = template
        .tensors
        .iter()
        .map(|t| vec![0.0f32; t.len()])
        .collect();
    let mut wsums = vec![0.0f64; n_tensors];

    for c in chunk {
        let w = if discount_staleness {
            c.weight * staleness_discount(c.staleness)
        } else {
            c.weight
        };
        if w <= 0.0 {
            continue;
        }
        for (i, u) in c.update.tensors.iter().enumerate() {
            let j = c.update.boundary + i;
            // Same normaliser rule as `average_delta`: FedBuff's published
            // discount divides by the undiscounted buffer weight.
            wsums[j] += if discount_staleness { c.weight } else { w };
            let dst = &mut sums[j];
            debug_assert_eq!(dst.len(), u.len());
            let wf = w as f32;
            for (a, b) in dst.iter_mut().zip(u) {
                *a += wf * b;
            }
        }
    }

    if forward == ForwardPolicy::Uniform {
        // Normalise at the edge; the root then averages partial MEANS per
        // covered tensor instead of re-weighting by client count.
        for (t, w) in sums.iter_mut().zip(wsums.iter_mut()) {
            if *w > 0.0 {
                let inv = (1.0 / *w) as f32;
                for v in t.iter_mut() {
                    *v *= inv;
                }
                *w = 1.0;
            }
        }
    }

    PartialAggregate { sums, wsums }
}

/// Root merge: sum the partials' accumulators and normalisers, then divide
/// each covered tensor — the identical finishing division `average_delta`
/// performs. A single partial is moved, not re-accumulated, keeping the
/// one-group case bit-exact.
pub fn root_merge(template: &ParamVec, partials: Vec<PartialAggregate>) -> Update {
    let mut iter = partials.into_iter();
    let Some(mut acc) = iter.next() else {
        return Update {
            boundary: 0,
            tensors: template.tensors.iter().map(|t| vec![0.0f32; t.len()]).collect(),
        };
    };
    for p in iter {
        acc.merge(&p);
    }
    for (t, &w) in acc.sums.iter_mut().zip(&acc.wsums) {
        if w > 0.0 {
            let inv = (1.0 / w) as f32;
            for v in t.iter_mut() {
                *v *= inv;
            }
        }
    }
    Update {
        boundary: 0,
        tensors: acc.sums,
    }
}

/// One edge aggregator's independent clock (`hier_clock = region`): the
/// pure deadline algebra, kept free of event-queue and network plumbing so
/// `rust/tests/fleet_properties.rs` can exercise it artifact-free.
///
/// Lifecycle: the first [`RegionClock::absorb`] into an idle clock opens a
/// window and **arms** a deadline `now + interval` (bumping the event
/// generation — the engine's `EdgeFlush { region, gen }` alarms carry the
/// generation so a re-armed window invalidates stale alarms). Further
/// absorbs merge into the held partial without touching the deadline. At
/// or after the deadline the window is **ripe**; [`RegionClock::flush`]
/// closes it, feeds the realized flush clock to the per-region
/// [`HorizonEstimator`] (backing `hier_flush_secs = auto`) and hands the
/// held partial back for the priced uplink leg.
#[derive(Debug, Default)]
pub struct RegionClock {
    held: Option<PartialAggregate>,
    deadline: Option<SimTime>,
    horizon: HorizonEstimator,
    gen: u64,
}

impl RegionClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// True while a window is open (a partial is held).
    pub fn holds(&self) -> bool {
        self.held.is_some()
    }

    /// The armed flush deadline, if a window is open.
    pub fn deadline(&self) -> Option<SimTime> {
        self.deadline
    }

    /// Current window generation; an `EdgeFlush` alarm is valid only if its
    /// generation matches AND a deadline is still armed.
    pub fn gen(&self) -> u64 {
        self.gen
    }

    /// The flush interval this clock would arm right now: the fixed
    /// `hier_flush_secs` value, or the region's own EWMA-calibrated cadence
    /// under `auto` (falling back to the fixed value until the first
    /// inter-flush interval is observed).
    pub fn interval(&self, flush_secs: f64, flush_auto: bool) -> f64 {
        if flush_auto {
            self.horizon.horizon(flush_secs)
        } else {
            flush_secs
        }
    }

    /// Merge `partial` into the open window, opening one (and arming its
    /// deadline) if the clock was idle. Returns `Some(deadline)` exactly
    /// when a new window was armed — the engine schedules its `EdgeFlush`
    /// alarm off that.
    pub fn absorb(
        &mut self,
        partial: PartialAggregate,
        now: SimTime,
        flush_secs: f64,
        flush_auto: bool,
    ) -> Option<SimTime> {
        match &mut self.held {
            Some(held) => {
                held.merge(&partial);
                None
            }
            None => {
                let deadline = now + self.interval(flush_secs, flush_auto);
                self.held = Some(partial);
                self.deadline = Some(deadline);
                self.gen += 1;
                Some(deadline)
            }
        }
    }

    /// True when the armed deadline has passed and a partial is held.
    pub fn ripe(&self, now: SimTime) -> bool {
        self.holds() && self.deadline.is_some_and(|d| d <= now)
    }

    /// Valid-alarm check for an `EdgeFlush { gen }` event: the window that
    /// armed it must still be open.
    pub fn alarm_matches(&self, gen: u64) -> bool {
        self.gen == gen && self.deadline.is_some()
    }

    /// Close the window at `clock`: disarm, feed the realized flush clock
    /// to the per-region EWMA and hand back the held partial. `None` if no
    /// window was open. Flushing at the *deadline* clock (not the caller's
    /// later observation time) makes event-driven and boundary-polled
    /// flushes equivalent.
    pub fn flush(&mut self, clock: SimTime) -> Option<PartialAggregate> {
        let held = self.held.take()?;
        self.deadline = None;
        self.horizon.observe(clock);
        Some(held)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(tensors: Vec<Vec<f32>>) -> ParamVec {
        ParamVec { tensors }
    }

    fn contrib(
        client_id: usize,
        boundary: usize,
        tensors: Vec<Vec<f32>>,
        weight: f64,
        staleness: u64,
    ) -> Contribution {
        Contribution {
            client_id,
            update: Update { boundary, tensors },
            weight,
            staleness,
        }
    }

    fn tree(regions: usize, fan_in: usize, forward: ForwardPolicy) -> HierarchyConfig {
        HierarchyConfig {
            topology: Topology::Tree,
            regions,
            fan_in,
            forward,
            ..HierarchyConfig::default()
        }
    }

    fn mixed_contributions() -> Vec<Contribution> {
        vec![
            contrib(0, 0, vec![vec![2.0, -1.0], vec![4.0]], 1.0, 0),
            contrib(1, 0, vec![vec![0.5, 2.0], vec![0.25]], 3.0, 1),
            contrib(2, 1, vec![vec![6.0]], 1.0, 2),
            contrib(3, 0, vec![vec![-1.5, 0.75], vec![1.0]], 2.0, 0),
            contrib(7, 1, vec![vec![0.125]], 1.0, 5),
        ]
    }

    #[test]
    fn flat_topology_is_average_delta() {
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = mixed_contributions();
        for discount in [false, true] {
            let flat = HierarchyConfig::default().aggregate(&template, &cs, discount);
            assert_eq!(flat, average_delta(&template, &cs, discount));
        }
    }

    #[test]
    fn single_group_tree_is_bit_exact_to_flat() {
        // The acceptance-criterion reduction: regions = 1, unbounded
        // fan-in. This runs the REAL tiered code path (edge + root), not
        // a structural shortcut, and must still match bitwise.
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = mixed_contributions();
        for discount in [false, true] {
            let tiered = tree(1, 0, ForwardPolicy::Weighted).aggregate(&template, &cs, discount);
            let flat = average_delta(&template, &cs, discount);
            assert_eq!(tiered.boundary, flat.boundary);
            for (a, b) in tiered.tensors.iter().zip(&flat.tensors) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "single-group tier must be bit-exact");
                }
            }
        }
    }

    #[test]
    fn weighted_forward_matches_flat_mean_up_to_rounding() {
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = mixed_contributions();
        let flat = average_delta(&template, &cs, true);
        for (regions, fan_in) in [(2, 0), (3, 0), (4, 1), (2, 2)] {
            let tiered =
                tree(regions, fan_in, ForwardPolicy::Weighted).aggregate(&template, &cs, true);
            for (a, b) in tiered.tensors.iter().zip(&flat.tensors) {
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        (x - y).abs() <= 1e-5 * y.abs().max(1.0),
                        "weighted tier diverged from flat: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn fan_in_chunking_preserves_the_weighted_mean() {
        // fan_in = 1 degenerates to one partial per contribution; the
        // weighted merge must still recover the same mean.
        let template = pv(vec![vec![0.0]]);
        let cs = vec![
            contrib(0, 0, vec![vec![1.0]], 3.0, 0),
            contrib(2, 0, vec![vec![5.0]], 1.0, 0),
        ];
        let tiered = tree(2, 1, ForwardPolicy::Weighted).aggregate(&template, &cs, false);
        assert!((tiered.tensors[0][0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn uniform_forward_counts_each_edge_equally() {
        // Region 0 holds two clients saying +1, region 1 one client saying
        // +4. Weighted mean = 2.0; uniform-across-edges mean = 2.5.
        let template = pv(vec![vec![0.0]]);
        let cs = vec![
            contrib(0, 0, vec![vec![1.0]], 1.0, 0),
            contrib(2, 0, vec![vec![1.0]], 1.0, 0),
            contrib(1, 0, vec![vec![4.0]], 1.0, 0),
        ];
        let weighted = tree(2, 0, ForwardPolicy::Weighted).aggregate(&template, &cs, false);
        let uniform = tree(2, 0, ForwardPolicy::Uniform).aggregate(&template, &cs, false);
        assert!((weighted.tensors[0][0] - 2.0).abs() < 1e-6);
        assert!((uniform.tensors[0][0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn per_tensor_normalizer_survives_the_tier() {
        // A partially-trained client must not dilute tensors it froze,
        // even when its region's partial aggregate covers them.
        let template = pv(vec![vec![0.0], vec![0.0]]);
        let cs = vec![
            contrib(0, 0, vec![vec![2.0], vec![2.0]], 1.0, 0),
            contrib(1, 1, vec![vec![6.0]], 1.0, 0),
        ];
        for forward in [ForwardPolicy::Weighted, ForwardPolicy::Uniform] {
            let tiered = tree(2, 0, forward).aggregate(&template, &cs, false);
            assert_eq!(tiered.tensors[0], vec![2.0], "{forward:?}");
            assert_eq!(tiered.tensors[1], vec![4.0], "{forward:?}");
        }
    }

    #[test]
    fn empty_contributions_give_zero_delta() {
        let template = pv(vec![vec![0.0, 0.0]]);
        let tiered = tree(3, 2, ForwardPolicy::Weighted).aggregate(&template, &[], false);
        assert_eq!(tiered.tensors, vec![vec![0.0, 0.0]]);
        assert_eq!(tiered.boundary, 0);
    }

    #[test]
    fn depth_two_is_bit_exact_to_the_default_and_deeper_trees_stay_close() {
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = mixed_contributions();
        let mut base = tree(3, 1, ForwardPolicy::Weighted);
        base.depth = 2;
        let two = base.aggregate(&template, &cs, true);
        // depth is defaulted to 2, so the explicit spelling is the same path.
        let default_depth = tree(3, 1, ForwardPolicy::Weighted).aggregate(&template, &cs, true);
        for (a, b) in two.tensors.iter().zip(&default_depth.tensors) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "depth 2 must be the two-tier path");
            }
        }
        // Extra levels only re-group the same f32 additions.
        for depth in [3, 4, 5] {
            let mut deep = tree(3, 1, ForwardPolicy::Weighted);
            deep.depth = depth;
            let got = deep.aggregate(&template, &cs, true);
            for (a, b) in got.tensors.iter().zip(&two.tensors) {
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        (x - y).abs() <= 1e-5 * y.abs().max(1.0),
                        "depth {depth} diverged: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn collapse_level_merges_fan_in_siblings_in_order() {
        let one = |v: f32, w: f64| PartialAggregate { sums: vec![vec![v]], wsums: vec![w] };
        let partials = vec![one(1.0, 1.0), one(2.0, 1.0), one(4.0, 2.0)];
        let collapsed = collapse_level(partials, 2);
        assert_eq!(collapsed.len(), 2);
        assert_eq!(collapsed[0].sums[0][0], 3.0);
        assert_eq!(collapsed[0].wsums[0], 2.0);
        assert_eq!(collapsed[1].sums[0][0], 4.0);
        // fan_in = 0 collapses everything into one partial.
        let all = collapse_level(vec![one(1.0, 1.0), one(2.0, 1.0), one(4.0, 2.0)], 0);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].sums[0][0], 7.0);
        assert_eq!(all[0].wsums[0], 4.0);
    }

    #[test]
    fn region_partials_root_merge_matches_the_synchronous_tier() {
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = mixed_contributions();
        for forward in [ForwardPolicy::Weighted, ForwardPolicy::Uniform] {
            let cfg = tree(3, 2, forward);
            let sync = cfg.aggregate(&template, &cs, true);
            let partials = cfg.region_partials(&template, &cs, true);
            assert!(partials.len() <= 3);
            let regions: Vec<usize> = partials.iter().map(|(r, _)| *r).collect();
            assert!(regions.windows(2).all(|w| w[0] < w[1]), "ascending region order");
            let merged = root_merge(
                &template,
                partials.into_iter().map(|(_, p)| p).collect(),
            );
            for (a, b) in merged.tensors.iter().zip(&sync.tensors) {
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        (x - y).abs() <= 1e-5 * y.abs().max(1.0),
                        "{forward:?}: region partials diverged: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn region_clock_arms_flushes_and_invalidates_stale_alarms() {
        let part = || PartialAggregate { sums: vec![vec![1.0]], wsums: vec![1.0] };
        let mut rc = RegionClock::new();
        assert!(!rc.holds());
        assert!(!rc.ripe(1e9));
        // First absorb opens the window and arms now + interval.
        let d = rc.absorb(part(), 100.0, 50.0, false);
        assert_eq!(d, Some(150.0));
        assert!(rc.alarm_matches(rc.gen()));
        // Second absorb merges without re-arming.
        assert_eq!(rc.absorb(part(), 120.0, 50.0, false), None);
        assert!(!rc.ripe(149.0));
        assert!(rc.ripe(150.0));
        let flushed = rc.flush(150.0).expect("held partial");
        assert_eq!(flushed.sums[0][0], 2.0);
        assert_eq!(flushed.wsums[0], 2.0);
        // Flushed: disarmed, and the old alarm generation no longer matches.
        let gen = rc.gen();
        assert!(!rc.alarm_matches(gen));
        assert!(rc.flush(160.0).is_none());
        // Re-arming bumps the generation (stale alarms stay invalid).
        rc.absorb(part(), 200.0, 50.0, false);
        assert_eq!(rc.gen(), gen + 1);
        assert!(!rc.alarm_matches(gen));
    }

    #[test]
    fn region_clock_auto_interval_calibrates_from_its_own_flush_cadence() {
        let part = || PartialAggregate { sums: vec![vec![1.0]], wsums: vec![1.0] };
        let mut rc = RegionClock::new();
        // No estimate yet: auto falls back to the fixed interval.
        assert_eq!(rc.interval(30.0, true), 30.0);
        rc.absorb(part(), 0.0, 30.0, true);
        rc.flush(30.0);
        // One flush sets the EWMA baseline clock, still no interval.
        assert_eq!(rc.interval(30.0, true), 30.0);
        rc.absorb(part(), 40.0, 30.0, true);
        rc.flush(70.0);
        // First observed inter-flush interval (70 - 30 = 40) becomes the
        // estimate; later flushes fold in at the EWMA rate.
        assert_eq!(rc.interval(30.0, true), 40.0);
        let d = rc.absorb(part(), 100.0, 30.0, true).unwrap();
        assert_eq!(d, 140.0);
    }

    #[test]
    fn parse_round_trips_and_rejects_unknowns() {
        for t in [Topology::Flat, Topology::Tree] {
            assert_eq!(Topology::parse(t.name()).unwrap(), t);
        }
        // The historical two-tier spellings all parse to the tree variant.
        for s in ["two-tier", "two_tier", "twotier", "TREE"] {
            assert_eq!(Topology::parse(s).unwrap(), Topology::Tree);
        }
        assert!(Topology::parse("ring").is_err());
        for f in [ForwardPolicy::Weighted, ForwardPolicy::Uniform] {
            assert_eq!(ForwardPolicy::parse(f.name()).unwrap(), f);
        }
        assert!(ForwardPolicy::parse("median").is_err());
        for c in [ClockMode::Shared, ClockMode::Region] {
            assert_eq!(ClockMode::parse(c.name()).unwrap(), c);
        }
        assert_eq!(ClockMode::parse("edge").unwrap(), ClockMode::Region);
        assert!(ClockMode::parse("lamport").is_err());
        assert!(tree(0, 0, ForwardPolicy::Weighted).validate().is_err());
        assert!(HierarchyConfig::default().validate().is_ok());
    }

    #[test]
    fn validate_gates_the_region_clock_surface() {
        let mut cfg = tree(4, 0, ForwardPolicy::Weighted);
        cfg.validate().unwrap();
        cfg.depth = 1;
        assert!(cfg.validate().is_err(), "depth < 2 is not a tree");
        cfg.depth = 3;
        cfg.validate().unwrap();
        // Region clocks need a flush interval (fixed or auto)...
        cfg.clock = ClockMode::Region;
        assert!(cfg.validate().is_err(), "region clock needs an interval");
        cfg.flush_secs = 60.0;
        cfg.validate().unwrap();
        cfg.flush_secs = 0.0;
        cfg.flush_auto = true;
        cfg.validate().unwrap();
        // ...and a tiered topology.
        cfg.topology = Topology::Flat;
        assert!(cfg.validate().is_err(), "region clock on flat is meaningless");
        cfg.topology = Topology::Tree;
        cfg.up_ratio = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.up_ratio = 0.5;
        cfg.uplink = "carrier-pigeon".into();
        assert!(cfg.validate().is_err());
        cfg.uplink = "priced".into();
        cfg.validate().unwrap();
        let model = cfg.uplink_model().unwrap();
        assert_eq!(model.name(), "priced");
        assert_eq!(model.downlink_secs(10.0), 5.0, "hier_up_ratio prices the leg");
        cfg.uplink = "free".into();
        assert_eq!(cfg.uplink_model().unwrap().downlink_secs(10.0), 0.0);
    }
}
