//! Hierarchical aggregation tier: regional/edge aggregators between the
//! clients and the root coordinator (Papaya-style, see PAPERS.md).
//!
//! The tier is a pure composition over the aggregation algebra: strategies
//! keep collecting [`Contribution`]s exactly as before and hand the batch
//! to [`HierarchyConfig::aggregate`] instead of calling
//! [`average_delta`] directly. With the default flat topology that call
//! *is* `average_delta`; with `hierarchy = two-tier` the contributions are
//! routed through per-region edge aggregators (region = `client_id %
//! regions`, the same assignment correlated churn uses), each edge buffers
//! at most `fan_in` updates into a [`PartialAggregate`], and the root
//! merges the partials. All four registered strategies run unmodified
//! beneath the tier.
//!
//! Determinism notes:
//! - A **single** edge group (`hier_regions = 1`, `hier_fan_in = 0`)
//!   reduces to flat aggregation **bit-exactly**: the edge accumulation
//!   loop mirrors `average_delta`'s operation order f32-for-f32, and the
//!   root merge of one partial is a move, not a re-accumulation.
//! - Two or more groups under the `weighted` forward policy compute the
//!   same per-tensor weighted mean but in a different floating-point
//!   summation order — equal to a few ulps, not bitwise.
//! - The `uniform` forward policy is deliberately *different semantics*:
//!   each edge forwards its normalised partial mean and the root averages
//!   the partial means per covered tensor, so every edge counts equally
//!   regardless of how many clients reported through it.

use anyhow::Result;

use crate::aggregation::{average_delta, average_delta_jobs, staleness_discount, Contribution};
use crate::model::{ParamVec, Update};

/// Aggregation topology between clients and the root coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Every contribution goes straight to the root (the historical path).
    Flat,
    /// Contributions buffer in per-region edge aggregators that forward
    /// partial aggregates to the root.
    TwoTier,
}

impl Topology {
    pub fn parse(s: &str) -> Result<Topology> {
        match s.to_ascii_lowercase().as_str() {
            "flat" => Ok(Topology::Flat),
            "two-tier" | "two_tier" | "twotier" => Ok(Topology::TwoTier),
            other => anyhow::bail!("unknown hierarchy topology {other:?} (known: flat, two-tier)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::Flat => "flat",
            Topology::TwoTier => "two-tier",
        }
    }
}

/// How an edge aggregator forwards its buffered updates to the root.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForwardPolicy {
    /// Forward per-tensor weighted sums + weight totals; the root's merge
    /// is mathematically identical to flat aggregation (same weighted
    /// mean, floating-point summation order aside).
    Weighted,
    /// Forward the edge's normalised partial mean; the root averages the
    /// partial means per covered tensor, so each edge counts equally.
    Uniform,
}

impl ForwardPolicy {
    pub fn parse(s: &str) -> Result<ForwardPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "weighted" => Ok(ForwardPolicy::Weighted),
            "uniform" => Ok(ForwardPolicy::Uniform),
            other => {
                anyhow::bail!("unknown hierarchy forward policy {other:?} (known: weighted, uniform)")
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ForwardPolicy::Weighted => "weighted",
            ForwardPolicy::Uniform => "uniform",
        }
    }
}

/// Config surface of the aggregation tier (`hierarchy=`, `hier_regions=`,
/// `hier_fan_in=`, `hier_forward=` overrides).
#[derive(Clone, Debug, PartialEq)]
pub struct HierarchyConfig {
    pub topology: Topology,
    /// Edge aggregator count; a client reports to edge `client_id % regions`.
    pub regions: usize,
    /// Max contributions one edge buffers into a single partial aggregate
    /// before cutting the next one; 0 = unbounded (one partial per edge).
    pub fan_in: usize,
    pub forward: ForwardPolicy,
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        HierarchyConfig {
            topology: Topology::Flat,
            regions: 4,
            fan_in: 0,
            forward: ForwardPolicy::Weighted,
        }
    }
}

impl HierarchyConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.regions >= 1, "hier_regions must be >= 1");
        Ok(())
    }

    pub fn is_tiered(&self) -> bool {
        self.topology == Topology::TwoTier
    }

    /// Aggregate a round's contributions through the configured topology.
    /// Flat delegates to [`average_delta`]; two-tier groups by region,
    /// chunks by fan-in, edge-aggregates each chunk and root-merges the
    /// partials. Returns a full-shape `Update` with `boundary = 0`.
    pub fn aggregate(
        &self,
        template: &ParamVec,
        contributions: &[Contribution],
        discount_staleness: bool,
    ) -> Update {
        self.aggregate_jobs(template, contributions, discount_staleness, 1)
    }

    /// [`HierarchyConfig::aggregate`] with a worker-thread count for the
    /// flat path (`agg_jobs=` config key; bit-identical for any count —
    /// see [`average_delta_jobs`]). The two-tier path stays serial: the
    /// edge/root split is already the parallel structure there, and its
    /// per-chunk accumulation order is part of the documented semantics.
    pub fn aggregate_jobs(
        &self,
        template: &ParamVec,
        contributions: &[Contribution],
        discount_staleness: bool,
        jobs: usize,
    ) -> Update {
        if !self.is_tiered() {
            return average_delta_jobs(template, contributions, discount_staleness, jobs);
        }
        // Route every contribution to its edge, preserving arrival order
        // within a region (edges see uploads in the order they landed).
        let regions = self.regions;
        let mut groups: Vec<Vec<&Contribution>> = vec![Vec::new(); regions];
        for c in contributions {
            groups[c.client_id % regions].push(c);
        }
        let mut partials = Vec::new();
        for group in &groups {
            if group.is_empty() {
                continue;
            }
            let chunk_len = if self.fan_in == 0 { group.len() } else { self.fan_in };
            for chunk in group.chunks(chunk_len) {
                partials.push(edge_aggregate(
                    template,
                    chunk,
                    discount_staleness,
                    self.forward,
                ));
            }
        }
        root_merge(template, partials)
    }
}

/// What one edge forwards to the root: per-tensor f32 accumulators plus a
/// per-tensor f64 normaliser. Under [`ForwardPolicy::Weighted`] these are
/// weighted sums and weight totals; under [`ForwardPolicy::Uniform`] the
/// sums are already normalised partial means and the normaliser is a
/// coverage count (1.0 per covered tensor). Either way the root's merge is
/// the same: add everything, divide each tensor by its normaliser.
#[derive(Clone, Debug)]
pub struct PartialAggregate {
    pub sums: Vec<Vec<f32>>,
    pub wsums: Vec<f64>,
}

/// Buffer one edge chunk into a partial aggregate. The accumulation loop
/// mirrors [`average_delta`] operation-for-operation (same skip rule, same
/// normaliser choice, same f32 multiply-accumulate) so a single-chunk
/// hierarchy reduces to the flat path bit-exactly.
pub fn edge_aggregate(
    template: &ParamVec,
    chunk: &[&Contribution],
    discount_staleness: bool,
    forward: ForwardPolicy,
) -> PartialAggregate {
    let n_tensors = template.tensors.len();
    let mut sums: Vec<Vec<f32>> = template
        .tensors
        .iter()
        .map(|t| vec![0.0f32; t.len()])
        .collect();
    let mut wsums = vec![0.0f64; n_tensors];

    for c in chunk {
        let w = if discount_staleness {
            c.weight * staleness_discount(c.staleness)
        } else {
            c.weight
        };
        if w <= 0.0 {
            continue;
        }
        for (i, u) in c.update.tensors.iter().enumerate() {
            let j = c.update.boundary + i;
            // Same normaliser rule as `average_delta`: FedBuff's published
            // discount divides by the undiscounted buffer weight.
            wsums[j] += if discount_staleness { c.weight } else { w };
            let dst = &mut sums[j];
            debug_assert_eq!(dst.len(), u.len());
            let wf = w as f32;
            for (a, b) in dst.iter_mut().zip(u) {
                *a += wf * b;
            }
        }
    }

    if forward == ForwardPolicy::Uniform {
        // Normalise at the edge; the root then averages partial MEANS per
        // covered tensor instead of re-weighting by client count.
        for (t, w) in sums.iter_mut().zip(wsums.iter_mut()) {
            if *w > 0.0 {
                let inv = (1.0 / *w) as f32;
                for v in t.iter_mut() {
                    *v *= inv;
                }
                *w = 1.0;
            }
        }
    }

    PartialAggregate { sums, wsums }
}

/// Root merge: sum the partials' accumulators and normalisers, then divide
/// each covered tensor — the identical finishing division `average_delta`
/// performs. A single partial is moved, not re-accumulated, keeping the
/// one-group case bit-exact.
pub fn root_merge(template: &ParamVec, partials: Vec<PartialAggregate>) -> Update {
    let mut iter = partials.into_iter();
    let Some(mut acc) = iter.next() else {
        return Update {
            boundary: 0,
            tensors: template.tensors.iter().map(|t| vec![0.0f32; t.len()]).collect(),
        };
    };
    for p in iter {
        for (dst, src) in acc.sums.iter_mut().zip(&p.sums) {
            debug_assert_eq!(dst.len(), src.len());
            for (a, b) in dst.iter_mut().zip(src) {
                *a += b;
            }
        }
        for (a, b) in acc.wsums.iter_mut().zip(&p.wsums) {
            *a += b;
        }
    }
    for (t, &w) in acc.sums.iter_mut().zip(&acc.wsums) {
        if w > 0.0 {
            let inv = (1.0 / w) as f32;
            for v in t.iter_mut() {
                *v *= inv;
            }
        }
    }
    Update {
        boundary: 0,
        tensors: acc.sums,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(tensors: Vec<Vec<f32>>) -> ParamVec {
        ParamVec { tensors }
    }

    fn contrib(
        client_id: usize,
        boundary: usize,
        tensors: Vec<Vec<f32>>,
        weight: f64,
        staleness: u64,
    ) -> Contribution {
        Contribution {
            client_id,
            update: Update { boundary, tensors },
            weight,
            staleness,
        }
    }

    fn two_tier(regions: usize, fan_in: usize, forward: ForwardPolicy) -> HierarchyConfig {
        HierarchyConfig {
            topology: Topology::TwoTier,
            regions,
            fan_in,
            forward,
        }
    }

    fn mixed_contributions() -> Vec<Contribution> {
        vec![
            contrib(0, 0, vec![vec![2.0, -1.0], vec![4.0]], 1.0, 0),
            contrib(1, 0, vec![vec![0.5, 2.0], vec![0.25]], 3.0, 1),
            contrib(2, 1, vec![vec![6.0]], 1.0, 2),
            contrib(3, 0, vec![vec![-1.5, 0.75], vec![1.0]], 2.0, 0),
            contrib(7, 1, vec![vec![0.125]], 1.0, 5),
        ]
    }

    #[test]
    fn flat_topology_is_average_delta() {
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = mixed_contributions();
        for discount in [false, true] {
            let flat = HierarchyConfig::default().aggregate(&template, &cs, discount);
            assert_eq!(flat, average_delta(&template, &cs, discount));
        }
    }

    #[test]
    fn single_group_two_tier_is_bit_exact_to_flat() {
        // The acceptance-criterion reduction: regions = 1, unbounded
        // fan-in. This runs the REAL two-tier code path (edge + root), not
        // a structural shortcut, and must still match bitwise.
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = mixed_contributions();
        for discount in [false, true] {
            let tiered =
                two_tier(1, 0, ForwardPolicy::Weighted).aggregate(&template, &cs, discount);
            let flat = average_delta(&template, &cs, discount);
            assert_eq!(tiered.boundary, flat.boundary);
            for (a, b) in tiered.tensors.iter().zip(&flat.tensors) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "single-group tier must be bit-exact");
                }
            }
        }
    }

    #[test]
    fn weighted_forward_matches_flat_mean_up_to_rounding() {
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = mixed_contributions();
        let flat = average_delta(&template, &cs, true);
        for (regions, fan_in) in [(2, 0), (3, 0), (4, 1), (2, 2)] {
            let tiered =
                two_tier(regions, fan_in, ForwardPolicy::Weighted).aggregate(&template, &cs, true);
            for (a, b) in tiered.tensors.iter().zip(&flat.tensors) {
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        (x - y).abs() <= 1e-5 * y.abs().max(1.0),
                        "weighted tier diverged from flat: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn fan_in_chunking_preserves_the_weighted_mean() {
        // fan_in = 1 degenerates to one partial per contribution; the
        // weighted merge must still recover the same mean.
        let template = pv(vec![vec![0.0]]);
        let cs = vec![
            contrib(0, 0, vec![vec![1.0]], 3.0, 0),
            contrib(2, 0, vec![vec![5.0]], 1.0, 0),
        ];
        let tiered = two_tier(2, 1, ForwardPolicy::Weighted).aggregate(&template, &cs, false);
        assert!((tiered.tensors[0][0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn uniform_forward_counts_each_edge_equally() {
        // Region 0 holds two clients saying +1, region 1 one client saying
        // +4. Weighted mean = 2.0; uniform-across-edges mean = 2.5.
        let template = pv(vec![vec![0.0]]);
        let cs = vec![
            contrib(0, 0, vec![vec![1.0]], 1.0, 0),
            contrib(2, 0, vec![vec![1.0]], 1.0, 0),
            contrib(1, 0, vec![vec![4.0]], 1.0, 0),
        ];
        let weighted = two_tier(2, 0, ForwardPolicy::Weighted).aggregate(&template, &cs, false);
        let uniform = two_tier(2, 0, ForwardPolicy::Uniform).aggregate(&template, &cs, false);
        assert!((weighted.tensors[0][0] - 2.0).abs() < 1e-6);
        assert!((uniform.tensors[0][0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn per_tensor_normalizer_survives_the_tier() {
        // A partially-trained client must not dilute tensors it froze,
        // even when its region's partial aggregate covers them.
        let template = pv(vec![vec![0.0], vec![0.0]]);
        let cs = vec![
            contrib(0, 0, vec![vec![2.0], vec![2.0]], 1.0, 0),
            contrib(1, 1, vec![vec![6.0]], 1.0, 0),
        ];
        for forward in [ForwardPolicy::Weighted, ForwardPolicy::Uniform] {
            let tiered = two_tier(2, 0, forward).aggregate(&template, &cs, false);
            assert_eq!(tiered.tensors[0], vec![2.0], "{forward:?}");
            assert_eq!(tiered.tensors[1], vec![4.0], "{forward:?}");
        }
    }

    #[test]
    fn empty_contributions_give_zero_delta() {
        let template = pv(vec![vec![0.0, 0.0]]);
        let tiered = two_tier(3, 2, ForwardPolicy::Weighted).aggregate(&template, &[], false);
        assert_eq!(tiered.tensors, vec![vec![0.0, 0.0]]);
        assert_eq!(tiered.boundary, 0);
    }

    #[test]
    fn parse_round_trips_and_rejects_unknowns() {
        for t in [Topology::Flat, Topology::TwoTier] {
            assert_eq!(Topology::parse(t.name()).unwrap(), t);
        }
        assert_eq!(Topology::parse("two_tier").unwrap(), Topology::TwoTier);
        assert!(Topology::parse("ring").is_err());
        for f in [ForwardPolicy::Weighted, ForwardPolicy::Uniform] {
            assert_eq!(ForwardPolicy::parse(f.name()).unwrap(), f);
        }
        assert!(ForwardPolicy::parse("median").is_err());
        assert!(two_tier(0, 0, ForwardPolicy::Weighted).validate().is_err());
        assert!(HierarchyConfig::default().validate().is_ok());
    }
}
