//! TimelyFL: heterogeneity-aware asynchronous federated learning with
//! adaptive partial training.
//!
//! Reproduction of Zhang et al., "TimelyFL: Heterogeneity-aware Asynchronous
//! Federated Learning with Adaptive Partial Training" (2023), as a
//! three-layer rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the federated-learning coordinator:
//!   pluggable FL protocols behind a `Strategy` trait + registry
//!   (`coordinator::registry`; TimelyFL, FedBuff, SyncFL, SemiAsync) driven
//!   by a shared `SimEngine` that owns local-time estimation inputs, client
//!   sampling, aggregation lifecycle, FedAvg / FedOpt server optimizers, a
//!   machine-readable run-event stream (`metrics::events`), and an
//!   event-driven heterogeneous-device simulator with a first-class client
//!   availability & churn subsystem (`availability`: always-on / Markov
//!   on-off / diurnal / trace-driven / correlated-regional processes whose
//!   transitions are `simtime` events, with degrade-before-drop bandwidth
//!   coupling) plus availability-aware client sampling
//!   (`coordinator::sampler`: uniform / stay-prob / drop-aware policies
//!   behind a registry), a scheduling subsystem (`scheduling`: pluggable
//!   per-update aggregation weighting behind an `AggWeigher` registry,
//!   fairness-capped sampling, calibrated sampling horizons) and
//!   million-client fleet support (`fleet`: a lazy,
//!   indexed sim core plus a hierarchical aggregation tier, both
//!   byte-identical to the flat/eager paths where they overlap). See
//!   `docs/architecture.md`. The evaluation surface
//!   is declarative: named scenarios × sweep grids × a thread-parallel
//!   multi-seed runner (`experiment`; `timelyfl sweep`,
//!   `docs/experiments.md`).
//! - **Layer 2 (python/compile/model.py)** — JAX forward/backward train-step
//!   graphs (with partial-training variants) lowered once to HLO text.
//! - **Layer 1 (python/compile/kernels/)** — Pallas kernels for the dense
//!   compute hot-spot, verified against a pure-jnp oracle.
//!
//! Python never runs on the training path: the rust binary loads the AOT
//! artifacts via PJRT (`xla` crate) and drives everything.

pub mod aggregation;
pub mod availability;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod devices;
pub mod experiment;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod network;
pub mod runtime;
pub mod scheduling;
pub mod simtime;
pub mod util;
