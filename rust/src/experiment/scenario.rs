//! Scenario registry: name → reusable experimental setup, in canonical
//! listing order (mirrors `coordinator::registry` for strategies).
//!
//! A scenario names a *setup*, not a sweep: base preset × availability
//! process × fleet heterogeneity × non-iid level. Sweeps are declared on
//! top with [`super::SweepGrid`] axes. Overrides are plain `key = value`
//! pairs applied through `config::parse::apply_override`, so a scenario
//! is validated exactly like a config file — adding one is appending a
//! [`ScenarioSpec`] entry with strings, no new code paths.

use anyhow::Result;

use crate::config::{parse as cfgparse, RunConfig};

/// One registered scenario.
pub struct ScenarioSpec {
    /// Canonical display name (what `timelyfl sweep --scenario` takes).
    pub name: &'static str,
    /// Extra accepted spellings (lowercase); the canonical name matches
    /// case-insensitively without being listed.
    pub aliases: &'static [&'static str],
    /// One-liner for `timelyfl scenarios`.
    pub summary: &'static str,
    /// Base paper preset (`RunConfig::preset`); `None` = the default config.
    pub preset: Option<&'static str>,
    /// `key = value` overrides on top of the preset, applied through
    /// `config::parse` (same validation as a config file).
    pub overrides: &'static [(&'static str, &'static str)],
}

impl ScenarioSpec {
    /// Materialise the scenario's base `RunConfig` (validated).
    pub fn config(&self) -> Result<RunConfig> {
        let mut cfg = match self.preset {
            Some(p) => RunConfig::preset(p)?,
            None => RunConfig::default(),
        };
        for (k, v) in self.overrides {
            cfgparse::apply_override(&mut cfg, k, v)
                .map_err(|e| anyhow::anyhow!("scenario {}: {k} = {v}: {e:#}", self.name))?;
        }
        cfg.validate()
            .map_err(|e| anyhow::anyhow!("scenario {}: {e:#}", self.name))?;
        Ok(cfg)
    }
}

/// All registered scenarios, in listing order: the paper presets first
/// (aliased by their preset names so bench `Case` tables resolve
/// unchanged), then the availability / non-iid / fleet variants that go
/// beyond the paper.
pub static SCENARIOS: &[ScenarioSpec] = &[
    ScenarioSpec {
        name: "cifar",
        aliases: &["cifar_fedavg"],
        summary: "CIFAR-10 / ResNet-20, FedAvg, always-on population (paper §4.1 baseline)",
        preset: Some("cifar_fedavg"),
        overrides: &[],
    },
    ScenarioSpec {
        name: "cifar_fedopt",
        aliases: &[],
        summary: "CIFAR-10 / ResNet-20 with the Adam server optimizer",
        preset: Some("cifar_fedopt"),
        overrides: &[],
    },
    ScenarioSpec {
        name: "speech",
        aliases: &["speech_fedavg"],
        summary: "Google Speech / VGG11, FedAvg; ~507 MB model, comm-bound stragglers",
        preset: Some("speech_fedavg"),
        overrides: &[],
    },
    ScenarioSpec {
        name: "speech_fedopt",
        aliases: &[],
        summary: "Google Speech / VGG11 with the Adam server optimizer",
        preset: Some("speech_fedopt"),
        overrides: &[],
    },
    ScenarioSpec {
        name: "kws",
        aliases: &["kws_fedavg"],
        summary: "lightweight KWS model (79k params, Table 2), FedAvg",
        preset: Some("kws_fedavg"),
        overrides: &[],
    },
    ScenarioSpec {
        name: "kws_fedopt",
        aliases: &[],
        summary: "lightweight KWS model with the Adam server optimizer",
        preset: Some("kws_fedopt"),
        overrides: &[],
    },
    ScenarioSpec {
        name: "reddit",
        aliases: &["reddit_fedavg"],
        summary: "Reddit / ALBERT next-word prediction (perplexity), FedAvg",
        preset: Some("reddit_fedavg"),
        overrides: &[],
    },
    ScenarioSpec {
        name: "reddit_fedopt",
        aliases: &[],
        summary: "Reddit / ALBERT with the Adam server optimizer",
        preset: Some("reddit_fedopt"),
        overrides: &[],
    },
    ScenarioSpec {
        name: "cifar_churn",
        aliases: &["churn"],
        summary: "CIFAR under heavy Markov churn (~1/3 online, dwells ~ round times) — \
                  the SEAFL selective-participation regime",
        preset: Some("cifar_fedavg"),
        overrides: &[
            ("availability", "markov"),
            ("avail_mean_online_secs", "400"),
            ("avail_mean_offline_secs", "800"),
        ],
    },
    ScenarioSpec {
        name: "cifar_diurnal",
        aliases: &["diurnal"],
        summary: "CIFAR with sine-gated diurnal availability, 8 timezone shards",
        preset: Some("cifar_fedavg"),
        overrides: &[
            ("availability", "diurnal"),
            ("avail_diurnal_period_secs", "7200"),
            ("avail_diurnal_duty", "0.5"),
            ("avail_diurnal_shards", "8"),
        ],
    },
    ScenarioSpec {
        name: "cifar_regional",
        aliases: &["regional"],
        summary: "CIFAR under correlated regional churn (8 regions flipping together, \
                  bandwidth degrading before drops) — the availability-aware-sampler testbed",
        preset: Some("cifar_fedavg"),
        overrides: &[
            ("availability", "correlated"),
            ("avail_regions", "8"),
            ("avail_region_mtbf_secs", "2400"),
            ("avail_region_outage_secs", "800"),
            ("avail_mean_online_secs", "2400"),
            ("avail_mean_offline_secs", "600"),
            ("avail_degrade_window_secs", "300"),
            ("avail_degrade_floor", "0.25"),
            ("sampler_horizon_secs", "400"),
        ],
    },
    ScenarioSpec {
        name: "cifar_downlink",
        aliases: &["downlink"],
        summary: "cifar_regional plus priced model dissemination (asymmetric downlink, \
                  bandwidth-aware workload rebalancing) — the network-subsystem testbed; \
                  sweep `network=free,priced` to isolate the dissemination cost",
        preset: Some("cifar_fedavg"),
        overrides: &[
            ("availability", "correlated"),
            ("avail_regions", "8"),
            ("avail_region_mtbf_secs", "2400"),
            ("avail_region_outage_secs", "800"),
            ("avail_mean_online_secs", "2400"),
            ("avail_mean_offline_secs", "600"),
            ("avail_degrade_window_secs", "300"),
            ("avail_degrade_floor", "0.25"),
            ("sampler_horizon_secs", "400"),
            ("network", "priced"),
            ("net_down_ratio", "0.25"),
            ("net_rebalance", "true"),
        ],
    },
    ScenarioSpec {
        name: "cifar_noniid",
        aliases: &["noniid"],
        summary: "CIFAR at severe non-iid (Dirichlet alpha 0.05) — where inclusiveness \
                  matters most (Fig. 6's hard end)",
        preset: Some("cifar_fedavg"),
        overrides: &[("dirichlet_alpha", "0.05")],
    },
    ScenarioSpec {
        name: "fleet_hetero",
        aliases: &[],
        summary: "1000-client calibrated fleet, no training — compute/bandwidth \
                  distribution studies (Fig. 8)",
        preset: None,
        overrides: &[("population", "1000"), ("concurrency", "32")],
    },
    ScenarioSpec {
        name: "kws_smoke",
        aliases: &["smoke"],
        summary: "tiny KWS setup (12 clients, 4 rounds) for CI smokes and quick sweeps",
        preset: Some("kws_fedavg"),
        overrides: &[
            ("population", "12"),
            ("concurrency", "6"),
            ("rounds", "4"),
            ("eval_every", "2"),
            ("eval_batches", "1"),
            ("steps_per_epoch", "1"),
            ("max_local_epochs", "2"),
            ("sim_model_bytes", "3.2e5"),
        ],
    },
    ScenarioSpec {
        name: "fleet_1m",
        aliases: &["fleet1m", "million"],
        summary: "million-client KWS fleet under Markov churn on the lazy, indexed sim core \
                  with two-tier aggregation (32 regions, fan-in 64) — the Table 1-style \
                  four-strategy comparison at planetary scale",
        preset: Some("kws_fedavg"),
        overrides: &[
            ("population", "1000000"),
            ("concurrency", "256"),
            ("rounds", "4"),
            ("eval_every", "4"),
            ("eval_batches", "1"),
            ("steps_per_epoch", "1"),
            ("max_local_epochs", "2"),
            ("sim_model_bytes", "3.2e5"),
            ("availability", "markov"),
            ("avail_mean_online_secs", "14400"),
            ("avail_mean_offline_secs", "7200"),
            ("fleet_core", "lazy"),
            ("hierarchy", "two-tier"),
            ("hier_regions", "32"),
            ("hier_fan_in", "64"),
        ],
    },
    ScenarioSpec {
        name: "fleet_50k",
        aliases: &["fleet50k"],
        summary: "50k-client downscale of fleet_1m (2 regions, unbounded fan-in) — the \
                  CI-sized hierarchical smoke; `--set fleet_core=eager` flips it to the \
                  byte-identical reference path",
        preset: Some("kws_fedavg"),
        overrides: &[
            ("population", "50000"),
            ("concurrency", "64"),
            ("rounds", "4"),
            ("eval_every", "4"),
            ("eval_batches", "1"),
            ("steps_per_epoch", "1"),
            ("max_local_epochs", "2"),
            ("sim_model_bytes", "3.2e5"),
            ("availability", "markov"),
            ("fleet_core", "lazy"),
            ("hierarchy", "two-tier"),
            ("hier_regions", "2"),
        ],
    },
    ScenarioSpec {
        name: "fleet_tree",
        aliases: &["tree"],
        summary: "fleet_50k on a depth-3 tree with region-clocked edge aggregators \
                  (auto-calibrated flush windows, priced edge->root uplink) — the \
                  edge-clock testbed; `--set hier_clock=shared` flips back to the \
                  byte-identical lockstep reference",
        preset: Some("kws_fedavg"),
        overrides: &[
            ("population", "50000"),
            ("concurrency", "64"),
            ("rounds", "4"),
            ("eval_every", "4"),
            ("eval_batches", "1"),
            ("steps_per_epoch", "1"),
            ("max_local_epochs", "2"),
            ("sim_model_bytes", "3.2e5"),
            ("availability", "markov"),
            ("fleet_core", "lazy"),
            ("hierarchy", "tree"),
            ("hier_regions", "4"),
            ("hier_fan_in", "2"),
            ("hier_depth", "3"),
            ("hier_clock", "region"),
            ("hier_flush_secs", "auto"),
            ("hier_uplink", "priced"),
            ("hier_up_ratio", "0.25"),
        ],
    },
];

/// Case-insensitive lookup by canonical name or alias.
pub fn find(name: &str) -> Option<&'static ScenarioSpec> {
    let needle = name.to_ascii_lowercase();
    SCENARIOS
        .iter()
        .find(|s| s.name.to_ascii_lowercase() == needle || s.aliases.contains(&needle.as_str()))
}

/// Like [`find`], but an actionable error listing the known scenarios.
pub fn resolve(name: &str) -> Result<&'static ScenarioSpec> {
    find(name).ok_or_else(|| {
        anyhow::anyhow!("unknown scenario {name:?} (known: {})", names().join(", "))
    })
}

/// Canonical names, in registry order.
pub fn names() -> Vec<&'static str> {
    SCENARIOS.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::availability::AvailabilityKind;

    #[test]
    fn every_scenario_materialises_a_valid_config() {
        for s in SCENARIOS {
            let cfg = s.config().unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
            assert!(!s.summary.is_empty(), "{}: empty summary", s.name);
        }
    }

    #[test]
    fn names_and_aliases_unique_and_resolvable() {
        let mut keys = std::collections::BTreeSet::new();
        for s in SCENARIOS {
            assert!(keys.insert(s.name.to_ascii_lowercase()), "dup name {}", s.name);
            assert_eq!(find(s.name).unwrap().name, s.name);
            assert_eq!(find(&s.name.to_ascii_uppercase()).unwrap().name, s.name);
            for a in s.aliases {
                assert!(keys.insert(a.to_string()), "alias {a} collides");
                assert_eq!(find(a).unwrap().name, s.name, "alias {a} resolves elsewhere");
            }
        }
    }

    #[test]
    fn resolve_error_lists_known_scenarios() {
        let err = resolve("bogus").unwrap_err().to_string();
        for s in SCENARIOS {
            assert!(err.contains(s.name), "error should list {}", s.name);
        }
        assert!(find("").is_none());
    }

    #[test]
    fn preset_aliases_keep_bench_cases_resolving() {
        // The table benches name paper presets; scenario aliases keep those
        // strings working unchanged.
        for preset in ["cifar_fedavg", "speech_fedavg", "kws_fedavg", "reddit_fedavg"] {
            let s = resolve(preset).unwrap();
            assert_eq!(s.preset, Some(preset));
        }
    }

    #[test]
    fn variant_scenarios_apply_their_overrides() {
        let churn = resolve("cifar_churn").unwrap().config().unwrap();
        assert_eq!(churn.availability.kind, AvailabilityKind::Markov);
        assert_eq!(churn.availability.mean_online_secs, 400.0);
        assert_eq!(churn.availability.mean_offline_secs, 800.0);

        let regional = resolve("regional").unwrap().config().unwrap();
        assert_eq!(regional.availability.kind, AvailabilityKind::Correlated);
        assert_eq!(regional.availability.regions, 8);
        assert_eq!(regional.availability.degrade_window_secs, 300.0);
        assert_eq!(regional.sampler, "uniform", "sampler stays an explicit axis");
        assert_eq!(regional.sampler_horizon_secs, 400.0);

        let downlink = resolve("downlink").unwrap().config().unwrap();
        assert_eq!(downlink.availability.kind, AvailabilityKind::Correlated);
        assert_eq!(downlink.network.model, "priced");
        assert_eq!(downlink.network.down_ratio, 0.25);
        assert!(downlink.network.rebalance);
        assert_eq!(
            downlink.network.stale_correction,
            crate::network::StaleCorrection::None,
            "stale correction stays an explicit axis"
        );

        let smoke = resolve("smoke").unwrap().config().unwrap();
        assert_eq!(smoke.model, "kws_lite");
        assert_eq!(smoke.population, 12);
        assert_eq!(smoke.rounds, 4);

        let fleet = resolve("fleet_hetero").unwrap().config().unwrap();
        assert_eq!(fleet.population, 1000);
    }

    #[test]
    fn fleet_scenarios_select_the_lazy_core_and_the_tier() {
        use crate::fleet::{ClockMode, FleetCore, Topology};
        let big = resolve("million").unwrap().config().unwrap();
        assert_eq!(big.population, 1_000_000);
        assert_eq!(big.fleet_core, FleetCore::Lazy);
        assert_eq!(big.hierarchy.topology, Topology::Tree);
        assert_eq!(big.hierarchy.depth, 2, "two-tier spelling is the depth-2 tree");
        assert_eq!(big.hierarchy.regions, 32);
        assert_eq!(big.hierarchy.fan_in, 64);
        assert_eq!(big.hierarchy.clock, ClockMode::Shared, "lockstep stays the default");
        assert_eq!(big.availability.kind, AvailabilityKind::Markov);

        let small = resolve("fleet_50k").unwrap().config().unwrap();
        assert_eq!(small.population, 50_000);
        assert_eq!(small.fleet_core, FleetCore::Lazy);
        assert_eq!(small.hierarchy.regions, 2);
        assert_eq!(small.hierarchy.fan_in, 0, "unbounded fan-in");
        assert_eq!(small.hierarchy.clock, ClockMode::Shared);

        let tree = resolve("fleet_tree").unwrap().config().unwrap();
        assert_eq!(tree.population, 50_000);
        assert_eq!(tree.hierarchy.topology, Topology::Tree);
        assert_eq!(tree.hierarchy.depth, 3);
        assert_eq!(tree.hierarchy.regions, 4);
        assert_eq!(tree.hierarchy.fan_in, 2);
        assert_eq!(tree.hierarchy.clock, ClockMode::Region);
        assert!(tree.hierarchy.flush_auto, "flush windows calibrate per region");
        assert_eq!(tree.hierarchy.uplink, "priced");
        assert_eq!(tree.hierarchy.up_ratio, 0.25);
    }
}
