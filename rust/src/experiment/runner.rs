//! `ExperimentRunner` — thread-parallel execution of a grid's cell × seed
//! matrix.
//!
//! Parallelism model: a deterministic job list (cells × seeds, cell-major)
//! is drained by `jobs` std threads over an atomic cursor. Each worker
//! thread builds ONE artifact manifest + PJRT client and reuses them for
//! every run it picks up (`PjRtClient` is not `Sync`, so sharing one across
//! workers is not an option — this mirrors how `benchkit::Bench` shares a
//! client across a bench's serial runs). Results land in per-job slots, so
//! completion order never affects output order: a `--jobs J` sweep is
//! byte-identical to `--jobs 1` (summaries and manifests are also
//! wall-clock-free; see `experiment::summary`).
//!
//! Seed replication: job `k` of a cell runs the cell's config with
//! `seed = cfg.seed + k` (wrapping). Aggregation to [`CellSummary`] happens
//! after the queue drains, in cell order.
//!
//! Warm-ledger sweeps (`--warm-ledger`) parallelize too: cells run in
//! order with a barrier between them, every replicate of a cell seeds from
//! the same cumulative ledger snapshot, and after the cell drains each
//! job's increment folds back in seed order (`WarmLedger::fold_delta`) —
//! so `--jobs J` is byte-identical to `--jobs 1` by construction.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};
use xla::PjRtClient;

use super::grid::{GridCell, SweepGrid};
use super::summary::CellSummary;
use crate::coordinator::Simulation;
use crate::metrics::events::JsonlSink;
use crate::metrics::RunReport;
use crate::runtime::{Manifest, Task};
use crate::scheduling::WarmLedger;

/// One unit of work: a grid cell at one replicate seed.
pub struct CellJob<'g> {
    pub cell: &'g GridCell,
    /// Replicate index in `0..seeds`.
    pub seed_index: usize,
    /// The derived master seed (`cell.cfg.seed + seed_index`, wrapping).
    pub seed: u64,
}

/// The deterministic job list for `cells` × `seeds` (cell-major: all of a
/// cell's replicates are adjacent).
pub fn cell_jobs(cells: &[GridCell], seeds: usize) -> Vec<CellJob<'_>> {
    let mut jobs = Vec::with_capacity(cells.len() * seeds);
    for cell in cells {
        for k in 0..seeds {
            jobs.push(CellJob {
                cell,
                seed_index: k,
                seed: cell.cfg.seed.wrapping_add(k as u64),
            });
        }
    }
    jobs
}

/// Drain `items` with up to `jobs` worker threads, each owning one context
/// built by `make_worker` (reused across that worker's items). Results come
/// back in item order regardless of scheduling; the first error (by item
/// index) propagates. `jobs <= 1` runs serially on the calling thread —
/// the reference path the parallel path must match byte-for-byte.
pub fn run_queue<T, W, MW, F>(jobs: usize, items: &[CellJob<'_>], make_worker: MW, f: F) -> Result<Vec<T>>
where
    T: Send,
    MW: Fn() -> Result<W> + Sync,
    F: Fn(&mut W, &CellJob<'_>) -> Result<T> + Sync,
{
    let n = items.len();
    let job_context =
        |i: usize| format!("sweep job {i} ({})", items[i].cell.label());
    let workers = jobs.clamp(1, n.max(1));
    if workers <= 1 {
        let mut w = make_worker()?;
        return items
            .iter()
            .enumerate()
            .map(|(i, j)| f(&mut w, j).with_context(|| job_context(i)))
            .collect();
    }
    let cursor = AtomicUsize::new(0);
    // First failure aborts the drain: without this, a --jobs J sweep would
    // burn through every remaining (possibly hours-long) PJRT run before
    // surfacing the error the serial path reports immediately.
    let failed = AtomicBool::new(false);
    let slots: Mutex<Vec<Option<Result<T>>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut worker = match make_worker() {
                    Ok(w) => Some(w),
                    Err(e) => {
                        // A worker that cannot build its context claims one
                        // job to surface the error, then retires; the other
                        // workers keep draining.
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i < n {
                            slots.lock().unwrap()[i] =
                                Some(Err(e.context("building sweep worker context")));
                            failed.store(true, Ordering::Relaxed);
                        }
                        // i >= n: every job is already claimed by healthy
                        // workers — this late build failure is irrelevant.
                        None
                    }
                };
                let Some(w) = worker.as_mut() else { return };
                while !failed.load(Ordering::Relaxed) {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(w, &items[i]);
                    if out.is_err() {
                        failed.store(true, Ordering::Relaxed);
                    }
                    slots.lock().unwrap()[i] = Some(out);
                }
            });
        }
    });
    let slots = slots.into_inner().unwrap();
    if failed.load(Ordering::Relaxed) {
        // Propagate the first error by item index (deterministic however
        // the workers were scheduled).
        for (i, slot) in slots.into_iter().enumerate() {
            if let Some(Err(e)) = slot {
                return Err(e.context(job_context(i)));
            }
        }
        unreachable!("failure flagged but no error slot recorded");
    }
    let mut out = Vec::with_capacity(n);
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(v)) => out.push(v),
            Some(Err(_)) => unreachable!("error without failure flag"),
            None => anyhow::bail!(
                "{} was never executed (drain aborted?)",
                job_context(i)
            ),
        }
    }
    Ok(out)
}

/// One cell's complete outcome: the per-seed reports plus their aggregate.
pub struct CellResult {
    pub cell: GridCell,
    /// One report per replicate, seed order.
    pub reports: Vec<RunReport>,
    pub summary: CellSummary,
}

/// All cells of one sweep, grid order.
pub struct SweepResult {
    pub seeds: usize,
    pub cells: Vec<CellResult>,
}

impl SweepResult {
    pub fn summaries(&self) -> Vec<CellSummary> {
        self.cells.iter().map(|c| c.summary.clone()).collect()
    }

    /// Consume the sweep into one report per cell (the single-seed bench
    /// idiom: each cell's FIRST replicate, cell order). Multi-seed sweeps
    /// should aggregate via `CellSummary` instead.
    pub fn into_first_reports(self) -> Vec<RunReport> {
        self.cells
            .into_iter()
            .map(|c| {
                c.reports
                    .into_iter()
                    .next()
                    .expect("every cell carries >= 1 replicate")
            })
            .collect()
    }

    /// The machine-readable sweep manifest (see `experiment::summary`).
    pub fn manifest(&self, scenario: Option<&str>, axis_keys: &[String]) -> String {
        super::summary::sweep_manifest(scenario, axis_keys, self.seeds, &self.summaries())
    }
}

/// Fold a flat job-ordered report list back into per-cell results
/// (pure — shared by [`ExperimentRunner::run`] and the artifact-free
/// parallel-vs-serial property tests).
pub fn assemble(
    cells: Vec<GridCell>,
    flat: Vec<RunReport>,
    seeds: usize,
    higher_better: &dyn Fn(&GridCell) -> bool,
) -> SweepResult {
    assert_eq!(flat.len(), cells.len() * seeds, "job/report count mismatch");
    let mut it = flat.into_iter();
    let cells = cells
        .into_iter()
        .map(|cell| {
            let reports: Vec<RunReport> = (0..seeds).map(|_| it.next().unwrap()).collect();
            let summary = CellSummary::from_reports(&cell, &reports, higher_better(&cell));
            CellResult { cell, reports, summary }
        })
        .collect();
    SweepResult { seeds, cells }
}

/// Executes a [`SweepGrid`]'s cell × seed matrix against the AOT artifacts.
pub struct ExperimentRunner {
    artifacts: PathBuf,
    seeds: usize,
    jobs: usize,
    events_dir: Option<PathBuf>,
    warm_ledger: bool,
}

impl ExperimentRunner {
    pub fn new(artifacts: impl Into<PathBuf>) -> ExperimentRunner {
        ExperimentRunner {
            artifacts: artifacts.into(),
            seeds: 1,
            jobs: 1,
            events_dir: None,
            warm_ledger: false,
        }
    }

    /// Replicates per cell (>= 1); replicate `k` runs at `cfg.seed + k`.
    pub fn seeds(mut self, seeds: usize) -> Self {
        self.seeds = seeds.max(1);
        self
    }

    /// Worker threads (>= 1). Output is identical for every value.
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// Stream every run's JSONL event records (the PR-2 `metrics::events`
    /// machinery) into `dir/cell{index}_seed{k}.events.jsonl`.
    pub fn events_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.events_dir = Some(dir.into());
        self
    }

    /// Carry one drop ledger (per-client delivered/churned counters) across
    /// the cell matrix, cell by cell, so evidence-based policies
    /// (`drop-aware`, `fair-cap`, the `sched-joint` weigher) warm-start in
    /// later cells (`--warm-ledger`). Cells are a barrier: every replicate
    /// of a cell seeds from the snapshot accumulated over the PRIOR cells,
    /// runs under the normal `jobs` parallelism, and after the cell drains
    /// the replicates' increments fold into the cumulative ledger in seed
    /// order (`WarmLedger::fold_delta`) — deterministic for any `jobs`.
    pub fn warm_ledger(mut self, on: bool) -> Self {
        self.warm_ledger = on;
        self
    }

    fn make_worker(&self) -> Result<(Manifest, PjRtClient)> {
        let manifest = Manifest::load(&self.artifacts)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok((manifest, client))
    }

    /// Run the full matrix; each job is one `Simulation::run` (with an
    /// event sink when an events dir is configured). With
    /// [`warm_ledger`](Self::warm_ledger) on, cells run in order with a
    /// barrier between them and one drop ledger carries cell-to-cell via
    /// `Simulation::run_warm` + `WarmLedger::fold_delta`.
    pub fn run(&self, grid: &SweepGrid) -> Result<SweepResult> {
        let cells = grid.cells()?;
        let jobs = cell_jobs(&cells, self.seeds);
        if let Some(dir) = &self.events_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating events dir {}", dir.display()))?;
        }
        let events_dir = self.events_dir.as_deref();
        let flat = if self.warm_ledger {
            // Per-cell barrier: every replicate of a cell seeds from the
            // same cumulative snapshot and runs under the normal `jobs`
            // parallelism; the replicates' increments then fold back in
            // seed order, so the cumulative ledger — and therefore every
            // downstream run — is independent of worker scheduling.
            let mut cumulative = WarmLedger::default();
            let mut flat = Vec::with_capacity(jobs.len());
            for cell_jobs in jobs.chunks(self.seeds) {
                let snapshot = cumulative.clone();
                let outcomes = run_queue(
                    self.jobs,
                    cell_jobs,
                    || self.make_worker(),
                    |worker, job| {
                        let (manifest, client) = &*worker;
                        let mut cfg = job.cell.cfg.clone();
                        cfg.seed = job.seed;
                        let sim = Simulation::with_client(cfg, manifest, client)?;
                        let mut local = snapshot.clone();
                        let report = match events_dir {
                            Some(dir) => {
                                run_with_event_file(&sim, dir, job, Some(&mut local))?
                            }
                            None => sim.run_warm(None, &mut local)?,
                        };
                        Ok((report, local))
                    },
                )?;
                for (report, harvest) in outcomes {
                    cumulative.fold_delta(&snapshot, &harvest);
                    flat.push(report);
                }
            }
            flat
        } else {
            run_queue(
                self.jobs,
                &jobs,
                || self.make_worker(),
                |worker, job| {
                    let (manifest, client) = &*worker;
                    let mut cfg = job.cell.cfg.clone();
                    cfg.seed = job.seed;
                    let sim = Simulation::with_client(cfg, manifest, client)?;
                    match events_dir {
                        Some(dir) => run_with_event_file(&sim, dir, job, None),
                        None => sim.run(),
                    }
                },
            )?
        };
        drop(jobs); // release the borrow of `cells` before moving it
        // Task direction (accuracy vs perplexity) per cell, resolved once
        // against the manifest on the coordinating thread.
        let manifest = Manifest::load(&self.artifacts)?;
        let higher_better = |cell: &GridCell| -> bool {
            manifest
                .model(&cell.cfg.model)
                .map(|m| m.task == Task::Classify)
                .unwrap_or(true)
        };
        Ok(assemble(cells, flat, self.seeds, &higher_better))
    }

    /// Run an arbitrary per-job measurement instead of `Simulation::run`
    /// (micro-benches that need the `Simulation` itself). Returns results
    /// grouped per cell, seed order within.
    pub fn map<T, F>(&self, grid: &SweepGrid, f: F) -> Result<Vec<Vec<T>>>
    where
        T: Send,
        F: Fn(&Simulation, &CellJob<'_>) -> Result<T> + Sync,
    {
        let cells = grid.cells()?;
        let jobs = cell_jobs(&cells, self.seeds);
        let flat = run_queue(
            self.jobs,
            &jobs,
            || self.make_worker(),
            |worker, job| {
                let (manifest, client) = &*worker;
                let mut cfg = job.cell.cfg.clone();
                cfg.seed = job.seed;
                let sim = Simulation::with_client(cfg, manifest, client)?;
                f(&sim, job)
            },
        )?;
        let mut grouped = Vec::with_capacity(cells.len());
        let mut it = flat.into_iter();
        for _ in 0..cells.len() {
            grouped.push((0..self.seeds).map(|_| it.next().unwrap()).collect());
        }
        Ok(grouped)
    }
}

fn run_with_event_file(
    sim: &Simulation,
    dir: &Path,
    job: &CellJob<'_>,
    ledger: Option<&mut WarmLedger>,
) -> Result<RunReport> {
    use std::io::Write as _;
    let path = dir.join(format!(
        "cell{:04}_seed{}.events.jsonl",
        job.cell.index, job.seed_index
    ));
    let file = std::fs::File::create(&path)
        .with_context(|| format!("creating event stream {}", path.display()))?;
    let mut sink = JsonlSink::new(std::io::BufWriter::new(file));
    let report = match ledger {
        Some(ledger) => sim.run_warm(Some(&mut sink), ledger)?,
        None => sim.run_with_sink(&mut sink)?,
    };
    anyhow::ensure!(
        sink.errors == 0,
        "{} event-stream writes failed for {}",
        sink.errors,
        path.display()
    );
    sink.into_inner().flush()?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn cells(n: usize) -> Vec<GridCell> {
        (0..n)
            .map(|index| GridCell {
                index,
                settings: vec![("i".into(), index.to_string())],
                cfg: RunConfig::default(),
            })
            .collect()
    }

    #[test]
    fn cell_jobs_are_cell_major_with_derived_seeds() {
        let cs = cells(2);
        let jobs = cell_jobs(&cs, 3);
        assert_eq!(jobs.len(), 6);
        assert_eq!(jobs[0].cell.index, 0);
        assert_eq!(jobs[2].seed_index, 2);
        assert_eq!(jobs[2].seed, RunConfig::default().seed + 2);
        assert_eq!(jobs[3].cell.index, 1);
        assert_eq!(jobs[3].seed, RunConfig::default().seed);
    }

    #[test]
    fn run_queue_preserves_item_order_under_parallelism() {
        let cs = cells(7);
        let jobs = cell_jobs(&cs, 3);
        let serial = run_queue(1, &jobs, || Ok(()), |_, j| {
            Ok((j.cell.index, j.seed_index, j.seed))
        })
        .unwrap();
        let parallel = run_queue(4, &jobs, || Ok(()), |_, j| {
            Ok((j.cell.index, j.seed_index, j.seed))
        })
        .unwrap();
        assert_eq!(serial, parallel);
        assert_eq!(serial.len(), 21);
    }

    #[test]
    fn run_queue_worker_context_is_reused_within_a_worker() {
        // Serial path: one context serves every job, so a per-worker counter
        // ends at the job count.
        let cs = cells(5);
        let jobs = cell_jobs(&cs, 1);
        let out = run_queue(1, &jobs, || Ok(0usize), |w, _| {
            *w += 1;
            Ok(*w)
        })
        .unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn run_queue_propagates_the_first_error_by_index() {
        let cs = cells(4);
        let jobs = cell_jobs(&cs, 1);
        for workers in [1, 3] {
            let err = run_queue(workers, &jobs, || Ok(()), |_, j| {
                if j.cell.index >= 2 {
                    anyhow::bail!("boom {}", j.cell.index)
                }
                Ok(j.cell.index)
            })
            .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("boom 2"), "expected job 2's error, got: {msg}");
        }
    }

    #[test]
    fn run_queue_surfaces_worker_build_failure() {
        let cs = cells(2);
        let jobs = cell_jobs(&cs, 1);
        for workers in [1, 2] {
            let err = run_queue::<(), (), _, _>(
                workers,
                &jobs,
                || anyhow::bail!("no context"),
                |_, _| Ok(()),
            )
            .unwrap_err();
            assert!(format!("{err:#}").contains("no context"));
        }
    }
}
