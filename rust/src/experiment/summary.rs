//! `CellSummary` — per-cell aggregation over the seed replicates, and the
//! machine-readable sweep manifest.
//!
//! Everything here is **deterministic in (grid, seeds)**: summaries carry
//! no wall-clock (per-run wall seconds stay on the `RunReport`s), and the
//! manifest is assembled in cell order after all runs complete, so a
//! `--jobs J` sweep writes a byte-identical manifest to a `--jobs 1` sweep
//! of the same grid and seed set.

use anyhow::Result;

use super::grid::GridCell;
use crate::metrics::RunReport;
use crate::util::json::Json;
use crate::util::stats::{mean, std_dev};

/// Mean ± population standard deviation over the seed replicates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeanStd {
    pub mean: f64,
    pub std: f64,
}

impl MeanStd {
    pub fn of(xs: &[f64]) -> MeanStd {
        MeanStd { mean: mean(xs), std: std_dev(xs) }
    }

    /// `1.234±0.056` (std omitted for single-seed cells).
    pub fn fmt(&self, prec: usize) -> String {
        if self.std == 0.0 {
            format!("{:.prec$}", self.mean)
        } else {
            format!("{:.prec$}±{:.prec$}", self.mean, self.std)
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![("mean", Json::num(self.mean)), ("std", Json::num(self.std))])
    }

    pub fn from_json(v: &Json) -> Result<MeanStd> {
        Ok(MeanStd {
            mean: v.expect("mean")?.as_f64()?,
            std: v.expect("std")?.as_f64()?,
        })
    }
}

/// Time-to-target aggregation for cells whose config sets `target_metric`.
#[derive(Clone, Debug, PartialEq)]
pub struct TargetStat {
    pub target: f64,
    /// Seeds that reached the target within budget.
    pub reached: usize,
    /// Simulated hours to target, over the seeds that reached it (`None`
    /// when none did — the paper's "> budget" cells).
    pub hours: Option<MeanStd>,
}

impl TargetStat {
    /// Aggregate `target` over seed replicates (also the table benches'
    /// per-target aggregation — one implementation of "reached + hours").
    pub fn of(reports: &[RunReport], target: f64, higher_better: bool) -> TargetStat {
        let hit: Vec<f64> = reports
            .iter()
            .filter_map(|r| r.time_to_target(target, higher_better))
            .collect();
        TargetStat {
            target,
            reached: hit.len(),
            hours: (!hit.is_empty()).then(|| MeanStd::of(&hit)),
        }
    }

    /// Mean-hours ratio of `self` relative to `base` (`None` when either
    /// side never reached its target): the "Nx slower" annotation.
    pub fn ratio_vs(&self, base: &TargetStat) -> Option<f64> {
        match (&base.hours, &self.hours) {
            (Some(a), Some(b)) if a.mean > 0.0 => Some(b.mean / a.mean),
            _ => None,
        }
    }
}

/// Seed-aggregated result of one grid cell. Wall-clock-free by design (see
/// module docs); counts are aggregated as means over seeds.
#[derive(Clone, Debug, PartialEq)]
pub struct CellSummary {
    /// `key=value,...` cell label (axis declaration order).
    pub label: String,
    pub settings: Vec<(String, String)>,
    pub seeds: usize,
    pub rounds: MeanStd,
    pub sim_hours: MeanStd,
    /// `None` when no replicate recorded an eval point (e.g. population
    /// offline from t=0).
    pub final_metric: Option<MeanStd>,
    pub best_metric: Option<MeanStd>,
    pub mean_participation: MeanStd,
    pub mean_online_fraction: MeanStd,
    pub avail_drops: MeanStd,
    pub deadline_drops: MeanStd,
    pub trainings_executed: MeanStd,
    pub trainings_avoided: MeanStd,
    pub time_to_target: Option<TargetStat>,
}

impl CellSummary {
    /// Aggregate one cell's seed replicates. `higher_better` selects the
    /// best-metric / time-to-target comparisons (accuracy vs perplexity).
    pub fn from_reports(cell: &GridCell, reports: &[RunReport], higher_better: bool) -> CellSummary {
        assert!(!reports.is_empty(), "cell {} summarised with no reports", cell.index);
        let agg = |f: &dyn Fn(&RunReport) -> f64| {
            MeanStd::of(&reports.iter().map(f).collect::<Vec<_>>())
        };
        let opt_agg = |f: &dyn Fn(&RunReport) -> Option<f64>| {
            let xs: Vec<f64> = reports.iter().filter_map(f).collect();
            (!xs.is_empty()).then(|| MeanStd::of(&xs))
        };
        let time_to_target = cell
            .cfg
            .target_metric
            .map(|target| TargetStat::of(reports, target, higher_better));
        CellSummary {
            label: cell.label(),
            settings: cell.settings.clone(),
            seeds: reports.len(),
            rounds: agg(&|r| r.total_rounds as f64),
            sim_hours: agg(&|r| r.sim_secs / 3600.0),
            final_metric: opt_agg(&|r| r.final_metric()),
            best_metric: opt_agg(&|r| r.best_metric(higher_better)),
            mean_participation: agg(&|r| r.mean_participation()),
            mean_online_fraction: agg(&|r| r.mean_online_fraction()),
            avail_drops: agg(&|r| r.total_avail_drops() as f64),
            deadline_drops: agg(&|r| r.total_deadline_drops() as f64),
            trainings_executed: agg(&|r| r.trainings_executed as f64),
            trainings_avoided: agg(&|r| r.trainings_avoided as f64),
            time_to_target,
        }
    }

    pub fn to_json(&self) -> Json {
        let opt = |m: &Option<MeanStd>| m.as_ref().map_or(Json::Null, |m| m.to_json());
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            (
                "settings",
                Json::arr(
                    self.settings
                        .iter()
                        .map(|(k, v)| {
                            Json::arr(vec![Json::str(k.clone()), Json::str(v.clone())])
                        })
                        .collect(),
                ),
            ),
            ("seeds", Json::num(self.seeds as f64)),
            ("rounds", self.rounds.to_json()),
            ("sim_hours", self.sim_hours.to_json()),
            ("final_metric", opt(&self.final_metric)),
            ("best_metric", opt(&self.best_metric)),
            ("mean_participation", self.mean_participation.to_json()),
            ("mean_online_fraction", self.mean_online_fraction.to_json()),
            ("avail_drops", self.avail_drops.to_json()),
            ("deadline_drops", self.deadline_drops.to_json()),
            ("trainings_executed", self.trainings_executed.to_json()),
            ("trainings_avoided", self.trainings_avoided.to_json()),
            (
                "time_to_target",
                self.time_to_target.as_ref().map_or(Json::Null, |t| {
                    Json::obj(vec![
                        ("target", Json::num(t.target)),
                        ("reached", Json::num(t.reached as f64)),
                        ("hours", opt(&t.hours)),
                    ])
                }),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CellSummary> {
        let opt = |v: &Json| -> Result<Option<MeanStd>> {
            Ok(match v {
                Json::Null => None,
                other => Some(MeanStd::from_json(other)?),
            })
        };
        let settings = v
            .expect("settings")?
            .as_arr()?
            .iter()
            .map(|p| {
                let pair = p.as_arr()?;
                anyhow::ensure!(pair.len() == 2, "setting pair arity");
                Ok((pair[0].as_str()?.to_string(), pair[1].as_str()?.to_string()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(CellSummary {
            label: v.expect("label")?.as_str()?.to_string(),
            settings,
            seeds: v.expect("seeds")?.as_usize()?,
            rounds: MeanStd::from_json(v.expect("rounds")?)?,
            sim_hours: MeanStd::from_json(v.expect("sim_hours")?)?,
            final_metric: opt(v.expect("final_metric")?)?,
            best_metric: opt(v.expect("best_metric")?)?,
            mean_participation: MeanStd::from_json(v.expect("mean_participation")?)?,
            mean_online_fraction: MeanStd::from_json(v.expect("mean_online_fraction")?)?,
            avail_drops: MeanStd::from_json(v.expect("avail_drops")?)?,
            deadline_drops: MeanStd::from_json(v.expect("deadline_drops")?)?,
            trainings_executed: MeanStd::from_json(v.expect("trainings_executed")?)?,
            trainings_avoided: MeanStd::from_json(v.expect("trainings_avoided")?)?,
            time_to_target: match v.expect("time_to_target")? {
                Json::Null => None,
                t => Some(TargetStat {
                    target: t.expect("target")?.as_f64()?,
                    reached: t.expect("reached")?.as_usize()?,
                    hours: opt(t.expect("hours")?)?,
                }),
            },
        })
    }
}

/// Machine-readable sweep manifest: JSONL in the `reason`-discriminated
/// idiom of `metrics::events`. One `sweep` header line, then one `cell`
/// line per grid cell in deterministic cell order.
pub fn sweep_manifest(
    scenario: Option<&str>,
    axis_keys: &[String],
    seeds: usize,
    summaries: &[CellSummary],
) -> String {
    let mut out = String::new();
    let header = Json::obj(vec![
        ("reason", Json::str("sweep")),
        (
            "scenario",
            scenario.map_or(Json::Null, Json::str),
        ),
        (
            "axes",
            Json::arr(axis_keys.iter().map(|k| Json::str(k.clone())).collect()),
        ),
        ("seeds", Json::num(seeds as f64)),
        ("cells", Json::num(summaries.len() as f64)),
    ]);
    out.push_str(&header.to_string());
    out.push('\n');
    for (i, s) in summaries.iter().enumerate() {
        let line = Json::obj(vec![
            ("reason", Json::str("cell")),
            ("index", Json::num(i as f64)),
            ("summary", s.to_json()),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Parse a sweep manifest back into its cell summaries (downstream tooling
/// and the round-trip property test).
pub fn parse_sweep_manifest(text: &str) -> Result<Vec<CellSummary>> {
    let mut summaries = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| anyhow::anyhow!("manifest line {}: {e}", lineno + 1))?;
        match v.expect("reason")?.as_str()? {
            "sweep" => {}
            "cell" => summaries.push(CellSummary::from_json(v.expect("summary")?)?),
            other => anyhow::bail!("manifest line {}: unknown reason {other:?}", lineno + 1),
        }
    }
    Ok(summaries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::metrics::EvalPoint;

    fn report(seed_shift: f64) -> RunReport {
        RunReport {
            strategy: "TimelyFL".into(),
            model: "vision".into(),
            eval_points: vec![
                EvalPoint { round: 0, sim_secs: 1800.0, mean_loss: 2.0, metric: 0.3 + seed_shift },
                EvalPoint { round: 4, sim_secs: 3600.0, mean_loss: 1.5, metric: 0.5 + seed_shift },
            ],
            rounds: vec![],
            participation: vec![0.5, 1.0],
            online_fraction: vec![1.0, 1.0],
            sim_secs: 3600.0,
            wall_secs: 1.23, // must never reach the summary
            total_rounds: 5,
            events_processed: 10,
            real_train_steps: 100,
            trainings_executed: 8,
            trainings_avoided: 2,
            tail_dropped: 0,
            tail_avail_dropped: 0,
            downlink_wait_secs: 0.0,
            stale_starts: 0,
            edge_flushes: 0,
            edge_uplink_wait_secs: 0.0,
            edge_root_merges: 0,
        }
    }

    fn cell() -> GridCell {
        let mut cfg = RunConfig::default();
        cfg.target_metric = Some(0.45);
        GridCell {
            index: 0,
            settings: vec![("strategy".into(), "TimelyFL".into())],
            cfg,
        }
    }

    #[test]
    fn aggregates_mean_and_std_over_seeds() {
        let s = CellSummary::from_reports(&cell(), &[report(0.0), report(0.1)], true);
        assert_eq!(s.seeds, 2);
        assert!((s.final_metric.unwrap().mean - 0.55).abs() < 1e-12);
        assert!((s.final_metric.unwrap().std - 0.05).abs() < 1e-12);
        assert!((s.sim_hours.mean - 1.0).abs() < 1e-12);
        assert_eq!(s.rounds.mean, 5.0);
        assert!((s.mean_participation.mean - 0.75).abs() < 1e-12);
        assert_eq!(s.trainings_executed.mean, 8.0);
        let tt = s.time_to_target.unwrap();
        assert_eq!(tt.reached, 2); // 0.5 and 0.6 both pass 0.45
        assert!((tt.hours.unwrap().mean - 1.0).abs() < 1e-12);
        assert_eq!(s.label, "strategy=TimelyFL");
    }

    #[test]
    fn target_not_reached_yields_budget_cell() {
        let mut c = cell();
        c.cfg.target_metric = Some(0.99);
        let s = CellSummary::from_reports(&c, &[report(0.0)], true);
        let tt = s.time_to_target.unwrap();
        assert_eq!(tt.reached, 0);
        assert!(tt.hours.is_none());
    }

    #[test]
    fn lower_is_better_metrics_aggregate() {
        // Perplexity-style: best = min.
        let s = CellSummary::from_reports(&cell(), &[report(0.0)], false);
        assert!((s.best_metric.unwrap().mean - 0.3).abs() < 1e-12);
    }

    #[test]
    fn summary_json_round_trips() {
        let s = CellSummary::from_reports(&cell(), &[report(0.0), report(0.2)], true);
        let back = CellSummary::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn manifest_round_trips_and_is_jsonl() {
        let s1 = CellSummary::from_reports(&cell(), &[report(0.0)], true);
        let s2 = CellSummary::from_reports(&cell(), &[report(0.1)], true);
        let text = sweep_manifest(
            Some("cifar"),
            &["strategy".to_string()],
            1,
            &[s1.clone(), s2.clone()],
        );
        assert_eq!(text.lines().count(), 3, "header + one line per cell");
        let head = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(head.expect("reason").unwrap().as_str().unwrap(), "sweep");
        assert_eq!(head.expect("cells").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(head.expect("scenario").unwrap().as_str().unwrap(), "cifar");
        let back = parse_sweep_manifest(&text).unwrap();
        assert_eq!(back, vec![s1, s2]);
        // Wall-clock never leaks into the manifest (jobs-count identity).
        assert!(!text.contains("wall"), "manifest must stay wall-clock-free");
        assert!(parse_sweep_manifest("{\"reason\":\"bogus\"}\n").is_err());
    }

    #[test]
    fn meanstd_formats_compactly() {
        assert_eq!(MeanStd { mean: 1.25, std: 0.0 }.fmt(3), "1.250");
        assert_eq!(MeanStd { mean: 1.25, std: 0.5 }.fmt(2), "1.25±0.50");
    }
}
