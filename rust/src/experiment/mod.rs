//! First-class experiment API: declarative scenarios, sweep grids, and a
//! thread-parallel multi-seed runner.
//!
//! The paper's evidence is sweep-shaped — participation vs. availability
//! (Figs. 1/5/10), time-to-accuracy curves (Fig. 4), non-iid and
//! heterogeneity sweeps (Figs. 6/8) — and production FL evaluation
//! (Papaya) lives on running many configurations at scale. This module is
//! the seam that turns every such study into a few declarative lines
//! instead of a hand-rolled bench loop:
//!
//! - [`scenario`] — a static registry of named, reusable experimental
//!   setups (base preset × availability process × fleet heterogeneity ×
//!   non-iid level), mirroring `coordinator::registry`. Listed by
//!   `timelyfl scenarios`.
//! - [`grid`] — [`SweepGrid`], a typed axis-expansion API: `cross` axes
//!   (`axis("avail_frac", &[1.0, 0.8, 0.5, 0.3])`) and `zip`ped parallel
//!   axes expand into cells; every cell materialises a `RunConfig` through
//!   `config::parse::apply_override`, so cells get exactly the validation
//!   (and the registry-resolved strategy canonicalization) of a config
//!   file or `--set` flag.
//! - [`runner`] — [`ExperimentRunner`] executes the cell × seed matrix
//!   over a work queue of std threads (one PJRT client + artifact manifest
//!   per worker, reused across that worker's runs), replicates each cell
//!   over N derived seeds, and aggregates to [`CellSummary`] (mean/std).
//! - [`summary`] — [`CellSummary`] / [`MeanStd`] and the machine-readable
//!   sweep manifest (JSONL, same `reason`-discriminated idiom as
//!   `metrics::events`).
//!
//! Summaries and the manifest are **wall-clock-free by construction**, so a
//! `--jobs J` run is byte-identical to a `--jobs 1` run of the same grid
//! and seeds (locked by `rust/tests/experiment_properties.rs` and the CI
//! sweep smoke). Per-run wall seconds stay available on the underlying
//! `RunReport`s for perf-sensitive benches.
//!
//! A whole sweep in three lines (see `docs/experiments.md`):
//!
//! ```no_run
//! # use timelyfl::experiment::{scenario, ExperimentRunner, SweepGrid};
//! let grid = SweepGrid::new(scenario::resolve("cifar")?.config()?)
//!     .axis("avail_frac", &[1.0, 0.8, 0.5, 0.3])
//!     .strategy_axis_all();
//! let result = ExperimentRunner::new("artifacts").seeds(3).jobs(4).run(&grid)?;
//! # anyhow::Ok(())
//! ```
//!
//! Or without writing rust at all:
//! `timelyfl sweep --scenario cifar --axis avail_frac=1.0,0.8,0.5,0.3 --seeds 3 --jobs 4`.

pub mod grid;
pub mod runner;
pub mod scenario;
pub mod summary;

pub use grid::{GridCell, SweepGrid};
pub use runner::{run_queue, CellJob, CellResult, ExperimentRunner, SweepResult};
pub use scenario::ScenarioSpec;
pub use summary::{sweep_manifest, CellSummary, MeanStd, TargetStat};
