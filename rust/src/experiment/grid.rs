//! `SweepGrid` — typed axis expansion into validated per-cell `RunConfig`s.
//!
//! An axis is a config key plus a value list; values are applied through
//! `config::parse::apply_override`, so a grid cell goes through exactly the
//! validation (and strategy-registry canonicalization) of a config file.
//! Two combinators:
//!
//! - [`SweepGrid::axis`] — a cross-product axis: every value combines with
//!   every combination of the other groups;
//! - [`SweepGrid::zip`] — parallel axes that advance together (one group of
//!   several keys whose i-th values form the i-th row), for paired settings
//!   like `(rounds, target_metric)` per dataset.
//!
//! Cell order is deterministic and row-major: the first-declared group is
//! the outermost loop, the last-declared varies fastest — the same order
//! the hand-rolled bench loops used.

use anyhow::{Context, Result};

use crate::config::{parse as cfgparse, RunConfig};
use crate::coordinator::registry;

/// One expansion group: a single key with N values (cross axis) or several
/// keys with N rows of parallel values (zip).
struct AxisGroup {
    keys: Vec<String>,
    /// `rows[i]` holds one value per key.
    rows: Vec<Vec<String>>,
}

/// A declarative sweep: base config × expansion axes.
pub struct SweepGrid {
    base: RunConfig,
    groups: Vec<AxisGroup>,
}

/// One materialised grid cell: the settings that produced it (in axis
/// declaration order) and the validated config.
#[derive(Clone)]
pub struct GridCell {
    /// Position in the grid's deterministic cell order.
    pub index: usize,
    /// `(key, value)` pairs, axis declaration order.
    pub settings: Vec<(String, String)>,
    pub cfg: RunConfig,
}

impl GridCell {
    /// Human/machine label: `key=value,key=value` in axis order ("base" for
    /// the axis-free one-cell grid).
    pub fn label(&self) -> String {
        if self.settings.is_empty() {
            return "base".into();
        }
        self.settings
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl SweepGrid {
    /// A grid over `base`; with no axes it has exactly one cell (the base).
    pub fn new(base: RunConfig) -> SweepGrid {
        SweepGrid { base, groups: Vec::new() }
    }

    /// Add a cross-product axis: `key` swept over `values`. Values are
    /// stringified and applied through `config::parse`, so any config key
    /// works — including derived ones like `avail_frac` and the
    /// registry-resolved `strategy`.
    pub fn axis<V: std::fmt::Display>(mut self, key: &str, values: &[V]) -> SweepGrid {
        self.groups.push(AxisGroup {
            keys: vec![key.to_string()],
            rows: values.iter().map(|v| vec![v.to_string()]).collect(),
        });
        self
    }

    /// Add zipped parallel axes: `keys` advance together, row by row. Each
    /// row must carry exactly one value per key (checked at [`cells`] time).
    pub fn zip(mut self, keys: &[&str], rows: &[&[&str]]) -> SweepGrid {
        self.groups.push(AxisGroup {
            keys: keys.iter().map(|k| k.to_string()).collect(),
            rows: rows
                .iter()
                .map(|r| r.iter().map(|v| v.to_string()).collect())
                .collect(),
        });
        self
    }

    /// Convenience: a `strategy` axis over the whole coordinator registry,
    /// in canonical comparison order — a newly-registered strategy joins
    /// every such sweep with zero changes.
    pub fn strategy_axis_all(self) -> SweepGrid {
        self.axis("strategy", &registry::names())
    }

    /// Flattened axis keys, declaration order (for manifests/tables).
    pub fn axis_keys(&self) -> Vec<String> {
        self.groups.iter().flat_map(|g| g.keys.clone()).collect()
    }

    /// Number of cells the grid expands to (product of group row counts; a
    /// grid with no axes has one cell, a group with no rows zero).
    pub fn len(&self) -> usize {
        self.groups.iter().map(|g| g.rows.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand into validated cells, deterministic row-major order (first
    /// group outermost). Errors name the offending cell and setting.
    pub fn cells(&self) -> Result<Vec<GridCell>> {
        for g in &self.groups {
            for (i, row) in g.rows.iter().enumerate() {
                anyhow::ensure!(
                    row.len() == g.keys.len(),
                    "zip axis {:?}: row {i} has {} values for {} keys",
                    g.keys,
                    row.len(),
                    g.keys.len()
                );
            }
        }
        let total = self.len();
        let mut cells = Vec::with_capacity(total);
        for index in 0..total {
            // Mixed-radix digits of `index`, first group most significant.
            let mut rem = index;
            let mut picks = vec![0usize; self.groups.len()];
            for (gi, g) in self.groups.iter().enumerate().rev() {
                picks[gi] = rem % g.rows.len();
                rem /= g.rows.len();
            }
            let mut settings = Vec::new();
            let mut cfg = self.base.clone();
            for (g, &pick) in self.groups.iter().zip(&picks) {
                for (k, v) in g.keys.iter().zip(&g.rows[pick]) {
                    cfgparse::apply_override(&mut cfg, k, v)
                        .with_context(|| format!("grid cell {index}: {k} = {v}"))?;
                    settings.push((k.clone(), v.clone()));
                }
            }
            cfg.validate()
                .with_context(|| format!("grid cell {index} invalid"))?;
            cells.push(GridCell { index, settings, cfg });
        }
        Ok(cells)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axis_free_grid_is_the_base() {
        let grid = SweepGrid::new(RunConfig::default());
        assert_eq!(grid.len(), 1);
        let cells = grid.cells().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].label(), "base");
        assert_eq!(cells[0].cfg.rounds, RunConfig::default().rounds);
    }

    #[test]
    fn cross_product_counts_and_order() {
        let grid = SweepGrid::new(RunConfig::default())
            .axis("rounds", &[10, 20])
            .axis("strategy", &["TimelyFL", "SyncFL", "FedBuff"]);
        assert_eq!(grid.len(), 6);
        let cells = grid.cells().unwrap();
        // First axis outermost, second fastest — the bench nested-loop order.
        let labels: Vec<String> = cells.iter().map(|c| c.label()).collect();
        assert_eq!(labels[0], "rounds=10,strategy=TimelyFL");
        assert_eq!(labels[1], "rounds=10,strategy=SyncFL");
        assert_eq!(labels[3], "rounds=20,strategy=TimelyFL");
        assert_eq!(cells[3].cfg.rounds, 20);
        assert_eq!(cells[3].cfg.strategy, "TimelyFL");
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn zip_advances_keys_together() {
        let grid = SweepGrid::new(RunConfig::default())
            .zip(
                &["rounds", "target_metric"],
                &[&["10", "0.4"], &["20", "0.5"], &["30", "none"]],
            )
            .axis("strategy", &["TimelyFL", "FedBuff"]);
        assert_eq!(grid.len(), 6);
        let cells = grid.cells().unwrap();
        assert_eq!(cells[0].cfg.rounds, 10);
        assert_eq!(cells[0].cfg.target_metric, Some(0.4));
        assert_eq!(cells[2].cfg.rounds, 20);
        assert_eq!(cells[2].cfg.target_metric, Some(0.5));
        assert_eq!(cells[4].cfg.target_metric, None);
        assert_eq!(
            cells[2].label(),
            "rounds=20,target_metric=0.5,strategy=TimelyFL"
        );
    }

    #[test]
    fn zip_row_arity_mismatch_errors() {
        let grid = SweepGrid::new(RunConfig::default())
            .zip(&["rounds", "target_metric"], &[&["10", "0.4"], &["20"]]);
        let err = format!("{:#}", grid.cells().unwrap_err());
        assert!(err.contains("row 1"), "error should name the bad row: {err}");
    }

    #[test]
    fn cells_get_config_parse_validation() {
        // Bad value: caught by the same parser as a config file.
        let bad_value = SweepGrid::new(RunConfig::default()).axis("rounds", &["ten"]);
        assert!(bad_value.cells().is_err());
        // Unknown key.
        let bad_key = SweepGrid::new(RunConfig::default()).axis("bogus_key", &[1]);
        let err = format!("{:#}", bad_key.cells().unwrap_err());
        assert!(err.contains("bogus_key"));
        // Semantically invalid cell (concurrency > population) fails
        // validate() with the cell named.
        let invalid = SweepGrid::new(RunConfig::default()).axis("concurrency", &[100_000]);
        let err = format!("{:#}", invalid.cells().unwrap_err());
        assert!(err.contains("grid cell 0"), "cell not named: {err}");
    }

    #[test]
    fn strategy_axis_canonicalizes_through_registry() {
        let cells = SweepGrid::new(RunConfig::default())
            .axis("strategy", &["timely", "sync", "seafl"])
            .cells()
            .unwrap();
        let names: Vec<&str> = cells.iter().map(|c| c.cfg.strategy.as_str()).collect();
        assert_eq!(names, ["TimelyFL", "SyncFL", "SemiAsync"]);
        // Unknown strategies fail with the registry's name-listing error.
        let err = format!(
            "{:#}",
            SweepGrid::new(RunConfig::default())
                .axis("strategy", &["bogus"])
                .cells()
                .unwrap_err()
        );
        assert!(err.contains("TimelyFL"), "registry courtesy missing: {err}");
    }

    #[test]
    fn strategy_axis_all_covers_the_registry() {
        let cells = SweepGrid::new(RunConfig::default())
            .strategy_axis_all()
            .cells()
            .unwrap();
        assert_eq!(cells.len(), registry::STRATEGIES.len());
        for (c, info) in cells.iter().zip(registry::STRATEGIES) {
            assert_eq!(c.cfg.strategy, info.name);
        }
    }

    #[test]
    fn expansion_is_deterministic() {
        let make = || {
            SweepGrid::new(RunConfig::default())
                .axis("avail_frac", &["1.0", "0.5"])
                .strategy_axis_all()
        };
        let a: Vec<String> = make().cells().unwrap().iter().map(|c| c.label()).collect();
        let b: Vec<String> = make().cells().unwrap().iter().map(|c| c.label()).collect();
        assert_eq!(a, b);
        assert_eq!(make().axis_keys(), vec!["avail_frac", "strategy"]);
    }
}
