//! SemiAsync — a SEAFL-style semi-asynchronous baseline (Islam et al.
//! 2025): a **deadline-gated** update buffer with **selective
//! participation**, landed on the engine's event-driven hook surface to
//! prove the `Strategy` API (this file + one registry entry is the whole
//! change).
//!
//! Like FedBuff, `n` clients are always training the full model and
//! finished updates land in a buffer. Unlike FedBuff, the server does NOT
//! flush on a count: it aggregates on a fixed cadence D — the k-th smallest
//! expected full-round time across the population, measured once at start —
//! taking whatever landed in the window (staleness-discounted). Updates
//! that miss a window simply wait in the buffer for the next one; only the
//! staleness cap / injected failures discard.
//!
//! Selective participation: when refilling a concurrency slot the server
//! prefers idle clients *predicted to stay online* through their own
//! expected round time (SEAFL picks by predicted availability; we stand in
//! the predictor with the availability process itself — an oracle upper
//! bound on prediction quality), falling back to the whole idle pool when
//! nobody qualifies.

use anyhow::Result;

use super::engine::{ClientFinish, EngineEvent, EventStrategy, SimEngine, Strategy};
use super::local_time::truth;
use super::Simulation;
use crate::aggregation::{Contribution, ServerOpt};
use crate::fleet::HierarchyConfig;
use crate::metrics::events::DropCause;
use crate::model::VersionedParams;
use crate::simtime::SimTime;
use crate::util::stats::kth_smallest;

pub struct SemiAsync {
    global: VersionedParams,
    server_opt: ServerOpt,
    buffer: Vec<Contribution>,
    buffer_losses: Vec<f64>,
    /// `batch_exec` bookkeeping: buffered placeholder entries (ticket →
    /// buffer index) patched with real outcomes when the flush drains the
    /// engine's batch queue. Always empty under serial execution.
    pending_tickets: Vec<(u64, usize)>,
    /// Aggregation cadence D (set once in `on_start`).
    deadline_secs: f64,
    /// Per-client expected full-round seconds — the selection horizon.
    expected_secs: Vec<f64>,
    /// Aggregation topology (flat reproduces `average_delta` verbatim).
    hierarchy: HierarchyConfig,
}

/// Registry constructor.
pub fn build(sim: &Simulation) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(SemiAsync {
        global: VersionedParams {
            version: 0,
            params: sim.runtime.init_params(sim.cfg.init_seed)?,
        },
        server_opt: ServerOpt::new(sim.cfg.server_opt, sim.cfg.server_lr)
            .with_jobs(sim.cfg.agg_jobs),
        buffer: Vec::new(),
        buffer_losses: Vec::new(),
        pending_tickets: Vec::new(),
        deadline_secs: 0.0,
        expected_secs: Vec::new(),
        hierarchy: sim.cfg.hierarchy.clone(),
    }))
}

impl SemiAsync {
    /// Selective dispatch: pick one client from the idle-online pool,
    /// preferring those predicted to stay online through their own round.
    /// (Selection reduces churn cancellations; deferred dispatch execution
    /// in the engine makes the remaining ones free on the accelerator.)
    /// The final pick within the filtered pool goes through the configured
    /// sampling policy, so SemiAsync's protocol-level filter composes with
    /// e.g. `stay-prob` weighting (uniform reproduces the historical draw).
    fn select_and_dispatch(&self, eng: &mut SimEngine, now: SimTime) -> Result<()> {
        let idle = eng.idle_online_clients(now);
        if idle.is_empty() {
            return Ok(());
        }
        let safe: Vec<usize> = idle
            .iter()
            .copied()
            .filter(|&c| eng.avail.online_through(c, now, now + self.expected_secs[c]))
            .collect();
        let pool = if safe.is_empty() { &idle } else { &safe };
        let next = eng.pick_client(now, pool);
        eng.dispatch_full(next, &self.global.params, self.global.version)
    }

    /// Flush whatever landed in the closing window.
    fn flush(&mut self, eng: &mut SimEngine, now: SimTime) -> Result<()> {
        // Batched execution: one stacked drain covers every plan that
        // resolved in the window; buffered placeholders patch by ticket
        // (drain order == enqueue order). Unclaimed tickets belong to
        // strategy-dropped finishes whose plans the serial path executed at
        // their finish events — the ledger needs them executed here too.
        for out in eng.drain_batch(None)? {
            if let Some(&(_, idx)) = self.pending_tickets.iter().find(|(t, _)| *t == out.ticket) {
                self.buffer[idx].update = out.update;
                self.buffer_losses[idx] = out.mean_loss;
            }
        }
        self.pending_tickets.clear();
        // A fast client can land more than one update per window; it still
        // participated in the round once (participation = rounds
        // contributed / total rounds stays in [0, 1]).
        let mut participant_ids: Vec<usize> = self.buffer.iter().map(|c| c.client_id).collect();
        participant_ids.sort_unstable();
        participant_ids.dedup();
        // Weigher first (uniform rewrites the 1.0 already there), then the
        // protocol's staleness discount applies on top inside aggregation.
        eng.weigh(&mut self.buffer);
        // Under `hier_clock = region` the window's buffer goes to the
        // edges and the root may see nothing this flush (`None`); the
        // version still advances — the cadence defines the round — so
        // staleness accounting matches the shared-clock protocol.
        let mut params = self.global.params.clone();
        if let Some(avg) =
            eng.hier_aggregate(&self.hierarchy, &self.global.params, &self.buffer, true, now)
        {
            self.server_opt.apply(&mut params, &avg);
        }
        self.global = VersionedParams {
            version: self.global.version + 1,
            params,
        };
        let mean_loss = if self.buffer_losses.is_empty() {
            None
        } else {
            Some(self.buffer_losses.iter().sum::<f64>() / self.buffer_losses.len() as f64)
        };
        eng.complete_round(now, &participant_ids, mean_loss, &self.global.params)?;
        self.buffer.clear();
        self.buffer_losses.clear();
        Ok(())
    }
}

impl Strategy for SemiAsync {
    fn name(&self) -> &'static str {
        "SemiAsync"
    }

    fn run(&mut self, eng: &mut SimEngine) -> Result<()> {
        eng.drive_events(self)
    }
}

impl EventStrategy for SemiAsync {
    fn on_start(&mut self, eng: &mut SimEngine) -> Result<()> {
        let sim = eng.sim;
        let cfg = &sim.cfg;
        // Expected full-round time per client (one conditions draw each),
        // and the cadence D = k-th smallest across the population.
        self.expected_secs = (0..cfg.population)
            .map(|c| {
                let cond = sim.fleet.round_conditions(&mut eng.rng);
                truth(&sim.fleet.devices[c], &cond, cfg.sim_model_bytes)
                    .round_secs(cfg.fedbuff_local_epochs as f64, 1.0, 1.0)
            })
            .collect();
        self.deadline_secs = kth_smallest(&self.expected_secs, cfg.k_target());

        // Initial cohort: fill every slot through the selective policy
        // (dispatch marks a client busy, removing it from the next pool).
        let want = cfg.concurrency.min(eng.avail.online_clients(0.0).len());
        for _ in 0..want {
            self.select_and_dispatch(eng, 0.0)?;
        }
        eng.events.schedule_in(self.deadline_secs, EngineEvent::Alarm);
        Ok(())
    }

    fn on_client_online(&mut self, eng: &mut SimEngine, client: usize) -> Result<()> {
        // A freed slot goes through the same selective policy as refills
        // (the newly-online client is in the pool but not privileged —
        // SEAFL picks by predicted availability, not arrival order).
        if !eng.is_busy(client) && eng.in_flight() < eng.sim.cfg.concurrency {
            let now = eng.now();
            self.select_and_dispatch(eng, now)?;
        }
        Ok(())
    }

    fn on_slot_freed(&mut self, eng: &mut SimEngine, now: SimTime) -> Result<()> {
        self.select_and_dispatch(eng, now)
    }

    fn on_finish(&mut self, eng: &mut SimEngine, now: SimTime, fin: ClientFinish) -> Result<()> {
        let cfg = &eng.sim.cfg;
        let staleness = self.global.version - fin.base_version;
        let lost = cfg.dropout_prob > 0.0 && eng.rng.f64() < cfg.dropout_prob;
        if cfg.max_staleness.is_some_and(|cap| staleness > cap) || lost {
            eng.drop_client(fin.client, DropCause::Deadline);
        } else {
            if let Some(ticket) = fin.ticket {
                self.pending_tickets.push((ticket, self.buffer.len()));
            }
            self.buffer.push(Contribution {
                client_id: fin.client,
                update: fin.update,
                weight: 1.0,
                staleness,
            });
            self.buffer_losses.push(fin.mean_loss);
        }
        self.select_and_dispatch(eng, now)
    }

    fn on_alarm(&mut self, eng: &mut SimEngine, now: SimTime) -> Result<()> {
        // (The engine's event loop enforces the sim-time budget before
        // every event, so an over-budget alarm never reaches this hook.)
        // Re-arm unless the run is provably dead (nothing in flight or
        // buffered and nobody will ever come back online) — then the queue
        // drains and the engine ends the run gracefully.
        let dead = self.buffer.is_empty()
            && eng.in_flight() == 0
            && eng.avail.earliest_transition(now).is_none();
        if !dead {
            eng.events.schedule_in(self.deadline_secs, EngineEvent::Alarm);
        }
        if !self.buffer.is_empty() {
            self.flush(eng, now)?;
        }
        Ok(())
    }
}
