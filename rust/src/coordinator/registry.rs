//! Strategy registry: name → constructor, in canonical comparison order.
//!
//! Everything that used to match on a closed `StrategyKind` enum — config
//! parsing, `Simulation::run`, the CLI's `run`/`compare`, the benches —
//! resolves through this table instead. Adding a strategy is three steps
//! (see `docs/architecture.md`): write the module, implement the hook
//! trait(s) + [`Strategy`], and append one [`StrategyInfo`] entry here.
//!
//! The fleet subsystem composes *over* this table, not into it: the
//! hierarchical aggregation tier (`fleet::HierarchyConfig`) sits behind
//! each strategy's aggregation call, and the lazy sim core sits behind the
//! engine's sampling/idle seams — every registered strategy runs unmodified
//! under `hierarchy = two-tier` and `fleet_core = lazy`.

use anyhow::Result;

use super::engine::Strategy;
use super::{fedbuff, semiasync, syncfl, timelyfl, Simulation};

/// One registered strategy.
pub struct StrategyInfo {
    /// Canonical display name (what `RunReport::strategy` carries).
    pub name: &'static str,
    /// Extra accepted spellings (lowercase) for config/CLI lookup; the
    /// canonical name matches case-insensitively without being listed.
    pub aliases: &'static [&'static str],
    /// One-liner for `timelyfl strategies`.
    pub summary: &'static str,
    /// Build a fresh strategy instance for one run.
    pub build: fn(&Simulation) -> Result<Box<dyn Strategy>>,
}

/// All registered strategies. Order is the canonical comparison order used
/// by `timelyfl compare` and the sweep benches.
pub static STRATEGIES: &[StrategyInfo] = &[
    StrategyInfo {
        name: "TimelyFL",
        aliases: &["timely"],
        summary: "the paper's contribution: adaptive partial training inside a k-th-smallest aggregation interval (Alg. 1-3)",
        build: timelyfl::build,
    },
    StrategyInfo {
        name: "FedBuff",
        aliases: &[],
        summary: "buffered asynchronous baseline (Nguyen et al. 2021): aggregate the k fastest arrivals, staleness-discounted",
        build: fedbuff::build,
    },
    StrategyInfo {
        name: "SyncFL",
        aliases: &["sync"],
        summary: "fully synchronous FedAvg/FedOpt baseline: every round waits for its slowest sampled client",
        build: syncfl::build,
    },
    StrategyInfo {
        name: "SemiAsync",
        aliases: &["semi", "seafl"],
        summary: "SEAFL-style semi-async baseline: deadline-gated buffer flushes with availability-selective dispatch",
        build: semiasync::build,
    },
];

/// Case-insensitive lookup by canonical name or alias.
pub fn find(name: &str) -> Option<&'static StrategyInfo> {
    let needle = name.to_ascii_lowercase();
    STRATEGIES
        .iter()
        .find(|s| s.name.to_ascii_lowercase() == needle || s.aliases.contains(&needle.as_str()))
}

/// Like [`find`], but an actionable error listing the known strategies.
pub fn resolve(name: &str) -> Result<&'static StrategyInfo> {
    find(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown strategy {name:?} (known: {})",
            names().join(", ")
        )
    })
}

/// Canonical names, in registry order.
pub fn names() -> Vec<&'static str> {
    STRATEGIES.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_unique_case_insensitive() {
        let mut seen = std::collections::BTreeSet::new();
        for s in STRATEGIES {
            assert!(
                seen.insert(s.name.to_ascii_lowercase()),
                "duplicate strategy name {}",
                s.name
            );
        }
    }

    #[test]
    fn aliases_resolve_to_their_entry_and_never_collide() {
        for s in STRATEGIES {
            assert_eq!(find(s.name).unwrap().name, s.name);
            assert_eq!(find(&s.name.to_ascii_uppercase()).unwrap().name, s.name);
            for a in s.aliases {
                assert_eq!(
                    find(a).unwrap().name,
                    s.name,
                    "alias {a} resolves elsewhere"
                );
            }
        }
        // No alias shadows another entry's canonical name.
        let mut keys = std::collections::BTreeSet::new();
        for s in STRATEGIES {
            assert!(keys.insert(s.name.to_ascii_lowercase()));
            for a in s.aliases {
                assert!(keys.insert(a.to_string()), "alias {a} collides");
            }
        }
    }

    #[test]
    fn resolve_error_lists_known_strategies() {
        let err = resolve("bogus").unwrap_err().to_string();
        for s in STRATEGIES {
            assert!(err.contains(s.name), "error should list {}", s.name);
        }
        assert!(find("").is_none());
    }

    #[test]
    fn registry_order_starts_with_the_paper_trio() {
        // compare/bench output layouts depend on this prefix order.
        let n = names();
        assert_eq!(&n[..3], &["TimelyFL", "FedBuff", "SyncFL"]);
        assert!(n.contains(&"SemiAsync"));
    }
}
