//! FedBuff baseline (Nguyen et al. 2021 / PAPAYA) — buffered asynchronous
//! FL, event-driven.
//!
//! `n` clients (the training concurrency) are always training, each on the
//! global model version it pulled at dispatch time. Finished updates land
//! in a buffer; when the buffer holds `K` updates (the *aggregation goal*)
//! the server takes one global step with staleness-discounted weights
//! (1/sqrt(1+tau)) and the version counter advances. The finishing client
//! immediately re-dispatches on the fresh model.
//!
//! This is the behaviour the paper criticizes: fast devices cycle many
//! times per aggregation round, slow devices contribute rarely and stale —
//! the participation-rate gap of Figs. 1/5.

use std::sync::Arc;

use anyhow::Result;

use super::local_time::truth;
use super::trainer::train_client;
use super::{Recorder, Simulation};
use crate::aggregation::{average_delta, Contribution, ServerOpt};
use crate::metrics::RunReport;
use crate::model::{Update, VersionedParams};
use crate::simtime::EventQueue;
use crate::util::rng::Rng;

/// A client finishing local training (update computed eagerly at dispatch —
/// it only depends on the base snapshot, so this is equivalent and keeps
/// the event payload self-contained).
struct Finish {
    client: usize,
    base_version: u64,
    update: Update,
    mean_loss: f64,
}

pub fn run(sim: &Simulation) -> Result<RunReport> {
    let cfg = &sim.cfg;
    let rt = &sim.runtime;
    let mut rng = Rng::seed_from(cfg.seed);
    let mut client_rngs: Vec<Rng> = (0..cfg.population)
        .map(|i| rng.fork(i as u64))
        .collect();

    let mut global = Arc::new(VersionedParams {
        version: 0,
        params: rt.init_params(cfg.init_seed)?,
    });
    let mut server_opt = ServerOpt::new(cfg.server_opt, cfg.server_lr);
    let mut rec = Recorder::new(cfg.population);
    let mut events: EventQueue<Finish> = EventQueue::new();
    let k_goal = cfg.k_target();

    let mut busy = vec![false; cfg.population];

    // Dispatch one client: train eagerly on the current global, schedule
    // the finish event at the simulated completion time.
    let dispatch = |client: usize,
                        global: &Arc<VersionedParams>,
                        events: &mut EventQueue<Finish>,
                        rng: &mut Rng,
                        client_rngs: &mut [Rng],
                        busy: &mut [bool]|
     -> Result<()> {
        busy[client] = true;
        let cond = sim.fleet.round_conditions(rng);
        let t = truth(&sim.fleet.devices[client], &cond, cfg.sim_model_bytes);
        let duration = t.round_secs(cfg.fedbuff_local_epochs as f64, 1.0, 1.0);
        let full = rt
            .meta
            .ratio_exact(1.0)
            .expect("full ratio always compiled");
        let outcome = train_client(
            rt,
            &sim.dataset,
            client,
            &global.params,
            full,
            cfg.fedbuff_local_epochs,
            cfg.steps_per_epoch,
            cfg.client_lr,
            &mut client_rngs[client],
        )?;
        events.schedule_in(
            duration,
            Finish {
                client,
                base_version: global.version,
                update: outcome.update,
                mean_loss: outcome.mean_loss,
            },
        );
        Ok(())
    };

    // Start: n distinct clients training.
    for &c in &rng
        .clone()
        .sample_without_replacement(cfg.population, cfg.concurrency)
    {
        dispatch(c, &global, &mut events, &mut rng, &mut client_rngs, &mut busy)?;
    }

    let mut buffer: Vec<Contribution> = Vec::new();
    let mut buffer_losses: Vec<f64> = Vec::new();
    let mut completed_rounds = 0usize;

    while completed_rounds < cfg.rounds {
        let Some((now, fin)) = events.pop() else {
            anyhow::bail!("event queue drained with {completed_rounds} rounds done");
        };
        busy[fin.client] = false;

        let staleness = global.version - fin.base_version;
        // Failure injection: finished but the upload never arrived.
        let lost = cfg.dropout_prob > 0.0 && rng.f64() < cfg.dropout_prob;
        let dropped_stale = cfg.max_staleness.is_some_and(|cap| staleness > cap) || lost;
        if !dropped_stale {
            buffer.push(Contribution {
                client_id: fin.client,
                update: fin.update,
                weight: 1.0,
                staleness,
            });
            buffer_losses.push(fin.mean_loss);
        }

        // The finished client immediately starts again on the fresh model.
        // (Uniform re-sampling over idle clients keeps concurrency at n,
        // matching FedBuff's "training concurrency" definition.)
        let idle: Vec<usize> = (0..cfg.population).filter(|&i| !busy[i]).collect();
        let next = idle[rng.usize_below(idle.len())];
        dispatch(next, &global, &mut events, &mut rng, &mut client_rngs, &mut busy)?;

        if buffer.len() >= k_goal {
            let round = completed_rounds;
            let participant_ids: Vec<usize> = buffer.iter().map(|c| c.client_id).collect();
            let avg = average_delta(&global.params, &buffer, true);
            let mut params = global.params.clone();
            server_opt.apply(&mut params, &avg);
            global = Arc::new(VersionedParams {
                version: global.version + 1,
                params,
            });

            let mean_loss =
                buffer_losses.iter().sum::<f64>() / buffer_losses.len().max(1) as f64;
            let dropped = if dropped_stale { 1 } else { 0 };
            rec.record_round(round, now, &participant_ids, dropped, mean_loss);
            rec.maybe_eval(sim, round, now, &global.params)?;
            buffer.clear();
            buffer_losses.clear();
            completed_rounds += 1;
            if rec.should_stop(sim, now) {
                break;
            }
        }
    }

    let sim_secs = events.now();
    Ok(rec.finish(sim, sim_secs, completed_rounds))
}
