//! FedBuff baseline (Nguyen et al. 2021 / PAPAYA) — buffered asynchronous
//! FL, event-driven.
//!
//! `n` clients (the training concurrency) are always training, each on the
//! global model version it pulled at dispatch time. Finished updates land
//! in a buffer; when the buffer holds `K` updates (the *aggregation goal*)
//! the server takes one global step with staleness-discounted weights
//! (1/sqrt(1+tau)) and the version counter advances. The finishing client
//! immediately re-dispatches on the fresh model.
//!
//! The loop drives off ONE `EventQueue` carrying two event kinds: client
//! finishes and availability transitions. A client whose availability
//! process takes it offline mid-training loses its in-flight update (its
//! pending finish event is invalidated by a per-client dispatch generation
//! counter), so realized staleness now interacts with churn: slow devices
//! are the most likely to churn out before delivering. Offline clients are
//! never dispatched; when a client comes back online it fills a free
//! concurrency slot immediately.
//!
//! This is the behaviour the paper criticizes: fast devices cycle many
//! times per aggregation round, slow devices contribute rarely and stale —
//! the participation-rate gap of Figs. 1/5, now amplified by churn.

use std::sync::Arc;

use anyhow::Result;

use super::local_time::truth;
use super::trainer::train_client;
use super::{Recorder, Simulation};
use crate::aggregation::{average_delta, Contribution, ServerOpt};
use crate::availability::{AvailabilityModel, SEED_SALT};
use crate::metrics::RunReport;
use crate::model::{Update, VersionedParams};
use crate::simtime::EventQueue;
use crate::util::rng::Rng;

/// A client finishing local training (update computed eagerly at dispatch —
/// it only depends on the base snapshot, so this is equivalent and keeps
/// the event payload self-contained). `gen` is the dispatch generation the
/// finish belongs to; a mid-training offline transition bumps the client's
/// generation, invalidating the pending finish.
struct Finish {
    client: usize,
    gen: u64,
    base_version: u64,
    update: Update,
    mean_loss: f64,
}

/// Everything that can wake the FedBuff server.
enum Event {
    Finish(Finish),
    /// `client`'s availability state flips at this timestamp; the next
    /// transition is chained onto the queue when this one is processed.
    Transition { client: usize },
}

pub fn run(sim: &Simulation) -> Result<RunReport> {
    let cfg = &sim.cfg;
    let rt = &sim.runtime;
    let mut rng = Rng::seed_from(cfg.seed);
    let mut client_rngs: Vec<Rng> = (0..cfg.population)
        .map(|i| rng.fork(i as u64))
        .collect();
    let mut avail = AvailabilityModel::build(
        &cfg.availability,
        cfg.population,
        cfg.seed ^ SEED_SALT,
    )?;

    let mut global = Arc::new(VersionedParams {
        version: 0,
        params: rt.init_params(cfg.init_seed)?,
    });
    let mut server_opt = ServerOpt::new(cfg.server_opt, cfg.server_lr);
    let mut rec = Recorder::new(cfg.population);
    let mut events: EventQueue<Event> = EventQueue::new();
    let k_goal = cfg.k_target();

    let mut busy = vec![false; cfg.population];
    let mut gens: Vec<u64> = vec![0; cfg.population];
    let mut in_flight = 0usize;

    // Seed the queue with each client's first availability transition (the
    // chain re-schedules itself as transitions are processed). Always-on
    // schedules nothing — the queue is then bit-identical to the
    // pre-availability code.
    for c in 0..cfg.population {
        if let Some(t) = avail.next_transition(c, 0.0) {
            events.schedule_at(t, Event::Transition { client: c });
        }
    }

    // Dispatch one client: train eagerly on the current global, schedule
    // the finish event at the simulated completion time.
    let dispatch = |client: usize,
                        global: &Arc<VersionedParams>,
                        events: &mut EventQueue<Event>,
                        rng: &mut Rng,
                        client_rngs: &mut [Rng],
                        busy: &mut [bool],
                        gens: &[u64],
                        in_flight: &mut usize|
     -> Result<()> {
        busy[client] = true;
        *in_flight += 1;
        let cond = sim.fleet.round_conditions(rng);
        let t = truth(&sim.fleet.devices[client], &cond, cfg.sim_model_bytes);
        let duration = t.round_secs(cfg.fedbuff_local_epochs as f64, 1.0, 1.0);
        let full = rt
            .meta
            .ratio_exact(1.0)
            .expect("full ratio always compiled");
        let outcome = train_client(
            rt,
            &sim.dataset,
            client,
            &global.params,
            full,
            cfg.fedbuff_local_epochs,
            cfg.steps_per_epoch,
            cfg.client_lr,
            &mut client_rngs[client],
        )?;
        events.schedule_in(
            duration,
            Event::Finish(Finish {
                client,
                gen: gens[client],
                base_version: global.version,
                update: outcome.update,
                mean_loss: outcome.mean_loss,
            }),
        );
        Ok(())
    };

    // Start: n distinct currently-online clients training. When everyone
    // is online this samples exactly the seed's 0..population index space.
    {
        let online0 = avail.online_clients(0.0);
        let want = cfg.concurrency.min(online0.len());
        for &i in &rng.clone().sample_without_replacement(online0.len(), want) {
            dispatch(
                online0[i],
                &global,
                &mut events,
                &mut rng,
                &mut client_rngs,
                &mut busy,
                &gens,
                &mut in_flight,
            )?;
        }
    }

    let mut buffer: Vec<Contribution> = Vec::new();
    let mut buffer_losses: Vec<f64> = Vec::new();
    let mut completed_rounds = 0usize;
    // Drop attribution accumulated since the last buffer flush.
    let mut dropped_pending = 0usize;
    let mut avail_dropped_pending = 0usize;

    while completed_rounds < cfg.rounds {
        let Some((now, ev)) = events.pop() else {
            // A drained queue under always-on means the dispatch invariant
            // broke — that is a bug. Under churn it is a legitimate end
            // state (the population went permanently offline, e.g. a trace
            // ran out): finish gracefully with the rounds that completed,
            // like the round-stepped drivers do.
            if avail.is_always_on() {
                anyhow::bail!("event queue drained with {completed_rounds} rounds done");
            }
            break;
        };
        match ev {
            Event::Transition { client } => {
                // Chain the client's next transition onto the queue.
                let next = avail.next_transition(client, now);
                if let Some(t) = next {
                    events.schedule_at(t, Event::Transition { client });
                }
                // Read the post-transition state at the segment midpoint:
                // the state is constant until the next transition, and the
                // midpoint dodges ulp-level ambiguity of evaluating the
                // diurnal gate exactly at a boundary instant.
                let online_now = match next {
                    Some(t) => avail.is_available(client, (now + t) / 2.0),
                    None => avail.is_available(client, now),
                };
                if online_now {
                    // Came online: fill a free concurrency slot with it.
                    if !busy[client] && in_flight < cfg.concurrency {
                        dispatch(
                            client,
                            &global,
                            &mut events,
                            &mut rng,
                            &mut client_rngs,
                            &mut busy,
                            &gens,
                            &mut in_flight,
                        )?;
                    }
                } else if busy[client] {
                    // Went offline mid-training: the in-flight update is
                    // lost with it. Invalidate the pending finish and
                    // restore concurrency from the online idle pool.
                    gens[client] += 1;
                    busy[client] = false;
                    in_flight -= 1;
                    avail_dropped_pending += 1;
                    let idle: Vec<usize> = (0..cfg.population)
                        .filter(|&i| !busy[i] && avail.is_available(i, now))
                        .collect();
                    if !idle.is_empty() {
                        let next = idle[rng.usize_below(idle.len())];
                        dispatch(
                            next,
                            &global,
                            &mut events,
                            &mut rng,
                            &mut client_rngs,
                            &mut busy,
                            &gens,
                            &mut in_flight,
                        )?;
                    }
                }
            }
            Event::Finish(fin) => {
                if fin.gen != gens[fin.client] {
                    continue; // cancelled by an offline transition
                }
                busy[fin.client] = false;
                in_flight -= 1;

                let staleness = global.version - fin.base_version;
                // Failure injection: finished but the upload never arrived.
                let lost = cfg.dropout_prob > 0.0 && rng.f64() < cfg.dropout_prob;
                let dropped_stale =
                    cfg.max_staleness.is_some_and(|cap| staleness > cap) || lost;
                if dropped_stale {
                    dropped_pending += 1;
                } else {
                    buffer.push(Contribution {
                        client_id: fin.client,
                        update: fin.update,
                        weight: 1.0,
                        staleness,
                    });
                    buffer_losses.push(fin.mean_loss);
                }

                // The finished client immediately starts again on the fresh
                // model. (Uniform re-sampling over online idle clients
                // keeps concurrency at n, matching FedBuff's "training
                // concurrency" definition; under churn the pool can be
                // momentarily empty — the slot refills when someone comes
                // back online.)
                let idle: Vec<usize> = (0..cfg.population)
                    .filter(|&i| !busy[i] && avail.is_available(i, now))
                    .collect();
                if !idle.is_empty() {
                    let next = idle[rng.usize_below(idle.len())];
                    dispatch(
                        next,
                        &global,
                        &mut events,
                        &mut rng,
                        &mut client_rngs,
                        &mut busy,
                        &gens,
                        &mut in_flight,
                    )?;
                }

                if buffer.len() >= k_goal {
                    let round = completed_rounds;
                    let participant_ids: Vec<usize> =
                        buffer.iter().map(|c| c.client_id).collect();
                    let avg = average_delta(&global.params, &buffer, true);
                    let mut params = global.params.clone();
                    server_opt.apply(&mut params, &avg);
                    global = Arc::new(VersionedParams {
                        version: global.version + 1,
                        params,
                    });

                    let mean_loss = if buffer_losses.is_empty() {
                        None
                    } else {
                        Some(buffer_losses.iter().sum::<f64>() / buffer_losses.len() as f64)
                    };
                    rec.record_round(
                        round,
                        now,
                        &participant_ids,
                        dropped_pending,
                        avail_dropped_pending,
                        mean_loss,
                    );
                    rec.maybe_eval(sim, round, now, &global.params)?;
                    buffer.clear();
                    buffer_losses.clear();
                    dropped_pending = 0;
                    avail_dropped_pending = 0;
                    completed_rounds += 1;
                    if rec.should_stop(sim, now) {
                        break;
                    }
                }
            }
        }
    }

    // Drops that accumulated after the last flush would otherwise vanish
    // from the attribution totals.
    rec.absorb_tail_drops(dropped_pending, avail_dropped_pending);

    let sim_secs = events.now();
    Ok(rec.finish(
        sim,
        sim_secs,
        completed_rounds,
        events.events_processed(),
        &mut avail,
    ))
}
