//! FedBuff baseline (Nguyen et al. 2021 / PAPAYA) — buffered asynchronous
//! FL, as an [`EventStrategy`].
//!
//! `n` clients (the training concurrency) are always training, each on the
//! global model version it pulled at dispatch time. Finished updates land
//! in a buffer; when the buffer holds `K` updates (the *aggregation goal*)
//! the server takes one global step with staleness-discounted weights
//! (1/sqrt(1+tau)) and the version counter advances. The finishing client
//! immediately re-dispatches on the fresh model.
//!
//! The engine owns the event loop (one `EventQueue` carrying client
//! finishes and availability transitions), churn cancellation (a client
//! going offline mid-training loses its in-flight update via a per-client
//! dispatch generation — and, with deferred dispatch execution, never runs
//! its PJRT work at all), and drop attribution; this module is only the
//! protocol: uniform dispatch over the idle-online pool, the buffer, and
//! the K-updates flush rule.
//!
//! This is the behaviour the paper criticizes: fast devices cycle many
//! times per aggregation round, slow devices contribute rarely and stale —
//! the participation-rate gap of Figs. 1/5, amplified by churn.

use anyhow::Result;

use super::engine::{ClientFinish, EventStrategy, SimEngine, Strategy};
use super::Simulation;
use crate::aggregation::{Contribution, ServerOpt};
use crate::fleet::HierarchyConfig;
use crate::metrics::events::DropCause;
use crate::model::VersionedParams;
use crate::simtime::SimTime;

pub struct FedBuff {
    global: VersionedParams,
    server_opt: ServerOpt,
    buffer: Vec<Contribution>,
    buffer_losses: Vec<f64>,
    /// `batch_exec` bookkeeping: buffered placeholder entries (ticket →
    /// buffer index) patched with real outcomes when the flush drains the
    /// engine's batch queue. Always empty under serial execution.
    pending_tickets: Vec<(u64, usize)>,
    k_goal: usize,
    /// Aggregation topology (`hierarchy = flat` reproduces `average_delta`
    /// verbatim; `two-tier` routes the flush through regional edges).
    hierarchy: HierarchyConfig,
}

/// Registry constructor.
pub fn build(sim: &Simulation) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(FedBuff {
        global: VersionedParams {
            version: 0,
            params: sim.runtime.init_params(sim.cfg.init_seed)?,
        },
        server_opt: ServerOpt::new(sim.cfg.server_opt, sim.cfg.server_lr)
            .with_jobs(sim.cfg.agg_jobs),
        buffer: Vec::new(),
        buffer_losses: Vec::new(),
        pending_tickets: Vec::new(),
        k_goal: sim.cfg.k_target(),
        hierarchy: sim.cfg.hierarchy.clone(),
    }))
}

impl FedBuff {
    /// Dispatch `client` on the current global (full model, fixed epochs).
    /// The engine snapshots the version-keyed base and defers the PJRT
    /// work to the finish event (churn-cancelled dispatches cost nothing).
    fn dispatch(&self, eng: &mut SimEngine, client: usize) -> Result<()> {
        eng.dispatch_full(client, &self.global.params, self.global.version)
    }

    /// Re-sampling over online idle clients keeps concurrency at n,
    /// matching FedBuff's "training concurrency" definition; the pick goes
    /// through the configured sampling policy (`uniform` reproduces the
    /// historical draw exactly). Under churn the pool can be momentarily
    /// empty — the slot refills when someone comes back online.
    fn refill_slot(&self, eng: &mut SimEngine, now: SimTime) -> Result<()> {
        if let Some(next) = eng.refill_pick(now) {
            self.dispatch(eng, next)?;
        }
        Ok(())
    }
}

impl Strategy for FedBuff {
    fn name(&self) -> &'static str {
        "FedBuff"
    }

    fn run(&mut self, eng: &mut SimEngine) -> Result<()> {
        eng.drive_events(self)
    }
}

impl EventStrategy for FedBuff {
    fn on_start(&mut self, eng: &mut SimEngine) -> Result<()> {
        // Start: n distinct currently-online clients training, drawn
        // through the sampling policy from a CLONE of the master RNG (not
        // the stream itself) — the seed behaviour, preserved for
        // bit-identical runs.
        let online0 = eng.avail.online_clients(0.0);
        let want = eng.sim.cfg.concurrency.min(online0.len());
        let cohort = eng.sample_cohort_detached(0.0, &online0, want);
        for c in cohort {
            self.dispatch(eng, c)?;
        }
        Ok(())
    }

    fn on_client_online(&mut self, eng: &mut SimEngine, client: usize) -> Result<()> {
        // Came online: fill a free concurrency slot with it.
        if !eng.is_busy(client) && eng.in_flight() < eng.sim.cfg.concurrency {
            self.dispatch(eng, client)?;
        }
        Ok(())
    }

    fn on_slot_freed(&mut self, eng: &mut SimEngine, now: SimTime) -> Result<()> {
        // A churned-out client's slot goes back to the online idle pool.
        self.refill_slot(eng, now)
    }

    fn on_finish(&mut self, eng: &mut SimEngine, now: SimTime, fin: ClientFinish) -> Result<()> {
        let cfg = &eng.sim.cfg;
        let staleness = self.global.version - fin.base_version;
        // Failure injection: finished but the upload never arrived.
        let lost = cfg.dropout_prob > 0.0 && eng.rng.f64() < cfg.dropout_prob;
        if cfg.max_staleness.is_some_and(|cap| staleness > cap) || lost {
            eng.drop_client(fin.client, DropCause::Deadline);
        } else {
            if let Some(ticket) = fin.ticket {
                self.pending_tickets.push((ticket, self.buffer.len()));
            }
            self.buffer.push(Contribution {
                client_id: fin.client,
                update: fin.update,
                weight: 1.0,
                staleness,
            });
            self.buffer_losses.push(fin.mean_loss);
        }

        // The finished client's slot immediately restarts on the fresh
        // model (uniform over the online idle pool, which includes it).
        self.refill_slot(eng, now)?;

        // Placeholders count toward the goal, so the flush trigger fires at
        // exactly the same event as under serial execution.
        if self.buffer.len() >= self.k_goal {
            // Batched execution: one stacked drain covers every plan that
            // resolved since the last flush. Outcomes for tickets no longer
            // buffered (strategy-dropped finishes) still executed — the
            // serial ledger ran those at their finish events too.
            for out in eng.drain_batch(None)? {
                if let Some(&(_, idx)) =
                    self.pending_tickets.iter().find(|(t, _)| *t == out.ticket)
                {
                    self.buffer[idx].update = out.update;
                    self.buffer_losses[idx] = out.mean_loss;
                }
            }
            self.pending_tickets.clear();
            let participant_ids: Vec<usize> =
                self.buffer.iter().map(|c| c.client_id).collect();
            // Weigher first (uniform rewrites the 1.0 already there), then
            // the protocol's own staleness discount applies on top inside
            // aggregation — the two compose multiplicatively.
            eng.weigh(&mut self.buffer);
            // Under `hier_clock = region` the flush hands the buffer to
            // the edges and the root may see nothing this round (`None`);
            // the version still advances — a flush is a flush — so
            // staleness accounting matches the shared-clock protocol.
            let mut params = self.global.params.clone();
            if let Some(avg) =
                eng.hier_aggregate(&self.hierarchy, &self.global.params, &self.buffer, true, now)
            {
                self.server_opt.apply(&mut params, &avg);
            }
            self.global = VersionedParams {
                version: self.global.version + 1,
                params,
            };

            let mean_loss = if self.buffer_losses.is_empty() {
                None
            } else {
                Some(self.buffer_losses.iter().sum::<f64>() / self.buffer_losses.len() as f64)
            };
            eng.complete_round(now, &participant_ids, mean_loss, &self.global.params)?;
            self.buffer.clear();
            self.buffer_losses.clear();
        }
        Ok(())
    }
}
