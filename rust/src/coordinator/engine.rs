//! `SimEngine` — the shared run lifecycle behind every FL strategy, plus
//! the `Strategy` hook traits.
//!
//! The engine owns everything the three original drivers duplicated: the
//! seeded RNG tree (one master stream + one forked stream per client), the
//! availability model, the `simtime::EventQueue` clock, online-client
//! sampling, idle-until-transition waits, churn-vs-deadline drop
//! attribution, eval/stop handling, the run-event stream, and
//! `Recorder::finish`. Strategies implement a small hook surface:
//!
//! - **round-stepped** protocols (TimelyFL, SyncFL) implement
//!   [`RoundStrategy::run_round`]: one aggregation round over a cohort the
//!   engine already sampled from the currently-online population. The
//!   engine drives the loop via [`SimEngine::drive_rounds`].
//! - **event-driven** protocols (FedBuff, SemiAsync) implement
//!   [`EventStrategy`]: the engine seeds and chains availability
//!   transitions, cancels in-flight work on churn, validates finish
//!   generations, and routes each event to a hook via
//!   [`SimEngine::drive_events`].
//!
//! Both drivers preserve the pre-refactor drivers' exact RNG draw order and
//! event schedule, so a ported strategy's `RunReport` is bit-identical to
//! its hand-rolled predecessor (locked by the golden tests in
//! `rust/tests/strategies_integration.rs`).

use anyhow::Result;

use super::trainer::train_client;
use super::{local_time, Recorder, Simulation};
use crate::availability::{AvailabilityModel, SEED_SALT};
use crate::metrics::events::{DropCause, EventSink, RunEvent};
use crate::metrics::RunReport;
use crate::model::{ParamVec, Update};
use crate::runtime::manifest::RatioMeta;
use crate::simtime::{EventQueue, SimTime};
use crate::util::rng::Rng;

/// A dispatched client finishing local training. The update is computed
/// eagerly at dispatch time (it only depends on the base snapshot, so this
/// is equivalent and keeps the event payload self-contained); `gen` is the
/// dispatch generation the finish belongs to — a mid-training offline
/// transition bumps the client's generation, invalidating the pending
/// finish.
pub struct ClientFinish {
    pub client: usize,
    pub gen: u64,
    /// Global model version the client trained against (for staleness).
    pub base_version: u64,
    pub update: Update,
    pub mean_loss: f64,
}

/// Everything that can move the engine's clock.
pub enum EngineEvent {
    /// A round boundary or idle-wake (scheduled by the round-stepped loop).
    Tick,
    /// `client`'s availability state flips at this timestamp; the next
    /// transition is chained onto the queue when this one is processed.
    Transition { client: usize },
    /// A dispatched client's simulated local training completes.
    Finish(ClientFinish),
    /// A strategy-scheduled timer (deadline-gated protocols re-arm it from
    /// [`EventStrategy::on_alarm`]).
    Alarm,
}

/// What a round-stepped strategy hands back for one aggregation round.
pub struct RoundOutcome {
    /// Simulated seconds the round occupied; the engine advances the clock
    /// by this (as a popped `Tick` event — the clock only moves through the
    /// queue).
    pub advance_secs: f64,
    /// Clients whose updates entered this aggregation.
    pub participants: Vec<usize>,
    /// Mean client-reported train loss; `None` when nobody delivered.
    pub mean_train_loss: Option<f64>,
}

/// One round's working context. Borrows the engine mutably for the round's
/// duration; `sampled` is the cohort the engine drew (uniformly, size
/// `min(concurrency, online)`) from the currently-online population, so
/// strategies never re-implement sampling. Split-borrow note: take
/// `let eng = &mut *ctx.eng;` first — `ctx.sampled` stays readable through
/// the disjoint field.
pub struct RoundCtx<'e, 'a> {
    /// Index of the aggregation round about to complete.
    pub round: usize,
    /// Simulated time at the round's start.
    pub now: SimTime,
    /// The sampled cohort (client ids).
    pub sampled: &'e [usize],
    pub eng: &'e mut SimEngine<'a>,
}

/// Hook surface for round-stepped protocols (TimelyFL, SyncFL).
pub trait RoundStrategy {
    /// Run one aggregation round over `ctx.sampled`. Report lost clients
    /// through [`SimEngine::drop_client`]; the engine folds them into the
    /// round record.
    fn run_round(&mut self, ctx: &mut RoundCtx<'_, '_>) -> Result<RoundOutcome>;

    /// Current global parameters — the engine evaluates these on the
    /// configured cadence.
    fn global_params(&self) -> &ParamVec;
}

/// Hook surface for event-driven protocols (FedBuff-shaped: a pool of
/// `concurrency` in-flight clients, updates landing asynchronously). The
/// engine owns busy/generation bookkeeping and churn cancellation; hooks
/// decide dispatch policy, buffering, and when a round completes (via
/// [`SimEngine::complete_round`]).
pub trait EventStrategy {
    /// Called once at t=0 (after availability transitions are seeded):
    /// dispatch the initial cohort.
    fn on_start(&mut self, eng: &mut SimEngine) -> Result<()>;

    /// `client` just flipped online. It is not dispatched automatically.
    fn on_client_online(&mut self, eng: &mut SimEngine, client: usize) -> Result<()>;

    /// A concurrency slot was freed by churn cancellation (the lost update
    /// is already attributed); refill it if the protocol wants to.
    fn on_slot_freed(&mut self, eng: &mut SimEngine, now: SimTime) -> Result<()>;

    /// A generation-valid finish arrived (its slot is already freed).
    fn on_finish(&mut self, eng: &mut SimEngine, now: SimTime, fin: ClientFinish) -> Result<()>;

    /// A strategy-scheduled [`EngineEvent::Alarm`] fired.
    fn on_alarm(&mut self, eng: &mut SimEngine, now: SimTime) -> Result<()> {
        let _ = (eng, now);
        Ok(())
    }
}

/// A registered FL strategy: constructed per run by the registry
/// (`coordinator::registry`), then handed the engine.
pub trait Strategy {
    /// Canonical display name; also the registry key and what
    /// `RunReport::strategy` carries.
    fn name(&self) -> &'static str;

    /// Execute the full run — typically one line delegating to
    /// [`SimEngine::drive_rounds`] or [`SimEngine::drive_events`].
    fn run(&mut self, eng: &mut SimEngine) -> Result<()>;
}

/// Shared per-run state + lifecycle driver. One engine drives one run.
pub struct SimEngine<'a> {
    pub sim: &'a Simulation,
    /// Master RNG stream (sampling, round conditions, dropout draws).
    pub rng: Rng,
    /// Per-client forked streams (data order inside local training).
    pub client_rngs: Vec<Rng>,
    pub avail: AvailabilityModel,
    pub events: EventQueue<EngineEvent>,
    pub recorder: Recorder,
    busy: Vec<bool>,
    gens: Vec<u64>,
    in_flight: usize,
    completed_rounds: usize,
    /// Drop attribution accumulated since the last completed round.
    dropped_pending: usize,
    avail_dropped_pending: usize,
    stop: bool,
    sink: Option<&'a mut dyn EventSink>,
}

impl<'a> SimEngine<'a> {
    /// Build the engine exactly as every pre-refactor driver did: master
    /// RNG from `cfg.seed`, one forked stream per client, availability
    /// model on the salted seed (its draws never perturb sampling).
    pub fn new(
        sim: &'a Simulation,
        sink: Option<&'a mut dyn EventSink>,
    ) -> Result<SimEngine<'a>> {
        let cfg = &sim.cfg;
        let mut rng = Rng::seed_from(cfg.seed);
        let client_rngs: Vec<Rng> = (0..cfg.population).map(|i| rng.fork(i as u64)).collect();
        let avail =
            AvailabilityModel::build(&cfg.availability, cfg.population, cfg.seed ^ SEED_SALT)?;
        Ok(SimEngine {
            sim,
            rng,
            client_rngs,
            avail,
            events: EventQueue::new(),
            recorder: Recorder::new(cfg.population),
            busy: vec![false; cfg.population],
            gens: vec![0; cfg.population],
            in_flight: 0,
            completed_rounds: 0,
            dropped_pending: 0,
            avail_dropped_pending: 0,
            stop: false,
            sink,
        })
    }

    pub fn now(&self) -> SimTime {
        self.events.now()
    }

    pub fn completed_rounds(&self) -> usize {
        self.completed_rounds
    }

    /// Is `client` currently dispatched?
    pub fn is_busy(&self, client: usize) -> bool {
        self.busy[client]
    }

    /// Clients currently training (bounded by `cfg.concurrency`).
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Ask the driver loop to end after the current hook returns (the
    /// engine arms this itself when the eval target / time budget is hit).
    pub fn request_stop(&mut self) {
        self.stop = true;
    }

    fn emit(&mut self, ev: RunEvent) {
        if let Some(sink) = self.sink.as_deref_mut() {
            sink.emit(&ev);
        }
    }

    /// Attribute one lost client update and emit its `client-dropped`
    /// record. Folded into the NEXT completed round's attribution (for
    /// round-stepped strategies that is the current round).
    pub fn drop_client(&mut self, client: usize, cause: DropCause) {
        match cause {
            DropCause::Availability => self.avail_dropped_pending += 1,
            DropCause::Deadline => self.dropped_pending += 1,
        }
        let ev = RunEvent::ClientDropped {
            client,
            sim_secs: self.events.now(),
            cause,
        };
        self.emit(ev);
    }

    /// When the whole population is momentarily offline, advance the clock
    /// (as an event) to the next availability transition. `false` = no
    /// transition will ever come — permanently offline, end gracefully.
    fn idle_until_transition(&mut self) -> bool {
        let Some(t) = self.avail.earliest_transition(self.events.now()) else {
            return false;
        };
        self.events.schedule_at(t, EngineEvent::Tick);
        self.events.pop();
        true
    }

    /// Record one completed aggregation round at `clock`: consumes the
    /// pending drop attribution, emits `round-complete` (and `eval-point`
    /// when the cadence fires), evaluates `global`, and arms the stop flag
    /// once the target metric or sim-time budget is hit.
    pub fn complete_round(
        &mut self,
        clock: SimTime,
        participant_ids: &[usize],
        mean_train_loss: Option<f64>,
        global: &ParamVec,
    ) -> Result<()> {
        let sim = self.sim;
        let round = self.completed_rounds;
        let dropped = std::mem::take(&mut self.dropped_pending);
        let avail_dropped = std::mem::take(&mut self.avail_dropped_pending);
        self.recorder.record_round(
            round,
            clock,
            participant_ids,
            dropped,
            avail_dropped,
            mean_train_loss,
        );
        self.emit(RunEvent::RoundComplete {
            round,
            sim_secs: clock,
            participants: participant_ids.len(),
            dropped,
            avail_dropped,
            mean_train_loss,
        });
        if let Some(p) = self.recorder.maybe_eval(sim, round, clock, global)? {
            self.emit(RunEvent::EvalPoint {
                round: p.round,
                sim_secs: p.sim_secs,
                mean_loss: p.mean_loss,
                metric: p.metric,
            });
        }
        self.completed_rounds += 1;
        if self.recorder.should_stop(sim, clock) {
            self.stop = true;
        }
        Ok(())
    }

    /// The shared round-stepped loop: sample an online cohort, run the
    /// strategy's round, advance the clock by the round's span, record /
    /// eval / stop. Idles (as events) across whole-population offline gaps.
    pub fn drive_rounds(&mut self, strat: &mut dyn RoundStrategy) -> Result<()> {
        let sim = self.sim;
        let cfg = &sim.cfg;
        while self.completed_rounds < cfg.rounds {
            let now = self.events.now();
            // When everyone is online, `online` is exactly 0..population and
            // index-sampling from it is bit-identical to sampling the whole
            // population (the always-on compatibility path).
            let online = self.avail.online_clients(now);
            if online.is_empty() {
                if !self.idle_until_transition()
                    || self.recorder.should_stop(sim, self.events.now())
                {
                    break;
                }
                continue;
            }
            let want = cfg.concurrency.min(online.len());
            let sampled: Vec<usize> = self
                .rng
                .sample_without_replacement(online.len(), want)
                .into_iter()
                .map(|i| online[i])
                .collect();

            let round = self.completed_rounds;
            let outcome = strat.run_round(&mut RoundCtx {
                round,
                now,
                sampled: &sampled,
                eng: &mut *self,
            })?;

            // The round boundary is an event popped off the queue, so all
            // strategies share one clock discipline.
            self.events.schedule_in(outcome.advance_secs, EngineEvent::Tick);
            let (clock, _) = self.events.pop().expect("round boundary was scheduled");
            self.complete_round(
                clock,
                &outcome.participants,
                outcome.mean_train_loss,
                strat.global_params(),
            )?;
            if self.stop {
                break;
            }
        }
        Ok(())
    }

    /// The shared event-driven loop: seeds + chains availability
    /// transitions, cancels in-flight updates on churn, validates finish
    /// generations, and routes everything else to the strategy's hooks.
    pub fn drive_events(&mut self, strat: &mut dyn EventStrategy) -> Result<()> {
        let sim = self.sim;
        let cfg = &sim.cfg;
        // Seed the queue with each client's first availability transition
        // (the chain re-schedules itself as transitions are processed).
        // Always-on schedules nothing.
        for c in 0..cfg.population {
            if let Some(t) = self.avail.next_transition(c, 0.0) {
                self.events.schedule_at(t, EngineEvent::Transition { client: c });
            }
        }
        strat.on_start(self)?;

        while self.completed_rounds < cfg.rounds {
            let Some((now, ev)) = self.events.pop() else {
                // A drained queue under always-on means the dispatch
                // invariant broke — that is a bug. Under churn it is a
                // legitimate end state (population permanently offline).
                if self.avail.is_always_on() {
                    anyhow::bail!(
                        "event queue drained with {} rounds done",
                        self.completed_rounds
                    );
                }
                break;
            };
            // Budget guard at the loop top, not only at round completion: a
            // heavily-churned population can keep transitions (and real
            // training dispatches) flowing forever without ever filling a
            // buffer. No-op under the default infinite budget.
            if self.recorder.should_stop(sim, now) {
                break;
            }
            match ev {
                // Only the round-stepped loop schedules Ticks; tolerate a
                // stray one (it already advanced the clock) rather than
                // aborting a run.
                EngineEvent::Tick => {}
                EngineEvent::Transition { client } => {
                    let next = self.avail.next_transition(client, now);
                    if let Some(t) = next {
                        self.events.schedule_at(t, EngineEvent::Transition { client });
                    }
                    // Read the post-transition state at the segment
                    // midpoint: the state is constant until the next
                    // transition, and the midpoint dodges ulp-level
                    // ambiguity of evaluating the diurnal gate exactly at a
                    // boundary instant.
                    let online_now = match next {
                        Some(t) => self.avail.is_available(client, (now + t) / 2.0),
                        None => self.avail.is_available(client, now),
                    };
                    self.emit(RunEvent::AvailabilityTransition {
                        client,
                        sim_secs: now,
                        online: online_now,
                    });
                    if online_now {
                        strat.on_client_online(self, client)?;
                    } else if self.busy[client] {
                        // Went offline mid-training: the in-flight update is
                        // lost with it.
                        self.cancel_in_flight(client);
                        strat.on_slot_freed(self, now)?;
                    }
                }
                EngineEvent::Finish(fin) => {
                    if fin.gen != self.gens[fin.client] {
                        continue; // cancelled by an offline transition
                    }
                    self.busy[fin.client] = false;
                    self.in_flight -= 1;
                    strat.on_finish(self, now, fin)?;
                    if self.stop {
                        break;
                    }
                }
                EngineEvent::Alarm => {
                    strat.on_alarm(self, now)?;
                    if self.stop {
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    /// Invalidate `client`'s pending finish (generation bump), return its
    /// concurrency slot, and attribute the loss to availability churn.
    fn cancel_in_flight(&mut self, client: usize) {
        self.gens[client] += 1;
        self.busy[client] = false;
        self.in_flight -= 1;
        self.drop_client(client, DropCause::Availability);
    }

    /// Dispatch one client for event-driven protocols: train eagerly on
    /// `base` and schedule the finish event at the simulated completion
    /// time. Callers pick only currently-online, non-busy clients.
    pub fn dispatch(
        &mut self,
        client: usize,
        epochs: usize,
        ratio: &RatioMeta,
        base: &ParamVec,
        base_version: u64,
    ) -> Result<()> {
        let sim = self.sim;
        let cfg = &sim.cfg;
        debug_assert!(!self.busy[client], "client {client} dispatched twice");
        self.busy[client] = true;
        self.in_flight += 1;
        let cond = sim.fleet.round_conditions(&mut self.rng);
        let t = local_time::truth(&sim.fleet.devices[client], &cond, cfg.sim_model_bytes);
        // Compute scales with the nominal compiled ratio, upload with the
        // realized trainable fraction; both are exactly 1.0 for full-model
        // dispatches.
        let duration = t.round_secs(epochs as f64, ratio.ratio, ratio.trainable_fraction);
        let outcome = train_client(
            &sim.runtime,
            &sim.dataset,
            client,
            base,
            ratio,
            epochs,
            cfg.steps_per_epoch,
            cfg.client_lr,
            &mut self.client_rngs[client],
        )?;
        self.events.schedule_in(
            duration,
            EngineEvent::Finish(ClientFinish {
                client,
                gen: self.gens[client],
                base_version,
                update: outcome.update,
                mean_loss: outcome.mean_loss,
            }),
        );
        Ok(())
    }

    /// Full-model [`SimEngine::dispatch`] with the shared
    /// `fedbuff_local_epochs` setting — the common case for buffered
    /// asynchronous protocols.
    pub fn dispatch_full(
        &mut self,
        client: usize,
        base: &ParamVec,
        base_version: u64,
    ) -> Result<()> {
        let sim = self.sim;
        let full = sim
            .runtime
            .meta
            .ratio_exact(1.0)
            .expect("full ratio always compiled");
        self.dispatch(client, sim.cfg.fedbuff_local_epochs, full, base, base_version)
    }

    /// Currently-idle, currently-online clients — the slot-refill pool for
    /// event-driven dispatch policies.
    pub fn idle_online_clients(&mut self, now: SimTime) -> Vec<usize> {
        (0..self.sim.cfg.population)
            .filter(|&i| !self.busy[i] && self.avail.is_available(i, now))
            .collect()
    }

    /// Close out the run: absorb any post-round drop tail and build the
    /// final report.
    pub fn finish(self, strategy_name: &str) -> RunReport {
        let SimEngine {
            sim,
            mut recorder,
            mut avail,
            events,
            completed_rounds,
            dropped_pending,
            avail_dropped_pending,
            ..
        } = self;
        recorder.absorb_tail_drops(dropped_pending, avail_dropped_pending);
        recorder.finish(
            strategy_name,
            sim,
            events.now(),
            completed_rounds,
            events.events_processed(),
            &mut avail,
        )
    }
}
