//! `SimEngine` — the shared run lifecycle behind every FL strategy, plus
//! the `Strategy` hook traits.
//!
//! The engine owns everything the three original drivers duplicated: the
//! seeded RNG tree (one master stream + one forked stream per client), the
//! availability model, the `simtime::EventQueue` clock, online-client
//! sampling, idle-until-transition waits, churn-vs-deadline drop
//! attribution, eval/stop handling, the run-event stream, and
//! `Recorder::finish`. Strategies implement a small hook surface:
//!
//! - **round-stepped** protocols (TimelyFL, SyncFL) implement
//!   [`RoundStrategy::run_round`]: one aggregation round over a cohort the
//!   engine already sampled from the currently-online population. The
//!   engine drives the loop via [`SimEngine::drive_rounds`].
//! - **event-driven** protocols (FedBuff, SemiAsync) implement
//!   [`EventStrategy`]: the engine seeds and chains availability
//!   transitions, cancels in-flight work on churn, validates finish
//!   generations, and routes each event to a hook via
//!   [`SimEngine::drive_events`].
//!
//! Both drivers preserve the pre-refactor drivers' exact RNG draw order and
//! event schedule, so a ported strategy's `RunReport` is bit-identical to
//! its hand-rolled predecessor (locked by the golden tests in
//! `rust/tests/strategies_integration.rs`).
//!
//! # Deferred client training
//!
//! [`SimEngine::dispatch`] splits local training into *plan* and *execute*
//! phases (`coordinator::trainer`). The plan — every data-batch draw — is
//! taken eagerly from the per-client RNG at dispatch time, preserving
//! stream positions and therefore golden-report bit-identity; the PJRT
//! executions are deferred until the dispatch's Finish event arrives with a
//! still-valid generation. A mid-training availability drop discards the
//! pending [`TrainPlan`] without ever touching the accelerator
//! (`trainings_avoided` in the report; `cfg.eager_train` restores the
//! train-at-dispatch behaviour for A/B measurement). Base-model snapshots
//! for pending plans live in a version-keyed refcounted [`SnapshotStore`]
//! so concurrent dispatches against one global version share a single
//! copy.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use super::local_time::TimeTruth;
use super::sampler::{self, ClientSampler, SamplerCtx};
use super::trainer::{
    execute_plan, execute_plans_batched, plan_client, train_client, LocalOutcome, TrainPlan,
};
use super::{local_time, Recorder, Simulation};
use crate::aggregation::Contribution;
use crate::availability::{AvailabilityModel, BandwidthSignal, SEED_SALT};
use crate::devices::RoundConditions;
use crate::fleet::{
    root_merge, ClientTables, FleetCore, HierarchyConfig, LazyAvailability, PartialAggregate,
    RegionClock,
};
use crate::metrics::events::{AggWeight, ClientWorkload, DropCause, EventSink, RunEvent};
use crate::scheduling::{AggWeigher, HorizonEstimator, WarmLedger};
use crate::metrics::RunReport;
use crate::model::{ParamVec, Update};
use crate::network::{self, NetworkModel, StaleCorrection};
use crate::runtime::manifest::RatioMeta;
use crate::simtime::{EventQueue, SimTime};
use crate::util::rng::Rng;

/// A dispatched client's completed local training, as delivered to
/// [`EventStrategy::on_finish`]. Under deferred execution (the default) the
/// update is computed by the engine when the Finish event validates; under
/// `cfg.eager_train` it was computed at dispatch time and stashed. Either
/// way the hook sees the same payload. `gen` is the dispatch generation the
/// finish belongs to — a mid-training offline transition bumps the
/// client's generation, invalidating the pending finish.
pub struct ClientFinish {
    pub client: usize,
    pub gen: u32,
    /// Global model version the client trained against (for staleness).
    pub base_version: u64,
    pub update: Update,
    pub mean_loss: f64,
    /// `Some` under `cfg.batch_exec`: the finish's deferred plan was queued
    /// on the engine's [`BatchQueue`] instead of executed — `update` is an
    /// empty placeholder and `mean_loss` is NaN until the strategy drains
    /// the queue at its next aggregation boundary ([`SimEngine::drain_batch`])
    /// and patches its buffered entry by this ticket.
    pub ticket: Option<u64>,
}

/// Everything that can move the engine's clock. `Finish` is a lightweight
/// marker — the dispatch's stashed work lives in the engine's pending
/// table, not in the queue — so cancelling it never wastes accelerator
/// work.
pub enum EngineEvent {
    /// A round boundary or idle-wake (scheduled by the round-stepped loop).
    Tick,
    /// `client`'s availability state flips at this timestamp; the next
    /// transition is chained onto the queue when this one is processed.
    Transition { client: usize },
    /// A dispatched client's simulated local training completes. Valid iff
    /// `gen` still matches the client's dispatch generation.
    Finish { client: usize, gen: u32 },
    /// A strategy-scheduled timer (deadline-gated protocols re-arm it from
    /// [`EventStrategy::on_alarm`]).
    Alarm,
    /// A region's edge-aggregator flush deadline (`hier_clock = region`,
    /// event-driven strategies only — round-stepped strategies poll
    /// deadlines at their aggregation boundaries instead, so their Tick
    /// discipline never sees this variant). Valid iff `gen` still matches
    /// the region clock's window generation.
    EdgeFlush { region: usize, gen: u64 },
}

/// What a round-stepped strategy hands back for one aggregation round.
pub struct RoundOutcome {
    /// Simulated seconds the round occupied; the engine advances the clock
    /// by this (as a popped `Tick` event — the clock only moves through the
    /// queue).
    pub advance_secs: f64,
    /// Clients whose updates entered this aggregation.
    pub participants: Vec<usize>,
    /// Mean client-reported train loss; `None` when nobody delivered.
    pub mean_train_loss: Option<f64>,
}

/// One round's working context. Borrows the engine mutably for the round's
/// duration; `sampled` is the cohort the engine drew (uniformly, size
/// `min(concurrency, online)`) from the currently-online population, so
/// strategies never re-implement sampling. Split-borrow note: take
/// `let eng = &mut *ctx.eng;` first — `ctx.sampled` stays readable through
/// the disjoint field.
pub struct RoundCtx<'e, 'a> {
    /// Index of the aggregation round about to complete.
    pub round: usize,
    /// Simulated time at the round's start.
    pub now: SimTime,
    /// The sampled cohort (client ids).
    pub sampled: &'e [usize],
    pub eng: &'e mut SimEngine<'a>,
}

/// Hook surface for round-stepped protocols (TimelyFL, SyncFL).
pub trait RoundStrategy {
    /// Run one aggregation round over `ctx.sampled`. Report lost clients
    /// through [`SimEngine::drop_client`]; the engine folds them into the
    /// round record.
    fn run_round(&mut self, ctx: &mut RoundCtx<'_, '_>) -> Result<RoundOutcome>;

    /// Current global parameters — the engine evaluates these on the
    /// configured cadence.
    fn global_params(&self) -> &ParamVec;
}

/// Hook surface for event-driven protocols (FedBuff-shaped: a pool of
/// `concurrency` in-flight clients, updates landing asynchronously). The
/// engine owns busy/generation bookkeeping and churn cancellation; hooks
/// decide dispatch policy, buffering, and when a round completes (via
/// [`SimEngine::complete_round`]).
pub trait EventStrategy {
    /// Called once at t=0 (after availability transitions are seeded):
    /// dispatch the initial cohort.
    fn on_start(&mut self, eng: &mut SimEngine) -> Result<()>;

    /// `client` just flipped online. It is not dispatched automatically.
    fn on_client_online(&mut self, eng: &mut SimEngine, client: usize) -> Result<()>;

    /// A concurrency slot was freed by churn cancellation (the lost update
    /// is already attributed); refill it if the protocol wants to.
    fn on_slot_freed(&mut self, eng: &mut SimEngine, now: SimTime) -> Result<()>;

    /// A generation-valid finish arrived (its slot is already freed).
    fn on_finish(&mut self, eng: &mut SimEngine, now: SimTime, fin: ClientFinish) -> Result<()>;

    /// A strategy-scheduled [`EngineEvent::Alarm`] fired.
    fn on_alarm(&mut self, eng: &mut SimEngine, now: SimTime) -> Result<()> {
        let _ = (eng, now);
        Ok(())
    }
}

/// A registered FL strategy: constructed per run by the registry
/// (`coordinator::registry`), then handed the engine.
pub trait Strategy {
    /// Canonical display name; also the registry key and what
    /// `RunReport::strategy` carries.
    fn name(&self) -> &'static str;

    /// Execute the full run — typically one line delegating to
    /// [`SimEngine::drive_rounds`] or [`SimEngine::drive_events`].
    fn run(&mut self, eng: &mut SimEngine) -> Result<()>;
}

/// The stashed half of an in-flight dispatch, resolved when its Finish
/// event validates (or discarded when churn cancels it).
enum PendingWork {
    /// Deferred (default): the PJRT executions happen at the Finish event;
    /// the plan pins the RNG draws, the `Arc` keeps the base snapshot
    /// alive.
    Planned { plan: TrainPlan, base: Arc<ParamVec> },
    /// Eager (`cfg.eager_train`): trained at dispatch time, outcome stashed
    /// until the finish — the pre-deferral behaviour, kept for A/B
    /// measurement.
    Trained { update: Update, mean_loss: f64 },
}

struct PendingDispatch {
    base_version: u64,
    /// Simulated time the dispatch's downlink transfer landed at the
    /// client (equals the dispatch time under `network = free`).
    arrival: SimTime,
    /// The downlink leg's duration; strictly positive only for priced
    /// dissemination — the gate on all stale-start bookkeeping.
    down_secs: f64,
    work: PendingWork,
}

/// One resolve-ready plan parked on the [`BatchQueue`] awaiting the next
/// aggregation boundary. Round-stepped strategies queue with `base: None`
/// (every plan in the round trains against the round's shared global, which
/// the drain call supplies — zero snapshot clones); event-driven strategies
/// carry the dispatch's version-keyed snapshot `Arc` plus the version to
/// release once the plan executes.
struct QueuedPlan {
    ticket: u64,
    client: usize,
    plan: TrainPlan,
    base: Option<(Arc<ParamVec>, u64)>,
}

/// Accumulator for resolve-ready plans under `cfg.batch_exec`: instead of
/// one PJRT dispatch per client, plans collect here between aggregation
/// boundaries and drain through `trainer::execute_plans_batched` — waves of
/// up to `meta.lanes` clients per stacked dispatch. Tickets are handed out
/// in enqueue order and the drain returns outcomes in the same order, so
/// strategies can patch buffered placeholders deterministically.
#[derive(Default)]
struct BatchQueue {
    items: Vec<QueuedPlan>,
    next_ticket: u64,
}

impl BatchQueue {
    fn push(&mut self, client: usize, plan: TrainPlan, base: Option<(Arc<ParamVec>, u64)>) -> u64 {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.items.push(QueuedPlan {
            ticket,
            client,
            plan,
            base,
        });
        ticket
    }

    fn take(&mut self) -> Vec<QueuedPlan> {
        std::mem::take(&mut self.items)
    }
}

/// A drained plan's executed outcome, in enqueue (ticket) order.
pub struct BatchedOutcome {
    pub ticket: u64,
    pub client: usize,
    pub update: Update,
    pub mean_loss: f64,
}

/// Version-keyed store of base-model snapshots for deferred dispatches.
/// `retain` hands out a shared `Arc` per global version (cloning the
/// parameters at most once per version, however many clients dispatch on
/// it); `release` drops a reference and evicts the version once its last
/// pending plan resolves — executed or cancelled — so the store never
/// outgrows the set of versions with work still in flight.
#[derive(Default)]
pub(crate) struct SnapshotStore {
    entries: BTreeMap<u64, (Arc<ParamVec>, usize)>,
}

impl SnapshotStore {
    fn retain(&mut self, version: u64, params: &ParamVec) -> Arc<ParamVec> {
        let entry = self
            .entries
            .entry(version)
            .or_insert_with(|| (Arc::new(params.clone()), 0));
        entry.1 += 1;
        Arc::clone(&entry.0)
    }

    fn release(&mut self, version: u64) {
        let Some(entry) = self.entries.get_mut(&version) else {
            debug_assert!(false, "release of unretained snapshot version {version}");
            return;
        };
        entry.1 -= 1;
        if entry.1 == 0 {
            self.entries.remove(&version);
        }
    }

    /// Versions currently held (bounded by distinct in-flight versions).
    #[cfg(test)]
    fn versions_held(&self) -> usize {
        self.entries.len()
    }
}

/// Engine-side region-clock plumbing (`hier_clock = region` only). The
/// engine holds `Option<EdgeClocks>` and default runs build `None`, which
/// is the byte-identity anchor: with no edge state, `hier_aggregate`
/// reduces to the historical synchronous `aggregate_jobs` call and every
/// edge counter stays at zero.
struct EdgeClocks {
    hierarchy: HierarchyConfig,
    /// One independent flush clock per region (`client_id % regions`).
    clocks: Vec<RegionClock>,
    /// The priced edge→root leg (`hier_uplink`), resolved through the
    /// `NetworkModel` registry with `hier_up_ratio` as the ratio knob.
    uplink: Box<dyn NetworkModel>,
    /// Flushed partials in transit to the root: (arrival time on the
    /// shared sim clock, partial). Drained in insertion order once ripe.
    in_transit: Vec<(SimTime, PartialAggregate)>,
    /// Per-region (sum, count) of the open window's contributors'
    /// last-known effective upload seconds — the uplink pricing base.
    /// Reset at flush.
    window_tcom: Vec<(f64, usize)>,
    /// Last effective upload seconds observed per client (recorded where
    /// dispatch timing truth is computed). Deterministic: no extra RNG.
    last_tcom: Vec<f64>,
}

impl EdgeClocks {
    fn new(hierarchy: &HierarchyConfig, population: usize) -> Result<EdgeClocks> {
        Ok(EdgeClocks {
            hierarchy: hierarchy.clone(),
            clocks: (0..hierarchy.regions).map(|_| RegionClock::new()).collect(),
            uplink: hierarchy.uplink_model()?,
            in_transit: Vec::new(),
            window_tcom: vec![(0.0, 0); hierarchy.regions],
            last_tcom: vec![0.0; population],
        })
    }

    /// Flush `region` at `clock` (its deadline): price the uplink from the
    /// mean effective upload time of the window's contributors and put the
    /// partial in transit. Returns the priced uplink seconds, or `None` if
    /// the region held nothing.
    fn flush_region(&mut self, region: usize, clock: SimTime) -> Option<f64> {
        let partial = self.clocks[region].flush(clock)?;
        let (sum, count) = std::mem::take(&mut self.window_tcom[region]);
        let base = if count == 0 { 0.0 } else { sum / count as f64 };
        let up = self.uplink.downlink_secs(base);
        self.in_transit.push((clock + up, partial));
        Some(up)
    }
}

/// Shared per-run state + lifecycle driver. One engine drives one run.
pub struct SimEngine<'a> {
    pub sim: &'a Simulation,
    /// Master RNG stream (sampling, round conditions, dropout draws).
    pub rng: Rng,
    /// Per-client forked streams (data order inside local training).
    pub client_rngs: Vec<Rng>,
    pub avail: AvailabilityModel,
    pub events: EventQueue<EngineEvent>,
    pub recorder: Recorder,
    /// The sampling policy (`RunConfig::sampler`, resolved through
    /// `coordinator::sampler`): every cohort draw and slot-refill pick
    /// goes through it.
    sampler: Box<dyn ClientSampler>,
    /// Per-client ledgers (sampler scores, delivered/churned counts, busy
    /// flags, dispatch generations), struct-of-arrays (`fleet::ClientTables`).
    tables: ClientTables,
    /// The lazy sim core (`fleet_core = lazy`): incrementally-maintained
    /// online-set index + next-transition agenda. `None` keeps the
    /// historical eager scans.
    lazy: Option<LazyAvailability>,
    /// Per-client stashed dispatch work (at most one — the busy flag
    /// gates), keyed sparsely so memory tracks in-flight concurrency
    /// rather than fleet size.
    pending: BTreeMap<usize, PendingDispatch>,
    snapshots: SnapshotStore,
    /// Resolve-ready plans awaiting the next aggregation boundary
    /// (`cfg.batch_exec`; always empty otherwise).
    batch: BatchQueue,
    in_flight: usize,
    completed_rounds: usize,
    /// Drop attribution accumulated since the last completed round.
    dropped_pending: usize,
    avail_dropped_pending: usize,
    /// Workload assignments (Alg. 3's E_c / alpha_c, as dispatched)
    /// accumulated since the last completed round; drained onto the
    /// `round-complete` event record so sweep JSONL output exposes the
    /// scheduler's per-client decisions.
    workloads_pending: Vec<ClientWorkload>,
    /// The configured aggregation weigher (`crate::scheduling`, resolved
    /// from `cfg.scheduling.weigher`). `uniform` scores every update at
    /// exactly 1.0 — the value strategies historically hardcoded — which is
    /// what keeps default runs bit-identical.
    weigher: Box<dyn AggWeigher>,
    /// Per-update weights assigned since the last completed round (drained
    /// onto the round-complete record; only bookkept when a sink is
    /// attached, like `workloads_pending`).
    agg_weights_pending: Vec<AggWeight>,
    /// EWMA tracker of the realized aggregation interval. Always observed
    /// (pure bookkeeping off the round clock); only *consulted* for the
    /// sampler horizon under `cfg.scheduling.horizon_auto`.
    horizon_est: HorizonEstimator,
    /// The configured model-dissemination pricer (`crate::network`,
    /// resolved from `cfg.network`). `free` prices every downlink at
    /// exactly 0.0 and keeps all dissemination bookkeeping untouched.
    net: Box<dyn NetworkModel>,
    /// First simulated time each global version was seen on a dispatch — a
    /// lower bound on its birth, enough for conservative stale-start
    /// detection (`network::overtaken_by`). Only populated while downlinks
    /// cost time, so `free` runs never grow it. Bounded by the number of
    /// global versions.
    version_born: BTreeMap<u64, SimTime>,
    /// Downlink-wait seconds / stale starts accumulated since the last
    /// completed round (drained onto the round-complete record and into
    /// the Recorder's run totals).
    downlink_wait_pending: f64,
    stale_starts_pending: u64,
    /// Region-clock state (`hier_clock = region`); `None` on default runs.
    edge: Option<EdgeClocks>,
    /// Edge flushes / priced uplink-wait seconds / root merges accumulated
    /// since the last completed round (drained like the network counters).
    edge_flushes_pending: u64,
    edge_uplink_wait_pending: f64,
    edge_root_merges_pending: u64,
    /// True once an event-driven strategy owns the queue (`drive_events`).
    /// Round-stepped drivers pop their own Ticks with nothing else in the
    /// queue, so `EdgeFlush` alarms are only ever scheduled when this is
    /// set; round strategies poll deadlines at aggregation boundaries.
    event_driven: bool,
    stop: bool,
    sink: Option<&'a mut dyn EventSink>,
}

impl<'a> SimEngine<'a> {
    /// Build the engine exactly as every pre-refactor driver did: master
    /// RNG from `cfg.seed`, one forked stream per client, availability
    /// model on the salted seed (its draws never perturb sampling).
    pub fn new(
        sim: &'a Simulation,
        sink: Option<&'a mut dyn EventSink>,
    ) -> Result<SimEngine<'a>> {
        let cfg = &sim.cfg;
        let mut rng = Rng::seed_from(cfg.seed);
        let client_rngs: Vec<Rng> = (0..cfg.population).map(|i| rng.fork(i as u64)).collect();
        let mut avail =
            AvailabilityModel::build(&cfg.availability, cfg.population, cfg.seed ^ SEED_SALT)?;
        let sampler = (sampler::resolve(&cfg.sampler)?.build)();
        let weigher = cfg.scheduling.build()?;
        // The lazy core's seeding pass queries the availability model in
        // client order at t=0 — the same order (and therefore the same
        // markov timeline materialisations) as the eager paths' first scan.
        let lazy = match cfg.fleet_core {
            FleetCore::Lazy => Some(LazyAvailability::new(&mut avail)),
            FleetCore::Eager => None,
        };
        let net = cfg.network.build()?;
        let edge = if cfg.hierarchy.region_clocked() {
            Some(EdgeClocks::new(&cfg.hierarchy, cfg.population)?)
        } else {
            None
        };
        Ok(SimEngine {
            sim,
            rng,
            client_rngs,
            avail,
            events: EventQueue::new(),
            recorder: Recorder::new(cfg.population),
            sampler,
            tables: ClientTables::new(cfg.population),
            lazy,
            pending: BTreeMap::new(),
            snapshots: SnapshotStore::default(),
            batch: BatchQueue::default(),
            in_flight: 0,
            completed_rounds: 0,
            dropped_pending: 0,
            avail_dropped_pending: 0,
            workloads_pending: Vec::new(),
            weigher,
            agg_weights_pending: Vec::new(),
            horizon_est: HorizonEstimator::default(),
            net,
            version_born: BTreeMap::new(),
            downlink_wait_pending: 0.0,
            stale_starts_pending: 0,
            edge,
            edge_flushes_pending: 0,
            edge_uplink_wait_pending: 0.0,
            edge_root_merges_pending: 0,
            event_driven: false,
            stop: false,
            sink,
        })
    }

    pub fn now(&self) -> SimTime {
        self.events.now()
    }

    pub fn completed_rounds(&self) -> usize {
        self.completed_rounds
    }

    /// Is `client` currently dispatched?
    pub fn is_busy(&self, client: usize) -> bool {
        self.tables.is_busy(client)
    }

    /// Clients currently training (bounded by `cfg.concurrency`).
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Ask the driver loop to end after the current hook returns (the
    /// engine arms this itself when the eval target / time budget is hit).
    pub fn request_stop(&mut self) {
        self.stop = true;
    }

    fn emit(&mut self, ev: RunEvent) {
        if let Some(sink) = self.sink.as_deref_mut() {
            sink.emit(&ev);
        }
    }

    /// The sampling horizon for this instant: the fixed
    /// `sampler_horizon_secs`, or — under `sampler_horizon = auto` — the
    /// EWMA estimate of the realized aggregation interval (falling back to
    /// the fixed value until the first interval completes).
    fn sampler_horizon(&self) -> f64 {
        let fixed = self.sim.cfg.sampler_horizon_secs;
        if self.sim.cfg.scheduling.horizon_auto {
            self.horizon_est.horizon(fixed)
        } else {
            fixed
        }
    }

    /// Draw a cohort of `want` distinct clients from `pool` (the
    /// currently-online candidates) through the configured sampling
    /// policy. Under `sampler = uniform` the RNG draws are exactly the
    /// pre-seam partial Fisher–Yates, so always-on runs stay bit-identical.
    pub fn sample_cohort(&mut self, now: SimTime, pool: &[usize], want: usize) -> Vec<usize> {
        let horizon = self.sampler_horizon();
        let SimEngine { sim, sampler, rng, avail, tables, .. } = self;
        let mut ctx = SamplerCtx {
            now,
            horizon,
            rng,
            avail,
            delivered: &tables.delivered,
            churned: &tables.churned,
            scores: &mut tables.scores,
            fair_cap: sim.cfg.scheduling.fair_cap,
            fair_explore: sim.cfg.scheduling.fair_explore,
        };
        sampler.sample(&mut ctx, pool, want)
    }

    /// Same, but drawing from a CLONE of the master stream (FedBuff's
    /// historical start-cohort behaviour: the initial draw must not
    /// advance the master RNG).
    pub fn sample_cohort_detached(
        &mut self,
        now: SimTime,
        pool: &[usize],
        want: usize,
    ) -> Vec<usize> {
        let horizon = self.sampler_horizon();
        let mut rng = self.rng.clone();
        let SimEngine { sim, sampler, avail, tables, .. } = self;
        let mut ctx = SamplerCtx {
            now,
            horizon,
            rng: &mut rng,
            avail,
            delivered: &tables.delivered,
            churned: &tables.churned,
            scores: &mut tables.scores,
            fair_cap: sim.cfg.scheduling.fair_cap,
            fair_explore: sim.cfg.scheduling.fair_explore,
        };
        sampler.sample(&mut ctx, pool, want)
    }

    /// Pick one client from the non-empty `pool` through the configured
    /// sampling policy (slot refills of event-driven strategies; uniform
    /// draws exactly the historical `usize_below`).
    pub fn pick_client(&mut self, now: SimTime, pool: &[usize]) -> usize {
        debug_assert!(!pool.is_empty(), "pick_client from an empty pool");
        let horizon = self.sampler_horizon();
        let SimEngine { sim, sampler, rng, avail, tables, .. } = self;
        let mut ctx = SamplerCtx {
            now,
            horizon,
            rng,
            avail,
            delivered: &tables.delivered,
            churned: &tables.churned,
            scores: &mut tables.scores,
            fair_cap: sim.cfg.scheduling.fair_cap,
            fair_explore: sim.cfg.scheduling.fair_explore,
        };
        sampler.pick_one(&mut ctx, pool)
    }

    /// True unit times for `client` under `cond` at simulated time `now`,
    /// with the availability model's degrade-before-drop coupling applied:
    /// the correlated process scales effective throughput down as the
    /// client's region approaches an outage (upload time divides by the
    /// factor). Every other process reports a factor of exactly 1.0, so
    /// the division is bit-exact and uncoupled runs are unchanged.
    pub fn truth_at(&mut self, client: usize, cond: &RoundConditions, now: SimTime) -> TimeTruth {
        let sim = self.sim;
        let t = local_time::truth(&sim.fleet.devices[client], cond, sim.cfg.sim_model_bytes);
        let factor = self.avail.bandwidth_factor(client, now);
        TimeTruth {
            t_cmp: t.t_cmp,
            t_com: t.t_com / factor,
        }
    }

    /// The shared per-client link-quality signal
    /// ([`crate::availability::BandwidthSignal`]) at `now` — the same
    /// factor `truth_at` already folds into upload times, exposed for the
    /// bandwidth-aware rebalancing seam (TimelyFL's Alg. 3 against the
    /// *effective* timeline). Reading it never consumes engine RNG draws:
    /// availability timelines are deterministic caches on their own salted
    /// streams.
    pub fn bandwidth_factor(&mut self, client: usize, now: SimTime) -> f64 {
        BandwidthSignal::bandwidth_factor(&mut self.avail, client, now)
    }

    /// Price one dispatch's downlink leg (server → client transfer of the
    /// global model) from the client's *effective* unit upload time, and
    /// accrue it on the round's downlink-wait counter. Exactly 0.0 — with
    /// zero bookkeeping — under the default `network = free`, which is what
    /// keeps free runs bit-identical. Accrues for every dispatch, including
    /// ones later cancelled by churn or dropped at the deadline: the model
    /// bytes crossed the wire either way.
    pub fn price_downlink(&mut self, effective_upload_secs: f64) -> f64 {
        let down = self.net.downlink_secs(effective_upload_secs);
        if down > 0.0 {
            self.downlink_wait_pending += down;
        }
        down
    }

    /// Note one client's dispatched workload (Alg. 3's E_c / alpha_c as
    /// realized) for the next `round-complete` record. Only bookkept when a
    /// sink is attached — the telemetry must cost nothing on sink-less runs.
    fn note_workload(&mut self, client: usize, epochs: usize, alpha: f64) {
        if self.sink.is_some() {
            self.workloads_pending.push(ClientWorkload {
                client,
                epochs,
                alpha,
                stay_prob: self.tables.scores[client],
            });
        }
    }

    /// Score a batch of delivered updates through the configured weigher,
    /// REPLACING each contribution's weight, immediately before the
    /// strategy hands them to aggregation. This is the single seam all
    /// four strategies call: the weigher reads only settled state (version
    /// lag + drop-ledger counters), so it can never perturb the schedule —
    /// `weigher = uniform` writes the literal 1.0 every strategy
    /// historically hardcoded, and non-uniform weighers move only the
    /// learning curve. Assigned weights are drained onto the next
    /// `round-complete` record (sink-gated, like workload telemetry).
    pub fn weigh(&mut self, contributions: &mut [Contribution]) {
        let telemetry = self.sink.is_some();
        for c in contributions.iter_mut() {
            c.weight = self.weigher.weight(
                c.staleness,
                self.tables.delivered[c.client_id],
                self.tables.churned[c.client_id],
            );
            if telemetry {
                self.agg_weights_pending.push(AggWeight {
                    client: c.client_id,
                    weight: c.weight,
                });
            }
        }
    }

    /// Aggregate one batch of contributions through the hierarchy — the
    /// single seam all four strategies call at their aggregation sites.
    ///
    /// Under the default `hier_clock = shared` (no edge state) this is
    /// exactly the historical synchronous call: one
    /// [`HierarchyConfig::aggregate_jobs`] pass, always `Some`. Under
    /// `hier_clock = region` the contributions are split into per-region
    /// partials absorbed by each region's [`RegionClock`]; nothing reaches
    /// the root until a region's flush deadline passes *and* its priced
    /// edge→root transfer elapses on the shared sim clock. The return is
    /// then `Some(update)` only when ripe partials arrived by `now` —
    /// `None` means "hold the global model this boundary".
    ///
    /// `now` is the aggregation boundary's clock (round strategies pass
    /// the post-advance boundary time, event strategies the flush event
    /// time). Ripe regions always flush at their *deadline* — not at
    /// `now` — so a late boundary poll prices and times the uplink
    /// identically to an exact `EdgeFlush` alarm.
    pub fn hier_aggregate(
        &mut self,
        hierarchy: &HierarchyConfig,
        template: &ParamVec,
        contributions: &[Contribution],
        discount_staleness: bool,
        now: SimTime,
    ) -> Option<Update> {
        if self.edge.is_none() {
            return Some(hierarchy.aggregate_jobs(
                template,
                contributions,
                discount_staleness,
                self.sim.cfg.agg_jobs,
            ));
        }
        // 1. Flush every region whose deadline already passed (round
        //    strategies have no alarms; event strategies can reach a
        //    boundary between an elapsed deadline and its alarm — the
        //    alarm then no-ops via the generation guard).
        self.edge_advance(now);
        // 2. Absorb this boundary's contributions into their regions,
        //    arming flush deadlines for newly-opened windows.
        let event_driven = self.event_driven;
        {
            let edge = self.edge.as_mut().expect("checked above");
            for c in contributions {
                let region = c.client_id % edge.hierarchy.regions;
                let cell = &mut edge.window_tcom[region];
                cell.0 += edge.last_tcom[c.client_id];
                cell.1 += 1;
            }
            let flush_secs = edge.hierarchy.flush_secs;
            let flush_auto = edge.hierarchy.flush_auto;
            let partials = edge
                .hierarchy
                .region_partials(template, contributions, discount_staleness);
            for (region, partial) in partials {
                if let Some(deadline) =
                    edge.clocks[region].absorb(partial, now, flush_secs, flush_auto)
                {
                    if event_driven {
                        let gen = edge.clocks[region].gen();
                        self.events
                            .schedule_at(deadline, EngineEvent::EdgeFlush { region, gen });
                    }
                }
            }
        }
        // 3. A zero-length window (uncalibrated `auto` with a 0 fallback)
        //    ripens at its own boundary — flush it now rather than one
        //    boundary late.
        self.edge_advance(now);
        // 4. Drain in-transit partials that arrived by `now` (insertion
        //    order) into one root merge.
        let edge = self.edge.as_mut().expect("checked above");
        let mut ready = Vec::new();
        let mut still = Vec::new();
        for (arrival, partial) in edge.in_transit.drain(..) {
            if arrival <= now {
                ready.push(partial);
            } else {
                still.push((arrival, partial));
            }
        }
        edge.in_transit = still;
        if ready.is_empty() {
            None
        } else {
            self.edge_root_merges_pending += 1;
            Some(root_merge(template, ready))
        }
    }

    /// Flush every region whose deadline is at or before `now`, clocked at
    /// its deadline (see [`Self::hier_aggregate`] for why).
    fn edge_advance(&mut self, now: SimTime) {
        let Some(edge) = self.edge.as_mut() else {
            return;
        };
        for region in 0..edge.clocks.len() {
            if edge.clocks[region].ripe(now) {
                let deadline = edge.clocks[region]
                    .deadline()
                    .expect("ripe region has an armed deadline");
                if let Some(up) = edge.flush_region(region, deadline) {
                    self.edge_flushes_pending += 1;
                    if up > 0.0 {
                        self.edge_uplink_wait_pending += up;
                    }
                }
            }
        }
    }

    /// Handle an `EdgeFlush { region, gen }` alarm (event-driven strategies
    /// only). Stale alarms — the window already flushed at a boundary poll,
    /// bumping the generation — no-op via `RegionClock::alarm_matches`.
    fn on_edge_flush(&mut self, region: usize, gen: u64, now: SimTime) {
        let Some(edge) = self.edge.as_mut() else {
            return;
        };
        if region >= edge.clocks.len() || !edge.clocks[region].alarm_matches(gen) {
            return;
        }
        // The alarm fires exactly at the armed deadline, so `now` IS the
        // deadline clock.
        if let Some(up) = edge.flush_region(region, now) {
            self.edge_flushes_pending += 1;
            if up > 0.0 {
                self.edge_uplink_wait_pending += up;
            }
        }
    }

    /// Record `client`'s effective upload seconds for edge uplink pricing
    /// (`hier_uplink = priced`). A no-op — zero bookkeeping — outside
    /// `hier_clock = region`. Called wherever dispatch timing truth is
    /// computed, so the pricing base is deterministic and costs no RNG.
    pub fn note_upload_secs(&mut self, client: usize, effective_upload_secs: f64) {
        if let Some(edge) = self.edge.as_mut() {
            edge.last_tcom[client] = effective_upload_secs;
        }
    }

    /// Seed this run's drop ledger from a previous run's harvest
    /// (`--warm-ledger`). Call before the strategy starts; a fresh ledger
    /// is a no-op.
    pub fn seed_ledger(&mut self, ledger: &WarmLedger) {
        ledger.seed_into(&mut self.tables.delivered, &mut self.tables.churned);
    }

    /// Harvest this run's drop ledger for the next run in a warm sweep.
    pub fn harvest_ledger(&self, ledger: &mut WarmLedger) {
        ledger.harvest(&self.tables.delivered, &self.tables.churned);
    }

    /// Attribute one lost client update and emit its `client-dropped`
    /// record. Folded into the NEXT completed round's attribution (for
    /// round-stepped strategies that is the current round).
    pub fn drop_client(&mut self, client: usize, cause: DropCause) {
        self.drop_client_inner(client, cause, false);
    }

    fn drop_client_inner(&mut self, client: usize, cause: DropCause, execution_avoided: bool) {
        match cause {
            DropCause::Availability => {
                self.avail_dropped_pending += 1;
                self.tables.churned[client] += 1;
            }
            DropCause::Deadline => self.dropped_pending += 1,
        }
        let ev = RunEvent::ClientDropped {
            client,
            sim_secs: self.events.now(),
            cause,
            execution_avoided,
        };
        self.emit(ev);
    }

    /// When the whole population is momentarily offline, advance the clock
    /// (as an event) to the next availability transition. `false` = no
    /// transition will ever come — permanently offline, end gracefully.
    /// The lazy core peeks its agenda (O(1)) where the eager core scans
    /// every client; both see the same earliest timestamp, and the wait is
    /// a popped Tick either way, so `events_processed` agrees.
    fn idle_until_transition(&mut self) -> bool {
        let now = self.events.now();
        let next = match self.lazy.as_mut() {
            Some(lazy) => {
                lazy.advance_to(&mut self.avail, now);
                lazy.earliest_transition()
            }
            None => self.avail.earliest_transition(now),
        };
        let Some(t) = next else {
            return false;
        };
        self.events.schedule_at(t, EngineEvent::Tick);
        self.events.pop();
        true
    }

    /// Record one completed aggregation round at `clock`: consumes the
    /// pending drop attribution, emits `round-complete` (and `eval-point`
    /// when the cadence fires), evaluates `global`, and arms the stop flag
    /// once the target metric or sim-time budget is hit.
    pub fn complete_round(
        &mut self,
        clock: SimTime,
        participant_ids: &[usize],
        mean_train_loss: Option<f64>,
        global: &ParamVec,
    ) -> Result<()> {
        let sim = self.sim;
        let round = self.completed_rounds;
        // Placeholder-loss hygiene: a `batch_exec` placeholder finish
        // carries `mean_loss = NaN` until its ticket is patched at the
        // flush; if an unpatched one ever leaks into a strategy's round
        // mean, drop the loss (report `null`) rather than poisoning the
        // report and every golden fingerprint downstream. Finite losses
        // pass through bit-identically.
        let mean_train_loss = mean_train_loss.filter(|l| l.is_finite());
        let dropped = std::mem::take(&mut self.dropped_pending);
        let avail_dropped = std::mem::take(&mut self.avail_dropped_pending);
        let workloads = std::mem::take(&mut self.workloads_pending);
        let agg_weights = std::mem::take(&mut self.agg_weights_pending);
        let downlink_wait_secs = std::mem::take(&mut self.downlink_wait_pending);
        let stale_starts = std::mem::take(&mut self.stale_starts_pending);
        let edge_flushes = std::mem::take(&mut self.edge_flushes_pending);
        let edge_uplink_wait_secs = std::mem::take(&mut self.edge_uplink_wait_pending);
        let edge_root_merges = std::mem::take(&mut self.edge_root_merges_pending);
        // Pure bookkeeping: observed whether or not `sampler_horizon = auto`
        // ever reads it, so calibration-off runs stay byte-identical.
        self.horizon_est.observe(clock);
        self.recorder.note_network(downlink_wait_secs, stale_starts);
        self.recorder
            .note_edge(edge_flushes, edge_uplink_wait_secs, edge_root_merges);
        self.recorder.record_round(
            round,
            clock,
            participant_ids,
            dropped,
            avail_dropped,
            mean_train_loss,
        );
        self.emit(RunEvent::RoundComplete {
            round,
            sim_secs: clock,
            participants: participant_ids.len(),
            dropped,
            avail_dropped,
            downlink_wait_secs,
            stale_starts,
            edge_flushes,
            edge_uplink_wait_secs,
            mean_train_loss,
            workloads,
            agg_weights,
        });
        if let Some(p) = self.recorder.maybe_eval(sim, round, clock, global)? {
            self.emit(RunEvent::EvalPoint {
                round: p.round,
                sim_secs: p.sim_secs,
                mean_loss: p.mean_loss,
                metric: p.metric,
            });
        }
        self.completed_rounds += 1;
        if self.recorder.should_stop(sim, clock) {
            self.stop = true;
        }
        Ok(())
    }

    /// The shared round-stepped loop: sample an online cohort, run the
    /// strategy's round, advance the clock by the round's span, record /
    /// eval / stop. Idles (as events) across whole-population offline gaps.
    pub fn drive_rounds(&mut self, strat: &mut dyn RoundStrategy) -> Result<()> {
        let sim = self.sim;
        let cfg = &sim.cfg;
        while self.completed_rounds < cfg.rounds {
            let now = self.events.now();
            let Some(sampled) = self.sample_round_cohort(now) else {
                // Whole population offline right now.
                if !self.idle_until_transition()
                    || self.recorder.should_stop(sim, self.events.now())
                {
                    break;
                }
                continue;
            };

            let round = self.completed_rounds;
            let outcome = strat.run_round(&mut RoundCtx {
                round,
                now,
                sampled: &sampled,
                eng: &mut *self,
            })?;

            // The round boundary is an event popped off the queue, so all
            // strategies share one clock discipline.
            self.events.schedule_in(outcome.advance_secs, EngineEvent::Tick);
            let (clock, _) = self.events.pop().expect("round boundary was scheduled");
            self.complete_round(
                clock,
                &outcome.participants,
                outcome.mean_train_loss,
                strat.global_params(),
            )?;
            if self.stop {
                break;
            }
        }
        Ok(())
    }

    /// Draw one round's cohort from the currently-online population, or
    /// `None` when nobody is online. This is the round drivers' only
    /// cohort source, and where the two sim cores fork:
    ///
    /// - **eager** scans all N clients (`online_clients`) and samples from
    ///   the materialised ascending pool — when everyone is online that
    ///   pool is exactly `0..population`, the always-on compatibility path;
    /// - **lazy** sweeps elapsed transitions off its agenda and, for a
    ///   uniform-equivalent sampler, draws straight from the online-set
    ///   index with the **same RNG stream** (`OnlineSetIndex::sample_distinct`
    ///   replays `sample_without_replacement`'s draws), never touching all
    ///   N. Weighted samplers score every candidate, so they still get the
    ///   materialised (ascending, therefore identical) pool.
    fn sample_round_cohort(&mut self, now: SimTime) -> Option<Vec<usize>> {
        let cap = self.sim.cfg.concurrency;
        match self.lazy.as_mut() {
            Some(lazy) => {
                lazy.advance_to(&mut self.avail, now);
                if lazy.online().is_empty() {
                    return None;
                }
                let want = cap.min(lazy.online().len());
                if self.sampler.uniform_equivalent() {
                    Some(lazy.online().sample_distinct(&mut self.rng, want))
                } else {
                    let pool = lazy.online().to_vec();
                    Some(self.sample_cohort(now, &pool, want))
                }
            }
            None => {
                let online = self.avail.online_clients(now);
                if online.is_empty() {
                    return None;
                }
                let want = cap.min(online.len());
                Some(self.sample_cohort(now, &online, want))
            }
        }
    }

    /// Pick one idle-online client for an event-driven slot refill, or
    /// `None` when nobody is eligible. Lazy core + uniform-equivalent
    /// sampler: one O(log n) indexed draw consuming the exact
    /// `usize_below(pool.len())` the eager path spends on
    /// `pool[rng.usize_below(..)]`. Everything else materialises the
    /// idle-online pool and routes through the policy.
    pub fn refill_pick(&mut self, now: SimTime) -> Option<usize> {
        if self.sampler.uniform_equivalent() {
            if let Some(lazy) = self.lazy.as_ref() {
                if lazy.online().is_empty() {
                    return None;
                }
                return Some(lazy.online().sample_one(&mut self.rng));
            }
        }
        let idle = self.idle_online_clients(now);
        if idle.is_empty() {
            None
        } else {
            Some(self.pick_client(now, &idle))
        }
    }

    /// The shared event-driven loop: seeds + chains availability
    /// transitions, cancels in-flight updates on churn, validates finish
    /// generations (executing deferred plans for the valid ones), and
    /// routes everything else to the strategy's hooks.
    pub fn drive_events(&mut self, strat: &mut dyn EventStrategy) -> Result<()> {
        let sim = self.sim;
        let cfg = &sim.cfg;
        // Event strategies get exact-time edge flushes via EdgeFlush
        // alarms; round drivers never set this, so their Tick-only queue
        // discipline is preserved.
        self.event_driven = true;
        // Seed the queue with each client's first availability transition
        // (the chain re-schedules itself as transitions are processed).
        // Always-on schedules nothing.
        for c in 0..cfg.population {
            if let Some(t) = self.avail.next_transition(c, 0.0) {
                self.events.schedule_at(t, EngineEvent::Transition { client: c });
            }
        }
        strat.on_start(self)?;

        while self.completed_rounds < cfg.rounds {
            let Some((now, ev)) = self.events.pop() else {
                // A drained queue under always-on means the dispatch
                // invariant broke — that is a bug. Under churn it is a
                // legitimate end state (population permanently offline).
                if self.avail.is_always_on() {
                    anyhow::bail!(
                        "event queue drained with {} rounds done",
                        self.completed_rounds
                    );
                }
                break;
            };
            // Budget guard at the loop top, not only at round completion: a
            // heavily-churned population can keep transitions (and real
            // training dispatches) flowing forever without ever filling a
            // buffer. No-op under the default infinite budget.
            if self.recorder.should_stop(sim, now) {
                break;
            }
            match ev {
                // Only the round-stepped loop schedules Ticks; tolerate a
                // stray one (it already advanced the clock) rather than
                // aborting a run.
                EngineEvent::Tick => {}
                EngineEvent::Transition { client } => {
                    let next = self.avail.next_transition(client, now);
                    if let Some(t) = next {
                        self.events.schedule_at(t, EngineEvent::Transition { client });
                    }
                    // Read the post-transition state at the segment
                    // midpoint: the state is constant until the next
                    // transition, and the midpoint dodges ulp-level
                    // ambiguity of evaluating the diurnal gate exactly at a
                    // boundary instant.
                    let online_now = match next {
                        Some(t) => self.avail.is_available(client, (now + t) / 2.0),
                        None => self.avail.is_available(client, now),
                    };
                    self.emit(RunEvent::AvailabilityTransition {
                        client,
                        sim_secs: now,
                        online: online_now,
                    });
                    // Event mode keeps every transition on the main queue
                    // (`events_processed` is part of the report); the lazy
                    // core's index rides along as the idle-online refill
                    // pool, maintained right here.
                    if let Some(lazy) = self.lazy.as_mut() {
                        lazy.note_event_transition(client, online_now, self.tables.is_busy(client));
                    }
                    if online_now {
                        strat.on_client_online(self, client)?;
                    } else if self.tables.is_busy(client) {
                        // Went offline mid-training: the in-flight update is
                        // lost with it (and its deferred execution skipped).
                        self.cancel_in_flight(client);
                        strat.on_slot_freed(self, now)?;
                    }
                }
                EngineEvent::Finish { client, gen } => {
                    if gen != self.tables.gen(client) {
                        continue; // cancelled by an offline transition
                    }
                    let fin = self.resolve_finish(client, gen)?;
                    self.tables.set_busy(client, false);
                    self.in_flight -= 1;
                    if let Some(lazy) = self.lazy.as_mut() {
                        // A gen-valid finish means the client stayed online
                        // throughout — it rejoins the idle-online pool.
                        lazy.note_idle(client);
                    }
                    strat.on_finish(self, now, fin)?;
                    if self.stop {
                        break;
                    }
                }
                EngineEvent::Alarm => {
                    strat.on_alarm(self, now)?;
                    if self.stop {
                        break;
                    }
                }
                // Engine-internal: flush the region at its deadline (the
                // partial then rides the priced uplink; the next
                // aggregation boundary drains arrivals). No strategy hook
                // — strategies observe region clocks only through
                // `hier_aggregate`'s return.
                EngineEvent::EdgeFlush { region, gen } => {
                    self.on_edge_flush(region, gen, now);
                }
            }
        }
        Ok(())
    }

    /// Turn a generation-valid finish marker into the hook payload: unstash
    /// an eager outcome, or run the deferred plan's PJRT executions now —
    /// the only point where the deferred path touches the accelerator.
    fn resolve_finish(&mut self, client: usize, gen: u32) -> Result<ClientFinish> {
        let pd = self
            .pending
            .remove(&client)
            .expect("generation-valid finish without stashed work");
        self.tables.delivered[client] += 1;
        // Stale-start detection: did a newer global version land while this
        // dispatch's downlink was still in the air? Under `network = free`
        // `down_secs` is 0.0 and this is a guaranteed None. With
        // delta-replay correction the delivered update is *accounted* as if
        // rebased onto the version at arrival (the Jia et al. update-replay
        // approximation) — the executed plan still ran against the ORIGINAL
        // snapshot, which is also what the snapshot store must release.
        let snapshot_version = pd.base_version;
        let mut base_version = pd.base_version;
        if let Some(newer) =
            network::overtaken_by(pd.down_secs, pd.base_version, pd.arrival, &self.version_born)
        {
            self.stale_starts_pending += 1;
            if self.sim.cfg.network.stale_correction == StaleCorrection::DeltaReplay {
                base_version = newer;
            }
        }
        let (update, mean_loss, ticket) = match pd.work {
            PendingWork::Trained { update, mean_loss } => (update, mean_loss, None),
            PendingWork::Planned { plan, base } if self.sim.cfg.batch_exec => {
                // Batched execution: park the plan on the queue (snapshot
                // stays retained, execution ledger untouched until the
                // drain) and hand the hook a ticketed placeholder.
                let ticket = self.batch.push(client, plan, Some((base, snapshot_version)));
                (
                    Update {
                        boundary: 0,
                        tensors: Vec::new(),
                    },
                    f64::NAN,
                    Some(ticket),
                )
            }
            PendingWork::Planned { plan, base } => {
                let outcome =
                    execute_plan(&self.sim.runtime, &plan, &base, self.sim.cfg.client_lr)?;
                self.snapshots.release(snapshot_version);
                self.recorder.wasted.on_execute();
                (outcome.update, outcome.mean_loss, None)
            }
        };
        Ok(ClientFinish {
            client,
            gen,
            base_version,
            update,
            mean_loss,
            ticket,
        })
    }

    /// Invalidate `client`'s pending finish (generation bump), discard its
    /// stashed work — a deferred plan dies here WITHOUT ever executing on
    /// the accelerator — return its concurrency slot, and attribute the
    /// loss to availability churn.
    fn cancel_in_flight(&mut self, client: usize) {
        self.tables.bump_gen(client);
        self.tables.set_busy(client, false);
        self.in_flight -= 1;
        let execution_avoided = match self.pending.remove(&client) {
            Some(PendingDispatch {
                base_version,
                work: PendingWork::Planned { .. },
                ..
            }) => {
                self.snapshots.release(base_version);
                self.recorder.wasted.on_avoid();
                true
            }
            // Eager dispatch: the PJRT work already burned at dispatch time.
            _ => false,
        };
        self.drop_client_inner(client, DropCause::Availability, execution_avoided);
    }

    /// Dispatch one client for event-driven protocols: draw the full data
    /// plan from the client's RNG stream now (pinning golden bit-identity),
    /// stash the work in the pending table, and schedule the finish marker
    /// at the simulated completion time. The PJRT executions run only when
    /// the finish validates (unless `cfg.eager_train`). Callers pick only
    /// currently-online, non-busy clients.
    pub fn dispatch(
        &mut self,
        client: usize,
        epochs: usize,
        ratio: &RatioMeta,
        base: &ParamVec,
        base_version: u64,
    ) -> Result<()> {
        let sim = self.sim;
        let cfg = &sim.cfg;
        debug_assert!(!self.tables.is_busy(client), "client {client} dispatched twice");
        self.tables.set_busy(client, true);
        if let Some(lazy) = self.lazy.as_mut() {
            lazy.note_busy(client);
        }
        self.in_flight += 1;
        let now = self.events.now();
        let cond = sim.fleet.round_conditions(&mut self.rng);
        let t = self.truth_at(client, &cond, now);
        self.note_upload_secs(client, t.t_com);
        // Model dissemination first: the global version rides the downlink
        // before any training starts. 0.0 under `network = free`, so the
        // scheduled finish time is unchanged there bit-for-bit.
        let down = self.price_downlink(t.t_com);
        if down > 0.0 {
            // Note the version's birth (first time it is seen leaving the
            // server) so later-arriving transfers can detect being
            // overtaken. Gated on a real transfer: free dissemination can
            // never be overtaken, so it never pays for the map.
            self.version_born.entry(base_version).or_insert(now);
        }
        // Compute scales with the nominal compiled ratio, upload with the
        // realized trainable fraction; both are exactly 1.0 for full-model
        // dispatches.
        let duration = down + t.round_secs(epochs as f64, ratio.ratio, ratio.trainable_fraction);
        let plan = plan_client(
            &sim.dataset,
            client,
            ratio,
            epochs,
            cfg.steps_per_epoch,
            &mut self.client_rngs[client],
        );
        self.recorder.wasted.on_dispatch();
        self.note_workload(client, epochs, ratio.ratio);
        let work = if cfg.eager_train {
            let outcome = execute_plan(&sim.runtime, &plan, base, cfg.client_lr)?;
            self.recorder.wasted.on_execute();
            PendingWork::Trained {
                update: outcome.update,
                mean_loss: outcome.mean_loss,
            }
        } else {
            let base = self.snapshots.retain(base_version, base);
            PendingWork::Planned { plan, base }
        };
        self.pending.insert(
            client,
            PendingDispatch {
                base_version,
                arrival: now + down,
                down_secs: down,
                work,
            },
        );
        self.events.schedule_in(
            duration,
            EngineEvent::Finish {
                client,
                gen: self.tables.gen(client),
            },
        );
        Ok(())
    }

    /// Full-model [`SimEngine::dispatch`] with the shared
    /// `fedbuff_local_epochs` setting — the common case for buffered
    /// asynchronous protocols.
    pub fn dispatch_full(
        &mut self,
        client: usize,
        base: &ParamVec,
        base_version: u64,
    ) -> Result<()> {
        let sim = self.sim;
        let full = sim
            .runtime
            .meta
            .ratio_exact(1.0)
            .expect("full ratio always compiled");
        self.dispatch(client, sim.cfg.fedbuff_local_epochs, full, base, base_version)
    }

    /// Synchronous training for round-stepped strategies: plan + execute in
    /// one call (round protocols decide eligibility BEFORE training, so
    /// there is never a speculative execution to defer), counted as one
    /// executed dispatch in the wasted-work ledger.
    pub fn train_now(
        &mut self,
        client: usize,
        base: &ParamVec,
        ratio: &RatioMeta,
        epochs: usize,
    ) -> Result<LocalOutcome> {
        let sim = self.sim;
        self.recorder.wasted.on_dispatch();
        self.note_workload(client, epochs, ratio.ratio);
        // Round protocols settle eligibility (incl. availability survival)
        // before training, so reaching here means the dispatch completed.
        self.tables.delivered[client] += 1;
        let outcome = train_client(
            &sim.runtime,
            &sim.dataset,
            client,
            base,
            ratio,
            epochs,
            sim.cfg.steps_per_epoch,
            sim.cfg.client_lr,
            &mut self.client_rngs[client],
        )?;
        self.recorder.wasted.on_execute();
        Ok(outcome)
    }

    /// Round-strategy training entry point with batching: execute
    /// immediately through [`SimEngine::train_now`] (returning `Some`), or,
    /// under `cfg.batch_exec`, queue the plan for the next
    /// [`SimEngine::drain_batch`] and return `None`. The
    /// dispatch-side bookkeeping (wasted-work ledger, workload telemetry,
    /// delivery count, the client-RNG plan draws) happens HERE either way,
    /// in the exact order `train_now` performs it — only the PJRT execution
    /// moves to the drain, which is why the two modes stay bit-identical.
    pub fn train_now_or_queue(
        &mut self,
        client: usize,
        base: &ParamVec,
        ratio: &RatioMeta,
        epochs: usize,
    ) -> Result<Option<LocalOutcome>> {
        if !self.sim.cfg.batch_exec {
            return Ok(Some(self.train_now(client, base, ratio, epochs)?));
        }
        let sim = self.sim;
        self.recorder.wasted.on_dispatch();
        self.note_workload(client, epochs, ratio.ratio);
        self.tables.delivered[client] += 1;
        let plan = plan_client(
            &sim.dataset,
            client,
            ratio,
            epochs,
            sim.cfg.steps_per_epoch,
            &mut self.client_rngs[client],
        );
        self.batch.push(client, plan, None);
        Ok(None)
    }

    /// Drain the batch queue: execute every parked plan through the stacked
    /// PJRT path (`trainer::execute_plans_batched`) and return the outcomes
    /// in enqueue (ticket) order. `shared_base` supplies the base model for
    /// plans queued without their own snapshot (round-stepped strategies
    /// pass the round's global); event-queued plans use their retained
    /// snapshots, released here once executed. A no-op returning an empty
    /// vec when nothing is queued — serial runs call through harmlessly.
    pub fn drain_batch(&mut self, shared_base: Option<&ParamVec>) -> Result<Vec<BatchedOutcome>> {
        let queued = self.batch.take();
        if queued.is_empty() {
            return Ok(Vec::new());
        }
        let items: Vec<(&TrainPlan, &ParamVec)> = queued
            .iter()
            .map(|q| {
                let base = match &q.base {
                    Some((snap, _)) => snap.as_ref(),
                    None => shared_base.expect("round-queued plan drained without a shared base"),
                };
                (&q.plan, base)
            })
            .collect();
        let outcomes = execute_plans_batched(&self.sim.runtime, &items, self.sim.cfg.client_lr)?;
        drop(items);
        let mut out = Vec::with_capacity(queued.len());
        for (q, o) in queued.into_iter().zip(outcomes) {
            if let Some((_, version)) = q.base {
                self.snapshots.release(version);
            }
            self.recorder.wasted.on_execute();
            out.push(BatchedOutcome {
                ticket: q.ticket,
                client: q.client,
                update: o.update,
                mean_loss: o.mean_loss,
            });
        }
        Ok(out)
    }

    /// Currently-idle, currently-online clients — the slot-refill pool for
    /// event-driven dispatch policies. Under the lazy core this is the
    /// incrementally-maintained index materialised (same ascending order);
    /// the eager core scans all N.
    pub fn idle_online_clients(&mut self, now: SimTime) -> Vec<usize> {
        if let Some(lazy) = self.lazy.as_ref() {
            return lazy.online().to_vec();
        }
        (0..self.sim.cfg.population)
            .filter(|&i| !self.tables.is_busy(i) && self.avail.is_available(i, now))
            .collect()
    }

    /// Close out the run: absorb any post-round drop tail, settle the
    /// wasted-work ledger (plans still pending when the run ends were never
    /// executed — deferred wins the eager path pays for), and build the
    /// final report.
    pub fn finish(self, strategy_name: &str) -> RunReport {
        let SimEngine {
            sim,
            mut recorder,
            mut avail,
            events,
            pending,
            completed_rounds,
            dropped_pending,
            avail_dropped_pending,
            downlink_wait_pending,
            stale_starts_pending,
            edge_flushes_pending,
            edge_uplink_wait_pending,
            edge_root_merges_pending,
            ..
        } = self;
        for pd in pending.into_values() {
            if matches!(pd.work, PendingWork::Planned { .. }) {
                recorder.wasted.on_avoid();
            }
        }
        recorder.absorb_tail_drops(dropped_pending, avail_dropped_pending);
        // Downlink waits / stale starts / edge flushes accrued after the
        // last completed round fold into the run totals (no round record to
        // carry them). Partials still held or in transit when the run ends
        // simply never arrive — like an in-flight client at the deadline.
        recorder.note_network(downlink_wait_pending, stale_starts_pending);
        recorder.note_edge(
            edge_flushes_pending,
            edge_uplink_wait_pending,
            edge_root_merges_pending,
        );
        recorder.finish(
            strategy_name,
            sim,
            events.now(),
            completed_rounds,
            events.events_processed(),
            &mut avail,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(vals: &[f32]) -> ParamVec {
        ParamVec {
            tensors: vec![vals.to_vec()],
        }
    }

    #[test]
    fn snapshot_store_shares_one_arc_per_version() {
        let mut store = SnapshotStore::default();
        let a = store.retain(3, &pv(&[1.0, 2.0]));
        let b = store.retain(3, &pv(&[9.0, 9.0])); // params ignored: version cached
        assert!(Arc::ptr_eq(&a, &b), "same version must share one snapshot");
        assert_eq!(a.tensors[0], vec![1.0, 2.0]);
        assert_eq!(store.versions_held(), 1);
        let c = store.retain(4, &pv(&[5.0]));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(store.versions_held(), 2);
    }

    #[test]
    fn snapshot_store_evicts_on_last_release() {
        let mut store = SnapshotStore::default();
        let snap = store.retain(0, &pv(&[1.0]));
        let _again = store.retain(0, &pv(&[1.0]));
        store.release(0);
        assert_eq!(store.versions_held(), 1, "one pending plan still holds version 0");
        store.release(0);
        assert_eq!(store.versions_held(), 0, "last release evicts the version");
        // Plans that grabbed the Arc keep their data past eviction.
        assert_eq!(snap.tensors[0], vec![1.0]);
        // Re-retaining after eviction re-clones fresh parameters.
        let fresh = store.retain(0, &pv(&[7.0]));
        assert_eq!(fresh.tensors[0], vec![7.0]);
        assert!(!Arc::ptr_eq(&snap, &fresh));
    }

    #[test]
    fn snapshot_store_interleaved_versions() {
        // Async reality: a slow client's old-version plan outlives several
        // newer versions' retain/release cycles.
        let mut store = SnapshotStore::default();
        let _old = store.retain(1, &pv(&[1.0]));
        for v in 2..6 {
            let _s = store.retain(v, &pv(&[v as f32]));
            store.release(v);
        }
        assert_eq!(store.versions_held(), 1, "only the old in-flight version survives");
        store.release(1);
        assert_eq!(store.versions_held(), 0);
    }
}
