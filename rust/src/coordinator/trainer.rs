//! Client-side local training executor: runs E local epochs of real PJRT
//! train-steps against a base model snapshot and returns the suffix delta.
//!
//! Training is split into two phases so the engine can *defer* the
//! accelerator work of an asynchronous dispatch until its Finish event
//! proves the work is still wanted (a churn-cancelled dispatch then never
//! touches PJRT — see `SimEngine::dispatch`):
//!
//! - [`plan_client`] draws everything stochastic — the full minibatch
//!   sequence — from the per-client RNG at dispatch time. Drawing eagerly
//!   pins the RNG stream position, so a deferred (or discarded) execution
//!   leaves every subsequent draw bit-identical to the eager path.
//! - [`execute_plan`] replays the planned batches through the chunked PJRT
//!   executions. It consumes no RNG and depends only on the plan and the
//!   base snapshot, so it can run at the Finish event (or never).
//!
//! [`train_client`] is the fused plan-then-execute convenience used by the
//! synchronous round-stepped strategies, byte-identical to the historical
//! single-phase implementation: batch i was always drawn before batch i+1,
//! and PJRT executions never touch the client RNG, so hoisting all draws
//! ahead of the first execution does not move any stream position.

use anyhow::Result;

use crate::data::FederatedDataset;
use crate::model::{ParamVec, Update};
use crate::runtime::engine::Batch;
use crate::runtime::manifest::RatioMeta;
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

/// Result of one client's local training for one round.
#[derive(Clone, Debug)]
pub struct LocalOutcome {
    pub client_id: usize,
    /// Suffix delta vs the base model (boundary = ratio's boundary).
    pub update: Update,
    /// Mean minibatch loss over all local steps (client-reported).
    pub mean_loss: f64,
    pub steps: u64,
}

/// The eagerly-drawn half of a client dispatch: everything local training
/// needs except the base model. A plan is cheap to discard — dropping it
/// costs nothing on the accelerator.
#[derive(Clone, Debug)]
pub struct TrainPlan {
    pub client_id: usize,
    /// Nominal compiled ratio; resolved back to [`RatioMeta`] at execute
    /// time (the plan must not borrow the runtime).
    pub ratio: f64,
    /// All `epochs * steps_per_epoch` minibatches, in draw order.
    pub batches: Vec<Batch>,
}

impl TrainPlan {
    /// Logical SGD steps this plan schedules.
    pub fn total_steps(&self) -> usize {
        self.batches.len()
    }
}

/// Sizes of the fused PJRT executions covering `total` steps at chunk
/// capacity `chunk`: full chunks followed by the remainder tail. Matches
/// the historical `remaining.min(chunk)` loop exactly.
pub fn chunk_sizes(total: usize, chunk: usize) -> Vec<usize> {
    debug_assert!(chunk >= 1);
    let mut sizes = Vec::with_capacity(total.div_ceil(chunk.max(1)));
    let mut remaining = total;
    while remaining > 0 {
        let take = remaining.min(chunk);
        sizes.push(take);
        remaining -= take;
    }
    sizes
}

/// The divergence guard on every chunk's reported loss. Extracted so the
/// error path is unit-testable without a PJRT runtime.
fn check_loss_finite(client: usize, mean_loss: f32, steps: u64) -> Result<()> {
    anyhow::ensure!(
        mean_loss.is_finite(),
        "client {client} diverged (loss {mean_loss}) after step {steps}"
    );
    Ok(())
}

/// Phase 1: draw the full data-batch plan for `client` from its RNG stream.
/// This is the ONLY stochastic part of local training; after it returns the
/// client's stream position is exactly where eager training would have left
/// it.
pub fn plan_client(
    ds: &FederatedDataset,
    client: usize,
    ratio: &RatioMeta,
    epochs: usize,
    steps_per_epoch: usize,
    rng: &mut Rng,
) -> TrainPlan {
    debug_assert!(epochs >= 1 && steps_per_epoch >= 1);
    let total_steps = epochs * steps_per_epoch;
    let batches = (0..total_steps).map(|_| ds.train_batch(client, rng)).collect();
    TrainPlan {
        client_id: client,
        ratio: ratio.ratio,
        batches,
    }
}

/// Phase 2: run the planned batches through ceil(total / chunk) fused PJRT
/// executions (see `ModelRuntime::train_chunk`) against `base`. Pure in the
/// plan + base: no RNG, no engine state.
pub fn execute_plan(
    rt: &ModelRuntime,
    plan: &TrainPlan,
    base: &ParamVec,
    lr: f32,
) -> Result<LocalOutcome> {
    let client = plan.client_id;
    let ratio = rt
        .meta
        .ratio_exact(plan.ratio)
        .ok_or_else(|| anyhow::anyhow!("planned ratio {} not compiled", plan.ratio))?;
    let mut params = base.clone();
    let mut loss_sum = 0.0;
    let mut steps = 0u64;
    let mut offset = 0usize;
    for take in chunk_sizes(plan.total_steps(), rt.meta.chunk) {
        let batches = &plan.batches[offset..offset + take];
        let (new_params, mean_loss) = rt.train_chunk(ratio, &params, batches, lr)?;
        check_loss_finite(client, mean_loss, steps)?;
        params = new_params;
        loss_sum += mean_loss as f64 * take as f64;
        steps += take as u64;
        offset += take;
    }
    let update = params.delta_from(base, ratio.boundary);
    Ok(LocalOutcome {
        client_id: client,
        update,
        mean_loss: loss_sum / steps.max(1) as f64,
        steps,
    })
}

/// Batched phase 2: execute many resolve-ready plans in as few PJRT
/// dispatches as possible (`batch_exec=on`). Plans are grouped by compiled
/// ratio (each ratio has its own batched executable), packed into waves of
/// up to `meta.lanes` lanes, and each wave's chunks run through
/// `ModelRuntime::train_chunk_batched` — one dispatch covers a chunk of
/// *every* lane, with per-lane `n_steps` masking so shorter plans pass
/// through once exhausted. Per lane, the arithmetic (chunk splits, loss
/// accumulation, delta extraction) mirrors [`execute_plan`] operation for
/// operation, so outcomes are bit-identical to executing each plan alone.
///
/// Outcomes are returned in input order.
pub fn execute_plans_batched(
    rt: &ModelRuntime,
    items: &[(&TrainPlan, &ParamVec)],
    lr: f32,
) -> Result<Vec<LocalOutcome>> {
    anyhow::ensure!(
        rt.meta.lanes >= 1,
        "model {} has no batched artifacts — the artifact set predates \
         batch_exec; re-run `make artifacts`",
        rt.meta.name
    );
    // Group item indices by compiled-ratio index, preserving input order
    // within each group (BTreeMap keeps the group order deterministic).
    let mut groups: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
    for (i, (plan, _)) in items.iter().enumerate() {
        let idx = rt
            .meta
            .ratios
            .iter()
            .position(|r| (r.ratio - plan.ratio).abs() < 1e-9)
            .ok_or_else(|| anyhow::anyhow!("planned ratio {} not compiled", plan.ratio))?;
        groups.entry(idx).or_default().push(i);
    }

    let mut outcomes: Vec<Option<LocalOutcome>> = (0..items.len()).map(|_| None).collect();
    for (ridx, group) in groups {
        let ratio = &rt.meta.ratios[ridx];
        for wave in group.chunks(rt.meta.lanes) {
            let mut params: Vec<ParamVec> =
                wave.iter().map(|&i| items[i].1.clone()).collect();
            let sizes: Vec<Vec<usize>> = wave
                .iter()
                .map(|&i| chunk_sizes(items[i].0.total_steps(), rt.meta.chunk))
                .collect();
            let mut loss_sums = vec![0f64; wave.len()];
            let mut steps_done = vec![0u64; wave.len()];
            let mut offsets = vec![0usize; wave.len()];
            let ncalls = sizes.iter().map(|s| s.len()).max().unwrap_or(0);
            for k in 0..ncalls {
                // Lanes whose plans still have a chunk at call k; exhausted
                // lanes drop out (equivalently n_steps = 0 padding).
                let active: Vec<usize> = (0..wave.len()).filter(|&w| k < sizes[w].len()).collect();
                let lane_args: Vec<(&ParamVec, &[Batch])> = active
                    .iter()
                    .map(|&w| {
                        let take = sizes[w][k];
                        let plan = items[wave[w]].0;
                        (&params[w], &plan.batches[offsets[w]..offsets[w] + take])
                    })
                    .collect();
                let outs = rt.train_chunk_batched(ratio, &lane_args, lr)?;
                drop(lane_args);
                for (j, &w) in active.iter().enumerate() {
                    let (new_params, mean_loss) = &outs[j];
                    let take = sizes[w][k];
                    check_loss_finite(items[wave[w]].0.client_id, *mean_loss, steps_done[w])?;
                    params[w] = new_params.clone();
                    loss_sums[w] += *mean_loss as f64 * take as f64;
                    steps_done[w] += take as u64;
                    offsets[w] += take;
                }
            }
            for (w, &i) in wave.iter().enumerate() {
                let (plan, base) = items[i];
                let update = params[w].delta_from(base, ratio.boundary);
                outcomes[i] = Some(LocalOutcome {
                    client_id: plan.client_id,
                    update,
                    mean_loss: loss_sums[w] / steps_done[w].max(1) as f64,
                    steps: steps_done[w],
                });
            }
        }
    }
    Ok(outcomes.into_iter().map(|o| o.expect("every item executed")).collect())
}

/// Train `client` for `epochs` local epochs (each `steps_per_epoch`
/// minibatches) at the given compiled partial ratio, starting from `base`.
/// Fused plan + execute — the synchronous path of the round-stepped
/// strategies and the `--eager-train` escape hatch.
#[allow(clippy::too_many_arguments)]
pub fn train_client(
    rt: &ModelRuntime,
    ds: &FederatedDataset,
    client: usize,
    base: &ParamVec,
    ratio: &RatioMeta,
    epochs: usize,
    steps_per_epoch: usize,
    lr: f32,
    rng: &mut Rng,
) -> Result<LocalOutcome> {
    let plan = plan_client(ds, client, ratio, epochs, steps_per_epoch, rng);
    execute_plan(rt, &plan, base, lr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::runtime::manifest::{ModelMeta, ParamMeta, Task, XDtype};

    #[test]
    fn chunk_sizes_cover_all_cases() {
        // chunk larger than the total: one partial execution.
        assert_eq!(chunk_sizes(3, 8), vec![3]);
        // exact multiple: full chunks only.
        assert_eq!(chunk_sizes(8, 4), vec![4, 4]);
        // remainder tail after full chunks.
        assert_eq!(chunk_sizes(10, 4), vec![4, 4, 2]);
        // single-step chunks degrade to one execution per minibatch.
        assert_eq!(chunk_sizes(3, 1), vec![1, 1, 1]);
        // zero steps schedule nothing.
        assert_eq!(chunk_sizes(0, 4), Vec::<usize>::new());
    }

    #[test]
    fn chunk_sizes_always_sum_to_total() {
        for total in 0..40 {
            for chunk in 1..10 {
                let sizes = chunk_sizes(total, chunk);
                assert_eq!(sizes.iter().sum::<usize>(), total, "total={total} chunk={chunk}");
                assert!(sizes.iter().all(|&s| s >= 1 && s <= chunk));
                // Only the last execution may be partial.
                for &s in sizes.iter().rev().skip(1) {
                    assert_eq!(s, chunk, "non-tail partial chunk (total={total})");
                }
            }
        }
    }

    #[test]
    fn divergence_guard_rejects_non_finite_losses() {
        check_loss_finite(3, 1.25, 10).unwrap();
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let err = check_loss_finite(7, bad, 4).unwrap_err().to_string();
            assert!(err.contains("client 7 diverged"), "message: {err}");
            assert!(err.contains("after step 4"), "message: {err}");
        }
    }

    /// A minimal classify-model meta sufficient for FederatedDataset.
    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            name: "tiny".into(),
            task: Task::Classify,
            batch: 2,
            eval_batch: 2,
            x_shape: vec![4],
            x_dtype: XDtype::F32,
            num_classes: 3,
            seq_len: 1,
            total_params: 4,
            chunk: 4,
            lanes: 0,
            params: vec![ParamMeta {
                name: "w".into(),
                shape: vec![4],
                size: 4,
            }],
            ratios: vec![],
            eval_artifact: String::new(),
            init_artifact: String::new(),
        }
    }

    fn full_ratio() -> RatioMeta {
        RatioMeta {
            ratio: 1.0,
            boundary: 0,
            trainable_fraction: 1.0,
            artifact: String::new(),
            batched_artifact: None,
        }
    }

    #[test]
    fn plan_draws_exactly_epochs_times_steps_batches() {
        let meta = tiny_meta();
        let ds = FederatedDataset::new(SyntheticSpec::default(), &meta, 4);
        let mut rng = Rng::seed_from(11);
        let plan = plan_client(&ds, 1, &full_ratio(), 3, 2, &mut rng);
        assert_eq!(plan.client_id, 1);
        assert_eq!(plan.total_steps(), 6);
        assert_eq!(plan.ratio, 1.0);
    }

    #[test]
    fn plan_leaves_rng_where_eager_interleaving_would() {
        // The deferred-execution determinism contract: planning draws the
        // batches in the same order the historical eager loop did, so the
        // stream position afterwards is identical — and a re-plan from the
        // same position reproduces the same batches.
        let meta = tiny_meta();
        let ds = FederatedDataset::new(SyntheticSpec::default(), &meta, 4);

        let mut planned = Rng::seed_from(99);
        let plan = plan_client(&ds, 2, &full_ratio(), 2, 3, &mut planned);

        // Historical order: one train_batch draw per step, nothing else.
        let mut eager = Rng::seed_from(99);
        let hand: Vec<Batch> = (0..6).map(|_| ds.train_batch(2, &mut eager)).collect();

        for (a, b) in plan.batches.iter().zip(&hand) {
            match (a, b) {
                (Batch::F32 { x: ax, y: ay }, Batch::F32 { x: bx, y: by }) => {
                    assert_eq!(ax, bx);
                    assert_eq!(ay, by);
                }
                _ => panic!("classify dataset must yield F32 batches"),
            }
        }
        // Both streams end at the same position.
        assert_eq!(planned.next_u64(), eager.next_u64());
    }

    #[test]
    fn discarding_a_plan_does_not_perturb_later_draws() {
        // Stream A cancels its first dispatch (plan discarded, never
        // executed); stream B's identical dispatch "runs". The NEXT
        // dispatch must plan identically from both streams — the whole
        // point of drawing batches at plan time.
        let meta = tiny_meta();
        let ds = FederatedDataset::new(SyntheticSpec::default(), &meta, 4);
        let mut a = Rng::seed_from(5);
        let mut b = Rng::seed_from(5);
        let plan_a = plan_client(&ds, 0, &full_ratio(), 2, 2, &mut a);
        let _plan_b = plan_client(&ds, 0, &full_ratio(), 2, 2, &mut b);
        drop(plan_a); // cancelled: discarding costs nothing and moves no RNG
        let next_a = plan_client(&ds, 0, &full_ratio(), 1, 2, &mut a);
        let next_b = plan_client(&ds, 0, &full_ratio(), 1, 2, &mut b);
        assert_eq!(next_a.total_steps(), next_b.total_steps());
        for (pa, pb) in next_a.batches.iter().zip(&next_b.batches) {
            match (pa, pb) {
                (Batch::F32 { x: ax, y: ay }, Batch::F32 { x: bx, y: by }) => {
                    assert_eq!(ax, bx);
                    assert_eq!(ay, by);
                }
                _ => panic!("classify dataset must yield F32 batches"),
            }
        }
    }
}
