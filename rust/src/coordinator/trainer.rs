//! Client-side local training executor: runs E local epochs of real PJRT
//! train-steps against a base model snapshot and returns the suffix delta.

use anyhow::Result;

use crate::data::FederatedDataset;
use crate::model::{ParamVec, Update};
use crate::runtime::manifest::RatioMeta;
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

/// Result of one client's local training for one round.
#[derive(Clone, Debug)]
pub struct LocalOutcome {
    pub client_id: usize,
    /// Suffix delta vs the base model (boundary = ratio's boundary).
    pub update: Update,
    /// Mean minibatch loss over all local steps (client-reported).
    pub mean_loss: f64,
    pub steps: u64,
}

/// Train `client` for `epochs` local epochs (each `steps_per_epoch`
/// minibatches) at the given compiled partial ratio, starting from `base`.
pub fn train_client(
    rt: &ModelRuntime,
    ds: &FederatedDataset,
    client: usize,
    base: &ParamVec,
    ratio: &RatioMeta,
    epochs: usize,
    steps_per_epoch: usize,
    lr: f32,
    rng: &mut Rng,
) -> Result<LocalOutcome> {
    debug_assert!(epochs >= 1 && steps_per_epoch >= 1);
    let total_steps = epochs * steps_per_epoch;
    let mut params = base.clone();
    let mut loss_sum = 0.0;
    let mut steps = 0u64;
    // Issue ceil(total / chunk) fused PJRT executions instead of one per
    // minibatch (see ModelRuntime::train_chunk).
    let chunk = rt.meta.chunk;
    let mut remaining = total_steps;
    while remaining > 0 {
        let take = remaining.min(chunk);
        let batches: Vec<_> = (0..take).map(|_| ds.train_batch(client, rng)).collect();
        let (new_params, mean_loss) = rt.train_chunk(ratio, &params, &batches, lr)?;
        anyhow::ensure!(
            mean_loss.is_finite(),
            "client {client} diverged (loss {mean_loss}) after step {steps}"
        );
        params = new_params;
        loss_sum += mean_loss as f64 * take as f64;
        steps += take as u64;
        remaining -= take;
    }
    let update = params.delta_from(base, ratio.boundary);
    Ok(LocalOutcome {
        client_id: client,
        update,
        mean_loss: loss_sum / steps.max(1) as f64,
        steps,
    })
}
