//! Layer-3 coordinator — the paper's system contribution.
//!
//! `Simulation` wires the compiled model runtime, the synthetic federated
//! dataset, and the heterogeneous device fleet together. FL protocols are
//! pluggable [`engine::Strategy`] implementations resolved through the
//! [`registry`] (name → constructor) and driven by a shared
//! [`engine::SimEngine`] that owns the run lifecycle: seeded RNG tree,
//! availability model, one `simtime::EventQueue` clock, online-client
//! sampling (WHO gets dispatched is itself a pluggable policy —
//! [`sampler::ClientSampler`], resolved through its own registry:
//! `uniform` | `stay-prob` | `drop-aware` | `fair-cap`), per-update
//! aggregation weighting (WHAT each delivered update counts for —
//! [`crate::scheduling::AggWeigher`], its own registry:
//! `uniform` | `staleness` | `sched-joint`), drop attribution, eval/stop,
//! and the machine-readable run-event stream (`metrics::events`).
//!
//! Client *training* is real (PJRT executions of the AOT artifacts); client
//! *timing* is simulated from the device model — the same emulation
//! methodology as the paper (§4.1). Every strategy samples only from
//! currently-available clients and attributes churn losses separately from
//! deadline losses. Asynchronous dispatches are *deferred*: the engine
//! draws the data plan eagerly (pinning RNG streams) but runs the PJRT
//! work only when the dispatch's finish event survives churn, so cancelled
//! dispatches never touch the accelerator (`Recorder::wasted`,
//! `RunReport::trainings_{executed,avoided}`; `cfg.eager_train` opts out).

pub mod engine;
pub mod fedbuff;
pub mod local_time;
pub mod registry;
pub mod sampler;
pub mod scheduler;
pub mod semiasync;
pub mod syncfl;
pub mod timelyfl;
pub mod trainer;

use std::time::Instant;

use anyhow::Result;
use xla::PjRtClient;

use crate::availability::AvailabilityModel;
use crate::config::RunConfig;
use crate::data::{FederatedDataset, SyntheticSpec};
use crate::devices::Fleet;
use crate::metrics::events::{EventSink, NullSink};
use crate::metrics::{EvalPoint, ParticipationTracker, RoundRecord, RunReport, WastedWork};
use crate::model::ParamVec;
use crate::runtime::engine::Batch;
use crate::runtime::{Manifest, ModelRuntime, Task};
use crate::util::rng::Rng;

pub use engine::{
    ClientFinish, EngineEvent, EventStrategy, RoundCtx, RoundOutcome, RoundStrategy, SimEngine,
    Strategy,
};
pub use registry::{StrategyInfo, STRATEGIES};
pub use sampler::{ClientSampler, SamplerCtx, SamplerInfo, SAMPLERS};

/// Everything a strategy needs for one run.
pub struct Simulation {
    pub cfg: RunConfig,
    pub runtime: ModelRuntime,
    pub dataset: FederatedDataset,
    pub fleet: Fleet,
    eval_set: Vec<Batch>,
}

impl Simulation {
    /// Build a simulation from a config + artifacts directory. Compiles all
    /// executables once; reusable across `run()` calls.
    pub fn new(cfg: RunConfig, artifacts_dir: impl AsRef<std::path::Path>) -> Result<Simulation> {
        cfg.validate()?;
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Self::with_client(cfg, &manifest, &client)
    }

    /// Same, sharing an existing PJRT client (benches build several
    /// simulations against one client).
    pub fn with_client(
        cfg: RunConfig,
        manifest: &Manifest,
        client: &PjRtClient,
    ) -> Result<Simulation> {
        cfg.validate()?;
        let runtime = ModelRuntime::load(client, manifest, &cfg.model)?;
        let spec = SyntheticSpec {
            dataset_seed: cfg.data_seed,
            alpha: cfg.dirichlet_alpha,
            template_scale: cfg.template_scale,
            lm_noise: cfg.lm_noise,
        };
        let dataset = FederatedDataset::new(spec, &runtime.meta, cfg.population);
        let mut fleet_rng = Rng::seed_from(cfg.seed ^ 0xF1EE7);
        let fleet = Fleet::generate(cfg.population, cfg.fleet.clone(), &mut fleet_rng);
        let eval_set = dataset.eval_batches(cfg.eval_batches, 0);
        Ok(Simulation {
            cfg,
            runtime,
            dataset,
            fleet,
            eval_set,
        })
    }

    /// Run the configured strategy, resolved through the registry.
    pub fn run(&self) -> Result<RunReport> {
        self.run_with_sink(&mut NullSink)
    }

    /// Same, streaming machine-readable run events into `sink`
    /// (`metrics::events`; the CLI's `--events FILE`).
    pub fn run_with_sink(&self, sink: &mut dyn EventSink) -> Result<RunReport> {
        self.run_inner(Some(sink), None)
    }

    /// Run with a warm drop ledger (`--warm-ledger`): the previous run's
    /// per-client delivered/churned counters seed this run's tables before
    /// the strategy starts, and the finished tables are harvested back —
    /// evidence-based policies (`drop-aware`, `fair-cap`, the `sched-joint`
    /// weigher) warm-start across the cells of a sweep. An empty ledger
    /// seeds nothing, so the first run of a warm sweep is identical to a
    /// cold one.
    pub fn run_warm(
        &self,
        sink: Option<&mut dyn EventSink>,
        ledger: &mut crate::scheduling::WarmLedger,
    ) -> Result<RunReport> {
        self.run_inner(sink, Some(ledger))
    }

    fn run_inner(
        &self,
        sink: Option<&mut dyn EventSink>,
        ledger: Option<&mut crate::scheduling::WarmLedger>,
    ) -> Result<RunReport> {
        let info = registry::resolve(&self.cfg.strategy)?;
        let mut strategy = (info.build)(self)?;
        let mut eng = SimEngine::new(self, sink)?;
        if let Some(ledger) = &ledger {
            eng.seed_ledger(ledger);
        }
        strategy.run(&mut eng)?;
        // Under `batch_exec` an event-driven run can stop (budget / target
        // metric) with resolve-ready plans still parked between flushes.
        // Serial execution ran those at their finish events, so drain them
        // for wasted-work-ledger parity before the report settles.
        eng.drain_batch(None)?;
        if let Some(ledger) = ledger {
            eng.harvest_ledger(ledger);
        }
        Ok(eng.finish(strategy.name()))
    }

    /// Is the run's target metric reached? (accuracy: higher better;
    /// perplexity: lower better.)
    pub fn target_reached(&self, metric: f64) -> bool {
        match self.cfg.target_metric {
            None => false,
            Some(t) => match self.runtime.meta.task {
                Task::Classify => metric >= t,
                Task::Lm => metric <= t,
            },
        }
    }
}

/// Run-recording machinery shared by every strategy (owned by the engine).
pub struct Recorder {
    started: Instant,
    pub participation: ParticipationTracker,
    pub eval_points: Vec<EvalPoint>,
    pub rounds: Vec<RoundRecord>,
    stop: bool,
    /// Wasted-work ledger for the plan/execute dispatch split: the engine
    /// bumps it at dispatch, execution, and cancellation.
    pub wasted: WastedWork,
    /// Drops that accumulated when NO round was ever recorded (population
    /// offline from t=0): carried at run level so attribution totals don't
    /// silently undercount.
    tail_dropped: usize,
    tail_avail_dropped: usize,
    /// Model-dissemination totals (`crate::network`): simulated seconds
    /// dispatches spent on the downlink, and dispatches that started on a
    /// stale version. The engine drains its pending per-round counters in
    /// here at each round completion (and folds the tail at run end); both
    /// stay exactly zero under `network = free`.
    downlink_wait_secs: f64,
    stale_starts: u64,
    /// Region-clock totals (`crate::fleet::RegionClock`): edge-aggregator
    /// flushes, simulated seconds partials spent on the priced edge→root
    /// uplink, and root merges assembled from arrived partials. Drained
    /// like the network counters; all exactly zero under the default
    /// `hier_clock = shared`.
    edge_flushes: u64,
    edge_uplink_wait_secs: f64,
    edge_root_merges: u64,
}

impl Recorder {
    pub fn new(population: usize) -> Recorder {
        Recorder {
            started: Instant::now(),
            participation: ParticipationTracker::new(population),
            eval_points: Vec::new(),
            rounds: Vec::new(),
            stop: false,
            wasted: WastedWork::default(),
            tail_dropped: 0,
            tail_avail_dropped: 0,
            downlink_wait_secs: 0.0,
            stale_starts: 0,
            edge_flushes: 0,
            edge_uplink_wait_secs: 0.0,
            edge_root_merges: 0,
        }
    }

    /// Accumulate dissemination totals (downlink-wait seconds + stale
    /// starts) into the run-level counters.
    pub fn note_network(&mut self, wait_secs: f64, stale: u64) {
        self.downlink_wait_secs += wait_secs;
        self.stale_starts += stale;
    }

    /// Accumulate region-clock totals (edge flushes, uplink-wait seconds,
    /// root merges) into the run-level counters. All-zero calls — every
    /// call under the default `hier_clock = shared` — change nothing.
    pub fn note_edge(&mut self, flushes: u64, uplink_wait_secs: f64, root_merges: u64) {
        self.edge_flushes += flushes;
        self.edge_uplink_wait_secs += uplink_wait_secs;
        self.edge_root_merges += root_merges;
    }

    /// Record one aggregation round's participants + stats. Deadline /
    /// staleness / injected-failure losses (`dropped`) are attributed
    /// separately from availability-churn losses (`avail_dropped`);
    /// `mean_train_loss` is `None` when no sampled client delivered.
    pub fn record_round(
        &mut self,
        round: usize,
        sim_secs: f64,
        participant_ids: &[usize],
        dropped: usize,
        avail_dropped: usize,
        mean_train_loss: Option<f64>,
    ) {
        // Defense in depth behind the engine's own filter: a non-finite
        // loss (an unpatched batch-exec placeholder's NaN) records as
        // `None`, never as a poison value in the report.
        let mean_train_loss = mean_train_loss.filter(|l| l.is_finite());
        self.participation.record_round(participant_ids.iter().copied());
        self.rounds.push(RoundRecord {
            round,
            sim_secs,
            participants: participant_ids.len(),
            dropped,
            avail_dropped,
            mean_train_loss,
        });
    }

    /// Evaluate the global model if the cadence says so; set the stop flag
    /// when the target metric or the sim-time budget is hit. Returns the
    /// recorded point when an evaluation ran.
    pub fn maybe_eval(
        &mut self,
        sim: &Simulation,
        round: usize,
        sim_secs: f64,
        global: &ParamVec,
    ) -> Result<Option<EvalPoint>> {
        let last = round + 1 == sim.cfg.rounds;
        if round % sim.cfg.eval_every != 0 && !last {
            return Ok(None);
        }
        let res = sim.runtime.evaluate(global, &self.eval_batches(sim))?;
        let point = EvalPoint {
            round,
            sim_secs,
            mean_loss: res.mean_loss,
            metric: res.metric,
        };
        self.eval_points.push(point);
        if sim.target_reached(res.metric) {
            self.stop = true;
        }
        Ok(Some(point))
    }

    fn eval_batches<'a>(&self, sim: &'a Simulation) -> &'a [Batch] {
        &sim.eval_set
    }

    pub fn should_stop(&self, sim: &Simulation, sim_secs: f64) -> bool {
        self.stop || sim_secs >= sim.cfg.sim_time_budget
    }

    /// Fold drops that accumulated after the last recorded aggregation
    /// into the final round's attribution, so end-of-run tails (budget
    /// stops, partially-filled buffers) don't silently undercount
    /// `total_avail_drops()` / `total_deadline_drops()`. When NO round was
    /// ever recorded (e.g. the population was offline from t=0) the counts
    /// are carried as run-level tail counters instead of being discarded.
    pub fn absorb_tail_drops(&mut self, dropped: usize, avail_dropped: usize) {
        if dropped == 0 && avail_dropped == 0 {
            return;
        }
        if let Some(last) = self.rounds.last_mut() {
            last.dropped += dropped;
            last.avail_dropped += avail_dropped;
        } else {
            self.tail_dropped += dropped;
            self.tail_avail_dropped += avail_dropped;
        }
    }

    /// Build the final report; per-client online fractions are measured
    /// from the availability model over the run's simulated span.
    pub fn finish(
        self,
        strategy: &str,
        sim: &Simulation,
        sim_secs: f64,
        total_rounds: usize,
        events_processed: u64,
        avail: &mut AvailabilityModel,
    ) -> RunReport {
        // The engine drains its pending table before finishing, so every
        // dispatch must have resolved to executed or avoided by now; a
        // non-zero residue means a path lost a dispatch without settling.
        debug_assert_eq!(
            self.wasted.pending(),
            0,
            "wasted-work ledger not settled: {:?}",
            self.wasted
        );
        let online_fraction = (0..sim.cfg.population)
            .map(|c| avail.online_fraction(c, sim_secs))
            .collect();
        RunReport {
            strategy: strategy.to_string(),
            model: sim.cfg.model.clone(),
            eval_points: self.eval_points,
            rounds: self.rounds,
            participation: self.participation.rates(),
            online_fraction,
            sim_secs,
            wall_secs: self.started.elapsed().as_secs_f64(),
            total_rounds,
            events_processed,
            real_train_steps: sim.runtime.stats().train_steps,
            trainings_executed: self.wasted.executed,
            trainings_avoided: self.wasted.avoided,
            tail_dropped: self.tail_dropped,
            tail_avail_dropped: self.tail_avail_dropped,
            downlink_wait_secs: self.downlink_wait_secs,
            stale_starts: self.stale_starts,
            edge_flushes: self.edge_flushes,
            edge_uplink_wait_secs: self.edge_uplink_wait_secs,
            edge_root_merges: self.edge_root_merges,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_drops_fold_into_last_round() {
        let mut rec = Recorder::new(4);
        rec.record_round(0, 10.0, &[1, 2], 1, 0, Some(2.0));
        rec.absorb_tail_drops(2, 3);
        let last = rec.rounds.last().unwrap();
        assert_eq!(last.dropped, 3);
        assert_eq!(last.avail_dropped, 3);
        assert_eq!(rec.tail_dropped, 0);
        assert_eq!(rec.tail_avail_dropped, 0);
    }

    #[test]
    fn tail_drops_survive_with_zero_rounds() {
        // Population offline from t=0: no round ever recorded. The counts
        // must be carried at run level, not silently discarded.
        let mut rec = Recorder::new(4);
        rec.absorb_tail_drops(0, 0); // no-op
        assert_eq!(rec.tail_avail_dropped, 0);
        rec.absorb_tail_drops(1, 7);
        assert!(rec.rounds.is_empty());
        assert_eq!(rec.tail_dropped, 1);
        assert_eq!(rec.tail_avail_dropped, 7);
    }

    #[test]
    fn non_finite_round_loss_records_as_none() {
        // An unpatched batch-exec placeholder carries mean_loss = NaN; if
        // one ever leaks into a round mean the record must say "no loss",
        // not poison downstream fingerprints.
        let mut rec = Recorder::new(4);
        rec.record_round(0, 1.0, &[0], 0, 0, Some(f64::NAN));
        rec.record_round(1, 2.0, &[1], 0, 0, Some(f64::INFINITY));
        rec.record_round(2, 3.0, &[2], 0, 0, Some(1.25));
        assert_eq!(rec.rounds[0].mean_train_loss, None);
        assert_eq!(rec.rounds[1].mean_train_loss, None);
        assert_eq!(rec.rounds[2].mean_train_loss, Some(1.25));
    }

    #[test]
    fn note_edge_accumulates_into_run_totals() {
        let mut rec = Recorder::new(4);
        rec.note_edge(0, 0.0, 0); // the shared-clock no-op
        assert_eq!(rec.edge_flushes, 0);
        rec.note_edge(3, 1.5, 1);
        rec.note_edge(2, 0.5, 1);
        assert_eq!(rec.edge_flushes, 5);
        assert!((rec.edge_uplink_wait_secs - 2.0).abs() < 1e-12);
        assert_eq!(rec.edge_root_merges, 2);
    }
}
