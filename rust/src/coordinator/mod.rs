//! Layer-3 coordinator — the paper's system contribution.
//!
//! `Simulation` wires the compiled model runtime, the synthetic federated
//! dataset, and the heterogeneous device fleet together; the three strategy
//! drivers (TimelyFL / FedBuff / SyncFL) share that context. Client
//! *training* is real (PJRT executions of the AOT artifacts); client
//! *timing* is simulated from the device model — the same emulation
//! methodology as the paper (§4.1).
//!
//! All three drivers share one `simtime::EventQueue` clock and one
//! availability model (`crate::availability`): round-stepped strategies pop
//! round-boundary events, FedBuff pops client-finish and
//! availability-transition events from a single queue, and every driver
//! samples only from currently-available clients, attributing
//! churn losses separately from deadline losses.

pub mod fedbuff;
pub mod local_time;
pub mod scheduler;
pub mod syncfl;
pub mod timelyfl;
pub mod trainer;

use std::time::Instant;

use anyhow::Result;
use xla::PjRtClient;

use crate::availability::AvailabilityModel;
use crate::config::{RunConfig, StrategyKind};
use crate::data::{FederatedDataset, SyntheticSpec};
use crate::devices::Fleet;
use crate::simtime::EventQueue;
use crate::metrics::{EvalPoint, ParticipationTracker, RoundRecord, RunReport};
use crate::model::ParamVec;
use crate::runtime::engine::Batch;
use crate::runtime::{Manifest, ModelRuntime, Task};
use crate::util::rng::Rng;

/// Everything a strategy driver needs for one run.
pub struct Simulation {
    pub cfg: RunConfig,
    pub runtime: ModelRuntime,
    pub dataset: FederatedDataset,
    pub fleet: Fleet,
    eval_set: Vec<Batch>,
}

impl Simulation {
    /// Build a simulation from a config + artifacts directory. Compiles all
    /// executables once; reusable across `run()` calls.
    pub fn new(cfg: RunConfig, artifacts_dir: impl AsRef<std::path::Path>) -> Result<Simulation> {
        cfg.validate()?;
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Self::with_client(cfg, &manifest, &client)
    }

    /// Same, sharing an existing PJRT client (benches build several
    /// simulations against one client).
    pub fn with_client(
        cfg: RunConfig,
        manifest: &Manifest,
        client: &PjRtClient,
    ) -> Result<Simulation> {
        cfg.validate()?;
        let runtime = ModelRuntime::load(client, manifest, &cfg.model)?;
        let spec = SyntheticSpec {
            dataset_seed: cfg.data_seed,
            alpha: cfg.dirichlet_alpha,
            template_scale: cfg.template_scale,
            lm_noise: cfg.lm_noise,
        };
        let dataset = FederatedDataset::new(spec, &runtime.meta, cfg.population);
        let mut fleet_rng = Rng::seed_from(cfg.seed ^ 0xF1EE7);
        let fleet = Fleet::generate(cfg.population, cfg.fleet.clone(), &mut fleet_rng);
        let eval_set = dataset.eval_batches(cfg.eval_batches, 0);
        Ok(Simulation {
            cfg,
            runtime,
            dataset,
            fleet,
            eval_set,
        })
    }

    /// Dispatch on the configured strategy.
    pub fn run(&self) -> Result<RunReport> {
        match self.cfg.strategy {
            StrategyKind::TimelyFl => timelyfl::run(self),
            StrategyKind::FedBuff => fedbuff::run(self),
            StrategyKind::SyncFl => syncfl::run(self),
        }
    }

    /// Is the run's target metric reached? (accuracy: higher better;
    /// perplexity: lower better.)
    pub fn target_reached(&self, metric: f64) -> bool {
        match self.cfg.target_metric {
            None => false,
            Some(t) => match self.runtime.meta.task {
                Task::Classify => metric >= t,
                Task::Lm => metric <= t,
            },
        }
    }
}

/// Shared run-recording machinery for the three drivers.
pub struct Recorder {
    started: Instant,
    pub participation: ParticipationTracker,
    pub eval_points: Vec<EvalPoint>,
    pub rounds: Vec<RoundRecord>,
    stop: bool,
}

impl Recorder {
    pub fn new(population: usize) -> Recorder {
        Recorder {
            started: Instant::now(),
            participation: ParticipationTracker::new(population),
            eval_points: Vec::new(),
            rounds: Vec::new(),
            stop: false,
        }
    }

    /// Record one aggregation round's participants + stats. Deadline /
    /// staleness / injected-failure losses (`dropped`) are attributed
    /// separately from availability-churn losses (`avail_dropped`);
    /// `mean_train_loss` is `None` when no sampled client delivered.
    pub fn record_round(
        &mut self,
        round: usize,
        sim_secs: f64,
        participant_ids: &[usize],
        dropped: usize,
        avail_dropped: usize,
        mean_train_loss: Option<f64>,
    ) {
        self.participation.record_round(participant_ids.iter().copied());
        self.rounds.push(RoundRecord {
            round,
            sim_secs,
            participants: participant_ids.len(),
            dropped,
            avail_dropped,
            mean_train_loss,
        });
    }

    /// Evaluate the global model if the cadence says so; set the stop flag
    /// when the target metric or the sim-time budget is hit.
    pub fn maybe_eval(
        &mut self,
        sim: &Simulation,
        round: usize,
        sim_secs: f64,
        global: &ParamVec,
    ) -> Result<()> {
        let last = round + 1 == sim.cfg.rounds;
        if round % sim.cfg.eval_every != 0 && !last {
            return Ok(());
        }
        let res = sim.runtime.evaluate(global, &self.eval_batches(sim))?;
        self.eval_points.push(EvalPoint {
            round,
            sim_secs,
            mean_loss: res.mean_loss,
            metric: res.metric,
        });
        if sim.target_reached(res.metric) {
            self.stop = true;
        }
        Ok(())
    }

    fn eval_batches<'a>(&self, sim: &'a Simulation) -> &'a [Batch] {
        &sim.eval_set
    }

    pub fn should_stop(&self, sim: &Simulation, sim_secs: f64) -> bool {
        self.stop || sim_secs >= sim.cfg.sim_time_budget
    }

    /// Fold drops that accumulated after the last recorded aggregation
    /// into the final round's attribution, so end-of-run tails (budget
    /// stops, partially-filled FedBuff buffers) don't silently undercount
    /// `total_avail_drops()` / `total_deadline_drops()`.
    pub fn absorb_tail_drops(&mut self, dropped: usize, avail_dropped: usize) {
        if dropped == 0 && avail_dropped == 0 {
            return;
        }
        if let Some(last) = self.rounds.last_mut() {
            last.dropped += dropped;
            last.avail_dropped += avail_dropped;
        }
    }

    /// Build the final report; per-client online fractions are measured
    /// from the availability model over the run's simulated span.
    pub fn finish(
        self,
        sim: &Simulation,
        sim_secs: f64,
        total_rounds: usize,
        events_processed: u64,
        avail: &mut AvailabilityModel,
    ) -> RunReport {
        let online_fraction = (0..sim.cfg.population)
            .map(|c| avail.online_fraction(c, sim_secs))
            .collect();
        RunReport {
            strategy: sim.cfg.strategy.name().to_string(),
            model: sim.cfg.model.clone(),
            eval_points: self.eval_points,
            rounds: self.rounds,
            participation: self.participation.rates(),
            online_fraction,
            sim_secs,
            wall_secs: self.started.elapsed().as_secs_f64(),
            total_rounds,
            events_processed,
            real_train_steps: sim.runtime.stats().train_steps,
        }
    }
}

/// Shared idle-wait for the round-stepped drivers: when the whole
/// population is momentarily offline, advance the clock (as an event) to
/// the next availability transition. Returns `false` when no transition
/// will ever come — the population is permanently offline and the run
/// should end gracefully.
pub(crate) fn idle_until_transition(
    avail: &mut AvailabilityModel,
    events: &mut EventQueue<()>,
) -> bool {
    let Some(t) = avail.earliest_transition(events.now()) else {
        return false;
    };
    events.schedule_at(t, ());
    events.pop();
    true
}
