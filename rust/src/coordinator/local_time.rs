//! Algorithm 2 — Local Time Update.
//!
//! Each sampled client estimates its *unit* times for this round: the
//! compute time of ONE local epoch of FULL-model training (extrapolated
//! from a one-data-batch probe, `t_cmp = t_batch / beta`) and the
//! communication time of a full-model upload (`t_com = M / Bw`).
//!
//! In simulation the true values come from the device model; the probe's
//! extrapolation error is modeled as multiplicative noise with configurable
//! relative std-dev (`estimate_noise`). The *actual* round times later use
//! the exact values, so the scheduler can be wrong in the same way a real
//! probe is.

use crate::devices::{DeviceProfile, RoundConditions};
use crate::util::rng::Rng;

/// Unit-time estimates reported to the server (Alg. 2 outputs).
#[derive(Clone, Copy, Debug)]
pub struct TimeEstimate {
    /// Estimated seconds per local epoch of full-model training.
    pub t_cmp: f64,
    /// Estimated seconds to upload one full model.
    pub t_com: f64,
}

impl TimeEstimate {
    /// Alg. 2 line 4: unit total time.
    pub fn t_total(&self) -> f64 {
        self.t_cmp + self.t_com
    }

    /// The estimate re-priced under a degraded link: the communication
    /// term stretches by `1 / factor` (the same transform the engine's
    /// ground truth applies in `SimEngine::truth_at`), compute untouched.
    /// This is what the bandwidth-aware rebalancing seam feeds Alg. 3 —
    /// scheduling against the *effective* timeline instead of the nominal
    /// probe — so a degrading region shrinks E_c / alpha_c instead of
    /// merely missing the deadline. `factor >= 1` (or a non-positive
    /// factor, which only an always-on model would produce as 1.0) leaves
    /// the estimate unchanged or faster, never slower.
    pub fn degraded(self, factor: f64) -> TimeEstimate {
        if factor > 0.0 {
            TimeEstimate {
                t_cmp: self.t_cmp,
                t_com: self.t_com / factor,
            }
        } else {
            self
        }
    }
}

/// Ground-truth unit times for the same round (used for the actual
/// completion-time check after training).
#[derive(Clone, Copy, Debug)]
pub struct TimeTruth {
    pub t_cmp: f64,
    pub t_com: f64,
}

impl TimeTruth {
    /// Wall time of a round with `epochs` local epochs at partial ratio
    /// `compute_ratio`, uploading `comm_fraction` of the model. Linear in
    /// ratio per the paper's measurement (Fig. 9 / Appendix A.2.1).
    pub fn round_secs(&self, epochs: f64, compute_ratio: f64, comm_fraction: f64) -> f64 {
        self.t_cmp * epochs * compute_ratio + self.t_com * comm_fraction
    }
}

/// Compute the true unit times for (device, round conditions, model size).
pub fn truth(device: &DeviceProfile, cond: &RoundConditions, model_bytes: f64) -> TimeTruth {
    TimeTruth {
        t_cmp: device.compute_secs(cond, 1.0, 1.0),
        t_com: device.upload_secs(cond, model_bytes),
    }
}

/// Run Algorithm 2: probe + extrapolate, with estimation noise.
pub fn local_time_update(
    device: &DeviceProfile,
    cond: &RoundConditions,
    model_bytes: f64,
    estimate_noise: f64,
    rng: &mut Rng,
) -> TimeEstimate {
    let t = truth(device, cond, model_bytes);
    let noisy = |v: f64, rng: &mut Rng| {
        if estimate_noise <= 0.0 {
            v
        } else {
            // multiplicative, clamped so an estimate is never <= 0
            v * (1.0 + estimate_noise * rng.normal()).max(0.05)
        }
    };
    TimeEstimate {
        t_cmp: noisy(t.t_cmp, rng),
        t_com: noisy(t.t_com, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> DeviceProfile {
        DeviceProfile {
            id: 0,
            base_epoch_secs: 100.0,
        }
    }

    fn cond() -> RoundConditions {
        RoundConditions {
            disturbance: 1.1,
            bandwidth: 1e6,
        }
    }

    #[test]
    fn truth_matches_device_model() {
        let t = truth(&dev(), &cond(), 2e6);
        assert!((t.t_cmp - 110.0).abs() < 1e-9);
        assert!((t.t_com - 2.0).abs() < 1e-9);
        assert!((t.round_secs(2.0, 0.5, 0.4) - (110.0 * 2.0 * 0.5 + 2.0 * 0.4)).abs() < 1e-9);
    }

    #[test]
    fn zero_noise_estimate_is_exact() {
        let mut rng = Rng::seed_from(1);
        let e = local_time_update(&dev(), &cond(), 2e6, 0.0, &mut rng);
        assert!((e.t_cmp - 110.0).abs() < 1e-9);
        assert!((e.t_com - 2.0).abs() < 1e-9);
        assert!((e.t_total() - 112.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_estimates_center_on_truth() {
        let mut rng = Rng::seed_from(2);
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| local_time_update(&dev(), &cond(), 2e6, 0.1, &mut rng).t_cmp)
            .sum::<f64>()
            / n as f64;
        assert!((mean - 110.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn degraded_stretches_only_the_comm_term() {
        let e = TimeEstimate {
            t_cmp: 110.0,
            t_com: 2.0,
        };
        let d = e.degraded(0.25);
        assert!((d.t_cmp - 110.0).abs() < 1e-12, "compute untouched");
        assert!((d.t_com - 8.0).abs() < 1e-12, "comm / factor");
        let full = e.degraded(1.0);
        assert!((full.t_cmp - e.t_cmp).abs() < 1e-12);
        assert!((full.t_com - e.t_com).abs() < 1e-12);
        // Degenerate factor: no change rather than a NaN/inf estimate.
        let z = e.degraded(0.0);
        assert!((z.t_com - e.t_com).abs() < 1e-12);
        // Monotone: worse link => never-smaller total.
        let mut prev = f64::INFINITY;
        for i in 1..=10 {
            let t = e.degraded(i as f64 / 10.0).t_total();
            assert!(t <= prev);
            prev = t;
        }
    }

    #[test]
    fn estimates_always_positive() {
        let mut rng = Rng::seed_from(3);
        for _ in 0..10_000 {
            let e = local_time_update(&dev(), &cond(), 2e6, 0.5, &mut rng);
            assert!(e.t_cmp > 0.0 && e.t_com > 0.0);
        }
    }
}
