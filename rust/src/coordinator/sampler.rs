//! Availability-aware client sampling: the `ClientSampler` trait, its
//! registry, and the three shipped policies.
//!
//! TimelyFL adapts *how much* it asks of each client; the sampler seam
//! decides *who* gets asked. Every strategy draws cohorts / refill picks
//! through the engine (`SimEngine::sample_cohort` / `pick_client`), which
//! delegates to the policy resolved from `RunConfig::sampler`:
//!
//! - **uniform** — the default: uniform over the currently-online pool,
//!   reproducing the pre-seam RNG draws exactly (bit-compatible goldens).
//! - **stay-prob** — weights each candidate by
//!   `AvailabilityModel::survival_prob(c, now, horizon)`: the probability
//!   it stays online through the sampling horizon
//!   (`sampler_horizon_secs`), predicted per process (analytic
//!   residual-dwell survival for Markov/correlated, exact 0/1 for the
//!   deterministic processes). SEAFL-style selective participation,
//!   without an oracle.
//! - **drop-aware** — weights by a smoothed posterior survival estimate
//!   from the run's own observed per-client drop ledger:
//!   `(delivered + 1) / (delivered + churned + 1)` — no process model
//!   needed, just history.
//! - **fair-cap** — fairness-aware selection over the same drop ledger:
//!   a client whose attempt count (delivered + churned) reaches
//!   `fair_cap × (pool-minimum attempts + 1)` is excluded until the rest
//!   of the pool catches up, and the remaining candidates weigh their
//!   availability posterior plus a UCB-style exploration bonus
//!   `fair_explore · sqrt(ln(total attempts + 1) / (attempts + 1))` —
//!   caps the fast-device participation skew of Figs. 1/5 instead of
//!   amplifying it. Knobs live in `SchedulingConfig`
//!   (`crate::scheduling`).
//!
//! **Equivalence contract**: when every candidate's weight is identical
//! (always-on availability makes every survival exactly 1.0; a drop-free
//! ledger likewise), the weighted policies take the *uniform code path* —
//! the same RNG calls in the same order — so their runs are byte-identical
//! to `sampler = uniform` (`rust/tests/sampler_equivalence.rs`). Weighted
//! draws only happen once weights actually diverge.

use anyhow::Result;

use crate::availability::AvailabilityModel;
use crate::simtime::SimTime;
use crate::util::rng::Rng;

/// Everything a policy may consult for one decision. Borrows disjoint
/// engine fields; `scores` is the engine's per-client decision-score table
/// (weighted policies overwrite the entries of the candidates they
/// considered, and the engine stamps the chosen client's score onto its
/// dispatch-carrying event records as `stay_prob`).
pub struct SamplerCtx<'a> {
    pub now: SimTime,
    /// Horizon the stay-prob policy predicts survival over
    /// (`RunConfig::sampler_horizon_secs`).
    pub horizon: f64,
    pub rng: &'a mut Rng,
    pub avail: &'a mut AvailabilityModel,
    /// Per-client dispatches that ran to completion (engine drop ledger).
    pub delivered: &'a [u32],
    /// Per-client dispatches lost to availability churn.
    pub churned: &'a [u32],
    pub scores: &'a mut [f64],
    /// `fair-cap` selection-cap multiplier (`SchedulingConfig::fair_cap`).
    pub fair_cap: usize,
    /// `fair-cap` UCB exploration coefficient
    /// (`SchedulingConfig::fair_explore`).
    pub fair_explore: f64,
}

/// A pluggable client-sampling policy (one instance per run, built by the
/// registry — stateless policies are the norm, but the trait allows state).
pub trait ClientSampler {
    /// Canonical display name (also the registry key and what config
    /// canonicalizes `sampler = ...` to).
    fn name(&self) -> &'static str;

    /// Draw a cohort of `want` distinct clients from `pool` (the
    /// currently-online candidates, ascending ids). `want <= pool.len()`.
    fn sample(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize], want: usize) -> Vec<usize>;

    /// Pick one client from the non-empty `pool` (slot refills of
    /// event-driven strategies).
    fn pick_one(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize]) -> usize;

    /// True iff this policy's draws depend only on the pool's size and
    /// ordering — never on per-client weights or scores — so the lazy sim
    /// core may sample directly from its online-set index
    /// (`fleet::OnlineSetIndex`) without materialising the pool. Weighted
    /// policies must keep the default `false`: they score every candidate
    /// (even when the weights turn out degenerate), so they genuinely need
    /// the materialised pool.
    fn uniform_equivalent(&self) -> bool {
        false
    }
}

/// Floor applied to weights in a non-degenerate weighted draw, so a
/// zero-survival candidate keeps an epsilon of mass (categorical stays
/// well-defined and no client is ever unreachable by sampling alone).
const WEIGHT_FLOOR: f64 = 1e-6;

/// All weights bit-identical? (The degenerate case that must take the
/// uniform code path — see the module docs' equivalence contract.)
fn degenerate(weights: &[f64]) -> bool {
    weights.iter().all(|&w| w == weights[0])
}

/// The uniform cohort draw — partial Fisher–Yates over pool indices,
/// exactly the pre-seam engine code (and the degenerate-weights path of
/// every weighted policy).
fn uniform_sample(rng: &mut Rng, pool: &[usize], want: usize) -> Vec<usize> {
    rng.sample_without_replacement(pool.len(), want)
        .into_iter()
        .map(|i| pool[i])
        .collect()
}

/// Weighted cohort draw: `want` successive categorical picks without
/// replacement (weights floored at [`WEIGHT_FLOOR`]). Callers handle the
/// degenerate case first.
fn weighted_sample(rng: &mut Rng, pool: &[usize], want: usize, weights: &[f64]) -> Vec<usize> {
    let mut remaining: Vec<usize> = (0..pool.len()).collect();
    let mut picked = Vec::with_capacity(want);
    for _ in 0..want {
        let w: Vec<f64> = remaining.iter().map(|&i| weights[i].max(WEIGHT_FLOOR)).collect();
        let j = rng.categorical(&w);
        picked.push(pool[remaining[j]]);
        remaining.swap_remove(j);
    }
    picked
}

/// Shared body of the two weighted policies: record scores, fall back to
/// the uniform code path on degenerate weights, else draw weighted.
fn sample_by_weight(
    ctx: &mut SamplerCtx<'_>,
    pool: &[usize],
    want: usize,
    weights: &[f64],
) -> Vec<usize> {
    for (i, &c) in pool.iter().enumerate() {
        ctx.scores[c] = weights[i];
    }
    if degenerate(weights) {
        uniform_sample(ctx.rng, pool, want)
    } else {
        weighted_sample(ctx.rng, pool, want, weights)
    }
}

fn pick_by_weight(ctx: &mut SamplerCtx<'_>, pool: &[usize], weights: &[f64]) -> usize {
    for (i, &c) in pool.iter().enumerate() {
        ctx.scores[c] = weights[i];
    }
    if degenerate(weights) {
        pool[ctx.rng.usize_below(pool.len())]
    } else {
        let w: Vec<f64> = weights.iter().map(|&x| x.max(WEIGHT_FLOOR)).collect();
        pool[ctx.rng.categorical(&w)]
    }
}

/// `uniform` — the availability-blind default (seed behaviour).
struct Uniform;

impl ClientSampler for Uniform {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn sample(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize], want: usize) -> Vec<usize> {
        uniform_sample(ctx.rng, pool, want)
    }

    fn pick_one(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize]) -> usize {
        pool[ctx.rng.usize_below(pool.len())]
    }

    fn uniform_equivalent(&self) -> bool {
        true
    }
}

/// `stay-prob` — weight by predicted survival through the horizon.
struct StayProb;

impl StayProb {
    fn weights(ctx: &mut SamplerCtx<'_>, pool: &[usize]) -> Vec<f64> {
        pool.iter()
            .map(|&c| ctx.avail.survival_prob(c, ctx.now, ctx.horizon))
            .collect()
    }
}

impl ClientSampler for StayProb {
    fn name(&self) -> &'static str {
        "stay-prob"
    }

    fn sample(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize], want: usize) -> Vec<usize> {
        let w = Self::weights(ctx, pool);
        sample_by_weight(ctx, pool, want, &w)
    }

    fn pick_one(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize]) -> usize {
        let w = Self::weights(ctx, pool);
        pick_by_weight(ctx, pool, &w)
    }
}

/// `drop-aware` — weight by the smoothed posterior survival rate from the
/// observed per-client drop ledger: `(delivered + 1) / (delivered +
/// churned + 1)`. Exactly 1.0 for every client until someone actually
/// churns out (the pseudo-count sits on the survival side), so drop-free
/// runs stay on the uniform path.
struct DropAware;

impl DropAware {
    fn weights(ctx: &SamplerCtx<'_>, pool: &[usize]) -> Vec<f64> {
        pool.iter()
            .map(|&c| {
                let s = ctx.delivered[c] as f64;
                let d = ctx.churned[c] as f64;
                (s + 1.0) / (s + d + 1.0)
            })
            .collect()
    }
}

impl ClientSampler for DropAware {
    fn name(&self) -> &'static str {
        "drop-aware"
    }

    fn sample(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize], want: usize) -> Vec<usize> {
        let w = Self::weights(ctx, pool);
        sample_by_weight(ctx, pool, want, &w)
    }

    fn pick_one(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize]) -> usize {
        let w = Self::weights(ctx, pool);
        pick_by_weight(ctx, pool, &w)
    }
}

/// `fair-cap` — fairness-aware sampling over the drop ledger: cap
/// over-selected clients, explore under-tried ones (UCB-style bonus).
/// A fresh ledger makes every weight exactly 1.0 (posterior 1.0, zero
/// exploration bonus since ln(0 + 1) = 0), so the first draw of every run
/// rides the uniform code path; weights diverge only once attempts do.
struct FairCap;

impl FairCap {
    fn weights(ctx: &SamplerCtx<'_>, pool: &[usize]) -> Vec<f64> {
        let attempts: Vec<u64> = pool
            .iter()
            .map(|&c| ctx.delivered[c] as u64 + ctx.churned[c] as u64)
            .collect();
        let pool_min = attempts.iter().copied().min().unwrap_or(0);
        let total: u64 = attempts.iter().sum();
        // The cap is relative to the pool's least-tried member, so it never
        // deadlocks: at least one candidate is always under it.
        let cap_limit = ctx.fair_cap as u64 * (pool_min + 1);
        pool.iter()
            .zip(&attempts)
            .map(|(&c, &a)| {
                if a >= cap_limit {
                    // Excluded until the pool catches up (the weighted draw
                    // floors this to an epsilon, never a hard zero).
                    0.0
                } else {
                    let s = ctx.delivered[c] as f64;
                    let d = ctx.churned[c] as f64;
                    let posterior = (s + 1.0) / (s + d + 1.0);
                    let bonus = ctx.fair_explore
                        * ((total as f64 + 1.0).ln() / (a as f64 + 1.0)).sqrt();
                    posterior + bonus
                }
            })
            .collect()
    }
}

impl ClientSampler for FairCap {
    fn name(&self) -> &'static str {
        "fair-cap"
    }

    fn sample(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize], want: usize) -> Vec<usize> {
        let w = Self::weights(ctx, pool);
        sample_by_weight(ctx, pool, want, &w)
    }

    fn pick_one(&mut self, ctx: &mut SamplerCtx<'_>, pool: &[usize]) -> usize {
        let w = Self::weights(ctx, pool);
        pick_by_weight(ctx, pool, &w)
    }
}

/// One registered sampling policy (mirrors `registry::StrategyInfo`).
pub struct SamplerInfo {
    /// Canonical display name (what `RunConfig::sampler` carries).
    pub name: &'static str,
    /// Extra accepted spellings (lowercase); the canonical name matches
    /// case-insensitively without being listed.
    pub aliases: &'static [&'static str],
    /// One-liner for `timelyfl samplers`.
    pub summary: &'static str,
    /// Build a fresh policy instance for one run.
    pub build: fn() -> Box<dyn ClientSampler>,
}

/// All registered sampling policies, in listing order.
pub static SAMPLERS: &[SamplerInfo] = &[
    SamplerInfo {
        name: "uniform",
        aliases: &[],
        summary: "availability-blind uniform sampling over the online pool (seed behaviour, default)",
        build: || Box::new(Uniform),
    },
    SamplerInfo {
        name: "stay-prob",
        aliases: &["stay_prob", "stayprob", "survival"],
        summary: "prefer clients predicted to stay online through the sampling horizon (per-process survival_prob)",
        build: || Box::new(StayProb),
    },
    SamplerInfo {
        name: "drop-aware",
        aliases: &["drop_aware", "dropaware", "posterior"],
        summary: "prefer clients with a good observed delivery record (smoothed posterior from the drop ledger)",
        build: || Box::new(DropAware),
    },
    SamplerInfo {
        name: "fair-cap",
        aliases: &["fair_cap", "faircap", "fair"],
        summary: "cap over-selected clients and explore under-tried ones (UCB over the drop ledger; fair_cap / fair_explore)",
        build: || Box::new(FairCap),
    },
];

/// Case-insensitive lookup by canonical name or alias.
pub fn find(name: &str) -> Option<&'static SamplerInfo> {
    let needle = name.to_ascii_lowercase();
    SAMPLERS
        .iter()
        .find(|s| s.name.to_ascii_lowercase() == needle || s.aliases.contains(&needle.as_str()))
}

/// Like [`find`], but an actionable error listing the known policies.
pub fn resolve(name: &str) -> Result<&'static SamplerInfo> {
    find(name)
        .ok_or_else(|| anyhow::anyhow!("unknown sampler {name:?} (known: {})", names().join(", ")))
}

/// Canonical names, in registry order.
pub fn names() -> Vec<&'static str> {
    SAMPLERS.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::availability::{AvailabilityConfig, AvailabilityKind};

    fn always_on_ctx<'a>(
        rng: &'a mut Rng,
        avail: &'a mut AvailabilityModel,
        delivered: &'a [u32],
        churned: &'a [u32],
        scores: &'a mut [f64],
    ) -> SamplerCtx<'a> {
        SamplerCtx {
            now: 0.0,
            horizon: 600.0,
            rng,
            avail,
            delivered,
            churned,
            scores,
            fair_cap: 4,
            fair_explore: 0.5,
        }
    }

    #[test]
    fn registry_names_and_aliases_resolve_uniquely() {
        let mut keys = std::collections::BTreeSet::new();
        for s in SAMPLERS {
            assert!(!s.name.is_empty() && !s.summary.is_empty());
            assert!(keys.insert(s.name.to_ascii_lowercase()), "dup {}", s.name);
            assert_eq!(find(s.name).unwrap().name, s.name);
            assert_eq!(find(&s.name.to_ascii_uppercase()).unwrap().name, s.name);
            assert_eq!((s.build)().name(), s.name, "built policy must match its entry");
            for a in s.aliases {
                assert!(keys.insert(a.to_string()), "alias {a} collides");
                assert_eq!(find(a).unwrap().name, s.name, "alias {a} resolves elsewhere");
            }
        }
        let err = resolve("bogus").unwrap_err().to_string();
        for s in SAMPLERS {
            assert!(err.contains(s.name), "error should list {}", s.name);
        }
        assert_eq!(names()[0], "uniform", "uniform is the default and lists first");
    }

    #[test]
    fn only_uniform_declares_itself_index_sampleable() {
        // The weighted policies score every candidate, so they must keep
        // forcing the lazy core to materialise the pool.
        for s in SAMPLERS {
            assert_eq!(
                (s.build)().uniform_equivalent(),
                s.name == "uniform",
                "{} has the wrong uniform_equivalent flag",
                s.name
            );
        }
    }

    #[test]
    fn degenerate_weights_take_the_uniform_rng_path() {
        // The equivalence contract at unit scale: with all-equal weights,
        // every policy must consume the SAME rng draws and return the SAME
        // cohort as uniform.
        let pool: Vec<usize> = (0..10).collect();
        let (delivered, churned) = (vec![5u32; 10], vec![0u32; 10]);
        for info in SAMPLERS {
            let mut uni_rng = Rng::seed_from(99);
            let mut avail = AvailabilityModel::always_on(10);
            let mut scores = vec![1.0; 10];
            let mut ctx = always_on_ctx(&mut uni_rng, &mut avail, &delivered, &churned, &mut scores);
            let reference = Uniform.sample(&mut ctx, &pool, 4);

            let mut rng = Rng::seed_from(99);
            let mut avail = AvailabilityModel::always_on(10);
            let mut scores = vec![1.0; 10];
            let mut ctx = always_on_ctx(&mut rng, &mut avail, &delivered, &churned, &mut scores);
            let mut policy = (info.build)();
            let got = policy.sample(&mut ctx, &pool, 4);
            assert_eq!(got, reference, "{} diverged on degenerate weights", info.name);
            // The post-draw rng states must also agree (downstream draws
            // are what the byte-identity tests actually observe).
            assert_eq!(rng.next_u64(), uni_rng.next_u64(), "{}: rng desync", info.name);

            let mut uni_rng = Rng::seed_from(7);
            let mut avail = AvailabilityModel::always_on(10);
            let mut scores = vec![1.0; 10];
            let mut ctx = always_on_ctx(&mut uni_rng, &mut avail, &delivered, &churned, &mut scores);
            let ref_pick = Uniform.pick_one(&mut ctx, &pool);
            let mut rng = Rng::seed_from(7);
            let mut avail = AvailabilityModel::always_on(10);
            let mut scores = vec![1.0; 10];
            let mut ctx = always_on_ctx(&mut rng, &mut avail, &delivered, &churned, &mut scores);
            let mut policy = (info.build)();
            assert_eq!(policy.pick_one(&mut ctx, &pool), ref_pick, "{}", info.name);
            assert_eq!(rng.next_u64(), uni_rng.next_u64(), "{}: pick rng desync", info.name);
        }
    }

    #[test]
    fn drop_aware_weights_are_one_until_someone_churns() {
        let delivered = vec![0u32, 3, 100, 7];
        let churned = vec![0u32; 4];
        let mut rng = Rng::seed_from(1);
        let mut avail = AvailabilityModel::always_on(4);
        let mut scores = vec![1.0; 4];
        let ctx = always_on_ctx(&mut rng, &mut avail, &delivered, &churned, &mut scores);
        let w = DropAware::weights(&ctx, &[0, 1, 2, 3]);
        assert!(w.iter().all(|&x| x == 1.0), "drop-free ledger must be degenerate: {w:?}");
        // One churn drop breaks the tie, and more drops weigh heavier.
        let churned = vec![0u32, 1, 0, 4];
        let ctx2 = always_on_ctx(&mut rng, &mut avail, &delivered, &churned, &mut scores);
        let w = DropAware::weights(&ctx2, &[0, 1, 2, 3]);
        assert!(!degenerate(&w));
        assert_eq!(w[0], 1.0);
        assert!((w[1] - 4.0 / 5.0).abs() < 1e-12);
        assert!(w[3] < w[1], "more churn -> lower weight");
    }

    #[test]
    fn fair_cap_fresh_ledger_is_degenerate() {
        // Round one of every run: no attempts anywhere, so the posterior is
        // 1.0 and the exploration bonus is exactly 0 (ln(0 + 1) = 0) — the
        // draw must ride the uniform code path.
        let (delivered, churned) = (vec![0u32; 6], vec![0u32; 6]);
        let mut rng = Rng::seed_from(11);
        let mut avail = AvailabilityModel::always_on(6);
        let mut scores = vec![1.0; 6];
        let ctx = always_on_ctx(&mut rng, &mut avail, &delivered, &churned, &mut scores);
        let w = FairCap::weights(&ctx, &[0, 1, 2, 3, 4, 5]);
        assert!(w.iter().all(|&x| x == 1.0), "fresh ledger must be degenerate: {w:?}");
    }

    #[test]
    fn fair_cap_excludes_overexposed_and_explores_undertried() {
        // Client 0 has been picked far past the cap relative to the
        // pool-minimum (client 2, 0 attempts): cap_limit = 4 * (0+1) = 4,
        // so its 12 attempts zero it out. Client 2 (never tried) gets the
        // biggest exploration bonus; client 3's churn dents its posterior.
        let delivered = vec![12u32, 2, 0, 1];
        let churned = vec![0u32, 0, 0, 2];
        let mut rng = Rng::seed_from(13);
        let mut avail = AvailabilityModel::always_on(4);
        let mut scores = vec![1.0; 4];
        let ctx = always_on_ctx(&mut rng, &mut avail, &delivered, &churned, &mut scores);
        let w = FairCap::weights(&ctx, &[0, 1, 2, 3]);
        assert_eq!(w[0], 0.0, "over-cap client must be excluded");
        assert!(w[2] > w[1], "never-tried client outranks a twice-tried one");
        assert!(w[1] > w[3], "churny client ranks below a clean one at similar attempts");
        assert!(!degenerate(&w));
        // The cap is relative to the pool minimum, so it never deadlocks:
        // with everyone heavily (equally) tried, nobody is excluded.
        let delivered = vec![50u32; 4];
        let churned = vec![0u32; 4];
        let mut rng = Rng::seed_from(13);
        let mut avail = AvailabilityModel::always_on(4);
        let mut scores = vec![1.0; 4];
        let ctx = always_on_ctx(&mut rng, &mut avail, &delivered, &churned, &mut scores);
        let w = FairCap::weights(&ctx, &[0, 1, 2, 3]);
        assert!(w.iter().all(|&x| x > 0.0), "equal saturation must not exclude anyone");
        assert!(degenerate(&w), "equal ledgers stay on the uniform path");
    }

    #[test]
    fn weighted_draw_prefers_heavy_clients() {
        // Deterministic frequency check: weight 9:1 between two clients.
        let mut rng = Rng::seed_from(5);
        let pool = [0usize, 1];
        let weights = [0.9, 0.1];
        let mut first = [0usize; 2];
        for _ in 0..2000 {
            let picked = weighted_sample(&mut rng, &pool, 1, &weights);
            first[picked[0]] += 1;
        }
        let frac = first[0] as f64 / 2000.0;
        assert!((frac - 0.9).abs() < 0.03, "heavy client picked {frac} of draws");
        // Without replacement: both clients appear when want == pool size.
        let both = weighted_sample(&mut rng, &pool, 2, &weights);
        let mut sorted = both.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1]);
    }

    #[test]
    fn stay_prob_records_scores_and_skews_under_churn() {
        // A trace where client 1 drops inside the horizon while client 0
        // stays: stay-prob must weight 0 over 1 and write both scores.
        use crate::availability::TraceEvent;
        let trace = "{\"at\":100.0,\"client\":1,\"online\":false}\n";
        let events: Vec<TraceEvent> = crate::availability::parse_trace(trace).unwrap();
        assert_eq!(events.len(), 1);
        let dir = std::env::temp_dir().join("timelyfl_sampler_test_trace.jsonl");
        std::fs::write(&dir, trace).unwrap();
        let cfg = AvailabilityConfig {
            kind: AvailabilityKind::Trace,
            trace_path: Some(dir.to_string_lossy().into_owned()),
            ..AvailabilityConfig::default()
        };
        let mut avail = AvailabilityModel::build(&cfg, 2, 1).unwrap();
        let mut rng = Rng::seed_from(3);
        let (delivered, churned) = (vec![0u32; 2], vec![0u32; 2]);
        let mut scores = vec![1.0; 2];
        let mut ctx = SamplerCtx {
            now: 0.0,
            horizon: 600.0,
            rng: &mut rng,
            avail: &mut avail,
            delivered: &delivered,
            churned: &churned,
            scores: &mut scores,
            fair_cap: 4,
            fair_explore: 0.5,
        };
        let mut policy = StayProb;
        let mut zero_picked = 0;
        for _ in 0..200 {
            if policy.pick_one(&mut ctx, &[0, 1]) == 0 {
                zero_picked += 1;
            }
        }
        assert!(zero_picked > 190, "doomed client over-picked: {zero_picked}/200");
        assert_eq!(scores[0], 1.0);
        assert_eq!(scores[1], 0.0, "doomed client's score must be recorded as 0");
        let _ = std::fs::remove_file(&dir);
    }
}
