//! SyncFL baseline: classic synchronous FedAvg/FedOpt.
//!
//! Every round samples `n` clients from the currently-available population,
//! all train the FULL model for the fixed number of local epochs, and the
//! server waits for the slowest one — the round time is max over sampled
//! clients of (E * t_cmp + t_com). No staleness, perfect participation
//! within a round, terrible wall-clock: the straggler column of Table 1.
//!
//! Availability churn hits SyncFL twice: a client that goes offline
//! mid-round loses its update (an availability drop — the server still
//! waits out its slot, exactly like the paper's timeout-and-discard
//! behaviour), and an offline client cannot be sampled at all. The round
//! boundary advances the shared `EventQueue` clock, so `events_processed()`
//! is meaningful here too.

use anyhow::Result;

use super::local_time::truth;
use super::trainer::train_client;
use super::{Recorder, Simulation};
use crate::aggregation::{average_delta, Contribution, ServerOpt};
use crate::availability::{AvailabilityModel, SEED_SALT};
use crate::metrics::RunReport;
use crate::simtime::EventQueue;
use crate::util::rng::Rng;

pub fn run(sim: &Simulation) -> Result<RunReport> {
    let cfg = &sim.cfg;
    let rt = &sim.runtime;
    let mut rng = Rng::seed_from(cfg.seed);
    let mut client_rngs: Vec<Rng> = (0..cfg.population)
        .map(|i| rng.fork(i as u64))
        .collect();
    let mut avail = AvailabilityModel::build(
        &cfg.availability,
        cfg.population,
        cfg.seed ^ SEED_SALT,
    )?;

    let mut global = rt.init_params(cfg.init_seed)?;
    let mut server_opt = ServerOpt::new(cfg.server_opt, cfg.server_lr);
    let mut rec = Recorder::new(cfg.population);
    let mut events: EventQueue<()> = EventQueue::new();
    let full = rt
        .meta
        .ratio_exact(1.0)
        .expect("full ratio always compiled");
    let epochs = cfg.fedbuff_local_epochs; // shared "local epochs" setting

    let mut completed_rounds = 0usize;
    while completed_rounds < cfg.rounds {
        let now = events.now();
        let online = avail.online_clients(now);
        if online.is_empty() {
            // Idle until someone comes back online (false = permanently
            // offline population — end the run gracefully).
            if !super::idle_until_transition(&mut avail, &mut events)
                || rec.should_stop(sim, events.now())
            {
                break;
            }
            continue;
        }
        let want = cfg.concurrency.min(online.len());
        let sampled: Vec<usize> = rng
            .sample_without_replacement(online.len(), want)
            .into_iter()
            .map(|i| online[i])
            .collect();

        let mut contributions = Vec::with_capacity(sampled.len());
        let mut participant_ids = Vec::with_capacity(sampled.len());
        let mut dropped = 0usize;
        let mut avail_dropped = 0usize;
        let mut loss_sum = 0.0;
        let mut round_secs = 0.0f64;
        for &c in &sampled {
            let cond = sim.fleet.round_conditions(&mut rng);
            let t = truth(&sim.fleet.devices[c], &cond, cfg.sim_model_bytes);
            let duration = t.round_secs(epochs as f64, 1.0, 1.0);
            // The server waits for the slowest sampled client whether or
            // not it delivers (timeout-and-discard).
            round_secs = round_secs.max(duration);

            // Churn: offline mid-round means the update never uploads.
            if !avail.online_through(c, now, now + duration) {
                avail_dropped += 1;
                continue;
            }
            // Failure injection: the server's cutoff fires without this
            // client's update (its wait time is still paid above).
            if cfg.dropout_prob > 0.0 && rng.f64() < cfg.dropout_prob {
                dropped += 1;
                continue;
            }

            let outcome = train_client(
                rt,
                &sim.dataset,
                c,
                &global,
                full,
                epochs,
                cfg.steps_per_epoch,
                cfg.client_lr,
                &mut client_rngs[c],
            )?;
            loss_sum += outcome.mean_loss;
            participant_ids.push(c);
            contributions.push(Contribution {
                client_id: c,
                update: outcome.update,
                weight: 1.0,
                staleness: 0,
            });
        }

        if !contributions.is_empty() {
            let avg = average_delta(&global, &contributions, false);
            server_opt.apply(&mut global, &avg);
        }
        events.schedule_in(round_secs, ());
        let (clock, ()) = events.pop().expect("round boundary was scheduled");
        let round = completed_rounds;
        completed_rounds += 1;

        let mean_loss = if participant_ids.is_empty() {
            None
        } else {
            Some(loss_sum / participant_ids.len() as f64)
        };
        rec.record_round(round, clock, &participant_ids, dropped, avail_dropped, mean_loss);
        rec.maybe_eval(sim, round, clock, &global)?;
        if rec.should_stop(sim, clock) {
            break;
        }
    }

    let sim_secs = events.now();
    Ok(rec.finish(
        sim,
        sim_secs,
        completed_rounds,
        events.events_processed(),
        &mut avail,
    ))
}
