//! SyncFL baseline: classic synchronous FedAvg/FedOpt, as a round-stepped
//! [`RoundStrategy`].
//!
//! Every round the engine samples `n` clients from the currently-available
//! population; all train the FULL model for the fixed number of local
//! epochs, and the server waits for the slowest one — the round time is max
//! over sampled clients of (E * t_cmp + t_com). No staleness, perfect
//! participation within a round, terrible wall-clock: the straggler column
//! of Table 1.
//!
//! Availability churn hits SyncFL twice: a client that goes offline
//! mid-round loses its update (an availability drop — the server still
//! waits out its slot, exactly like the paper's timeout-and-discard
//! behaviour), and an offline client cannot be sampled at all.

use anyhow::Result;

use super::engine::{RoundCtx, RoundOutcome, RoundStrategy, SimEngine, Strategy};
use super::Simulation;
use crate::aggregation::{Contribution, ServerOpt};
use crate::fleet::HierarchyConfig;
use crate::metrics::events::DropCause;
use crate::model::ParamVec;

pub struct SyncFl {
    global: ParamVec,
    server_opt: ServerOpt,
    /// Aggregation topology (flat reproduces `average_delta` verbatim).
    hierarchy: HierarchyConfig,
}

/// Registry constructor.
pub fn build(sim: &Simulation) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(SyncFl {
        global: sim.runtime.init_params(sim.cfg.init_seed)?,
        server_opt: ServerOpt::new(sim.cfg.server_opt, sim.cfg.server_lr)
            .with_jobs(sim.cfg.agg_jobs),
        hierarchy: sim.cfg.hierarchy.clone(),
    }))
}

impl Strategy for SyncFl {
    fn name(&self) -> &'static str {
        "SyncFL"
    }

    fn run(&mut self, eng: &mut SimEngine) -> Result<()> {
        eng.drive_rounds(self)
    }
}

impl RoundStrategy for SyncFl {
    fn global_params(&self) -> &ParamVec {
        &self.global
    }

    fn run_round(&mut self, ctx: &mut RoundCtx<'_, '_>) -> Result<RoundOutcome> {
        let now = ctx.now;
        let eng = &mut *ctx.eng;
        let sim = eng.sim;
        let cfg = &sim.cfg;
        let rt = &sim.runtime;
        let full = rt
            .meta
            .ratio_exact(1.0)
            .expect("full ratio always compiled");
        let epochs = cfg.fedbuff_local_epochs; // shared "local epochs" setting

        let mut contributions = Vec::with_capacity(ctx.sampled.len());
        let mut participant_ids = Vec::with_capacity(ctx.sampled.len());
        let mut loss_sum = 0.0;
        let mut round_secs = 0.0f64;
        for &c in ctx.sampled {
            let cond = sim.fleet.round_conditions(&mut eng.rng);
            // truth_at folds in the correlated process's
            // degrade-before-drop bandwidth factor (exactly 1.0 elsewhere).
            let t = eng.truth_at(c, &cond, now);
            eng.note_upload_secs(c, t.t_com);
            // Downlink dissemination leg first (0.0 under `network = free`):
            // the slowest client's wait now includes receiving the model.
            let down = eng.price_downlink(t.t_com);
            let duration = down + t.round_secs(epochs as f64, 1.0, 1.0);
            // The server waits for the slowest sampled client whether or
            // not it delivers (timeout-and-discard).
            round_secs = round_secs.max(duration);

            // Churn: offline mid-round means the update never uploads.
            if !eng.avail.online_through(c, now, now + duration) {
                eng.drop_client(c, DropCause::Availability);
                continue;
            }
            // Failure injection: the server's cutoff fires without this
            // client's update (its wait time is still paid above).
            if cfg.dropout_prob > 0.0 && eng.rng.f64() < cfg.dropout_prob {
                eng.drop_client(c, DropCause::Deadline);
                continue;
            }

            // Delivery is settled above, so this training is never
            // speculative — train synchronously through the engine (which
            // also keeps the wasted-work ledger). Under `batch_exec` the
            // plan parks on the engine's queue and executes in the stacked
            // drain below.
            if let Some(outcome) = eng.train_now_or_queue(c, &self.global, full, epochs)? {
                loss_sum += outcome.mean_loss;
                participant_ids.push(c);
                contributions.push(Contribution {
                    client_id: c,
                    update: outcome.update,
                    weight: 1.0,
                    staleness: 0,
                });
            }
        }

        // Batched drain (no-op when nothing queued): enqueue order == the
        // sampled-loop order, so the contribution list matches serial.
        for out in eng.drain_batch(Some(&self.global))? {
            loss_sum += out.mean_loss;
            participant_ids.push(out.client);
            contributions.push(Contribution {
                client_id: out.client,
                update: out.update,
                weight: 1.0,
                staleness: 0,
            });
        }

        // Under `hier_clock = region` the boundary clock is the round's
        // end (`now + round_secs`) and the engine may hold everything at
        // the edges (returning `None`).
        if !contributions.is_empty() {
            eng.weigh(&mut contributions);
            if let Some(avg) = eng.hier_aggregate(
                &self.hierarchy,
                &self.global,
                &contributions,
                false,
                now + round_secs,
            ) {
                self.server_opt.apply(&mut self.global, &avg);
            }
        }
        let mean_train_loss = if participant_ids.is_empty() {
            None
        } else {
            Some(loss_sum / participant_ids.len() as f64)
        };
        Ok(RoundOutcome {
            advance_secs: round_secs,
            participants: participant_ids,
            mean_train_loss,
        })
    }
}
