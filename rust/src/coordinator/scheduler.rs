//! Algorithm 3 — Workload Scheduling, plus the aggregation-interval rule
//! (Alg. 1 line 7: T_k = k-th smallest estimated unit total time).
//!
//! Fast clients (unit total <= T_k) are assigned extra local epochs to use
//! their idle time; slow clients get a partial ratio alpha < 1 so at least
//! one epoch (plus the shrunken upload) fits in the interval.

use super::local_time::TimeEstimate;
use crate::util::stats::kth_smallest;

/// The per-client workload for one round (Alg. 3 outputs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Workload {
    /// Local epochs E_c (>= 1).
    pub epochs: usize,
    /// Partial-training ratio alpha_c in (0, 1].
    pub alpha: f64,
    /// Report deadline t_rpt,c = T_k - t_com * alpha (wall time into the
    /// round by which compute must end so the upload still lands in T_k).
    pub t_rpt: f64,
}

/// Alg. 1 line 7: the aggregation interval for this round.
pub fn aggregation_interval(estimated_totals: &[f64], k: usize) -> f64 {
    kth_smallest(estimated_totals, k)
}

/// Alg. 3 body for one client.
pub fn schedule(t_k: f64, est: &TimeEstimate, max_epochs: usize) -> Workload {
    // line 2: E_c = max(floor((T_k - t_com) / t_cmp), 1)
    let raw_epochs = ((t_k - est.t_com) / est.t_cmp).floor();
    let epochs = if raw_epochs.is_finite() && raw_epochs >= 1.0 {
        (raw_epochs as usize).min(max_epochs)
    } else {
        1
    };
    // line 3: alpha_c = min(T_k / (t_com + t_cmp), 1)
    let alpha = (t_k / (est.t_com + est.t_cmp)).min(1.0);
    // line 4: t_rpt,c = T_k - t_com * alpha
    let t_rpt = t_k - est.t_com * alpha;
    Workload {
        epochs,
        alpha,
        t_rpt,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(t_cmp: f64, t_com: f64) -> TimeEstimate {
        TimeEstimate { t_cmp, t_com }
    }

    #[test]
    fn interval_is_kth_smallest() {
        let totals = [30.0, 10.0, 20.0, 40.0];
        assert_eq!(aggregation_interval(&totals, 2), 20.0);
        assert_eq!(aggregation_interval(&totals, 4), 40.0);
    }

    #[test]
    fn fast_client_gets_more_epochs_full_model() {
        // unit total 12s, interval 50s -> E = floor((50-2)/10) = 4, alpha 1
        let w = schedule(50.0, &est(10.0, 2.0), 100);
        assert_eq!(w.epochs, 4);
        assert_eq!(w.alpha, 1.0);
        assert!((w.t_rpt - 48.0).abs() < 1e-12);
    }

    #[test]
    fn epochs_capped() {
        let w = schedule(1000.0, &est(1.0, 0.0), 5);
        assert_eq!(w.epochs, 5);
    }

    #[test]
    fn slow_client_gets_partial_ratio() {
        // unit total 100s, interval 50s -> E = 1, alpha = 0.5
        let w = schedule(50.0, &est(80.0, 20.0), 4);
        assert_eq!(w.epochs, 1);
        assert!((w.alpha - 0.5).abs() < 1e-12);
        // t_rpt = 50 - 20 * 0.5 = 40
        assert!((w.t_rpt - 40.0).abs() < 1e-12);
    }

    #[test]
    fn client_exactly_at_interval_trains_full() {
        let w = schedule(100.0, &est(80.0, 20.0), 4);
        assert_eq!(w.epochs, 1);
        assert_eq!(w.alpha, 1.0);
    }

    #[test]
    fn partial_round_fits_interval_by_construction() {
        // With exact estimates, the scheduled workload's predicted time
        // fits in T_k: alpha * (t_cmp + t_com) <= T_k for slow clients,
        // E * t_cmp + t_com <= T_k for fast clients.
        for (t_cmp, t_com, t_k) in [
            (80.0, 20.0, 50.0),
            (10.0, 2.0, 50.0),
            (200.0, 300.0, 100.0),
            (5.0, 1.0, 6.0),
        ] {
            let e = est(t_cmp, t_com);
            let w = schedule(t_k, &e, 1000);
            let predicted = if w.alpha < 1.0 {
                // one epoch at ratio alpha, upload scaled by alpha
                e.t_cmp * w.alpha + e.t_com * w.alpha
            } else {
                e.t_cmp * w.epochs as f64 + e.t_com
            };
            assert!(
                predicted <= t_k + 1e-9,
                "cmp {t_cmp} com {t_com} tk {t_k}: predicted {predicted}"
            );
        }
    }

    #[test]
    fn degenerate_inputs_still_give_valid_workload() {
        // zero-ish compute time must not panic or yield epochs = 0
        let w = schedule(10.0, &est(1e-12, 20.0), 8);
        assert!(w.epochs >= 1);
        assert!(w.alpha > 0.0 && w.alpha <= 1.0);
    }
}
