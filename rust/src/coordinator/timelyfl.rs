//! TimelyFL — Algorithm 1, as a round-stepped [`RoundStrategy`].
//!
//! Per communication round (the engine samples the cohort and owns the
//! clock; this module is steps 2-6):
//!
//!   1. (engine) sample `n` clients uniformly from the CURRENTLY AVAILABLE
//!      population (training concurrency);
//!   2. every sampled client runs Local Time Update (Alg. 2) — a one-batch
//!      probe extrapolated to unit epoch + upload times;
//!   3. the server sets the aggregation interval T_k = k-th smallest
//!      estimated unit total time;
//!   4. Workload Scheduling (Alg. 3) assigns each client (E_c, alpha_c,
//!      t_rpt,c); alpha is rounded DOWN to the nearest AOT-compiled partial
//!      ratio so the client still meets its deadline;
//!   5. clients train for real; their *actual* round time (true unit times,
//!      scheduled workload) decides whether the upload lands within
//!      T_k (1 + grace) — estimation error can still cause misses. A client
//!      whose availability process takes it OFFLINE inside its own round
//!      window loses the update (counted as an availability drop, not a
//!      deadline miss);
//!   6. all landed updates aggregate (no staleness — every update is based
//!      on this round's model); the engine advances the clock by T_k.
//!
//! `cfg.adaptive = false` reproduces the Fig. 7 ablation: each client's
//! workload is frozen the first time it is scheduled and never re-adapted,
//! and T_k stays at its round-0 value.

use anyhow::Result;

use super::engine::{RoundCtx, RoundOutcome, RoundStrategy, SimEngine, Strategy};
use super::local_time::local_time_update;
use super::scheduler::{aggregation_interval, schedule, Workload};
use super::Simulation;
use crate::aggregation::{Contribution, ServerOpt};
use crate::fleet::HierarchyConfig;
use crate::metrics::events::DropCause;
use crate::model::ParamVec;

pub struct TimelyFl {
    global: ParamVec,
    server_opt: ServerOpt,
    /// Fig. 7 ablation state: frozen (T_k, workload) per client.
    frozen_tk: Option<f64>,
    frozen_workload: Vec<Option<Workload>>,
    /// Aggregation topology (flat reproduces `average_delta` verbatim).
    hierarchy: HierarchyConfig,
}

/// Registry constructor.
pub fn build(sim: &Simulation) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(TimelyFl {
        global: sim.runtime.init_params(sim.cfg.init_seed)?,
        server_opt: ServerOpt::new(sim.cfg.server_opt, sim.cfg.server_lr)
            .with_jobs(sim.cfg.agg_jobs),
        frozen_tk: None,
        frozen_workload: vec![None; sim.cfg.population],
        hierarchy: sim.cfg.hierarchy.clone(),
    }))
}

impl Strategy for TimelyFl {
    fn name(&self) -> &'static str {
        "TimelyFL"
    }

    fn run(&mut self, eng: &mut SimEngine) -> Result<()> {
        eng.drive_rounds(self)
    }
}

impl RoundStrategy for TimelyFl {
    fn global_params(&self) -> &ParamVec {
        &self.global
    }

    fn run_round(&mut self, ctx: &mut RoundCtx<'_, '_>) -> Result<RoundOutcome> {
        let now = ctx.now;
        let eng = &mut *ctx.eng;
        let sim = eng.sim;
        let cfg = &sim.cfg;
        let rt = &sim.runtime;

        // (2) Local Time Update per sampled client
        let probes: Vec<_> = ctx
            .sampled
            .iter()
            .map(|&c| {
                let cond = sim.fleet.round_conditions(&mut eng.rng);
                let est = local_time_update(
                    &sim.fleet.devices[c],
                    &cond,
                    cfg.sim_model_bytes,
                    cfg.estimate_noise,
                    &mut eng.rng,
                );
                (c, cond, est)
            })
            .collect();

        // (3) aggregation interval
        let totals: Vec<f64> = probes.iter().map(|(_, _, e)| e.t_total()).collect();
        let t_k = if cfg.adaptive {
            aggregation_interval(&totals, cfg.k_target())
        } else {
            *self
                .frozen_tk
                .get_or_insert_with(|| aggregation_interval(&totals, cfg.k_target()))
        };

        // (4)+(5) schedule, train, check availability + deadline
        let mut contributions = Vec::new();
        let mut participant_ids = Vec::new();
        let mut loss_sum = 0.0;

        for (c, cond, est) in &probes {
            let w = if cfg.adaptive {
                // Bandwidth-aware rebalancing (`net_rebalance`): schedule
                // against the *effective* timeline — the probe's estimate
                // with the shared bandwidth signal folded into its comm
                // term — so clients in degrading regions get their E_c /
                // alpha_c shrunk to what the degraded link can still land,
                // instead of being scheduled for the nominal link and
                // missing the deadline. T_k stays computed from the
                // nominal probes (the server's interval should not chase
                // regional weather). Off by default: the nominal estimate
                // reproduces the historical schedule exactly, and reading
                // the signal consumes no RNG draws either way.
                let est = if cfg.network.rebalance {
                    est.degraded(eng.bandwidth_factor(*c, now))
                } else {
                    *est
                };
                schedule(t_k, &est, cfg.max_local_epochs)
            } else {
                *self.frozen_workload[*c]
                    .get_or_insert_with(|| schedule(t_k, est, cfg.max_local_epochs))
            };
            let ratio = rt.meta.quantize_ratio(w.alpha);

            // Actual wall time with TRUE unit times and the scheduled
            // workload. Compute scales with the nominal compiled ratio
            // (paper's linear model); upload with the realized trainable
            // fraction (that is what goes over the wire). The engine
            // applies the correlated process's degrade-before-drop
            // bandwidth factor here — the probe estimated NOMINAL
            // throughput, so a destabilizing region shows up as deadline
            // misses the scheduler could not see coming.
            let t = eng.truth_at(*c, cond, now);
            eng.note_upload_secs(*c, t.t_com);
            // Model dissemination: the round's global version rides the
            // downlink before training starts (full model even for partial
            // training — partial ratios prune what the CLIENT uploads, not
            // what the server sends), so the transfer counts against the
            // deadline and the client's online window. 0.0 under the
            // default `network = free`.
            let down = eng.price_downlink(t.t_com);
            let actual =
                down + t.round_secs(w.epochs as f64, ratio.ratio, ratio.trainable_fraction);
            let landed = actual <= t_k * (1.0 + cfg.deadline_grace);
            // Failure injection: finished but never delivered.
            let lost = cfg.dropout_prob > 0.0 && eng.rng.f64() < cfg.dropout_prob;

            // Churn: the client must stay online for its whole round
            // window or the update is lost with it.
            if !eng.avail.online_through(*c, now, now + actual) {
                eng.drop_client(*c, DropCause::Availability);
                continue;
            }
            if !landed || lost {
                eng.drop_client(*c, DropCause::Deadline);
                continue;
            }

            // Eligibility is settled above, so this training is never
            // speculative — train synchronously through the engine (which
            // also keeps the wasted-work ledger). Under `batch_exec` the
            // plan parks on the engine's queue instead and executes in the
            // stacked drain below.
            if let Some(outcome) = eng.train_now_or_queue(*c, &self.global, ratio, w.epochs)? {
                loss_sum += outcome.mean_loss;
                participant_ids.push(*c);
                contributions.push(Contribution {
                    client_id: *c,
                    update: outcome.update,
                    weight: 1.0,
                    staleness: 0, // by construction: base model is this round's
                });
            }
        }

        // Batched drain (a no-op when nothing queued): outcomes arrive in
        // enqueue order — exactly the eligibility-loop order above — so the
        // contribution list is identical to the serial build.
        for out in eng.drain_batch(Some(&self.global))? {
            loss_sum += out.mean_loss;
            participant_ids.push(out.client);
            contributions.push(Contribution {
                client_id: out.client,
                update: out.update,
                weight: 1.0,
                staleness: 0,
            });
        }

        // (6) aggregate; the engine advances the shared clock by T_k.
        // The configured weigher rescores every contribution first
        // (`weigher = uniform` rewrites the 1.0 already there). Under
        // `hier_clock = region` the boundary clock is `now + t_k` and the
        // engine may hold everything at the edges (returning `None`).
        if !contributions.is_empty() {
            eng.weigh(&mut contributions);
            if let Some(avg) =
                eng.hier_aggregate(&self.hierarchy, &self.global, &contributions, false, now + t_k)
            {
                self.server_opt.apply(&mut self.global, &avg);
            }
        }
        let mean_train_loss = if participant_ids.is_empty() {
            None
        } else {
            Some(loss_sum / participant_ids.len() as f64)
        };
        Ok(RoundOutcome {
            advance_secs: t_k,
            participants: participant_ids,
            mean_train_loss,
        })
    }
}
