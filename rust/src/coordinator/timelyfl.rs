//! TimelyFL — Algorithm 1.
//!
//! Per communication round:
//!   1. sample `n` clients uniformly from the CURRENTLY AVAILABLE
//!      population (training concurrency);
//!   2. every sampled client runs Local Time Update (Alg. 2) — a one-batch
//!      probe extrapolated to unit epoch + upload times;
//!   3. the server sets the aggregation interval T_k = k-th smallest
//!      estimated unit total time;
//!   4. Workload Scheduling (Alg. 3) assigns each client (E_c, alpha_c,
//!      t_rpt,c); alpha is rounded DOWN to the nearest AOT-compiled partial
//!      ratio so the client still meets its deadline;
//!   5. clients train for real; their *actual* round time (true unit times,
//!      scheduled workload) decides whether the upload lands within
//!      T_k (1 + grace) — estimation error can still cause misses. A client
//!      whose availability process takes it OFFLINE inside its own round
//!      window loses the update (counted as an availability drop, not a
//!      deadline miss);
//!   6. all landed updates aggregate (no staleness — every update is based
//!      on this round's model); the round boundary is an `EventQueue` event,
//!      so all three drivers share one clock and `events_processed()` is
//!      meaningful.
//!
//! If the whole population is momentarily offline the server idles until
//! the next availability transition (also an event) instead of burning a
//! round.
//!
//! `cfg.adaptive = false` reproduces the Fig. 7 ablation: each client's
//! workload is frozen the first time it is scheduled and never re-adapted,
//! and T_k stays at its round-0 value.

use anyhow::Result;

use super::local_time::{local_time_update, truth};
use super::scheduler::{aggregation_interval, schedule, Workload};
use super::trainer::train_client;
use super::{Recorder, Simulation};
use crate::aggregation::{average_delta, Contribution, ServerOpt};
use crate::availability::{AvailabilityModel, SEED_SALT};
use crate::metrics::RunReport;
use crate::simtime::EventQueue;
use crate::util::rng::Rng;

pub fn run(sim: &Simulation) -> Result<RunReport> {
    let cfg = &sim.cfg;
    let rt = &sim.runtime;
    let mut rng = Rng::seed_from(cfg.seed);
    let mut client_rngs: Vec<Rng> = (0..cfg.population)
        .map(|i| rng.fork(i as u64))
        .collect();
    let mut avail = AvailabilityModel::build(
        &cfg.availability,
        cfg.population,
        cfg.seed ^ SEED_SALT,
    )?;

    let mut global = rt.init_params(cfg.init_seed)?;
    let mut server_opt = ServerOpt::new(cfg.server_opt, cfg.server_lr);
    let mut rec = Recorder::new(cfg.population);
    // Round boundaries (and idle waits for availability) are events: the
    // clock only moves by popping the queue.
    let mut events: EventQueue<()> = EventQueue::new();

    // Fig. 7 ablation state: frozen (workload, T_k) per client.
    let mut frozen_tk: Option<f64> = None;
    let mut frozen_workload: Vec<Option<Workload>> = vec![None; cfg.population];

    let mut completed_rounds = 0usize;
    while completed_rounds < cfg.rounds {
        let now = events.now();

        // (1) sample n clients from the currently-available population.
        // When everyone is online, `online` is exactly 0..population and
        // this is bit-identical to sampling the whole population.
        let online = avail.online_clients(now);
        if online.is_empty() {
            // Nobody to sample: idle until the next availability
            // transition wakes the server up (false = population
            // permanently offline, e.g. the trace ran out).
            if !super::idle_until_transition(&mut avail, &mut events)
                || rec.should_stop(sim, events.now())
            {
                break;
            }
            continue;
        }
        let want = cfg.concurrency.min(online.len());
        let sampled: Vec<usize> = rng
            .sample_without_replacement(online.len(), want)
            .into_iter()
            .map(|i| online[i])
            .collect();

        // (2) Local Time Update per sampled client
        let probes: Vec<_> = sampled
            .iter()
            .map(|&c| {
                let cond = sim.fleet.round_conditions(&mut rng);
                let est = local_time_update(
                    &sim.fleet.devices[c],
                    &cond,
                    cfg.sim_model_bytes,
                    cfg.estimate_noise,
                    &mut rng,
                );
                (c, cond, est)
            })
            .collect();

        // (3) aggregation interval
        let totals: Vec<f64> = probes.iter().map(|(_, _, e)| e.t_total()).collect();
        let t_k = if cfg.adaptive {
            aggregation_interval(&totals, cfg.k_target())
        } else {
            *frozen_tk.get_or_insert_with(|| aggregation_interval(&totals, cfg.k_target()))
        };

        // (4)+(5) schedule, train, check availability + deadline
        let mut contributions = Vec::new();
        let mut participant_ids = Vec::new();
        let mut dropped = 0usize;
        let mut avail_dropped = 0usize;
        let mut loss_sum = 0.0;

        for (c, cond, est) in &probes {
            let w = if cfg.adaptive {
                schedule(t_k, est, cfg.max_local_epochs)
            } else {
                *frozen_workload[*c]
                    .get_or_insert_with(|| schedule(t_k, est, cfg.max_local_epochs))
            };
            let ratio = rt.meta.quantize_ratio(w.alpha);

            // Actual wall time with TRUE unit times and the scheduled
            // workload. Compute scales with the nominal compiled ratio
            // (paper's linear model); upload with the realized trainable
            // fraction (that is what goes over the wire).
            let t = truth(&sim.fleet.devices[*c], cond, cfg.sim_model_bytes);
            let actual = t.round_secs(w.epochs as f64, ratio.ratio, ratio.trainable_fraction);
            let landed = actual <= t_k * (1.0 + cfg.deadline_grace);
            // Failure injection: finished but never delivered.
            let lost = cfg.dropout_prob > 0.0 && rng.f64() < cfg.dropout_prob;

            // Churn: the client must stay online for its whole round
            // window or the update is lost with it.
            if !avail.online_through(*c, now, now + actual) {
                avail_dropped += 1;
                continue;
            }
            if !landed || lost {
                dropped += 1;
                continue;
            }

            let outcome = train_client(
                rt,
                &sim.dataset,
                *c,
                &global,
                ratio,
                w.epochs,
                cfg.steps_per_epoch,
                cfg.client_lr,
                &mut client_rngs[*c],
            )?;
            loss_sum += outcome.mean_loss;
            participant_ids.push(*c);
            contributions.push(Contribution {
                client_id: *c,
                update: outcome.update,
                weight: 1.0,
                staleness: 0, // by construction: base model is this round's
            });
        }

        // (6) aggregate + advance the shared clock by the interval (the
        // round boundary is an event popped off the queue)
        if !contributions.is_empty() {
            let avg = average_delta(&global, &contributions, false);
            server_opt.apply(&mut global, &avg);
        }
        events.schedule_in(t_k, ());
        let (clock, ()) = events.pop().expect("round boundary was scheduled");
        let round = completed_rounds;
        completed_rounds += 1;

        let mean_loss = if participant_ids.is_empty() {
            None
        } else {
            Some(loss_sum / participant_ids.len() as f64)
        };
        rec.record_round(round, clock, &participant_ids, dropped, avail_dropped, mean_loss);
        rec.maybe_eval(sim, round, clock, &global)?;
        if rec.should_stop(sim, clock) {
            break;
        }
    }

    let sim_secs = events.now();
    Ok(rec.finish(
        sim,
        sim_secs,
        completed_rounds,
        events.events_processed(),
        &mut avail,
    ))
}
