//! Discrete-event simulation engine.
//!
//! Wall-clock "hours" in all reproduced tables are *simulated* time derived
//! from the device model — exactly as the paper's own FedScale-style
//! emulation. The engine is a classic priority-queue event loop shared by
//! all three strategy drivers: FedBuff pops client-finish and
//! availability-transition events (`crate::availability`) from one queue,
//! while the round-stepped strategies (TimelyFL, SyncFL) pop round-boundary
//! and idle-wait events — so `events_processed()` is meaningful in every
//! `RunReport` and the clock only ever moves through the queue.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated seconds since experiment start.
pub type SimTime = f64;

/// An event scheduled at a simulated timestamp. `seq` breaks ties FIFO so
/// identical timestamps pop deterministically.
#[derive(Clone, Debug)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest-first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Event queue + clock.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
        }
    }

    /// Current simulated time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn events_processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at absolute simulated time `at` (>= now).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        debug_assert!(at.is_finite(), "non-finite event time");
        let at = at.max(self.now); // never schedule into the past
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` after a simulated delay.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|s| {
            debug_assert!(s.at >= self.now, "time went backwards");
            self.now = s.at;
            self.processed += 1;
            (s.at, s.event)
        })
    }

    /// Advance the clock manually (round-stepped strategies).
    pub fn advance_to(&mut self, t: SimTime) {
        debug_assert!(t >= self.now, "advance_to into the past");
        self.now = self.now.max(t);
    }
}

/// A bare min-heap agenda over the same deterministic ordering as
/// [`EventQueue`] (earliest-first, FIFO tie-break) but with **no clock and
/// no processed counter**: popping an agenda entry is bookkeeping, not a
/// simulation event. `fleet::LazyAvailability` keeps per-client pending
/// availability transitions here so the round drivers can sweep them
/// without perturbing `events_processed()` in the `RunReport`.
#[derive(Clone, Debug)]
pub struct Agenda<T> {
    heap: BinaryHeap<Scheduled<T>>,
    seq: u64,
}

impl<T> Default for Agenda<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Agenda<T> {
    pub fn new() -> Self {
        Agenda {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn push(&mut self, at: SimTime, item: T) {
        debug_assert!(at.is_finite(), "non-finite agenda time");
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            event: item,
        });
        self.seq += 1;
    }

    /// Timestamp of the earliest pending entry.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }

    /// Pop the earliest entry if its time is <= `t`.
    pub fn pop_until(&mut self, t: SimTime) -> Option<(SimTime, T)> {
        if self.peek_time()? <= t {
            self.heap.pop().map(|s| (s.at, s.event))
        } else {
            None
        }
    }
}

/// Seconds -> hours, for reporting in the paper's units.
pub fn hours(secs: SimTime) -> f64 {
    secs / 3600.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 5.0);
        assert_eq!(q.events_processed(), 3);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, 1);
        q.schedule_at(2.0, 2);
        q.schedule_at(2.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn never_schedules_into_past() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, "x");
        q.pop();
        q.schedule_at(3.0, "late"); // clamped to now = 10.0
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(4.0, ());
        q.pop();
        q.schedule_in(2.5, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 6.5);
    }

    #[test]
    fn advance_to_moves_clock() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.advance_to(100.0);
        assert_eq!(q.now(), 100.0);
    }

    #[test]
    fn agenda_pops_in_order_with_fifo_ties() {
        let mut a = Agenda::new();
        a.push(5.0, "late");
        a.push(1.0, "first");
        a.push(1.0, "second");
        assert_eq!(a.peek_time(), Some(1.0));
        assert_eq!(a.pop_until(1.0), Some((1.0, "first")));
        assert_eq!(a.pop_until(1.0), Some((1.0, "second")));
        assert_eq!(a.pop_until(4.9), None, "5.0 entry not yet due");
        assert_eq!(a.peek_time(), Some(5.0));
        assert_eq!(a.pop_until(5.0), Some((5.0, "late")));
        assert!(a.is_empty());
        assert_eq!(a.pop_until(f64::INFINITY), None);
    }
}
