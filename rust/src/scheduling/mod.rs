//! Scheduling subsystem: pluggable aggregation weighting and calibrated
//! sampling horizons.
//!
//! WHO contributes to each aggregation — and with what weight — drives
//! convergence under heterogeneity. CSMAAFL (Ma et al.) derives client
//! scheduling and per-update aggregation weights *jointly*, and Papaya
//! (Huba et al. 2022) reports that staleness-discounted weighting is what
//! makes buffered-async viable at production scale. This module makes the
//! per-update weight a first-class, pluggable policy with the same
//! registry-over-trait shape as strategies, samplers, and networks:
//!
//! - **uniform** — every delivered update weighs exactly `1.0`, the value
//!   the strategies have always hardcoded. Reads no ledger, consumes no
//!   RNG, and is bit-identical to the pre-subsystem behaviour (locked by
//!   `rust/tests/weigher_equivalence.rs`).
//! - **staleness** — polynomial version-lag discount
//!   `1 / (1 + Δv)^p` (Papaya-style; `p = weigher_staleness_exp`). A
//!   zero-lag update weighs exactly `1.0`, so the round-stepped strategies
//!   (whose contributions are always fresh) are invariant under it. Note
//!   this composes *multiplicatively* with FedBuff's own
//!   `staleness_discount` (which the event strategies apply inside
//!   aggregation): the weigher scores the update, the protocol rule still
//!   applies on top.
//! - **sched-joint** — staleness discount × the drop-ledger availability
//!   posterior `(delivered + 1) / (delivered + churned + 1)` (CSMAAFL's
//!   joint scheduling/weighting idiom on the evidence the engine already
//!   keeps for the `drop-aware` sampler).
//!
//! A weigher only rescales `Contribution::weight` at the aggregation site:
//! it never touches the clock, the cohorts, the RNG streams, or the drop
//! counters, so non-uniform weighers move the *learning curve* and nothing
//! else.
//!
//! The module also owns the scheduling half of the run config: the
//! `fair-cap` sampler's knobs (`fair_cap` / `fair_explore`; the policy
//! itself lives in `coordinator::sampler` with its siblings) and the
//! calibrated sampling horizon (`sampler_horizon = auto` replaces the
//! fixed `sampler_horizon_secs` with an EWMA of the realized aggregation
//! interval — see [`HorizonEstimator`]).

use anyhow::Result;

/// EWMA smoothing factor for the calibrated horizon: one fifth new
/// observation, four fifths history — heavy enough to track a drifting
/// aggregation cadence, smooth enough to ignore one straggler round.
pub const HORIZON_EWMA_ALPHA: f64 = 0.2;

/// The scheduling half of a [`crate::config::RunConfig`].
#[derive(Clone, Debug)]
pub struct SchedulingConfig {
    /// Aggregation-weighting policy, resolved through this module's
    /// registry (`uniform` | `staleness` | `sched-joint`, aliases
    /// accepted; the parser canonicalizes).
    pub weigher: String,
    /// Polynomial exponent `p` of the staleness discount
    /// `1 / (1 + Δv)^p` (read by `staleness` and `sched-joint`).
    pub staleness_exp: f64,
    /// `fair-cap` sampler: a client whose attempt count reaches
    /// `fair_cap × (pool-minimum attempts + 1)` is excluded from selection
    /// until the rest of the pool catches up. Must be >= 1.
    pub fair_cap: usize,
    /// `fair-cap` sampler: UCB exploration coefficient — the weight bonus
    /// `fair_explore * sqrt(ln(total attempts) / (attempts + 1))` that
    /// pulls rarely-tried clients into the cohort.
    pub fair_explore: f64,
    /// `sampler_horizon = auto`: calibrate the sampling horizon online
    /// from the realized aggregation interval instead of the fixed
    /// `sampler_horizon_secs`.
    pub horizon_auto: bool,
}

impl Default for SchedulingConfig {
    fn default() -> Self {
        SchedulingConfig {
            weigher: "uniform".into(),
            staleness_exp: 1.0,
            fair_cap: 4,
            fair_explore: 0.5,
            horizon_auto: false,
        }
    }
}

impl SchedulingConfig {
    pub fn validate(&self) -> Result<()> {
        resolve(&self.weigher)?;
        anyhow::ensure!(
            self.staleness_exp.is_finite() && self.staleness_exp >= 0.0,
            "weigher_staleness_exp must be finite and >= 0 (a negative exponent REWARDS lag)"
        );
        anyhow::ensure!(
            self.fair_cap >= 1,
            "fair_cap must be >= 1 (cap 0 would exclude every client)"
        );
        anyhow::ensure!(
            self.fair_explore.is_finite() && self.fair_explore >= 0.0,
            "fair_explore must be finite and >= 0"
        );
        Ok(())
    }

    /// Build the configured weigher.
    pub fn build(&self) -> Result<Box<dyn AggWeigher>> {
        Ok((resolve(&self.weigher)?.build)(self))
    }
}

/// Scores one delivered update at its aggregation site.
///
/// Inputs are the update's version lag and the client's drop-ledger
/// counters — everything is already settled engine state, so a weigher can
/// never perturb the schedule: no RNG, no clock, no ledger writes. The
/// returned weight replaces `Contribution::weight` (which every strategy
/// initializes to 1.0) *before* the protocol's own staleness rule
/// (`aggregation::staleness_discount`) applies.
pub trait AggWeigher: Send {
    fn name(&self) -> &'static str;

    /// Weight for one update: `staleness` = version lag Δv at delivery
    /// (always 0 for round-stepped strategies), `delivered`/`churned` =
    /// the client's drop-ledger counters. Must be finite and > 0 (the
    /// uniform anchor returns exactly 1.0).
    fn weight(&self, staleness: u64, delivered: u32, churned: u32) -> f64;
}

/// Sample-count weighting — the bit-identity anchor: exactly the 1.0 every
/// strategy has always assigned.
pub struct UniformWeigher;

impl AggWeigher for UniformWeigher {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn weight(&self, _staleness: u64, _delivered: u32, _churned: u32) -> f64 {
        1.0
    }
}

/// Polynomial staleness discount `1 / (1 + Δv)^p` (Papaya-style).
pub struct StalenessWeigher {
    pub exp: f64,
}

/// The discount itself, exposed for the property tests: exactly 1.0 at
/// zero lag (`powi`/`powf` of 1.0 is 1.0 bit-exactly), strictly
/// decreasing in `staleness` for `p > 0`, and always in (0, 1].
pub fn staleness_poly(staleness: u64, exp: f64) -> f64 {
    1.0 / (1.0 + staleness as f64).powf(exp)
}

impl AggWeigher for StalenessWeigher {
    fn name(&self) -> &'static str {
        "staleness"
    }

    fn weight(&self, staleness: u64, _delivered: u32, _churned: u32) -> f64 {
        staleness_poly(staleness, self.exp)
    }
}

/// The drop-ledger availability posterior — the same smoothed estimate the
/// `drop-aware` sampler ranks by, reused here as an aggregation weight:
/// `(delivered + 1) / (delivered + churned + 1)`, always in (0, 1].
pub fn availability_posterior(delivered: u32, churned: u32) -> f64 {
    (delivered as f64 + 1.0) / (delivered as f64 + churned as f64 + 1.0)
}

/// CSMAAFL-style joint weight: staleness discount × availability
/// posterior. An update from a flaky, lagging client counts least; a
/// fresh update from a reliable client counts (almost) fully.
pub struct SchedJointWeigher {
    pub exp: f64,
}

impl AggWeigher for SchedJointWeigher {
    fn name(&self) -> &'static str {
        "sched-joint"
    }

    fn weight(&self, staleness: u64, delivered: u32, churned: u32) -> f64 {
        staleness_poly(staleness, self.exp) * availability_posterior(delivered, churned)
    }
}

/// One registered aggregation weigher.
pub struct WeigherInfo {
    /// Canonical name (what `SchedulingConfig::weigher` carries after
    /// parsing).
    pub name: &'static str,
    /// Extra accepted spellings (lowercase) for config/CLI lookup; the
    /// canonical name matches case-insensitively without being listed.
    pub aliases: &'static [&'static str],
    /// One-liner for `timelyfl weighers`.
    pub summary: &'static str,
    /// Build a fresh weigher instance for one run.
    pub build: fn(&SchedulingConfig) -> Box<dyn AggWeigher>,
}

/// All registered weighers. `uniform` first: it is the default and the
/// bit-compatibility anchor.
pub static WEIGHERS: &[WeigherInfo] = &[
    WeigherInfo {
        name: "uniform",
        aliases: &["samples", "flat"],
        summary: "every delivered update weighs exactly 1.0 (the historical behaviour; bit-identical default)",
        build: |_| Box::new(UniformWeigher),
    },
    WeigherInfo {
        name: "staleness",
        aliases: &["stale", "poly"],
        summary: "polynomial version-lag discount 1/(1+dv)^p (Papaya-style; p = weigher_staleness_exp)",
        build: |cfg| Box::new(StalenessWeigher { exp: cfg.staleness_exp }),
    },
    WeigherInfo {
        name: "sched-joint",
        aliases: &["sched_joint", "joint", "csma"],
        summary: "staleness discount x drop-ledger availability posterior (CSMAAFL-style joint weighting)",
        build: |cfg| Box::new(SchedJointWeigher { exp: cfg.staleness_exp }),
    },
];

/// Case-insensitive lookup by canonical name or alias.
pub fn find(name: &str) -> Option<&'static WeigherInfo> {
    let needle = name.to_ascii_lowercase();
    WEIGHERS
        .iter()
        .find(|w| w.name.to_ascii_lowercase() == needle || w.aliases.contains(&needle.as_str()))
}

/// Like [`find`], but an actionable error listing the known weighers.
pub fn resolve(name: &str) -> Result<&'static WeigherInfo> {
    find(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown weigher {name:?} (known: {})",
            names().join(", ")
        )
    })
}

/// Canonical names, in registry order.
pub fn names() -> Vec<&'static str> {
    WEIGHERS.iter().map(|w| w.name).collect()
}

/// Online sampling-horizon calibration (`sampler_horizon = auto`).
///
/// The fixed `sampler_horizon_secs` asks "will this client still be online
/// in N seconds?" for a hand-picked N. But the question the samplers are
/// actually asking is "will it survive until the NEXT aggregation" — and
/// the realized aggregation interval varies by strategy (TimelyFL's T_k,
/// FedBuff's buffer-fill time) and by churn. The estimator observes each
/// completed aggregation's clock and keeps an EWMA of the interval; until
/// the first interval completes, callers fall back to the configured
/// fixed horizon. Observation happens inside `SimEngine::complete_round`,
/// which runs identically whether or not anyone reads the estimate — so
/// `auto` off (the default) is byte-identical to the pre-subsystem runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct HorizonEstimator {
    /// Clock of the previous completed aggregation (None before the first).
    last_clock: Option<f64>,
    /// EWMA of the realized aggregation interval, seconds.
    estimate: Option<f64>,
}

impl HorizonEstimator {
    /// Fold in one completed aggregation at simulated time `clock`.
    /// Non-advancing flushes (two aggregations at the same instant) are
    /// ignored rather than collapsing the estimate to zero.
    pub fn observe(&mut self, clock: f64) {
        if let Some(prev) = self.last_clock {
            let interval = clock - prev;
            if interval > 0.0 && interval.is_finite() {
                self.estimate = Some(match self.estimate {
                    None => interval,
                    Some(e) => HORIZON_EWMA_ALPHA * interval + (1.0 - HORIZON_EWMA_ALPHA) * e,
                });
            }
        }
        self.last_clock = Some(clock);
    }

    /// The calibrated horizon, falling back to `fixed` until the first
    /// interval has been observed.
    pub fn horizon(&self, fixed: f64) -> f64 {
        self.estimate.unwrap_or(fixed)
    }
}

/// A drop ledger carried across runs (`--warm-ledger`): the per-client
/// `delivered` / `churned` counters harvested from one run's engine and
/// seeded into the next, so evidence-based policies (`drop-aware`,
/// `fair-cap`, the `sched-joint` weigher) warm-start instead of re-paying
/// for the same churn evidence in every sweep cell. Populations may differ
/// between cells: seeding copies the overlapping prefix (region and ledger
/// assignment are both `client % n`-shaped, so prefixes align).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WarmLedger {
    pub delivered: Vec<u32>,
    pub churned: Vec<u32>,
}

impl WarmLedger {
    pub fn is_empty(&self) -> bool {
        self.delivered.is_empty() && self.churned.is_empty()
    }

    /// Copy this ledger's overlapping prefix onto per-client tables.
    pub fn seed_into(&self, delivered: &mut [u32], churned: &mut [u32]) {
        for (dst, &src) in delivered.iter_mut().zip(&self.delivered) {
            *dst = src;
        }
        for (dst, &src) in churned.iter_mut().zip(&self.churned) {
            *dst = src;
        }
    }

    /// Replace this ledger with a finished run's tables (which already
    /// include whatever this ledger seeded).
    pub fn harvest(&mut self, delivered: &[u32], churned: &[u32]) {
        self.delivered = delivered.to_vec();
        self.churned = churned.to_vec();
    }

    /// Fold one run's *increment* into this ledger: `harvested` is a full
    /// post-run harvest and `base` is the snapshot that run was seeded
    /// from, so the increment per client is `harvested - base` (saturating
    /// — populations may shrink a counter's prefix view, never its value).
    /// This is what lets parallel sweep jobs share a warm ledger
    /// deterministically: every job in a cell seeds from the same `base`,
    /// and the jobs' deltas fold here in a fixed order, so the result is
    /// independent of which job finished first.
    pub fn fold_delta(&mut self, base: &WarmLedger, harvested: &WarmLedger) {
        fn fold(acc: &mut Vec<u32>, base: &[u32], harvested: &[u32]) {
            if acc.len() < harvested.len() {
                acc.resize(harvested.len(), 0);
            }
            for (i, &h) in harvested.iter().enumerate() {
                let b = base.get(i).copied().unwrap_or(0);
                acc[i] = acc[i].saturating_add(h.saturating_sub(b));
            }
        }
        fold(&mut self.delivered, &base.delivered, &harvested.delivered);
        fold(&mut self.churned, &base.churned, &harvested.churned);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- registry properties (the network/sampler registry test suite) --

    #[test]
    fn canonical_names_unique_case_insensitive() {
        let mut seen = std::collections::BTreeSet::new();
        for w in WEIGHERS {
            assert!(
                seen.insert(w.name.to_ascii_lowercase()),
                "duplicate weigher name {}",
                w.name
            );
        }
    }

    #[test]
    fn aliases_resolve_to_their_entry_and_never_collide() {
        for w in WEIGHERS {
            assert_eq!(find(w.name).unwrap().name, w.name);
            assert_eq!(find(&w.name.to_ascii_uppercase()).unwrap().name, w.name);
            for a in w.aliases {
                assert_eq!(find(a).unwrap().name, w.name, "alias {a} resolves elsewhere");
            }
        }
        let mut keys = std::collections::BTreeSet::new();
        for w in WEIGHERS {
            assert!(keys.insert(w.name.to_ascii_lowercase()));
            for a in w.aliases {
                assert!(keys.insert(a.to_string()), "alias {a} collides");
            }
        }
    }

    #[test]
    fn resolve_error_lists_known_weighers() {
        let err = resolve("bogus").unwrap_err().to_string();
        for w in WEIGHERS {
            assert!(err.contains(w.name), "error should list {}", w.name);
        }
        assert!(find("").is_none());
    }

    #[test]
    fn registry_order_starts_with_the_uniform_anchor() {
        assert_eq!(names()[0], "uniform", "uniform must stay the default anchor");
        assert!(names().contains(&"staleness"));
        assert!(names().contains(&"sched-joint"));
    }

    #[test]
    fn default_config_is_the_uniform_anchor_and_validates() {
        let cfg = SchedulingConfig::default();
        assert_eq!(cfg.weigher, "uniform");
        assert!(!cfg.horizon_auto);
        cfg.validate().unwrap();
        let w = cfg.build().unwrap();
        assert_eq!(w.name(), "uniform");
    }

    #[test]
    fn config_validation_rejects_bad_values() {
        let mut cfg = SchedulingConfig::default();
        cfg.weigher = "carrier-pigeon".into();
        assert!(cfg.validate().is_err());
        cfg.weigher = "staleness".into();
        cfg.staleness_exp = -1.0;
        assert!(cfg.validate().is_err(), "negative exponent rewards lag");
        cfg.staleness_exp = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.staleness_exp = 0.0;
        cfg.validate().unwrap();
        cfg.fair_cap = 0;
        assert!(cfg.validate().is_err(), "cap 0 excludes everyone");
        cfg.fair_cap = 1;
        cfg.fair_explore = -0.5;
        assert!(cfg.validate().is_err());
        cfg.fair_explore = 0.0;
        cfg.validate().unwrap();
    }

    // -- weight algebra (the artifact-free properties weigher_equivalence
    //    re-asserts through the registry; kept here at the unit seam) --

    #[test]
    fn uniform_weigher_is_exactly_one_for_all_inputs() {
        let w = UniformWeigher;
        for s in [0u64, 1, 7, 10_000] {
            for (d, c) in [(0u32, 0u32), (5, 0), (0, 5), (1000, 1000)] {
                assert_eq!(w.weight(s, d, c), 1.0, "uniform must be the literal 1.0");
            }
        }
    }

    #[test]
    fn staleness_poly_is_monotone_bounded_and_exact_at_zero_lag() {
        for exp in [0.25, 0.5, 1.0, 2.0] {
            assert_eq!(staleness_poly(0, exp), 1.0, "zero lag must weigh exactly 1.0");
            let mut prev = 1.0;
            for s in 1..50u64 {
                let w = staleness_poly(s, exp);
                assert!(w > 0.0 && w < prev, "discount must strictly decrease (p={exp}, s={s})");
                prev = w;
            }
        }
        // p = 0 disables the discount entirely.
        for s in [0u64, 1, 100] {
            assert_eq!(staleness_poly(s, 0.0), 1.0);
        }
        // Larger exponents discount harder at every positive lag.
        for s in 1..20u64 {
            assert!(staleness_poly(s, 2.0) < staleness_poly(s, 0.5));
        }
    }

    #[test]
    fn availability_posterior_is_bounded_and_monotone() {
        for d in 0..40u32 {
            for c in 0..40u32 {
                let p = availability_posterior(d, c);
                assert!(p > 0.0 && p <= 1.0, "posterior {p} out of (0, 1]");
            }
        }
        assert_eq!(availability_posterior(0, 0), 1.0, "no evidence = benefit of the doubt");
        // More churn lowers it; more deliveries raise it.
        for d in [0u32, 3, 10] {
            for c in 1..20u32 {
                assert!(availability_posterior(d, c) < availability_posterior(d, c - 1));
            }
        }
        for c in [1u32, 5, 20] {
            for d in 1..20u32 {
                assert!(availability_posterior(d, c) > availability_posterior(d - 1, c));
            }
        }
    }

    #[test]
    fn sched_joint_is_the_product_and_never_exceeds_its_factors() {
        let w = SchedJointWeigher { exp: 1.0 };
        for s in [0u64, 1, 5] {
            for (d, c) in [(0u32, 0u32), (4, 2), (0, 9)] {
                let got = w.weight(s, d, c);
                let want = staleness_poly(s, 1.0) * availability_posterior(d, c);
                assert_eq!(got, want);
                assert!(got <= staleness_poly(s, 1.0) && got <= availability_posterior(d, c));
                assert!(got > 0.0);
            }
        }
        // Fresh update, clean ledger: exactly 1.0 — the anchor composes.
        assert_eq!(w.weight(0, 0, 0), 1.0);
    }

    #[test]
    fn registry_weighers_build_and_score_finite_positive() {
        let mut cfg = SchedulingConfig::default();
        cfg.staleness_exp = 1.5;
        for info in WEIGHERS {
            cfg.weigher = info.name.into();
            let w = cfg.build().unwrap();
            assert_eq!(w.name(), info.name);
            for s in [0u64, 3, 17] {
                for (d, c) in [(0u32, 0u32), (7, 3), (0, 50)] {
                    let weight = w.weight(s, d, c);
                    assert!(
                        weight.is_finite() && weight > 0.0 && weight <= 1.0,
                        "{}: weight {weight} out of (0, 1]",
                        info.name
                    );
                }
            }
        }
    }

    // -- horizon calibration --

    #[test]
    fn horizon_estimator_falls_back_until_the_first_interval() {
        let mut h = HorizonEstimator::default();
        assert_eq!(h.horizon(600.0), 600.0);
        h.observe(100.0);
        // One observation is a clock, not yet an interval.
        assert_eq!(h.horizon(600.0), 600.0);
        h.observe(250.0);
        assert_eq!(h.horizon(600.0), 150.0, "first interval becomes the estimate");
    }

    #[test]
    fn horizon_estimator_ewma_tracks_the_interval() {
        let mut h = HorizonEstimator::default();
        h.observe(0.0);
        h.observe(100.0); // estimate = 100
        h.observe(300.0); // interval 200: 0.2*200 + 0.8*100 = 120
        assert!((h.horizon(0.0) - 120.0).abs() < 1e-12);
        // A long steady cadence converges to it.
        let mut clock = 300.0;
        for _ in 0..200 {
            clock += 50.0;
            h.observe(clock);
        }
        assert!((h.horizon(0.0) - 50.0).abs() < 1.0);
    }

    #[test]
    fn horizon_estimator_ignores_non_advancing_flushes() {
        let mut h = HorizonEstimator::default();
        h.observe(10.0);
        h.observe(10.0); // same instant: no interval
        assert_eq!(h.horizon(42.0), 42.0);
        h.observe(30.0);
        assert_eq!(h.horizon(42.0), 20.0);
    }

    // -- warm ledger --

    #[test]
    fn warm_ledger_seeds_the_overlapping_prefix() {
        let mut ledger = WarmLedger::default();
        assert!(ledger.is_empty());
        ledger.harvest(&[3, 1, 4], &[0, 2, 0]);
        // Larger next population: prefix seeded, tail untouched.
        let mut d = vec![0u32; 5];
        let mut c = vec![0u32; 5];
        ledger.seed_into(&mut d, &mut c);
        assert_eq!(d, vec![3, 1, 4, 0, 0]);
        assert_eq!(c, vec![0, 2, 0, 0, 0]);
        // Smaller next population: only what fits.
        let mut d = vec![0u32; 2];
        let mut c = vec![0u32; 2];
        ledger.seed_into(&mut d, &mut c);
        assert_eq!(d, vec![3, 1]);
        assert_eq!(c, vec![0, 2]);
    }

    #[test]
    fn warm_ledger_harvest_replaces_wholesale() {
        let mut ledger = WarmLedger::default();
        ledger.harvest(&[9, 9, 9, 9], &[9, 9, 9, 9]);
        ledger.harvest(&[1, 2], &[3, 4]);
        assert_eq!(ledger.delivered, vec![1, 2]);
        assert_eq!(ledger.churned, vec![3, 4]);
        assert!(!ledger.is_empty());
    }

    #[test]
    fn warm_ledger_fold_delta_adds_increments_in_any_order() {
        // Two jobs seeded from the same base harvest different increments;
        // folding both must equal base + sum of increments regardless of
        // fold order (the parallel-sweep determinism contract).
        let mut base = WarmLedger::default();
        base.harvest(&[2, 2], &[1, 0]);
        let mut job_a = WarmLedger::default();
        job_a.harvest(&[3, 2], &[1, 2]); // +1 delivered[0], +2 churned[1]
        let mut job_b = WarmLedger::default();
        job_b.harvest(&[2, 5, 7], &[4, 0, 1]); // grew the population too

        let mut ab = base.clone();
        ab.fold_delta(&base, &job_a);
        ab.fold_delta(&base, &job_b);
        let mut ba = base.clone();
        ba.fold_delta(&base, &job_b);
        ba.fold_delta(&base, &job_a);
        assert_eq!(ab, ba);
        assert_eq!(ab.delivered, vec![3, 5, 7]);
        assert_eq!(ab.churned, vec![4, 2, 1]);
    }

    #[test]
    fn warm_ledger_fold_delta_saturates_instead_of_underflowing() {
        // A smaller-population run's harvest can sit below the base in the
        // tail the run never saw; the delta clamps at zero.
        let mut base = WarmLedger::default();
        base.harvest(&[5, 5], &[5, 5]);
        let mut small = WarmLedger::default();
        small.harvest(&[6], &[7]);
        let mut acc = base.clone();
        acc.fold_delta(&base, &small);
        assert_eq!(acc.delivered, vec![6, 5]);
        assert_eq!(acc.churned, vec![7, 5]);
    }
}
