//! In-tree micro-benchmark timer (criterion replacement for the offline
//! vendor set): warmup, N timed iterations, robust summary statistics.

use std::time::Instant;

/// Summary of one micro-bench.
#[derive(Clone, Copy, Debug)]
pub struct MicroStats {
    pub iters: usize,
    pub min_ns: f64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl MicroStats {
    fn from_samples(mut ns: Vec<f64>) -> MicroStats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let iters = ns.len();
        let idx = |q: f64| ns[((iters - 1) as f64 * q).round() as usize];
        MicroStats {
            iters,
            min_ns: ns[0],
            mean_ns: ns.iter().sum::<f64>() / iters as f64,
            p50_ns: idx(0.5),
            p95_ns: idx(0.95),
        }
    }

    /// Human-scaled time (ns/us/ms/s).
    pub fn fmt(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} us", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    }

    pub fn row(&self, name: &str) -> Vec<String> {
        vec![
            name.to_string(),
            self.iters.to_string(),
            Self::fmt(self.min_ns),
            Self::fmt(self.p50_ns),
            Self::fmt(self.mean_ns),
            Self::fmt(self.p95_ns),
        ]
    }

    pub const HEADERS: [&'static str; 6] = ["bench", "iters", "min", "p50", "mean", "p95"];
}

/// Time `f` for `iters` iterations after `warmup` untimed ones. The closure
/// must do its own work-holding (return values are dropped); use
/// `std::hint::black_box` inside to defeat DCE.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> MicroStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_nanos() as f64
        })
        .collect();
    MicroStats::from_samples(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering_holds() {
        let s = bench(2, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.p95_ns);
        assert!(s.min_ns > 0.0);
        assert_eq!(s.iters, 50);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(MicroStats::fmt(500.0), "500 ns");
        assert_eq!(MicroStats::fmt(2_500.0), "2.50 us");
        assert_eq!(MicroStats::fmt(3_000_000.0), "3.00 ms");
        assert_eq!(MicroStats::fmt(1.5e9), "1.50 s");
    }

    #[test]
    fn from_samples_percentiles() {
        let s = MicroStats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.min_ns, 1.0);
        // nearest-rank on 100 samples: p50 -> index round(49.5) = 50
        assert_eq!(s.p50_ns, 51.0);
        assert_eq!(s.p95_ns, 95.0);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }
}
