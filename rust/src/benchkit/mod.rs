//! Bench-harness support shared by every `benches/` binary (criterion is
//! not in the offline vendor set, so micro-benching is in-tree too).
//!
//! Each paper table/figure has one bench binary (`cargo bench` runs all,
//! `cargo bench --bench table1_time_to_accuracy` one). They share:
//!
//! - [`Scale`] — `TIMELYFL_BENCH_FAST=1` shrinks round budgets ~4x for
//!   smoke runs; default budgets reproduce the paper's *shape* on this
//!   testbed (absolute numbers differ; see EXPERIMENTS.md).
//!   `TIMELYFL_BENCH_JOBS=J` overrides the cell parallelism of
//!   runner-based benches.
//! - [`Bench`] — one shared PJRT client + manifest across all runs of a
//!   bench (compiling executables once per model, like the coordinator),
//!   plus [`Bench::runner`]/[`Bench::serial_runner`] for the declarative
//!   scenario + grid path every sweep bench now uses
//!   (`crate::experiment`; see `docs/experiments.md`).
//! - [`micro`] — min/mean/p50/p95 micro-timing for the §Perf hot paths.
//! - [`results_dir`]/[`write_result`] — benches drop their tables + CSV
//!   series under `results/` so EXPERIMENTS.md can reference them.

pub mod micro;

use std::path::PathBuf;

use anyhow::Result;
use xla::PjRtClient;

use crate::config::RunConfig;
use crate::coordinator::Simulation;
use crate::experiment::ExperimentRunner;
use crate::metrics::RunReport;
use crate::runtime::Manifest;

/// Round-budget scaling for smoke runs.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub fast: bool,
}

impl Scale {
    pub fn from_env() -> Scale {
        Scale {
            fast: std::env::var("TIMELYFL_BENCH_FAST").is_ok_and(|v| v != "0" && !v.is_empty()),
        }
    }

    /// Shrink a round budget ~4x in fast mode (never below 20).
    pub fn rounds(&self, full: usize) -> usize {
        if self.fast {
            (full / 4).max(20)
        } else {
            full
        }
    }

    /// Shrink an iteration count ~4x in fast mode (never below 10).
    pub fn iters(&self, full: usize) -> usize {
        if self.fast {
            (full / 4).max(10)
        } else {
            full
        }
    }

    /// Worker threads for `ExperimentRunner`-based benches:
    /// `TIMELYFL_BENCH_JOBS` overrides, else available parallelism capped
    /// at 4 (cell runs are PJRT-heavy; oversubscribing the CPU client
    /// beyond that buys nothing). Wall-time-measuring benches pass
    /// `Scale::serial_jobs()` instead so co-running cells cannot skew
    /// their A/B deltas.
    pub fn jobs(&self) -> usize {
        Self::jobs_env().unwrap_or_else(|| {
            std::thread::available_parallelism().map_or(1, |n| n.get().min(4))
        })
    }

    /// Jobs for timing-sensitive benches: serial unless explicitly
    /// overridden via `TIMELYFL_BENCH_JOBS`.
    pub fn serial_jobs(&self) -> usize {
        Self::jobs_env().unwrap_or(1)
    }

    fn jobs_env() -> Option<usize> {
        std::env::var("TIMELYFL_BENCH_JOBS")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&j| j >= 1)
    }
}

/// Shared state for one bench binary: a single PJRT client + manifest so
/// model executables compile once per (bench, model) instead of per run.
pub struct Bench {
    pub client: PjRtClient,
    pub manifest: Manifest,
    pub scale: Scale,
}

impl Bench {
    /// Locate `artifacts/` relative to the workspace root (benches run from
    /// the workspace directory; `TIMELYFL_ARTIFACTS` overrides).
    pub fn artifacts_dir() -> PathBuf {
        std::env::var("TIMELYFL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn new() -> Result<Bench> {
        let manifest = Manifest::load(Self::artifacts_dir())?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Bench {
            client,
            manifest,
            scale: Scale::from_env(),
        })
    }

    /// Build + run one simulation on the shared client.
    pub fn run(&self, cfg: RunConfig) -> Result<RunReport> {
        let sim = Simulation::with_client(cfg, &self.manifest, &self.client)?;
        sim.run()
    }

    /// Build a simulation (callers that need the `Simulation` itself, e.g.
    /// to reach the runtime for micro-benches).
    pub fn simulation(&self, cfg: RunConfig) -> Result<Simulation> {
        Simulation::with_client(cfg, &self.manifest, &self.client)
    }

    /// An [`ExperimentRunner`] over this bench's artifacts at the default
    /// bench parallelism (`Scale::jobs`; `TIMELYFL_BENCH_JOBS` overrides).
    pub fn runner(&self) -> ExperimentRunner {
        ExperimentRunner::new(Self::artifacts_dir()).jobs(self.scale.jobs())
    }

    /// Same, pinned serial (timing-sensitive benches; see
    /// `Scale::serial_jobs`).
    pub fn serial_runner(&self) -> ExperimentRunner {
        ExperimentRunner::new(Self::artifacts_dir()).jobs(self.scale.serial_jobs())
    }
}

/// `results/` directory (created on first use).
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("TIMELYFL_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"));
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Drop a bench output file under `results/` (best-effort; benches must not
/// fail on a read-only checkout).
pub fn write_result(name: &str, content: &str) {
    let path = results_dir().join(name);
    match std::fs::write(&path, content) {
        Ok(()) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("warn: could not write {}: {e}", path.display()),
    }
}

/// Banner printed at the top of every bench binary.
pub fn banner(id: &str, paper: &str) {
    println!("=== {id} — reproduces {paper} ===");
    let scale = Scale::from_env();
    if scale.fast {
        println!("(TIMELYFL_BENCH_FAST set: ~4x reduced budgets — shapes only)");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_full_is_identity() {
        let s = Scale { fast: false };
        assert_eq!(s.rounds(400), 400);
        assert_eq!(s.iters(100), 100);
    }

    #[test]
    fn scale_fast_shrinks_with_floor() {
        let s = Scale { fast: true };
        assert_eq!(s.rounds(400), 100);
        assert_eq!(s.rounds(40), 20);
        assert_eq!(s.iters(8), 10);
    }

    #[test]
    fn results_dir_creates() {
        let d = results_dir();
        assert!(d.exists());
    }
}
