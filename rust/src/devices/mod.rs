//! Device heterogeneity model (DESIGN.md §3 substitutions).
//!
//! The paper emulates real devices by assigning per-client compute times
//! from AI Benchmark [10] and bandwidths from MobiPerf [8]. Neither trace is
//! distributable here, so we sample from log-normal distributions calibrated
//! to the paper's own summary statistics:
//!
//! - compute: slowest / fastest ≈ 13.3x (paper Fig. 8a)
//! - bandwidth: best / worst ≈ 200x (paper Fig. 8b), resampled every round
//!   to emulate intermittent connectivity
//! - per-round availability disturbance `w` drawn from truncated N(1, 0.3)
//!   clipped to [1, 1.3] (paper Eq. 2), multiplying the base compute time.
//!
//! Whether a client is *reachable at all* is a separate axis: the fleet
//! models how fast a client is when it participates, while
//! `crate::availability` models when it is online (churn, diurnal cycles,
//! traces). The two compose in the strategy drivers.

pub mod disturbance;
pub mod fleet;

pub use disturbance::disturbance_coefficient;
pub use fleet::{DeviceProfile, Fleet, FleetConfig, RoundConditions};
