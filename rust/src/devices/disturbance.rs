//! Per-round availability disturbance — paper Eq. 2, implemented exactly:
//!
//! ```text
//! x ~ N(1, 0.3)
//! w = 1    if x <= 1
//!     x    if 1 <= x <= 1.3
//!     1.3  if x >= 1.3
//! ```
//!
//! `w` multiplies the client's base computation time each round, emulating
//! low-power mode / concurrent apps on a mobile device.

use crate::util::rng::Rng;

pub const SIGMA: f64 = 0.3;
pub const W_MIN: f64 = 1.0;
pub const W_MAX: f64 = 1.3;

/// Draw the coefficient `w` for one client-round.
pub fn disturbance_coefficient(rng: &mut Rng) -> f64 {
    let x = rng.normal_with(1.0, SIGMA);
    x.clamp(W_MIN, W_MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded() {
        let mut rng = Rng::seed_from(11);
        for _ in 0..10_000 {
            let w = disturbance_coefficient(&mut rng);
            assert!((W_MIN..=W_MAX).contains(&w));
        }
    }

    #[test]
    fn mass_at_one_matches_eq2() {
        // P(x <= 1) = 0.5 exactly, so about half the draws clip to 1.0.
        let mut rng = Rng::seed_from(12);
        let n = 20_000;
        let ones = (0..n)
            .filter(|_| disturbance_coefficient(&mut rng) == 1.0)
            .count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "frac at w=1: {frac}");
    }

    #[test]
    fn mean_in_expected_band() {
        let mut rng = Rng::seed_from(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| disturbance_coefficient(&mut rng)).sum::<f64>() / n as f64;
        // E[w] ≈ 0.5*1 + truncated-mean part ≈ 1.10 ± a bit.
        assert!(mean > 1.05 && mean < 1.15, "mean {mean}");
    }
}
