//! The simulated device fleet: per-client compute capability (AI-Benchmark
//! analogue, fixed per client) and per-round network bandwidth (MobiPerf
//! analogue, resampled every round).

use crate::util::rng::Rng;

use super::disturbance::disturbance_coefficient;

/// Calibration of the heterogeneity distributions.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Median seconds for ONE local epoch of FULL-model training on the
    /// reference workload (the paper's "base computation time").
    pub median_epoch_secs: f64,
    /// Spread of compute capability: slowest/fastest ratio across the fleet
    /// (paper Fig. 8a reports ~13.3x for AI Benchmark).
    pub compute_spread: f64,
    /// Median uplink bandwidth in bytes/sec.
    pub median_bandwidth: f64,
    /// Spread of bandwidth: best/worst ratio (paper Fig. 8b: ~200x).
    pub bandwidth_spread: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            median_epoch_secs: 60.0,
            compute_spread: 13.3,
            median_bandwidth: 1.0 * 1024.0 * 1024.0, // 1 MiB/s
            bandwidth_spread: 200.0,
        }
    }
}

impl FleetConfig {
    /// Log-normal sigma such that the ~[p1, p99] range of exp(N(0, sigma^2))
    /// spans `spread`x: spread = exp(2 * 2.326 * sigma).
    fn sigma(spread: f64) -> f64 {
        spread.ln() / (2.0 * 2.326)
    }
}

/// Static, per-client capability (the AI-Benchmark assignment).
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub id: usize,
    /// Seconds for one epoch of full-model training, before disturbance.
    pub base_epoch_secs: f64,
}

/// Conditions a client experiences during one communication round.
#[derive(Clone, Copy, Debug)]
pub struct RoundConditions {
    /// Eq. 2 coefficient applied to compute time this round.
    pub disturbance: f64,
    /// Bytes/sec available this round (intermittent connectivity).
    pub bandwidth: f64,
}

impl DeviceProfile {
    /// Seconds of compute for one epoch of training a partial model of the
    /// given ratio, under this round's disturbance. Linear in ratio — the
    /// paper validates this on a Galaxy S20 + MNN (Fig. 9, Appendix A.2.1).
    pub fn compute_secs(&self, cond: &RoundConditions, ratio: f64, epochs: f64) -> f64 {
        self.base_epoch_secs * cond.disturbance * ratio * epochs
    }

    /// Seconds to upload `bytes` under this round's bandwidth.
    pub fn upload_secs(&self, cond: &RoundConditions, bytes: f64) -> f64 {
        bytes / cond.bandwidth
    }
}

/// The whole simulated population.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub config: FleetConfig,
    pub devices: Vec<DeviceProfile>,
    sigma_bw: f64,
}

impl Fleet {
    /// Sample `n` clients' static capabilities. The log-normal draw is
    /// clamped to the configured spread so a single outlier cannot exceed
    /// the paper's reported max/min ratio.
    pub fn generate(n: usize, config: FleetConfig, rng: &mut Rng) -> Fleet {
        let sigma_cmp = FleetConfig::sigma(config.compute_spread);
        let half = config.compute_spread.sqrt();
        let devices = (0..n)
            .map(|id| {
                let factor = rng.lognormal(0.0, sigma_cmp).clamp(1.0 / half, half);
                DeviceProfile {
                    id,
                    base_epoch_secs: config.median_epoch_secs * factor,
                }
            })
            .collect();
        let sigma_bw = FleetConfig::sigma(config.bandwidth_spread);
        Fleet {
            sigma_bw,
            config,
            devices,
        }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Draw one round's conditions for a client (disturbance + bandwidth).
    pub fn round_conditions(&self, rng: &mut Rng) -> RoundConditions {
        let half = self.config.bandwidth_spread.sqrt();
        let factor = rng.lognormal(0.0, self.sigma_bw).clamp(1.0 / half, half);
        RoundConditions {
            disturbance: disturbance_coefficient(rng),
            bandwidth: self.config.median_bandwidth * factor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_respected() {
        let mut rng = Rng::seed_from(21);
        let fleet = Fleet::generate(2000, FleetConfig::default(), &mut rng);
        let times: Vec<f64> = fleet.devices.iter().map(|d| d.base_epoch_secs).collect();
        let max = times.iter().cloned().fold(f64::MIN, f64::max);
        let min = times.iter().cloned().fold(f64::MAX, f64::min);
        let ratio = max / min;
        assert!(
            ratio <= 13.3 + 1e-9,
            "spread {ratio} exceeds configured 13.3"
        );
        assert!(ratio > 5.0, "spread {ratio} suspiciously tight");
    }

    #[test]
    fn bandwidth_spread_respected() {
        let mut rng = Rng::seed_from(22);
        let fleet = Fleet::generate(1, FleetConfig::default(), &mut rng);
        let bws: Vec<f64> = (0..5000)
            .map(|_| fleet.round_conditions(&mut rng).bandwidth)
            .collect();
        let max = bws.iter().cloned().fold(f64::MIN, f64::max);
        let min = bws.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min <= 200.0 + 1e-6);
        assert!(max / min > 20.0);
    }

    #[test]
    fn compute_time_linear_in_ratio_and_epochs() {
        let d = DeviceProfile {
            id: 0,
            base_epoch_secs: 10.0,
        };
        let cond = RoundConditions {
            disturbance: 1.2,
            bandwidth: 1e6,
        };
        let full = d.compute_secs(&cond, 1.0, 1.0);
        assert!((d.compute_secs(&cond, 0.5, 1.0) - 0.5 * full).abs() < 1e-12);
        assert!((d.compute_secs(&cond, 1.0, 3.0) - 3.0 * full).abs() < 1e-12);
        assert!((full - 12.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_by_seed() {
        let f1 = Fleet::generate(50, FleetConfig::default(), &mut Rng::seed_from(7));
        let f2 = Fleet::generate(50, FleetConfig::default(), &mut Rng::seed_from(7));
        for (a, b) in f1.devices.iter().zip(&f2.devices) {
            assert_eq!(a.base_epoch_secs, b.base_epoch_secs);
        }
    }
}
