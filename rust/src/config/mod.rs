//! Run configuration: typed config + presets matching the paper's
//! experimental setups (§4.1, Appendix A.1.3), plus a small `key = value`
//! file/CLI override parser (TOML subset — the offline vendor set has no
//! serde/toml).

pub mod parse;

use crate::aggregation::ServerOptKind;
use crate::availability::AvailabilityConfig;
use crate::devices::FleetConfig;
use crate::fleet::{FleetCore, HierarchyConfig};
use crate::network::NetworkConfig;
use crate::scheduling::SchedulingConfig;

/// Full specification of one simulated FL run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Model-zoo name (must exist in the artifact manifest).
    pub model: String,
    /// FL strategy name, resolved through `coordinator::registry` (any
    /// registered name or alias, case-insensitive; the parser canonicalizes
    /// so `RunReport::strategy` comparisons stay exact).
    pub strategy: String,
    /// Client-sampling policy, resolved through `coordinator::sampler`
    /// (`uniform` | `stay-prob` | `drop-aware`; canonicalized like
    /// `strategy`). `uniform` reproduces the pre-sampler RNG draws
    /// exactly.
    pub sampler: String,
    /// Horizon (simulated seconds) the `stay-prob` policy predicts client
    /// survival over — roughly one aggregation interval.
    pub sampler_horizon_secs: f64,

    /// Total client population.
    pub population: usize,
    /// Training concurrency `n`: clients training simultaneously (paper
    /// Alg. 1 input).
    pub concurrency: usize,
    /// Aggregation participation target `k` (TimelyFL) / aggregation goal
    /// (FedBuff) as a fraction of concurrency. Paper uses 50%.
    pub k_fraction: f64,
    /// Stop after this many global aggregation rounds.
    pub rounds: usize,
    /// ... or when simulated time exceeds this budget (seconds).
    pub sim_time_budget: f64,

    /// Client SGD learning rate.
    pub client_lr: f32,
    /// Server optimizer + learning rate (FedOpt).
    pub server_opt: ServerOptKind,
    pub server_lr: f64,
    /// Minibatches constituting one "local epoch" in simulation.
    pub steps_per_epoch: usize,
    /// Cap on scheduled local epochs E (Alg. 3 line 2 can grow unboundedly
    /// for very fast clients).
    pub max_local_epochs: usize,
    /// FedBuff local epochs (fixed; FedBuff has no workload scheduling).
    pub fedbuff_local_epochs: usize,
    /// Drop FedBuff updates staler than this many versions (None = keep all,
    /// staleness-discounted).
    pub max_staleness: Option<u64>,

    /// TimelyFL adaptive re-scheduling each round (false = Fig. 7 ablation:
    /// schedule frozen after round 0).
    pub adaptive: bool,
    /// Deadline grace factor: client included if actual <= T_k * (1+grace).
    pub deadline_grace: f64,
    /// Relative std-dev of the one-batch time-probe estimation error.
    pub estimate_noise: f64,
    /// Failure injection: probability that a client that finished local
    /// training fails to deliver its update this round (crash / lost
    /// connectivity — the paper's "temporarily disconnected" clients, §1).
    pub dropout_prob: f64,

    /// Dirichlet non-iid alpha.
    pub dirichlet_alpha: f64,
    /// Synthetic dataset seed + difficulty.
    pub data_seed: u64,
    pub template_scale: f32,
    pub lm_noise: f64,

    /// Device fleet calibration.
    pub fleet: FleetConfig,
    /// Client availability / churn process (default: always-on, the seed
    /// behaviour — strictly additive).
    pub availability: AvailabilityConfig,
    /// Simulated full-model bytes for communication time (PAPER-scale model
    /// size, not our stand-in's size — preserves the paper's compute/comm
    /// balance; see DESIGN.md §3).
    pub sim_model_bytes: f64,

    /// Sim-core implementation (`fleet_core = eager | lazy`). `lazy` swaps
    /// the engine's O(n) availability scans for the indexed
    /// `fleet::LazyAvailability` core — byte-identical `RunReport` JSON,
    /// wall-clock independent of idle fleet size (the 10^6-client switch).
    pub fleet_core: FleetCore,
    /// Aggregation topology between clients and the root coordinator
    /// (`hierarchy = flat | tree` + `hier_regions` / `hier_fan_in` /
    /// `hier_forward` / `hier_depth`; the historical `two-tier` spelling
    /// parses as the depth-2 tree). Flat is the historical path. Edge
    /// aggregators can additionally run on their own clocks
    /// (`hier_clock = region` + `hier_flush_secs` / `hier_uplink` /
    /// `hier_up_ratio`): each region holds its partial until a flush
    /// deadline and the edge->root leg prices through the network
    /// registry. The default `hier_clock = shared` is byte-identical to
    /// the pre-clock behaviour.
    pub hierarchy: HierarchyConfig,
    /// Model-dissemination (downlink) pricing + bandwidth-aware workload
    /// rebalancing (`network = free | priced` + `net_down_ratio` /
    /// `net_stale_correction` / `net_rebalance`). `free` is the historical
    /// path, bit-identical to pre-subsystem runs.
    pub network: NetworkConfig,
    /// Scheduling subsystem (`weigher = uniform | staleness | sched-joint`
    /// + `weigher_staleness_exp` / `fair_cap` / `fair_explore` /
    /// `sampler_horizon = auto`). `uniform` with a fixed horizon is the
    /// historical path, bit-identical to pre-subsystem runs.
    pub scheduling: SchedulingConfig,

    /// Escape hatch for A/B-measuring the deferred dispatch path: run a
    /// dispatched client's PJRT training at dispatch time (the historical
    /// behaviour) instead of deferring it to the generation-validated
    /// finish event. The run's *semantics* are bit-identical either way —
    /// same rounds, participants, drops, learning curve, simulated clock —
    /// only the perf accounting differs (`wall_secs`, `real_train_steps`,
    /// `trainings_executed`/`trainings_avoided`): eager burns real
    /// accelerator work on churn-cancelled dispatches, so its
    /// `trainings_avoided` is always 0.
    pub eager_train: bool,

    /// Batched plan execution (`batch_exec=`): coalesce the deferred
    /// `TrainPlan`s that resolve between two aggregation points into
    /// stacked multi-lane PJRT dispatches (`trainer::execute_plans_batched`
    /// over the manifest's `lanes`-wide batched artifacts) instead of one
    /// dispatch per client. Semantically bit-identical to serial execution
    /// — same RunReport JSON, same golden fingerprints — for every strategy
    /// (the per-lane scan body is the single-lane body; locked by
    /// `rust/tests/batched_equivalence.rs`); only the dispatch count and
    /// wall-clock change. Requires an artifact set recorded with batched
    /// variants (older sets fail with a re-record hint). Composes with
    /// `eager_train`, which moves event-strategy execution to dispatch time
    /// and so leaves nothing for the batch queue on that path.
    pub batch_exec: bool,
    /// Worker threads for server-side aggregation (`agg_jobs=`): the flat
    /// `average_delta` fold and the server-optimizer update loops partition
    /// over the TENSOR index with serial per-tensor accumulation order, so
    /// any thread count is bit-identical to `1` (the serial anchor; locked
    /// by `rust/tests/parallel_agg_properties.rs`).
    pub agg_jobs: usize,

    /// Evaluate every this many aggregation rounds.
    pub eval_every: usize,
    /// Held-out eval batches per evaluation.
    pub eval_batches: usize,
    /// Stop early once this target metric is reached (accuracy for
    /// classifiers — higher is better; perplexity for LMs — lower is
    /// better). None = run out the round budget.
    pub target_metric: Option<f64>,

    /// Master seed for everything (fleet, sampling, data order).
    pub seed: u64,
    /// Model-init seed (shared across strategies for paired comparisons).
    pub init_seed: i32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "vision".into(),
            strategy: "TimelyFL".into(),
            sampler: "uniform".into(),
            sampler_horizon_secs: 600.0,
            population: 128,
            concurrency: 32,
            k_fraction: 0.5,
            rounds: 100,
            sim_time_budget: f64::INFINITY,
            client_lr: 0.05,
            server_opt: ServerOptKind::FedAvg,
            server_lr: 1.0,
            steps_per_epoch: 2,
            max_local_epochs: 8,
            fedbuff_local_epochs: 1,
            max_staleness: None,
            adaptive: true,
            deadline_grace: 0.05,
            estimate_noise: 0.05,
            dropout_prob: 0.0,
            dirichlet_alpha: 0.1,
            data_seed: 1234,
            template_scale: 0.12,
            lm_noise: 0.1,
            fleet: FleetConfig::default(),
            availability: AvailabilityConfig::default(),
            sim_model_bytes: 1.09e6, // ResNet-20 f32 ~ 1.09 MB
            fleet_core: FleetCore::Eager,
            hierarchy: HierarchyConfig::default(),
            network: NetworkConfig::default(),
            scheduling: SchedulingConfig::default(),
            eager_train: false,
            batch_exec: false,
            agg_jobs: 1,
            eval_every: 10,
            eval_batches: 4,
            target_metric: None,
            seed: 7,
            init_seed: 0,
        }
    }
}

/// Every paper preset, as `(name, one-line summary)` — the single source
/// the `preset()` constructor, the unknown-preset error, and the
/// `timelyfl presets` subcommand all draw from (the same courtesy the
/// strategy registry gives for unknown strategies).
pub static PRESETS: &[(&str, &str)] = &[
    ("cifar_fedavg", "CIFAR-10 / ResNet-20, FedAvg server (paper §4.1)"),
    ("cifar_fedopt", "CIFAR-10 / ResNet-20, Adam server optimizer"),
    ("speech_fedavg", "Google Speech / VGG11, FedAvg; ~507 MB model, comm-bound stragglers"),
    ("speech_fedopt", "Google Speech / VGG11, Adam server optimizer"),
    ("kws_fedavg", "lightweight KWS (79k params, Table 2), FedAvg"),
    ("kws_fedopt", "lightweight KWS (79k params, Table 2), Adam server optimizer"),
    ("reddit_fedavg", "Reddit / ALBERT next-word prediction, FedAvg"),
    ("reddit_fedopt", "Reddit / ALBERT next-word prediction, Adam server optimizer"),
];

/// Preset names, in table order.
pub fn preset_names() -> Vec<&'static str> {
    PRESETS.iter().map(|(n, _)| *n).collect()
}

impl RunConfig {
    /// Aggregation participation target `k` in absolute clients.
    pub fn k_target(&self) -> usize {
        ((self.concurrency as f64 * self.k_fraction).round() as usize).clamp(1, self.concurrency)
    }

    /// Paper presets (§4.1 / A.1.3), scaled down in rounds/population for a
    /// CPU-only testbed; the scaling factors are recorded in EXPERIMENTS.md.
    pub fn preset(name: &str) -> anyhow::Result<RunConfig> {
        let mut c = RunConfig::default();
        match name {
            // CIFAR-10 / ResNet-20: population 128, concurrency 128 in the
            // paper; we keep the population and reduce concurrency.
            "cifar_fedavg" => {
                c.model = "vision".into();
                c.client_lr = 0.08;
                c.server_opt = ServerOptKind::FedAvg;
                c.fleet.median_epoch_secs = 60.0;
                c.sim_model_bytes = 1.09e6;
            }
            "cifar_fedopt" => {
                c.model = "vision".into();
                c.client_lr = 0.05;
                c.server_opt = ServerOptKind::Adam;
                c.server_lr = 0.003;
                c.fleet.median_epoch_secs = 60.0;
                c.sim_model_bytes = 1.09e6;
            }
            // Google Speech / VGG11: concurrency 20, model ~507 MB =>
            // heavily communication-bound stragglers.
            "speech_fedavg" => {
                c.model = "speech".into();
                c.population = 64;
                c.concurrency = 20;
                c.client_lr = 0.08;
                c.server_opt = ServerOptKind::FedAvg;
                c.fleet.median_epoch_secs = 180.0;
                c.sim_model_bytes = 5.07e8;
                c.fleet.median_bandwidth = 4.0 * 1024.0 * 1024.0;
            }
            "speech_fedopt" => {
                c = RunConfig::preset("speech_fedavg")?;
                c.client_lr = 0.05;
                c.server_opt = ServerOptKind::Adam;
                c.server_lr = 0.003;
            }
            // Lightweight KWS model (Table 2): tiny model, comm cheap.
            "kws_fedavg" => {
                c.model = "kws_lite".into();
                c.population = 106;
                c.concurrency = 26;
                c.client_lr = 0.1;
                c.server_opt = ServerOptKind::FedAvg;
                c.fleet.median_epoch_secs = 20.0;
                c.sim_model_bytes = 3.2e5; // 79k params
            }
            "kws_fedopt" => {
                c = RunConfig::preset("kws_fedavg")?;
                c.client_lr = 0.05;
                c.server_opt = ServerOptKind::Adam;
                c.server_lr = 0.003;
            }
            // Reddit / ALBERT next-word prediction: concurrency 20.
            "reddit_fedavg" => {
                c.model = "text".into();
                c.population = 64;
                c.concurrency = 20;
                c.client_lr = 0.1;
                c.server_opt = ServerOptKind::FedAvg;
                c.fleet.median_epoch_secs = 90.0;
                c.sim_model_bytes = 4.5e7; // ALBERT-base ~45 MB
            }
            "reddit_fedopt" => {
                c = RunConfig::preset("reddit_fedavg")?;
                c.client_lr = 0.05;
                c.server_opt = ServerOptKind::Adam;
                c.server_lr = 0.003;
            }
            other => anyhow::bail!(
                "unknown preset {other:?} (known: {})",
                preset_names().join(", ")
            ),
        }
        Ok(c)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        crate::coordinator::registry::resolve(&self.strategy)?;
        crate::coordinator::sampler::resolve(&self.sampler)?;
        anyhow::ensure!(
            self.sampler_horizon_secs > 0.0 && self.sampler_horizon_secs.is_finite(),
            "sampler_horizon_secs must be positive and finite"
        );
        anyhow::ensure!(self.population > 0, "population must be positive");
        anyhow::ensure!(
            self.concurrency > 0 && self.concurrency <= self.population,
            "concurrency must be in 1..=population"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.k_fraction) && self.k_fraction > 0.0,
            "k_fraction in (0, 1]"
        );
        anyhow::ensure!(self.rounds > 0, "rounds must be positive");
        anyhow::ensure!(self.steps_per_epoch > 0, "steps_per_epoch must be positive");
        anyhow::ensure!(self.max_local_epochs > 0, "max_local_epochs >= 1");
        anyhow::ensure!(self.dirichlet_alpha > 0.0, "dirichlet_alpha > 0");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.dropout_prob),
            "dropout_prob in [0, 1)"
        );
        anyhow::ensure!(self.sim_model_bytes > 0.0, "sim_model_bytes > 0");
        anyhow::ensure!(self.agg_jobs >= 1, "agg_jobs must be >= 1");
        anyhow::ensure!(self.eval_every > 0, "eval_every >= 1");
        self.availability.validate()?;
        self.hierarchy.validate()?;
        self.network.validate()?;
        self.scheduling.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn presets_all_validate() {
        // PRESETS is the single source of truth: every listed name builds
        // and validates, and nothing builds that is not listed.
        assert_eq!(PRESETS.len(), 8);
        for (p, summary) in PRESETS {
            assert!(!summary.is_empty(), "{p}: empty summary");
            let c = RunConfig::preset(p).unwrap();
            c.validate().unwrap_or_else(|e| panic!("{p}: {e}"));
        }
        assert!(RunConfig::preset("bogus").is_err());
    }

    #[test]
    fn unknown_preset_error_lists_known_names() {
        let err = format!("{:#}", RunConfig::preset("bogus").unwrap_err());
        for name in preset_names() {
            assert!(err.contains(name), "error should list {name}: {err}");
        }
    }

    #[test]
    fn k_target_rounds_and_clamps() {
        let mut c = RunConfig::default();
        c.concurrency = 20;
        c.k_fraction = 0.5;
        assert_eq!(c.k_target(), 10);
        c.k_fraction = 0.01;
        assert_eq!(c.k_target(), 1);
        c.k_fraction = 1.0;
        assert_eq!(c.k_target(), 20);
    }

    #[test]
    fn strategy_validated_through_registry() {
        let mut c = RunConfig::default();
        for name in crate::coordinator::registry::names() {
            c.strategy = name.to_string();
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        c.strategy = "x".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn network_validated_through_registry() {
        let mut c = RunConfig::default();
        assert_eq!(c.network.model, "free", "free must stay the default");
        for name in crate::network::names() {
            c.network.model = name.to_string();
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        c.network.model = "x".into();
        assert!(c.validate().is_err());
        c.network.model = "priced".into();
        c.network.down_ratio = -1.0;
        assert!(c.validate().is_err(), "negative down ratio must fail");
    }

    #[test]
    fn weigher_validated_through_registry() {
        let mut c = RunConfig::default();
        assert_eq!(c.scheduling.weigher, "uniform", "uniform must stay the default");
        for name in crate::scheduling::names() {
            c.scheduling.weigher = name.to_string();
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        c.scheduling.weigher = "x".into();
        assert!(c.validate().is_err());
        c.scheduling.weigher = "staleness".into();
        c.scheduling.staleness_exp = -0.5;
        assert!(c.validate().is_err(), "negative exponent must fail");
        c.scheduling.staleness_exp = 1.0;
        c.scheduling.fair_cap = 0;
        assert!(c.validate().is_err(), "fair_cap=0 must fail");
    }

    #[test]
    fn sampler_validated_through_registry() {
        let mut c = RunConfig::default();
        assert_eq!(c.sampler, "uniform", "uniform must stay the default");
        for name in crate::coordinator::sampler::names() {
            c.sampler = name.to_string();
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        c.sampler = "x".into();
        assert!(c.validate().is_err());
        c.sampler = "uniform".into();
        c.sampler_horizon_secs = 0.0;
        assert!(c.validate().is_err(), "zero horizon must fail");
        c.sampler_horizon_secs = f64::INFINITY;
        assert!(c.validate().is_err(), "infinite horizon must fail");
    }
}
