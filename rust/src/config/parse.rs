//! `key = value` config overrides (TOML subset) for files and `--set`
//! CLI flags. Comments (`#`), blank lines, strings with or without quotes,
//! numbers and booleans.

use anyhow::{Context, Result};

use super::RunConfig;
use crate::aggregation::ServerOptKind;
use crate::availability::AvailabilityKind;
use crate::coordinator::{registry, sampler};
use crate::fleet::{ClockMode, FleetCore, ForwardPolicy, Topology};

/// Every key `apply_override` accepts, in match-arm order — the single
/// source for the unknown-key error (same courtesy the preset, strategy
/// and sampler registries give for unknown names) and for `--axis`
/// validation in sweeps. A sync test asserts every listed key actually
/// parses.
pub const KNOWN_KEYS: &[&str] = &[
    "model",
    "strategy",
    "sampler",
    "sampler_horizon_secs",
    "sampler_horizon",
    "population",
    "concurrency",
    "k_fraction",
    "rounds",
    "sim_time_budget",
    "client_lr",
    "server_opt",
    "server_lr",
    "steps_per_epoch",
    "max_local_epochs",
    "fedbuff_local_epochs",
    "max_staleness",
    "adaptive",
    "deadline_grace",
    "estimate_noise",
    "dropout_prob",
    "dirichlet_alpha",
    "data_seed",
    "template_scale",
    "lm_noise",
    "availability",
    "avail_frac",
    "avail_mean_online_secs",
    "avail_mean_offline_secs",
    "avail_dwell_sigma",
    "avail_diurnal_period_secs",
    "avail_diurnal_duty",
    "avail_diurnal_shards",
    "avail_trace_path",
    "avail_regions",
    "avail_region_mtbf_secs",
    "avail_region_outage_secs",
    "avail_degrade_window_secs",
    "avail_degrade_floor",
    "median_epoch_secs",
    "compute_spread",
    "median_bandwidth",
    "bandwidth_spread",
    "sim_model_bytes",
    "fleet_core",
    "hierarchy",
    "hier_regions",
    "hier_fan_in",
    "hier_forward",
    "hier_depth",
    "hier_clock",
    "hier_flush_secs",
    "hier_uplink",
    "hier_up_ratio",
    "network",
    "net_down_ratio",
    "net_stale_correction",
    "net_rebalance",
    "weigher",
    "weigher_staleness_exp",
    "fair_cap",
    "fair_explore",
    "eager_train",
    "batch_exec",
    "agg_jobs",
    "eval_every",
    "eval_batches",
    "target_metric",
    "seed",
    "init_seed",
];

/// Parse one `key = value` line into an override on `cfg`.
pub fn apply_override(cfg: &mut RunConfig, key: &str, value: &str) -> Result<()> {
    let v = value.trim().trim_matches('"');
    match key.trim() {
        "model" => cfg.model = v.to_string(),
        "strategy" => cfg.strategy = registry::resolve(v)?.name.to_string(),
        "sampler" => cfg.sampler = sampler::resolve(v)?.name.to_string(),
        "sampler_horizon_secs" => cfg.sampler_horizon_secs = v.parse()?,
        // Calibrated horizons (one key, two modes): `auto` switches the
        // sampler horizon to the engine's EWMA estimate of the realized
        // aggregation interval; a number pins a fixed horizon (and turns
        // calibration off), subsuming `sampler_horizon_secs`.
        "sampler_horizon" => {
            if v.eq_ignore_ascii_case("auto") {
                cfg.scheduling.horizon_auto = true;
            } else {
                cfg.sampler_horizon_secs = v.parse().with_context(|| {
                    format!("sampler_horizon: expected \"auto\" or seconds, got {v:?}")
                })?;
                cfg.scheduling.horizon_auto = false;
            }
        }
        "population" => cfg.population = v.parse()?,
        "concurrency" => cfg.concurrency = v.parse()?,
        "k_fraction" => cfg.k_fraction = v.parse()?,
        "rounds" => cfg.rounds = v.parse()?,
        "sim_time_budget" => cfg.sim_time_budget = v.parse()?,
        "client_lr" => cfg.client_lr = v.parse()?,
        "server_opt" => cfg.server_opt = ServerOptKind::parse(v)?,
        "server_lr" => cfg.server_lr = v.parse()?,
        "steps_per_epoch" => cfg.steps_per_epoch = v.parse()?,
        "max_local_epochs" => cfg.max_local_epochs = v.parse()?,
        "fedbuff_local_epochs" => cfg.fedbuff_local_epochs = v.parse()?,
        "max_staleness" => {
            cfg.max_staleness = if v.eq_ignore_ascii_case("none") {
                None
            } else {
                Some(v.parse()?)
            }
        }
        "adaptive" => cfg.adaptive = parse_bool(v)?,
        "deadline_grace" => cfg.deadline_grace = v.parse()?,
        "estimate_noise" => cfg.estimate_noise = v.parse()?,
        "dropout_prob" => cfg.dropout_prob = v.parse()?,
        "dirichlet_alpha" => cfg.dirichlet_alpha = v.parse()?,
        "data_seed" => cfg.data_seed = v.parse()?,
        "template_scale" => cfg.template_scale = v.parse()?,
        "lm_noise" => cfg.lm_noise = v.parse()?,
        "availability" => cfg.availability.kind = AvailabilityKind::parse(v)?,
        // Derived sweep axis (paper Figs. 1/5/10 x-axis): target mean online
        // fraction. 1.0 selects the always-on process (bit-compatible with
        // the seed behaviour); below 1.0 it splits the CURRENT Markov cycle
        // (mean_online + mean_offline, default 1.5 h) into online/offline
        // dwells at that ratio, so a config can pin the cycle length first
        // and sweep the fraction with one key.
        "avail_frac" => {
            let f: f64 = v.parse()?;
            anyhow::ensure!(
                f > 0.0 && f <= 1.0,
                "avail_frac must be in (0, 1], got {f}"
            );
            if f >= 1.0 {
                cfg.availability.kind = AvailabilityKind::AlwaysOn;
            } else {
                let cycle =
                    cfg.availability.mean_online_secs + cfg.availability.mean_offline_secs;
                cfg.availability.kind = AvailabilityKind::Markov;
                cfg.availability.mean_online_secs = f * cycle;
                cfg.availability.mean_offline_secs = (1.0 - f) * cycle;
            }
        }
        "avail_mean_online_secs" => cfg.availability.mean_online_secs = v.parse()?,
        "avail_mean_offline_secs" => cfg.availability.mean_offline_secs = v.parse()?,
        "avail_dwell_sigma" => cfg.availability.dwell_sigma = v.parse()?,
        "avail_diurnal_period_secs" => cfg.availability.diurnal_period_secs = v.parse()?,
        "avail_diurnal_duty" => cfg.availability.diurnal_duty = v.parse()?,
        "avail_diurnal_shards" => cfg.availability.diurnal_shards = v.parse()?,
        "avail_trace_path" => {
            cfg.availability.trace_path = if v.eq_ignore_ascii_case("none") {
                None
            } else {
                Some(v.to_string())
            }
        }
        "avail_regions" => cfg.availability.regions = v.parse()?,
        "avail_region_mtbf_secs" => cfg.availability.region_mtbf_secs = v.parse()?,
        "avail_region_outage_secs" => cfg.availability.region_outage_secs = v.parse()?,
        "avail_degrade_window_secs" => cfg.availability.degrade_window_secs = v.parse()?,
        "avail_degrade_floor" => cfg.availability.degrade_floor = v.parse()?,
        "median_epoch_secs" => cfg.fleet.median_epoch_secs = v.parse()?,
        "compute_spread" => cfg.fleet.compute_spread = v.parse()?,
        "median_bandwidth" => cfg.fleet.median_bandwidth = v.parse()?,
        "bandwidth_spread" => cfg.fleet.bandwidth_spread = v.parse()?,
        "sim_model_bytes" => cfg.sim_model_bytes = v.parse()?,
        "fleet_core" => cfg.fleet_core = FleetCore::parse(v)?,
        "hierarchy" => cfg.hierarchy.topology = Topology::parse(v)?,
        "hier_regions" => cfg.hierarchy.regions = v.parse()?,
        "hier_fan_in" => cfg.hierarchy.fan_in = v.parse()?,
        "hier_forward" => cfg.hierarchy.forward = ForwardPolicy::parse(v)?,
        "hier_depth" => cfg.hierarchy.depth = v.parse()?,
        "hier_clock" => cfg.hierarchy.clock = ClockMode::parse(v)?,
        // Per-region flush deadline (one key, two modes, like
        // `sampler_horizon`): `auto` calibrates each region's window from
        // its HorizonEstimator EWMA; a number pins a fixed window (and
        // turns calibration off).
        "hier_flush_secs" => {
            if v.eq_ignore_ascii_case("auto") {
                cfg.hierarchy.flush_auto = true;
            } else {
                cfg.hierarchy.flush_secs = v.parse().with_context(|| {
                    format!("hier_flush_secs: expected \"auto\" or seconds, got {v:?}")
                })?;
                cfg.hierarchy.flush_auto = false;
            }
        }
        // The edge->root leg prices through the same NetworkModel registry
        // as the downlink, so aliases canonicalize identically.
        "hier_uplink" => cfg.hierarchy.uplink = crate::network::resolve(v)?.name.to_string(),
        "hier_up_ratio" => cfg.hierarchy.up_ratio = v.parse()?,
        "network" => cfg.network.model = crate::network::resolve(v)?.name.to_string(),
        "net_down_ratio" => cfg.network.down_ratio = v.parse()?,
        "net_stale_correction" => {
            cfg.network.stale_correction = crate::network::StaleCorrection::parse(v)?
        }
        "net_rebalance" => cfg.network.rebalance = parse_bool(v)?,
        "weigher" => cfg.scheduling.weigher = crate::scheduling::resolve(v)?.name.to_string(),
        "weigher_staleness_exp" => cfg.scheduling.staleness_exp = v.parse()?,
        "fair_cap" => cfg.scheduling.fair_cap = v.parse()?,
        "fair_explore" => cfg.scheduling.fair_explore = v.parse()?,
        "eager_train" => cfg.eager_train = parse_bool(v)?,
        "batch_exec" => cfg.batch_exec = parse_bool(v)?,
        "agg_jobs" => {
            cfg.agg_jobs = v
                .parse()
                .with_context(|| format!("agg_jobs: expected a positive integer, got {v:?}"))?
        }
        "eval_every" => cfg.eval_every = v.parse()?,
        "eval_batches" => cfg.eval_batches = v.parse()?,
        "target_metric" => {
            cfg.target_metric = if v.eq_ignore_ascii_case("none") {
                None
            } else {
                Some(v.parse()?)
            }
        }
        "seed" => cfg.seed = v.parse()?,
        "init_seed" => cfg.init_seed = v.parse()?,
        other => anyhow::bail!(
            "unknown config key {other:?} (known: {})",
            KNOWN_KEYS.join(", ")
        ),
    }
    Ok(())
}

fn parse_bool(v: &str) -> Result<bool> {
    match v.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        other => anyhow::bail!("expected bool, got {other:?}"),
    }
}

/// Parse a whole config file of `key = value` lines on top of `cfg`.
pub fn apply_file(cfg: &mut RunConfig, text: &str) -> Result<()> {
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        apply_override(cfg, k, v).with_context(|| format!("line {}", lineno + 1))?;
    }
    Ok(())
}

/// Parse a `--set key=value` CLI argument.
pub fn apply_cli(cfg: &mut RunConfig, kv: &str) -> Result<()> {
    let (k, v) = kv
        .split_once('=')
        .with_context(|| format!("--set {kv:?}: expected key=value"))?;
    apply_override(cfg, k, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_overrides() {
        let mut cfg = RunConfig::default();
        apply_file(
            &mut cfg,
            "# comment\n\
             strategy = fedbuff\n\
             rounds = 42   # trailing comment\n\
             client_lr = 0.5\n\
             adaptive = false\n\
             max_staleness = 10\n\
             eager_train = true\n",
        )
        .unwrap();
        assert_eq!(cfg.strategy, "FedBuff");
        assert_eq!(cfg.rounds, 42);
        assert_eq!(cfg.client_lr, 0.5);
        assert!(!cfg.adaptive);
        assert_eq!(cfg.max_staleness, Some(10));
        assert!(cfg.eager_train, "eager_train override not applied");
        let mut deferred = RunConfig::default();
        assert!(!deferred.eager_train, "deferred dispatch is the default");
        apply_cli(&mut deferred, "eager_train=no").unwrap();
        assert!(!deferred.eager_train);
    }

    #[test]
    fn hotpath_overrides() {
        let mut cfg = RunConfig::default();
        assert!(!cfg.batch_exec, "serial dispatch is the default");
        assert_eq!(cfg.agg_jobs, 1, "serial aggregation is the default");
        apply_file(&mut cfg, "batch_exec = true\nagg_jobs = 4\n").unwrap();
        assert!(cfg.batch_exec);
        assert_eq!(cfg.agg_jobs, 4);
        cfg.validate().unwrap();
        apply_cli(&mut cfg, "batch_exec=no").unwrap();
        assert!(!cfg.batch_exec);
        // Bad values fail at parse (not silently), bad counts at validate.
        assert!(apply_cli(&mut cfg, "batch_exec=maybe").is_err());
        assert!(apply_cli(&mut cfg, "agg_jobs=x").is_err());
        assert!(apply_cli(&mut cfg, "agg_jobs=-1").is_err());
        apply_cli(&mut cfg, "agg_jobs=0").unwrap();
        assert!(cfg.validate().is_err(), "agg_jobs=0 must be rejected");
    }

    #[test]
    fn availability_overrides() {
        let mut cfg = RunConfig::default();
        apply_file(
            &mut cfg,
            "availability = markov\n\
             avail_mean_online_secs = 1200\n\
             avail_mean_offline_secs = 600\n\
             avail_dwell_sigma = 0.3\n\
             avail_diurnal_period_secs = 7200\n\
             avail_diurnal_duty = 0.4\n\
             avail_diurnal_shards = 8\n\
             avail_trace_path = \"traces/day.jsonl\"\n",
        )
        .unwrap();
        assert_eq!(cfg.availability.kind, AvailabilityKind::Markov);
        assert_eq!(cfg.availability.mean_online_secs, 1200.0);
        assert_eq!(cfg.availability.mean_offline_secs, 600.0);
        assert_eq!(cfg.availability.dwell_sigma, 0.3);
        assert_eq!(cfg.availability.diurnal_period_secs, 7200.0);
        assert_eq!(cfg.availability.diurnal_duty, 0.4);
        assert_eq!(cfg.availability.diurnal_shards, 8);
        assert_eq!(cfg.availability.trace_path.as_deref(), Some("traces/day.jsonl"));
        apply_cli(&mut cfg, "avail_trace_path=none").unwrap();
        assert_eq!(cfg.availability.trace_path, None);
        assert!(apply_cli(&mut cfg, "availability=sometimes").is_err());
    }

    #[test]
    fn avail_frac_splits_the_current_cycle() {
        let mut cfg = RunConfig::default();
        apply_file(
            &mut cfg,
            "avail_mean_online_secs = 1800\n\
             avail_mean_offline_secs = 1800\n\
             avail_frac = 0.8\n",
        )
        .unwrap();
        assert_eq!(cfg.availability.kind, AvailabilityKind::Markov);
        assert!((cfg.availability.mean_online_secs - 2880.0).abs() < 1e-9);
        assert!((cfg.availability.mean_offline_secs - 720.0).abs() < 1e-9);
        // 1.0 restores the always-on seed behaviour.
        apply_cli(&mut cfg, "avail_frac=1.0").unwrap();
        assert_eq!(cfg.availability.kind, AvailabilityKind::AlwaysOn);
        assert!(apply_cli(&mut cfg, "avail_frac=0.0").is_err());
        assert!(apply_cli(&mut cfg, "avail_frac=1.5").is_err());
    }

    #[test]
    fn cli_override() {
        let mut cfg = RunConfig::default();
        apply_cli(&mut cfg, "model=text").unwrap();
        assert_eq!(cfg.model, "text");
        assert!(apply_cli(&mut cfg, "no_equals").is_err());
        assert!(apply_cli(&mut cfg, "bogus_key=1").is_err());
    }

    #[test]
    fn unknown_key_error_lists_known_keys() {
        // The sweep `--axis` / `--set` idiom: a typo'd key gets the full
        // catalogue, like unknown presets and unknown strategies do.
        let mut cfg = RunConfig::default();
        let err = format!("{:#}", apply_cli(&mut cfg, "populaton=64").unwrap_err());
        for key in ["population", "avail_frac", "fleet_core", "hierarchy", "seed"] {
            assert!(err.contains(key), "error should list {key}: {err}");
        }
    }

    #[test]
    fn known_keys_catalogue_stays_in_sync_with_the_match() {
        // Every advertised key must reach a real match arm: applying it may
        // fail on the VALUE, but never as an unknown KEY.
        for key in KNOWN_KEYS {
            let mut cfg = RunConfig::default();
            if let Err(e) = apply_override(&mut cfg, key, "1") {
                let msg = format!("{e:#}");
                assert!(
                    !msg.contains("unknown config key"),
                    "{key} is listed in KNOWN_KEYS but has no match arm: {msg}"
                );
            }
        }
    }

    #[test]
    fn fleet_overrides() {
        let mut cfg = RunConfig::default();
        apply_file(
            &mut cfg,
            "fleet_core = lazy\n\
             hierarchy = two-tier\n\
             hier_regions = 32\n\
             hier_fan_in = 64\n\
             hier_forward = uniform\n",
        )
        .unwrap();
        assert_eq!(cfg.fleet_core, crate::fleet::FleetCore::Lazy);
        // The historical "two-tier" spelling parses as the depth-2 tree.
        assert_eq!(cfg.hierarchy.topology, crate::fleet::Topology::Tree);
        assert_eq!(cfg.hierarchy.depth, 2);
        assert_eq!(cfg.hierarchy.regions, 32);
        assert_eq!(cfg.hierarchy.fan_in, 64);
        assert_eq!(cfg.hierarchy.forward, crate::fleet::ForwardPolicy::Uniform);
        cfg.validate().unwrap();
        apply_cli(&mut cfg, "hierarchy=flat").unwrap();
        assert_eq!(cfg.hierarchy.topology, crate::fleet::Topology::Flat);
        assert!(apply_cli(&mut cfg, "fleet_core=turbo").is_err());
        assert!(apply_cli(&mut cfg, "hierarchy=ring").is_err());
        assert!(apply_cli(&mut cfg, "hier_forward=median").is_err());
    }

    #[test]
    fn region_clock_overrides() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.hierarchy.clock, crate::fleet::ClockMode::Shared);
        apply_file(
            &mut cfg,
            "hierarchy = tree\n\
             hier_depth = 3\n\
             hier_clock = region\n\
             hier_flush_secs = 120\n\
             hier_uplink = priced\n\
             hier_up_ratio = 0.4\n",
        )
        .unwrap();
        assert_eq!(cfg.hierarchy.topology, crate::fleet::Topology::Tree);
        assert_eq!(cfg.hierarchy.depth, 3);
        assert_eq!(cfg.hierarchy.clock, crate::fleet::ClockMode::Region);
        assert_eq!(cfg.hierarchy.flush_secs, 120.0);
        assert!(!cfg.hierarchy.flush_auto);
        assert_eq!(cfg.hierarchy.uplink, "priced");
        assert_eq!(cfg.hierarchy.up_ratio, 0.4);
        cfg.validate().unwrap();
        // `auto` calibrates per-region windows; a number turns it back off.
        apply_cli(&mut cfg, "hier_flush_secs=AUTO").unwrap();
        assert!(cfg.hierarchy.flush_auto);
        apply_cli(&mut cfg, "hier_flush_secs=45").unwrap();
        assert!(!cfg.hierarchy.flush_auto);
        assert_eq!(cfg.hierarchy.flush_secs, 45.0);
        // Uplink aliases canonicalize through the network registry.
        apply_cli(&mut cfg, "hier_uplink=INSTANT").unwrap();
        assert_eq!(cfg.hierarchy.uplink, "free");
        assert!(apply_cli(&mut cfg, "hier_clock=lockstep").is_err());
        assert!(apply_cli(&mut cfg, "hier_uplink=bogus").is_err());
        assert!(apply_cli(&mut cfg, "hier_flush_secs=soonish").is_err());
        // Region clocks demand a tiered topology: validate, not parse,
        // rejects the flat combination.
        apply_cli(&mut cfg, "hierarchy=flat").unwrap();
        assert!(cfg.validate().is_err(), "region clocks need a tiered topology");
    }

    #[test]
    fn sampler_and_correlated_overrides() {
        let mut cfg = RunConfig::default();
        apply_file(
            &mut cfg,
            "sampler = stay-prob\n\
             sampler_horizon_secs = 450\n\
             availability = correlated\n\
             avail_regions = 8\n\
             avail_region_mtbf_secs = 3000\n\
             avail_region_outage_secs = 600\n\
             avail_degrade_window_secs = 240\n\
             avail_degrade_floor = 0.4\n",
        )
        .unwrap();
        assert_eq!(cfg.sampler, "stay-prob");
        assert_eq!(cfg.sampler_horizon_secs, 450.0);
        assert_eq!(cfg.availability.kind, AvailabilityKind::Correlated);
        assert_eq!(cfg.availability.regions, 8);
        assert_eq!(cfg.availability.region_mtbf_secs, 3000.0);
        assert_eq!(cfg.availability.region_outage_secs, 600.0);
        assert_eq!(cfg.availability.degrade_window_secs, 240.0);
        assert_eq!(cfg.availability.degrade_floor, 0.4);
        cfg.validate().unwrap();
        // Aliases canonicalize like strategies do.
        apply_cli(&mut cfg, "sampler=survival").unwrap();
        assert_eq!(cfg.sampler, "stay-prob");
        apply_cli(&mut cfg, "sampler=DROP_AWARE").unwrap();
        assert_eq!(cfg.sampler, "drop-aware");
        apply_cli(&mut cfg, "availability=regional").unwrap();
        assert_eq!(cfg.availability.kind, AvailabilityKind::Correlated);
        let err = apply_cli(&mut cfg, "sampler=bogus").unwrap_err();
        assert!(format!("{err:#}").contains("uniform"), "error lists known samplers");
    }

    #[test]
    fn scheduling_overrides() {
        let mut cfg = RunConfig::default();
        apply_file(
            &mut cfg,
            "weigher = staleness\n\
             weigher_staleness_exp = 2.0\n\
             fair_cap = 3\n\
             fair_explore = 0.25\n\
             sampler_horizon = auto\n",
        )
        .unwrap();
        assert_eq!(cfg.scheduling.weigher, "staleness");
        assert_eq!(cfg.scheduling.staleness_exp, 2.0);
        assert_eq!(cfg.scheduling.fair_cap, 3);
        assert_eq!(cfg.scheduling.fair_explore, 0.25);
        assert!(cfg.scheduling.horizon_auto);
        cfg.validate().unwrap();
        // Aliases canonicalize like strategies, samplers and networks do.
        apply_cli(&mut cfg, "weigher=CSMA").unwrap();
        assert_eq!(cfg.scheduling.weigher, "sched-joint");
        apply_cli(&mut cfg, "weigher=flat").unwrap();
        assert_eq!(cfg.scheduling.weigher, "uniform");
        // A numeric horizon pins the fixed value and turns calibration off.
        apply_cli(&mut cfg, "sampler_horizon=450").unwrap();
        assert_eq!(cfg.sampler_horizon_secs, 450.0);
        assert!(!cfg.scheduling.horizon_auto);
        apply_cli(&mut cfg, "sampler_horizon=AUTO").unwrap();
        assert!(cfg.scheduling.horizon_auto);
        let err = apply_cli(&mut cfg, "weigher=bogus").unwrap_err();
        assert!(format!("{err:#}").contains("uniform"), "error lists known weighers");
        assert!(apply_cli(&mut cfg, "sampler_horizon=soonish").is_err());
        // Bad values fail at validate, not silently.
        apply_cli(&mut cfg, "weigher_staleness_exp=-1").unwrap();
        assert!(cfg.validate().is_err(), "negative exponent must be rejected");
        apply_cli(&mut cfg, "weigher_staleness_exp=1").unwrap();
        apply_cli(&mut cfg, "fair_cap=0").unwrap();
        assert!(cfg.validate().is_err(), "fair_cap=0 must be rejected");
    }

    #[test]
    fn network_overrides() {
        let mut cfg = RunConfig::default();
        apply_file(
            &mut cfg,
            "network = priced\n\
             net_down_ratio = 0.4\n\
             net_stale_correction = delta-replay\n\
             net_rebalance = true\n",
        )
        .unwrap();
        assert_eq!(cfg.network.model, "priced");
        assert_eq!(cfg.network.down_ratio, 0.4);
        assert_eq!(
            cfg.network.stale_correction,
            crate::network::StaleCorrection::DeltaReplay
        );
        assert!(cfg.network.rebalance);
        cfg.validate().unwrap();
        // Aliases canonicalize like strategies and samplers do.
        apply_cli(&mut cfg, "network=downlink").unwrap();
        assert_eq!(cfg.network.model, "priced");
        apply_cli(&mut cfg, "network=INSTANT").unwrap();
        assert_eq!(cfg.network.model, "free");
        apply_cli(&mut cfg, "net_stale_correction=none").unwrap();
        assert_eq!(
            cfg.network.stale_correction,
            crate::network::StaleCorrection::None
        );
        let err = apply_cli(&mut cfg, "network=bogus").unwrap_err();
        assert!(format!("{err:#}").contains("free"), "error lists known models");
        assert!(apply_cli(&mut cfg, "net_stale_correction=rewind").is_err());
        assert!(apply_cli(&mut cfg, "net_rebalance=maybe").is_err());
    }

    #[test]
    fn strategy_aliases_canonicalize() {
        let mut cfg = RunConfig::default();
        apply_cli(&mut cfg, "strategy=sync").unwrap();
        assert_eq!(cfg.strategy, "SyncFL");
        apply_cli(&mut cfg, "strategy=seafl").unwrap();
        assert_eq!(cfg.strategy, "SemiAsync");
        apply_cli(&mut cfg, "strategy=TIMELYFL").unwrap();
        assert_eq!(cfg.strategy, "TimelyFL");
        let err = apply_cli(&mut cfg, "strategy=bogus").unwrap_err();
        assert!(format!("{err:#}").contains("TimelyFL"), "error lists known names");
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let mut cfg = RunConfig::default();
        let err = apply_file(&mut cfg, "rounds = 5\nbad line\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"));
    }
}
