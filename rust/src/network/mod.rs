//! Network subsystem: priced model *distribution* (the downlink leg).
//!
//! The sim has always priced the uplink — a client's update travels at its
//! device bandwidth, degraded by the availability model's
//! `bandwidth_factor` — but model distribution was free and instantaneous,
//! which hides a real bottleneck: "Efficient Federated Learning with Timely
//! Update Dissemination" (Jia et al.) shows downlink dissemination has its
//! own asynchronous dynamics and staleness consequences, and Papaya (Huba
//! et al. 2022) reports that at production scale the communication fabric,
//! not compute, dominates round time.
//!
//! A [`NetworkModel`] prices the server → client transfer of one global
//! model, given the client's current *effective* unit upload time (already
//! bandwidth-degraded — both directions ride the same
//! [`crate::availability::BandwidthSignal`]). Two registered models:
//!
//! - **free** — the default and the historical behaviour: every downlink
//!   is 0.0 seconds. Consumes no RNG draws and touches no counters, so
//!   `network = free` runs are byte-identical to pre-subsystem reports
//!   (locked by `rust/tests/network_equivalence.rs`).
//! - **priced** — the downlink costs `effective_upload_secs * down_ratio`
//!   (asymmetric up/down via `net_down_ratio`; consumer links are usually
//!   downlink-faster, so the default ratio is 0.25). A dispatch's training
//!   starts only after the transfer lands, and if a newer global version
//!   was born mid-transfer the client has *started stale* — what it trains
//!   against is decided by [`StaleCorrection`].
//!
//! The registry mirrors `coordinator::registry` / `coordinator::sampler`:
//! adding a model is three steps (see `docs/architecture.md`).

use std::collections::BTreeMap;
use std::ops::Bound;

use anyhow::Result;

use crate::simtime::SimTime;

/// What a stale-started client trains against (Jia et al. idiom).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StaleCorrection {
    /// Count the stale start but change nothing: the client trained
    /// against the version it was sent, and staleness-aware aggregators
    /// (FedBuff's discounting) see the original base version.
    #[default]
    None,
    /// Update-replay accounting: treat the update as if rebased onto the
    /// newest version that had landed by the client's transfer-arrival
    /// time. The executed plan still ran against the ORIGINAL snapshot —
    /// the correction is applied at the staleness-accounting level (the
    /// rewritten `base_version` feeds FedBuff's cap and discounting), the
    /// same approximation Jia et al.'s delta-replay makes server-side.
    DeltaReplay,
}

impl StaleCorrection {
    pub fn name(self) -> &'static str {
        match self {
            StaleCorrection::None => "none",
            StaleCorrection::DeltaReplay => "delta-replay",
        }
    }

    pub fn parse(s: &str) -> Result<StaleCorrection> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Ok(StaleCorrection::None),
            "delta-replay" | "delta_replay" | "replay" => Ok(StaleCorrection::DeltaReplay),
            other => anyhow::bail!(
                "unknown stale correction {other:?} (known: none, delta-replay)"
            ),
        }
    }
}

/// The network half of a [`crate::config::RunConfig`].
#[derive(Clone, Debug)]
pub struct NetworkConfig {
    /// Dissemination model name, resolved through this module's registry
    /// (`free` | `priced`, aliases accepted; the parser canonicalizes).
    pub model: String,
    /// Downlink duration as a fraction of the effective unit upload time
    /// (only the `priced` model reads it).
    pub down_ratio: f64,
    /// What a stale-started client trains against (priced model only).
    pub stale_correction: StaleCorrection,
    /// Region-aware workload rebalancing: TimelyFL's Alg. 3 schedules
    /// against the *effective* (bandwidth-degraded) timeline instead of the
    /// nominal probe, shrinking E_c / alpha_c for clients in degrading
    /// regions instead of merely watching them miss deadlines. Independent
    /// of the dissemination model (it reads the same bandwidth signal).
    pub rebalance: bool,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            model: "free".into(),
            down_ratio: 0.25,
            stale_correction: StaleCorrection::None,
            rebalance: false,
        }
    }
}

impl NetworkConfig {
    pub fn validate(&self) -> Result<()> {
        resolve(&self.model)?;
        anyhow::ensure!(
            self.down_ratio >= 0.0 && self.down_ratio.is_finite(),
            "net_down_ratio must be finite and >= 0"
        );
        Ok(())
    }

    /// Build the configured dissemination model.
    pub fn build(&self) -> Result<Box<dyn NetworkModel>> {
        Ok((resolve(&self.model)?.build)(self))
    }
}

/// Prices the server → client transfer of one global model.
///
/// `effective_upload_secs` is the client's bandwidth-degraded unit upload
/// time (`TimeTruth::t_com` after the engine divides by the availability
/// model's `bandwidth_factor`), so downlink pricing inherits the
/// degrade-before-drop coupling for free: the returned duration is monotone
/// non-increasing in the bandwidth factor by composition.
pub trait NetworkModel: Send {
    fn name(&self) -> &'static str;

    /// Seconds the global model spends on the wire server → client.
    /// `free` returns exactly 0.0 — callers gate all dissemination
    /// bookkeeping on a strictly positive duration, which is what keeps
    /// the default path byte-identical.
    fn downlink_secs(&self, effective_upload_secs: f64) -> f64;
}

/// The historical behaviour: model distribution is free and instantaneous.
pub struct FreeNetwork;

impl NetworkModel for FreeNetwork {
    fn name(&self) -> &'static str {
        "free"
    }

    fn downlink_secs(&self, _effective_upload_secs: f64) -> f64 {
        0.0
    }
}

/// Downlink costs a configurable fraction of the effective upload time.
pub struct PricedNetwork {
    pub down_ratio: f64,
}

impl NetworkModel for PricedNetwork {
    fn name(&self) -> &'static str {
        "priced"
    }

    fn downlink_secs(&self, effective_upload_secs: f64) -> f64 {
        effective_upload_secs * self.down_ratio
    }
}

/// One registered dissemination model.
pub struct NetworkInfo {
    /// Canonical name (what `NetworkConfig::model` carries after parsing).
    pub name: &'static str,
    /// Extra accepted spellings (lowercase) for config/CLI lookup; the
    /// canonical name matches case-insensitively without being listed.
    pub aliases: &'static [&'static str],
    /// One-liner for `timelyfl networks`.
    pub summary: &'static str,
    /// Build a fresh model instance for one run.
    pub build: fn(&NetworkConfig) -> Box<dyn NetworkModel>,
}

/// All registered models. `free` first: it is the default and the
/// bit-compatibility anchor.
pub static NETWORKS: &[NetworkInfo] = &[
    NetworkInfo {
        name: "free",
        aliases: &["instant"],
        summary: "model distribution is free and instantaneous (the historical behaviour; bit-identical default)",
        build: |_| Box::new(FreeNetwork),
    },
    NetworkInfo {
        name: "priced",
        aliases: &["downlink", "asym"],
        summary: "downlink costs net_down_ratio x the effective upload time; mid-transfer version births are stale starts",
        build: |cfg| Box::new(PricedNetwork { down_ratio: cfg.down_ratio }),
    },
];

/// Case-insensitive lookup by canonical name or alias.
pub fn find(name: &str) -> Option<&'static NetworkInfo> {
    let needle = name.to_ascii_lowercase();
    NETWORKS
        .iter()
        .find(|n| n.name.to_ascii_lowercase() == needle || n.aliases.contains(&needle.as_str()))
}

/// Like [`find`], but an actionable error listing the known models.
pub fn resolve(name: &str) -> Result<&'static NetworkInfo> {
    find(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown network model {name:?} (known: {})",
            names().join(", ")
        )
    })
}

/// Canonical names, in registry order.
pub fn names() -> Vec<&'static str> {
    NETWORKS.iter().map(|n| n.name).collect()
}

/// Stale-start detection: the newest global version strictly newer than
/// `base` that was already born when the client's downlink landed at
/// `arrival` — i.e. the version the server COULD have sent had the
/// transfer started later. `None` means the start was not stale: a free
/// (zero-duration) transfer can never be overtaken, and neither can a
/// transfer during which no newer version was born.
///
/// `born` maps each global version to the first simulated time a dispatch
/// carried it — a lower bound on its true birth (the engine can only
/// observe versions when they are sent), which makes stale detection
/// conservative: a version born between dispatches is seen slightly late.
pub fn overtaken_by(
    down_secs: f64,
    base: u64,
    arrival: SimTime,
    born: &BTreeMap<u64, SimTime>,
) -> Option<u64> {
    if down_secs <= 0.0 {
        return None;
    }
    born.range((Bound::Excluded(base), Bound::Unbounded))
        .filter(|&(_, &b)| b <= arrival)
        .map(|(&v, _)| v)
        .max()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_unique_case_insensitive() {
        let mut seen = std::collections::BTreeSet::new();
        for n in NETWORKS {
            assert!(
                seen.insert(n.name.to_ascii_lowercase()),
                "duplicate network model name {}",
                n.name
            );
        }
    }

    #[test]
    fn aliases_resolve_to_their_entry_and_never_collide() {
        for n in NETWORKS {
            assert_eq!(find(n.name).unwrap().name, n.name);
            assert_eq!(find(&n.name.to_ascii_uppercase()).unwrap().name, n.name);
            for a in n.aliases {
                assert_eq!(find(a).unwrap().name, n.name, "alias {a} resolves elsewhere");
            }
        }
        let mut keys = std::collections::BTreeSet::new();
        for n in NETWORKS {
            assert!(keys.insert(n.name.to_ascii_lowercase()));
            for a in n.aliases {
                assert!(keys.insert(a.to_string()), "alias {a} collides");
            }
        }
    }

    #[test]
    fn resolve_error_lists_known_models() {
        let err = resolve("bogus").unwrap_err().to_string();
        for n in NETWORKS {
            assert!(err.contains(n.name), "error should list {}", n.name);
        }
        assert!(find("").is_none());
    }

    #[test]
    fn registry_order_starts_with_the_free_anchor() {
        assert_eq!(names()[0], "free", "free must stay the default anchor");
        assert!(names().contains(&"priced"));
    }

    #[test]
    fn default_config_is_the_free_anchor_and_validates() {
        let cfg = NetworkConfig::default();
        assert_eq!(cfg.model, "free");
        assert_eq!(cfg.stale_correction, StaleCorrection::None);
        assert!(!cfg.rebalance);
        cfg.validate().unwrap();
        let model = cfg.build().unwrap();
        assert_eq!(model.name(), "free");
        for up in [0.0, 1.0, 3600.0] {
            assert_eq!(model.downlink_secs(up), 0.0, "free is always 0.0");
        }
    }

    #[test]
    fn config_validation_rejects_bad_values() {
        let mut cfg = NetworkConfig::default();
        cfg.model = "carrier-pigeon".into();
        assert!(cfg.validate().is_err());
        cfg.model = "priced".into();
        cfg.down_ratio = -0.1;
        assert!(cfg.validate().is_err());
        cfg.down_ratio = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.down_ratio = 0.0;
        cfg.validate().unwrap();
    }

    #[test]
    fn priced_downlink_scales_with_the_ratio_and_the_degraded_upload() {
        let mut cfg = NetworkConfig::default();
        cfg.model = "priced".into();
        cfg.down_ratio = 0.5;
        let model = cfg.build().unwrap();
        assert_eq!(model.name(), "priced");
        assert_eq!(model.downlink_secs(10.0), 5.0);
        // Effective upload = nominal / bandwidth_factor, so a degrading
        // region monotonically stretches the downlink too.
        let nominal = 8.0;
        let mut prev = f64::INFINITY;
        for i in 1..=10 {
            let factor = i as f64 / 10.0; // 0.1 ..= 1.0
            let d = model.downlink_secs(nominal / factor);
            assert!(d <= prev, "downlink must shrink as the factor recovers");
            prev = d;
        }
        assert_eq!(prev, nominal * 0.5, "factor 1.0 = nominal pricing");
    }

    #[test]
    fn stale_correction_parse_round_trips() {
        for sc in [StaleCorrection::None, StaleCorrection::DeltaReplay] {
            assert_eq!(StaleCorrection::parse(sc.name()).unwrap(), sc);
        }
        assert_eq!(
            StaleCorrection::parse("delta_replay").unwrap(),
            StaleCorrection::DeltaReplay
        );
        assert_eq!(StaleCorrection::parse("REPLAY").unwrap(), StaleCorrection::DeltaReplay);
        assert!(StaleCorrection::parse("rewind").is_err());
        assert_eq!(StaleCorrection::default(), StaleCorrection::None);
    }

    #[test]
    fn overtaken_by_gates_on_a_real_transfer() {
        let mut born = BTreeMap::new();
        born.insert(0, 0.0);
        born.insert(1, 100.0);
        born.insert(2, 200.0);
        // Zero-duration transfers are never overtaken, whatever was born.
        assert_eq!(overtaken_by(0.0, 0, 500.0, &born), None);
        // Version 1 and 2 both landed before arrival: the NEWEST wins.
        assert_eq!(overtaken_by(5.0, 0, 250.0, &born), Some(2));
        // Only version 1 had landed by t=150.
        assert_eq!(overtaken_by(5.0, 0, 150.0, &born), Some(1));
        // Nothing newer than the base had landed.
        assert_eq!(overtaken_by(5.0, 0, 50.0, &born), None);
        assert_eq!(overtaken_by(5.0, 2, 500.0, &born), None);
        // Raising the arrival time never un-stales a start.
        let mut last: Option<u64> = None;
        for arrival in [0.0, 100.0, 150.0, 200.0, 1000.0] {
            let v = overtaken_by(5.0, 0, arrival, &born);
            assert!(v >= last, "overtaking version must be monotone in arrival");
            last = v;
        }
    }

    #[test]
    fn overtaken_by_ignores_versions_at_or_below_the_base() {
        let mut born = BTreeMap::new();
        born.insert(7, 10.0);
        assert_eq!(overtaken_by(1.0, 7, 100.0, &born), None);
        assert_eq!(overtaken_by(1.0, 6, 100.0, &born), Some(7));
        // Birth exactly at arrival counts as landed (<=).
        assert_eq!(overtaken_by(1.0, 6, 10.0, &born), Some(7));
        assert_eq!(overtaken_by(1.0, 6, 9.999, &born), None);
    }
}
