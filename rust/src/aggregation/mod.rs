//! Server-side aggregation: FedAvg and FedOpt (paper §4 uses both), with
//! staleness-discounted weighting (FedBuff) and partial-update merging
//! (TimelyFL §3.2.2).
//!
//! Contributions arrive as suffix deltas (`model::Update`). The aggregate
//! delta is a **per-tensor** weighted mean: a tensor's weight normalizer
//! only includes the clients that actually trained it, so partially-trained
//! clients neither dilute nor drag the layers they froze. (A naive global
//! normalizer would shrink deep-layer updates whenever any client trained
//! partially — ablated in `benches/hotpath_criterion.rs` and unit tests.)

pub mod server_opt;

pub use server_opt::{ServerOpt, ServerOptKind};

use crate::model::{ParamVec, Update};

/// One client's contribution to a global aggregation.
#[derive(Clone, Debug)]
pub struct Contribution {
    pub client_id: usize,
    pub update: Update,
    /// Aggregation weight before staleness discount (e.g. #examples; we use
    /// 1.0 — uniform — matching the paper's FedBuff comparison).
    pub weight: f64,
    /// Rounds elapsed since the client pulled its base model (0 = fresh).
    pub staleness: u64,
}

/// FedBuff's staleness discount: s(tau) = 1 / sqrt(1 + tau).
pub fn staleness_discount(staleness: u64) -> f64 {
    1.0 / (1.0 + staleness as f64).sqrt()
}

/// Reduce contributions to a full-shape average delta.
///
/// Returns the per-tensor weighted mean of the suffix deltas, as a
/// full-model `Update` with `boundary = 0` (frozen-by-everyone tensors come
/// out as exact zeros).
///
/// `discount_staleness` selects the published FedBuff rule
/// (Nguyen et al. 2021, Alg. 1): `Δ̄ = (1/K) Σ s(τ_k) Δ_k` — the
/// normaliser is the BUFFER SIZE (sum of base weights), not the sum of
/// discounted weights, so a buffer full of stale updates takes a
/// proportionally smaller server step instead of being silently
/// renormalised back to full magnitude. (Renormalising would erase the
/// staleness penalty and flatter the baseline — ablated in the aggregation
/// unit tests.)
pub fn average_delta(
    template: &ParamVec,
    contributions: &[Contribution],
    discount_staleness: bool,
) -> Update {
    let n_tensors = template.tensors.len();
    let mut sum: Vec<Vec<f32>> = template
        .tensors
        .iter()
        .map(|t| vec![0.0f32; t.len()])
        .collect();
    let mut weight_per_tensor = vec![0.0f64; n_tensors];

    for c in contributions {
        let w = if discount_staleness {
            c.weight * staleness_discount(c.staleness)
        } else {
            c.weight
        };
        if w <= 0.0 {
            continue;
        }
        for (i, u) in c.update.tensors.iter().enumerate() {
            let j = c.update.boundary + i;
            // FedBuff normalises by the undiscounted weight (buffer size);
            // the fresh-update path normalises by what was accumulated.
            weight_per_tensor[j] += if discount_staleness { c.weight } else { w };
            let dst = &mut sum[j];
            debug_assert_eq!(dst.len(), u.len());
            let wf = w as f32;
            for (a, b) in dst.iter_mut().zip(u) {
                *a += wf * b;
            }
        }
    }

    for (t, &w) in sum.iter_mut().zip(&weight_per_tensor) {
        if w > 0.0 {
            let inv = (1.0 / w) as f32;
            for v in t.iter_mut() {
                *v *= inv;
            }
        }
    }

    Update {
        boundary: 0,
        tensors: sum,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(tensors: Vec<Vec<f32>>) -> ParamVec {
        ParamVec { tensors }
    }

    fn contrib(boundary: usize, tensors: Vec<Vec<f32>>, weight: f64, staleness: u64) -> Contribution {
        Contribution {
            client_id: 0,
            update: Update { boundary, tensors },
            weight,
            staleness,
        }
    }

    #[test]
    fn uniform_full_updates_average() {
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = vec![
            contrib(0, vec![vec![2.0, 0.0], vec![4.0]], 1.0, 0),
            contrib(0, vec![vec![0.0, 2.0], vec![0.0]], 1.0, 0),
        ];
        let avg = average_delta(&template, &cs, false);
        assert_eq!(avg.tensors, vec![vec![1.0, 1.0], vec![2.0]]);
    }

    #[test]
    fn partial_updates_use_per_tensor_normalizer() {
        let template = pv(vec![vec![0.0], vec![0.0]]);
        // Client A trained everything; client B only the last tensor.
        let cs = vec![
            contrib(0, vec![vec![2.0], vec![2.0]], 1.0, 0),
            contrib(1, vec![vec![6.0]], 1.0, 0),
        ];
        let avg = average_delta(&template, &cs, false);
        // tensor 0: only A contributed -> mean = 2.0 (NOT 1.0)
        assert_eq!(avg.tensors[0], vec![2.0]);
        // tensor 1: both -> mean = 4.0
        assert_eq!(avg.tensors[1], vec![4.0]);
    }

    #[test]
    fn untouched_tensor_stays_zero() {
        let template = pv(vec![vec![0.0], vec![0.0]]);
        let cs = vec![contrib(1, vec![vec![3.0]], 1.0, 0)];
        let avg = average_delta(&template, &cs, false);
        assert_eq!(avg.tensors[0], vec![0.0]);
        assert_eq!(avg.tensors[1], vec![3.0]);
    }

    #[test]
    fn staleness_discount_monotone() {
        assert_eq!(staleness_discount(0), 1.0);
        assert!(staleness_discount(1) < 1.0);
        assert!(staleness_discount(8) < staleness_discount(3));
        assert!((staleness_discount(3) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn staleness_weighting_applied() {
        let template = pv(vec![vec![0.0]]);
        // fresh says +1, stale (tau=3, discount 0.5) says -1
        let cs = vec![
            contrib(0, vec![vec![1.0]], 1.0, 0),
            contrib(0, vec![vec![-1.0]], 1.0, 3),
        ];
        let avg = average_delta(&template, &cs, true);
        // FedBuff rule: (1*1 + 0.5*(-1)) / K=2 = 0.25 — NOT renormalised
        // by the discounted weight sum (which would give 1/3).
        assert!((avg.tensors[0][0] - 0.25).abs() < 1e-6);
        let no = average_delta(&template, &cs, false);
        assert_eq!(no.tensors[0], vec![0.0]);
    }

    #[test]
    fn stale_buffer_takes_smaller_step() {
        // The magnitude penalty the renormalising variant would erase: an
        // all-stale buffer moves the model less than an all-fresh one.
        let template = pv(vec![vec![0.0]]);
        let fresh = vec![
            contrib(0, vec![vec![1.0]], 1.0, 0),
            contrib(0, vec![vec![1.0]], 1.0, 0),
        ];
        let stale = vec![
            contrib(0, vec![vec![1.0]], 1.0, 8),
            contrib(0, vec![vec![1.0]], 1.0, 8),
        ];
        let f = average_delta(&template, &fresh, true);
        let s = average_delta(&template, &stale, true);
        assert!((f.tensors[0][0] - 1.0).abs() < 1e-6);
        assert!((s.tensors[0][0] - 1.0 / 3.0).abs() < 1e-6); // s(8) = 1/3
        assert!(s.tensors[0][0] < f.tensors[0][0]);
    }

    #[test]
    fn empty_contributions_give_zero_delta() {
        let template = pv(vec![vec![0.0, 0.0]]);
        let avg = average_delta(&template, &[], false);
        assert_eq!(avg.tensors, vec![vec![0.0, 0.0]]);
    }

    #[test]
    fn weights_scale_contributions() {
        let template = pv(vec![vec![0.0]]);
        let cs = vec![
            contrib(0, vec![vec![1.0]], 3.0, 0),
            contrib(0, vec![vec![5.0]], 1.0, 0),
        ];
        let avg = average_delta(&template, &cs, false);
        assert!((avg.tensors[0][0] - 2.0).abs() < 1e-6);
    }
}
