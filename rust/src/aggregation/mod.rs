//! Server-side aggregation: FedAvg and FedOpt (paper §4 uses both), with
//! staleness-discounted weighting (FedBuff) and partial-update merging
//! (TimelyFL §3.2.2).
//!
//! Contributions arrive as suffix deltas (`model::Update`). The aggregate
//! delta is a **per-tensor** weighted mean: a tensor's weight normalizer
//! only includes the clients that actually trained it, so partially-trained
//! clients neither dilute nor drag the layers they froze. (A naive global
//! normalizer would shrink deep-layer updates whenever any client trained
//! partially — ablated in `benches/hotpath_criterion.rs` and unit tests.)

pub mod server_opt;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

pub use server_opt::{ServerOpt, ServerOptKind};

use crate::model::{ParamVec, Update};

/// One client's contribution to a global aggregation.
#[derive(Clone, Debug)]
pub struct Contribution {
    pub client_id: usize,
    pub update: Update,
    /// Aggregation weight before staleness discount (e.g. #examples; we use
    /// 1.0 — uniform — matching the paper's FedBuff comparison).
    pub weight: f64,
    /// Rounds elapsed since the client pulled its base model (0 = fresh).
    pub staleness: u64,
}

/// FedBuff's staleness discount: s(tau) = 1 / sqrt(1 + tau).
pub fn staleness_discount(staleness: u64) -> f64 {
    1.0 / (1.0 + staleness as f64).sqrt()
}

/// Reduce contributions to a full-shape average delta.
///
/// Returns the per-tensor weighted mean of the suffix deltas, as a
/// full-model `Update` with `boundary = 0` (frozen-by-everyone tensors come
/// out as exact zeros).
///
/// `discount_staleness` selects the published FedBuff rule
/// (Nguyen et al. 2021, Alg. 1): `Δ̄ = (1/K) Σ s(τ_k) Δ_k` — the
/// normaliser is the BUFFER SIZE (sum of base weights), not the sum of
/// discounted weights, so a buffer full of stale updates takes a
/// proportionally smaller server step instead of being silently
/// renormalised back to full magnitude. (Renormalising would erase the
/// staleness penalty and flatter the baseline — ablated in the aggregation
/// unit tests.)
pub fn average_delta(
    template: &ParamVec,
    contributions: &[Contribution],
    discount_staleness: bool,
) -> Update {
    let n_tensors = template.tensors.len();
    let mut sum: Vec<Vec<f32>> = template
        .tensors
        .iter()
        .map(|t| vec![0.0f32; t.len()])
        .collect();
    let mut weight_per_tensor = vec![0.0f64; n_tensors];

    for c in contributions {
        let w = if discount_staleness {
            c.weight * staleness_discount(c.staleness)
        } else {
            c.weight
        };
        if w <= 0.0 {
            continue;
        }
        for (i, u) in c.update.tensors.iter().enumerate() {
            let j = c.update.boundary + i;
            // FedBuff normalises by the undiscounted weight (buffer size);
            // the fresh-update path normalises by what was accumulated.
            weight_per_tensor[j] += if discount_staleness { c.weight } else { w };
            let dst = &mut sum[j];
            debug_assert_eq!(dst.len(), u.len());
            let wf = w as f32;
            for (a, b) in dst.iter_mut().zip(u) {
                *a += wf * b;
            }
        }
    }

    for (t, &w) in sum.iter_mut().zip(&weight_per_tensor) {
        if w > 0.0 {
            let inv = (1.0 / w) as f32;
            for v in t.iter_mut() {
                *v *= inv;
            }
        }
    }

    Update {
        boundary: 0,
        tensors: sum,
    }
}

/// Tensors per work unit in the chunk-parallel fold. Bit-identity is
/// insensitive to this by construction (each output tensor is reduced
/// independently, in serial contribution order); the size only trades
/// scheduling overhead against load balance.
pub const DEFAULT_AGG_CHUNK: usize = 8;

/// Deterministic fan-out driver for tensor-partitioned work (the
/// `experiment::runner::run_queue` shape, narrowed to in-process slices):
/// `jobs` scoped workers claim items off an atomic cursor; each item owns
/// disjoint `&mut` data, so there is no result ordering to reconcile — the
/// mutations land in place and the outcome is independent of which worker
/// ran what.
pub(crate) fn run_parallel<T: Send>(jobs: usize, items: Vec<T>, work: impl Fn(T) + Sync) {
    debug_assert!(jobs >= 2, "serial callers take the jobs <= 1 path");
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= slots.len() {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("each slot claimed once");
                work(item);
            });
        }
    });
}

/// Reduce ONE output tensor `j` exactly as [`average_delta`]'s serial loop
/// does: visit contributions in slice order, apply the same skip rule and
/// normaliser choice, multiply-accumulate in f32, divide once at the end.
/// Because the per-tensor addition sequence is identical to the serial
/// fold's, the chunk-parallel path below is bit-identical to serial no
/// matter how tensors are partitioned over workers.
fn reduce_tensor(j: usize, dst: &mut [f32], contributions: &[Contribution], discount: bool) {
    let mut weight = 0.0f64;
    for c in contributions {
        let w = if discount {
            c.weight * staleness_discount(c.staleness)
        } else {
            c.weight
        };
        if w <= 0.0 {
            continue;
        }
        if j < c.update.boundary {
            continue;
        }
        let Some(u) = c.update.tensors.get(j - c.update.boundary) else {
            continue;
        };
        weight += if discount { c.weight } else { w };
        debug_assert_eq!(dst.len(), u.len());
        let wf = w as f32;
        for (a, b) in dst.iter_mut().zip(u) {
            *a += wf * b;
        }
    }
    if weight > 0.0 {
        let inv = (1.0 / weight) as f32;
        for v in dst.iter_mut() {
            *v *= inv;
        }
    }
}

/// Chunk-parallel [`average_delta`] (`agg_jobs=` config key): the output
/// tensor index space splits into fixed-size chunks and `jobs` worker
/// threads fold them concurrently, each tensor accumulated in the identical
/// serial contribution order. `jobs <= 1` IS the serial path — the literal
/// [`average_delta`] call — which stays the bit-identity anchor; `jobs >= 2`
/// is bit-identical to it for any thread count (locked by
/// `rust/tests/parallel_agg_properties.rs`).
pub fn average_delta_jobs(
    template: &ParamVec,
    contributions: &[Contribution],
    discount_staleness: bool,
    jobs: usize,
) -> Update {
    average_delta_chunked(template, contributions, discount_staleness, jobs, DEFAULT_AGG_CHUNK)
}

/// [`average_delta_jobs`] with an explicit chunk size (tensors per work
/// unit) — exposed so the property suite can prove chunk-size insensitivity.
pub fn average_delta_chunked(
    template: &ParamVec,
    contributions: &[Contribution],
    discount_staleness: bool,
    jobs: usize,
    chunk: usize,
) -> Update {
    if jobs <= 1 {
        return average_delta(template, contributions, discount_staleness);
    }
    let chunk = chunk.max(1);
    let mut sum: Vec<Vec<f32>> = template
        .tensors
        .iter()
        .map(|t| vec![0.0f32; t.len()])
        .collect();
    let units: Vec<(usize, &mut [Vec<f32>])> = sum
        .chunks_mut(chunk)
        .enumerate()
        .map(|(ci, slab)| (ci * chunk, slab))
        .collect();
    run_parallel(jobs, units, |(j0, slab)| {
        for (k, dst) in slab.iter_mut().enumerate() {
            reduce_tensor(j0 + k, dst, contributions, discount_staleness);
        }
    });
    Update {
        boundary: 0,
        tensors: sum,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(tensors: Vec<Vec<f32>>) -> ParamVec {
        ParamVec { tensors }
    }

    fn contrib(boundary: usize, tensors: Vec<Vec<f32>>, weight: f64, staleness: u64) -> Contribution {
        Contribution {
            client_id: 0,
            update: Update { boundary, tensors },
            weight,
            staleness,
        }
    }

    #[test]
    fn uniform_full_updates_average() {
        let template = pv(vec![vec![0.0, 0.0], vec![0.0]]);
        let cs = vec![
            contrib(0, vec![vec![2.0, 0.0], vec![4.0]], 1.0, 0),
            contrib(0, vec![vec![0.0, 2.0], vec![0.0]], 1.0, 0),
        ];
        let avg = average_delta(&template, &cs, false);
        assert_eq!(avg.tensors, vec![vec![1.0, 1.0], vec![2.0]]);
    }

    #[test]
    fn partial_updates_use_per_tensor_normalizer() {
        let template = pv(vec![vec![0.0], vec![0.0]]);
        // Client A trained everything; client B only the last tensor.
        let cs = vec![
            contrib(0, vec![vec![2.0], vec![2.0]], 1.0, 0),
            contrib(1, vec![vec![6.0]], 1.0, 0),
        ];
        let avg = average_delta(&template, &cs, false);
        // tensor 0: only A contributed -> mean = 2.0 (NOT 1.0)
        assert_eq!(avg.tensors[0], vec![2.0]);
        // tensor 1: both -> mean = 4.0
        assert_eq!(avg.tensors[1], vec![4.0]);
    }

    #[test]
    fn untouched_tensor_stays_zero() {
        let template = pv(vec![vec![0.0], vec![0.0]]);
        let cs = vec![contrib(1, vec![vec![3.0]], 1.0, 0)];
        let avg = average_delta(&template, &cs, false);
        assert_eq!(avg.tensors[0], vec![0.0]);
        assert_eq!(avg.tensors[1], vec![3.0]);
    }

    #[test]
    fn staleness_discount_monotone() {
        assert_eq!(staleness_discount(0), 1.0);
        assert!(staleness_discount(1) < 1.0);
        assert!(staleness_discount(8) < staleness_discount(3));
        assert!((staleness_discount(3) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn staleness_weighting_applied() {
        let template = pv(vec![vec![0.0]]);
        // fresh says +1, stale (tau=3, discount 0.5) says -1
        let cs = vec![
            contrib(0, vec![vec![1.0]], 1.0, 0),
            contrib(0, vec![vec![-1.0]], 1.0, 3),
        ];
        let avg = average_delta(&template, &cs, true);
        // FedBuff rule: (1*1 + 0.5*(-1)) / K=2 = 0.25 — NOT renormalised
        // by the discounted weight sum (which would give 1/3).
        assert!((avg.tensors[0][0] - 0.25).abs() < 1e-6);
        let no = average_delta(&template, &cs, false);
        assert_eq!(no.tensors[0], vec![0.0]);
    }

    #[test]
    fn stale_buffer_takes_smaller_step() {
        // The magnitude penalty the renormalising variant would erase: an
        // all-stale buffer moves the model less than an all-fresh one.
        let template = pv(vec![vec![0.0]]);
        let fresh = vec![
            contrib(0, vec![vec![1.0]], 1.0, 0),
            contrib(0, vec![vec![1.0]], 1.0, 0),
        ];
        let stale = vec![
            contrib(0, vec![vec![1.0]], 1.0, 8),
            contrib(0, vec![vec![1.0]], 1.0, 8),
        ];
        let f = average_delta(&template, &fresh, true);
        let s = average_delta(&template, &stale, true);
        assert!((f.tensors[0][0] - 1.0).abs() < 1e-6);
        assert!((s.tensors[0][0] - 1.0 / 3.0).abs() < 1e-6); // s(8) = 1/3
        assert!(s.tensors[0][0] < f.tensors[0][0]);
    }

    #[test]
    fn empty_contributions_give_zero_delta() {
        let template = pv(vec![vec![0.0, 0.0]]);
        let avg = average_delta(&template, &[], false);
        assert_eq!(avg.tensors, vec![vec![0.0, 0.0]]);
    }

    #[test]
    fn weights_scale_contributions() {
        let template = pv(vec![vec![0.0]]);
        let cs = vec![
            contrib(0, vec![vec![1.0]], 3.0, 0),
            contrib(0, vec![vec![5.0]], 1.0, 0),
        ];
        let avg = average_delta(&template, &cs, false);
        assert!((avg.tensors[0][0] - 2.0).abs() < 1e-6);
    }

    fn assert_bit_identical(a: &Update, b: &Update) {
        assert_eq!(a.boundary, b.boundary);
        assert_eq!(a.tensors.len(), b.tensors.len());
        for (x, y) in a.tensors.iter().zip(&b.tensors) {
            assert_eq!(x.len(), y.len());
            for (p, q) in x.iter().zip(y) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
    }

    #[test]
    fn parallel_fold_is_bit_identical_to_serial() {
        // Mixed boundaries + weights + staleness: the shape the tensor
        // partition has to get right. Deeper sweeps (random contributions,
        // -0.0 / denormals) live in rust/tests/parallel_agg_properties.rs.
        let template = pv(vec![vec![0.0, 0.0], vec![0.0], vec![0.0, 0.0, 0.0]]);
        let cs = vec![
            contrib(0, vec![vec![2.0, -1.0], vec![4.0], vec![0.5, 0.5, 0.5]], 1.0, 0),
            contrib(1, vec![vec![6.0], vec![-1.5, 0.25, 0.75]], 3.0, 2),
            contrib(2, vec![vec![2.0, 0.0, -3.0]], 2.0, 5),
        ];
        for discount in [false, true] {
            let serial = average_delta(&template, &cs, discount);
            for jobs in [2, 3, 7] {
                let par = average_delta_jobs(&template, &cs, discount, jobs);
                assert_bit_identical(&par, &serial);
            }
            // Chunk size must not matter either (1 = one tensor per unit).
            for chunk in [1, 2, 64] {
                let par = average_delta_chunked(&template, &cs, discount, 2, chunk);
                assert_bit_identical(&par, &serial);
            }
        }
    }

    #[test]
    fn parallel_fold_jobs_one_is_the_serial_path() {
        let template = pv(vec![vec![0.0]]);
        let cs = vec![contrib(0, vec![vec![1.0]], 1.0, 0)];
        let a = average_delta_jobs(&template, &cs, false, 1);
        let b = average_delta(&template, &cs, false);
        assert_bit_identical(&a, &b);
    }
}
