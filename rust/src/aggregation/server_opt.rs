//! Server optimizers (FedOpt family, Reddi et al. 2021).
//!
//! The averaged client delta is treated as a pseudo-gradient
//! `g = -avg_delta`; the server then takes one optimizer step on the global
//! model. `FedAvg` is the identity server optimizer (apply the delta as-is,
//! server lr 1.0). The paper evaluates FedAvg and FedOpt-with-Adam; Yogi
//! and SGD-with-momentum are included for completeness (same family).

use super::run_parallel;
use crate::model::{ParamVec, Update};

/// Which server optimizer to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerOptKind {
    /// global += avg_delta (server lr fixed at 1.0): plain FedAvg.
    FedAvg,
    /// Adam on the pseudo-gradient (the paper's "FedOpt" configuration).
    Adam,
    /// Yogi variant (sign-based second-moment update).
    Yogi,
    /// SGD with momentum on the pseudo-gradient.
    SgdM,
}

impl ServerOptKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fedavg" | "avg" => ServerOptKind::FedAvg,
            "adam" | "fedopt" => ServerOptKind::Adam,
            "yogi" => ServerOptKind::Yogi,
            "sgdm" => ServerOptKind::SgdM,
            other => anyhow::bail!("unknown server optimizer {other:?}"),
        })
    }
}

/// Server optimizer state (first/second moments, allocated lazily to the
/// model's shape on the first step).
#[derive(Clone, Debug)]
pub struct ServerOpt {
    pub kind: ServerOptKind,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    step: u64,
    m: Option<Vec<Vec<f32>>>,
    v: Option<Vec<Vec<f32>>>,
    /// Worker threads for the per-tensor update loops (`agg_jobs=`). The
    /// optimizer arithmetic is element-local, so fanning tensors over
    /// threads is bit-identical to serial for any count; `1` (the default)
    /// runs the historical single-thread loops.
    jobs: usize,
}

impl ServerOpt {
    pub fn new(kind: ServerOptKind, lr: f64) -> ServerOpt {
        ServerOpt {
            kind,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            step: 0,
            m: None,
            v: None,
            jobs: 1,
        }
    }

    /// Builder-style worker-thread override (`agg_jobs` config key).
    pub fn with_jobs(mut self, jobs: usize) -> ServerOpt {
        self.jobs = jobs.max(1);
        self
    }

    pub fn steps_taken(&self) -> u64 {
        self.step
    }

    /// Apply one aggregated (full-shape, boundary=0) delta to the global
    /// model in place.
    pub fn apply(&mut self, global: &mut ParamVec, avg_delta: &Update) {
        assert_eq!(avg_delta.boundary, 0, "server opt needs full-shape delta");
        match self.kind {
            ServerOptKind::FedAvg => {
                if self.jobs >= 2 {
                    // Per-tensor `+=` fanned over workers; scale 1.0 means
                    // the serial path's `a += 1.0 * b` is literally `a += b`.
                    let units: Vec<(&mut Vec<f32>, &Vec<f32>)> = global.tensors
                        [avg_delta.boundary..]
                        .iter_mut()
                        .zip(&avg_delta.tensors)
                        .collect();
                    run_parallel(self.jobs, units, |(t, u)| {
                        debug_assert_eq!(t.len(), u.len());
                        for (a, b) in t.iter_mut().zip(u) {
                            *a += b;
                        }
                    });
                } else {
                    global.apply(avg_delta, 1.0);
                }
                self.step += 1;
            }
            ServerOptKind::SgdM => self.sgdm(global, avg_delta),
            ServerOptKind::Adam | ServerOptKind::Yogi => self.adam_like(global, avg_delta),
        }
    }

    fn ensure_state(&mut self, like: &Update) {
        if self.m.is_none() {
            self.m = Some(like.tensors.iter().map(|t| vec![0.0; t.len()]).collect());
            self.v = Some(like.tensors.iter().map(|t| vec![0.0; t.len()]).collect());
        }
    }

    fn sgdm(&mut self, global: &mut ParamVec, delta: &Update) {
        self.ensure_state(delta);
        self.step += 1;
        let jobs = self.jobs;
        let m = self.m.as_mut().unwrap();
        let beta = self.beta1 as f32;
        let lr = self.lr as f32;
        let step_tensor = |mj: &mut Vec<f32>, gj: &mut Vec<f32>, d: &Vec<f32>| {
            for i in 0..d.len() {
                let g = -d[i]; // pseudo-gradient
                mj[i] = beta * mj[i] + g;
                gj[i] -= lr * mj[i];
            }
        };
        if jobs >= 2 {
            let units: Vec<_> = m
                .iter_mut()
                .zip(global.tensors.iter_mut())
                .zip(&delta.tensors)
                .collect();
            run_parallel(jobs, units, |((mj, gj), d)| step_tensor(mj, gj, d));
        } else {
            for (j, d) in delta.tensors.iter().enumerate() {
                step_tensor(&mut m[j], &mut global.tensors[j], d);
            }
        }
    }

    fn adam_like(&mut self, global: &mut ParamVec, delta: &Update) {
        self.ensure_state(delta);
        self.step += 1;
        let (b1, b2) = (self.beta1, self.beta2);
        let bias1 = 1.0 - b1.powi(self.step as i32);
        let bias2 = 1.0 - b2.powi(self.step as i32);
        let lr = self.lr;
        let eps = self.eps;
        let yogi = self.kind == ServerOptKind::Yogi;
        let jobs = self.jobs;
        let m = self.m.as_mut().unwrap();
        let v = self.v.as_mut().unwrap();

        let step_tensor = |mj: &mut Vec<f32>, vj: &mut Vec<f32>, gj: &mut Vec<f32>, d: &Vec<f32>| {
            for i in 0..d.len() {
                let g = -(d[i] as f64); // pseudo-gradient
                let g2 = g * g;
                mj[i] = (b1 * mj[i] as f64 + (1.0 - b1) * g) as f32;
                if yogi {
                    let vv = vj[i] as f64;
                    vj[i] = (vv - (1.0 - b2) * g2 * (vv - g2).signum()) as f32;
                } else {
                    vj[i] = (b2 * vj[i] as f64 + (1.0 - b2) * g2) as f32;
                }
                let mhat = mj[i] as f64 / bias1;
                let vhat = (vj[i] as f64 / bias2).max(0.0);
                gj[i] -= (lr * mhat / (vhat.sqrt() + eps)) as f32;
            }
        };
        if jobs >= 2 {
            let units: Vec<_> = m
                .iter_mut()
                .zip(v.iter_mut())
                .zip(global.tensors.iter_mut())
                .zip(&delta.tensors)
                .collect();
            run_parallel(jobs, units, |(((mj, vj), gj), d)| step_tensor(mj, vj, gj, d));
        } else {
            for (j, d) in delta.tensors.iter().enumerate() {
                step_tensor(&mut m[j], &mut v[j], &mut global.tensors[j], d);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(tensors: Vec<Vec<f32>>) -> Update {
        Update {
            boundary: 0,
            tensors,
        }
    }

    fn global() -> ParamVec {
        ParamVec {
            tensors: vec![vec![1.0, 1.0], vec![0.0]],
        }
    }

    #[test]
    fn fedavg_is_identity_application() {
        let mut g = global();
        let mut opt = ServerOpt::new(ServerOptKind::FedAvg, 1.0);
        opt.apply(&mut g, &delta(vec![vec![0.5, -0.5], vec![1.0]]));
        assert_eq!(g.tensors, vec![vec![1.5, 0.5], vec![1.0]]);
    }

    #[test]
    fn adam_moves_against_pseudo_gradient() {
        let mut g = global();
        let before = g.tensors[0][0];
        let mut opt = ServerOpt::new(ServerOptKind::Adam, 0.01);
        // positive delta => negative pseudo-gradient => param increases
        opt.apply(&mut g, &delta(vec![vec![1.0, 1.0], vec![1.0]]));
        assert!(g.tensors[0][0] > before);
        // first Adam step size is ~lr regardless of gradient magnitude
        assert!((g.tensors[0][0] - before - 0.01).abs() < 1e-3);
    }

    #[test]
    fn adam_steps_bounded_by_lr_scale() {
        let mut g = global();
        let mut opt = ServerOpt::new(ServerOptKind::Adam, 0.1);
        for _ in 0..10 {
            opt.apply(&mut g, &delta(vec![vec![100.0, -100.0], vec![0.1]]));
        }
        // Adam normalizes: ten steps can move at most ~10 * lr * O(1).
        assert!((g.tensors[0][0] - 1.0).abs() < 1.5);
        assert_eq!(opt.steps_taken(), 10);
    }

    #[test]
    fn yogi_differs_from_adam_but_same_direction() {
        let mut ga = global();
        let mut gy = global();
        let mut a = ServerOpt::new(ServerOptKind::Adam, 0.05);
        let mut y = ServerOpt::new(ServerOptKind::Yogi, 0.05);
        for i in 0..5 {
            let d = delta(vec![vec![1.0 + i as f32, -1.0], vec![0.5]]);
            a.apply(&mut ga, &d);
            y.apply(&mut gy, &d);
        }
        assert!(ga.tensors[0][0] > 1.0 && gy.tensors[0][0] > 1.0);
        assert_ne!(ga.tensors[0][0], gy.tensors[0][0]);
    }

    #[test]
    fn sgdm_accumulates_momentum() {
        let mut g = ParamVec {
            tensors: vec![vec![0.0]],
        };
        let mut opt = ServerOpt::new(ServerOptKind::SgdM, 1.0);
        opt.apply(&mut g, &delta(vec![vec![1.0]]));
        let first = g.tensors[0][0];
        opt.apply(&mut g, &delta(vec![vec![1.0]]));
        let second_step = g.tensors[0][0] - first;
        assert!(second_step > first, "momentum should amplify");
    }

    #[test]
    fn jobs_fanout_is_bit_identical_for_every_kind() {
        for kind in [
            ServerOptKind::FedAvg,
            ServerOptKind::SgdM,
            ServerOptKind::Adam,
            ServerOptKind::Yogi,
        ] {
            let mut serial = ServerOpt::new(kind, 0.05);
            let mut fanned = ServerOpt::new(kind, 0.05).with_jobs(3);
            let mut gs = global();
            let mut gf = global();
            for i in 0..4 {
                let d = delta(vec![vec![1.0 + i as f32, -0.25], vec![0.5 * i as f32]]);
                serial.apply(&mut gs, &d);
                fanned.apply(&mut gf, &d);
            }
            for (a, b) in gs.tensors.iter().zip(&gf.tensors) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{kind:?} fanout drifted");
                }
            }
            assert_eq!(serial.steps_taken(), fanned.steps_taken());
        }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(ServerOptKind::parse("fedavg").unwrap(), ServerOptKind::FedAvg);
        assert_eq!(ServerOptKind::parse("FedOpt").unwrap(), ServerOptKind::Adam);
        assert_eq!(ServerOptKind::parse("yogi").unwrap(), ServerOptKind::Yogi);
        assert!(ServerOptKind::parse("nope").is_err());
    }
}
