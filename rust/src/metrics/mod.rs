//! Run metrics: participation tracking (paper Figs. 1a/1b/5), learning
//! curves over simulated time (Figs. 1c/4/6/7), and time-to-target
//! extraction (Tables 1/2).

pub mod events;
pub mod report;

use crate::simtime::hours;

/// One evaluation of the global model during a run.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    /// Global aggregation rounds completed at this point.
    pub round: usize,
    /// Simulated seconds elapsed.
    pub sim_secs: f64,
    pub mean_loss: f64,
    /// Accuracy (classify, higher better) or perplexity (lm, lower better).
    pub metric: f64,
}

/// Per-round bookkeeping.
#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub sim_secs: f64,
    /// Clients whose update entered this aggregation.
    pub participants: usize,
    /// Clients dropped for timing/injection reasons: deadline misses,
    /// staleness-cap discards, injected delivery failures.
    pub dropped: usize,
    /// Clients dropped because they went OFFLINE mid-round (availability
    /// churn) — attributed separately so Fig. 1/5-style participation
    /// numbers can tell connectivity losses from straggler losses.
    pub avail_dropped: usize,
    /// Mean reported client training loss this round; `None` when no
    /// sampled client delivered an update (a fabricated 0.0 here would
    /// read as a perfect loss).
    pub mean_train_loss: Option<f64>,
}

/// Wasted-work ledger for the plan/execute dispatch split
/// (`coordinator::trainer`): how many client dispatches were drawn, how
/// many actually reached the accelerator, and how many PJRT executions the
/// deferred path skipped (churn-cancelled plans plus plans still pending
/// when the run ended). Eager training (`cfg.eager_train`) executes at
/// dispatch time, so there `executed == dispatched` and `avoided == 0`.
///
/// Settled ledgers (after `SimEngine::finish` drains the pending table)
/// satisfy `executed + avoided == dispatched`; mid-run,
/// `dispatched - executed - avoided` is the in-flight count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WastedWork {
    pub dispatched: u64,
    pub executed: u64,
    pub avoided: u64,
}

impl WastedWork {
    /// A client dispatch was drawn (plan phase).
    pub fn on_dispatch(&mut self) {
        self.dispatched += 1;
    }

    /// A dispatch's PJRT executions actually ran.
    pub fn on_execute(&mut self) {
        self.executed += 1;
        debug_assert!(self.executed + self.avoided <= self.dispatched);
    }

    /// A dispatch's PJRT executions were skipped (cancelled or never
    /// resolved).
    pub fn on_avoid(&mut self) {
        self.avoided += 1;
        debug_assert!(self.executed + self.avoided <= self.dispatched);
    }

    /// Dispatches not yet resolved either way (0 in settled ledgers).
    pub fn pending(&self) -> u64 {
        self.dispatched - self.executed - self.avoided
    }

    /// Fraction of dispatches whose accelerator work was skipped, in
    /// [0, 1]; 0.0 for an empty ledger.
    pub fn avoided_ratio(&self) -> f64 {
        if self.dispatched == 0 {
            0.0
        } else {
            self.avoided as f64 / self.dispatched as f64
        }
    }
}

/// Tracks how often each client contributes to global aggregation.
/// Participation rate (paper definition): rounds contributed / total rounds.
#[derive(Clone, Debug)]
pub struct ParticipationTracker {
    contributions: Vec<u64>,
    total_rounds: u64,
}

impl ParticipationTracker {
    pub fn new(population: usize) -> Self {
        ParticipationTracker {
            contributions: vec![0; population],
            total_rounds: 0,
        }
    }

    pub fn record_round(&mut self, participant_ids: impl IntoIterator<Item = usize>) {
        self.total_rounds += 1;
        for id in participant_ids {
            self.contributions[id] += 1;
        }
    }

    pub fn total_rounds(&self) -> u64 {
        self.total_rounds
    }

    /// Per-client participation rates in [0, 1].
    pub fn rates(&self) -> Vec<f64> {
        if self.total_rounds == 0 {
            return vec![0.0; self.contributions.len()];
        }
        self.contributions
            .iter()
            .map(|&c| c as f64 / self.total_rounds as f64)
            .collect()
    }

    pub fn mean_rate(&self) -> f64 {
        crate::util::stats::mean(&self.rates())
    }

    /// Fraction of clients with a strictly higher rate than in `other`
    /// (paper: "66.4% of devices increase the participation rate").
    pub fn fraction_improved_over(&self, other: &ParticipationTracker) -> f64 {
        let a = self.rates();
        let b = other.rates();
        assert_eq!(a.len(), b.len(), "populations differ");
        let improved = a.iter().zip(&b).filter(|(x, y)| x > y).count();
        improved as f64 / a.len().max(1) as f64
    }
}

/// Everything a finished run reports.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub strategy: String,
    pub model: String,
    pub eval_points: Vec<EvalPoint>,
    pub rounds: Vec<RoundRecord>,
    pub participation: Vec<f64>,
    /// Per-client fraction of the run's simulated time spent online (all
    /// 1.0 under the default always-on process).
    pub online_fraction: Vec<f64>,
    pub sim_secs: f64,
    pub wall_secs: f64,
    pub total_rounds: usize,
    /// Simulation events processed by the driver's `EventQueue` (round
    /// boundaries, client finishes, availability transitions).
    pub events_processed: u64,
    /// Real PJRT train-steps executed (for perf accounting).
    pub real_train_steps: u64,
    /// Client dispatches whose local training actually ran on the
    /// accelerator (wasted-work accounting; see [`WastedWork`]).
    pub trainings_executed: u64,
    /// Client dispatches whose PJRT executions were skipped by deferred
    /// dispatch — churn-cancelled plans plus plans still pending at run
    /// end. Always 0 under eager training.
    pub trainings_avoided: u64,
    /// Deadline-side drops that accumulated when no round was ever
    /// recorded (e.g. the population was offline from t=0); included in
    /// `total_deadline_drops()`.
    pub tail_dropped: usize,
    /// Same, for availability-churn drops (`total_avail_drops()`).
    pub tail_avail_dropped: usize,
    /// Total simulated seconds dispatched clients spent waiting on the
    /// model-dissemination downlink (`crate::network`): the sum of every
    /// dispatch's server → client transfer duration. Exactly 0.0 under the
    /// default `network = free`.
    pub downlink_wait_secs: f64,
    /// Dispatches whose downlink was overtaken: a newer global version was
    /// born before the client's transfer landed, so training started on a
    /// stale model (whether that is corrected is `net_stale_correction`'s
    /// call). Exactly 0 under `network = free`.
    pub stale_starts: u64,
    /// Edge-aggregator flushes (`crate::fleet::RegionClock`): windows of
    /// held partials released at their per-region deadlines. Exactly 0
    /// under the default `hier_clock = shared`.
    pub edge_flushes: u64,
    /// Total simulated seconds flushed partials spent on the priced
    /// edge→root uplink (`hier_uplink = priced`). Exactly 0.0 under the
    /// default `hier_clock = shared` (and under `hier_uplink = free`).
    pub edge_uplink_wait_secs: f64,
    /// Root merges assembled from arrived region partials. At most one per
    /// aggregation boundary, so always ≤ `edge_flushes` once windows batch
    /// more than one region. Exactly 0 under `hier_clock = shared`.
    pub edge_root_merges: u64,
}

impl RunReport {
    /// Simulated hours to first reach `target` (accuracy: >=, ppl: <=).
    /// `higher_is_better` selects the comparison. None = never reached.
    pub fn time_to_target(&self, target: f64, higher_is_better: bool) -> Option<f64> {
        self.eval_points
            .iter()
            .find(|p| {
                if higher_is_better {
                    p.metric >= target
                } else {
                    p.metric <= target
                }
            })
            .map(|p| hours(p.sim_secs))
    }

    /// Best metric seen over the run.
    pub fn best_metric(&self, higher_is_better: bool) -> Option<f64> {
        let iter = self.eval_points.iter().map(|p| p.metric);
        if higher_is_better {
            iter.fold(None, |acc, x| Some(acc.map_or(x, |a: f64| a.max(x))))
        } else {
            iter.fold(None, |acc, x| Some(acc.map_or(x, |a: f64| a.min(x))))
        }
    }

    pub fn final_metric(&self) -> Option<f64> {
        self.eval_points.last().map(|p| p.metric)
    }

    pub fn mean_participation(&self) -> f64 {
        crate::util::stats::mean(&self.participation)
    }

    /// Gini coefficient of the per-client participation rates — the
    /// dispersion behind the paper's Fig. 1/5 participation-gap story in
    /// one number: 0 = every client contributed equally, → 1 = a few fast
    /// clients dominated the aggregations.
    pub fn participation_gini(&self) -> f64 {
        crate::util::stats::gini(&self.participation)
    }

    /// Population-mean online fraction (1.0 under always-on).
    pub fn mean_online_fraction(&self) -> f64 {
        crate::util::stats::mean(&self.online_fraction)
    }

    /// Total clients lost to availability churn across the whole run
    /// (per-round attribution plus the zero-round tail).
    pub fn total_avail_drops(&self) -> usize {
        self.rounds.iter().map(|r| r.avail_dropped).sum::<usize>() + self.tail_avail_dropped
    }

    /// Total clients lost to deadlines / staleness caps / injected failures
    /// (per-round attribution plus the zero-round tail).
    pub fn total_deadline_drops(&self) -> usize {
        self.rounds.iter().map(|r| r.dropped).sum::<usize>() + self.tail_dropped
    }

    /// Total client dispatches drawn over the run. The ledger is settled at
    /// report time, so this is exactly `executed + avoided`.
    pub fn total_train_dispatches(&self) -> u64 {
        self.trainings_executed + self.trainings_avoided
    }

    /// Fraction of dispatches whose accelerator work was skipped.
    pub fn trainings_avoided_ratio(&self) -> f64 {
        let total = self.total_train_dispatches();
        if total == 0 {
            0.0
        } else {
            self.trainings_avoided as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn participation_rates() {
        let mut t = ParticipationTracker::new(3);
        t.record_round([0, 1]);
        t.record_round([0]);
        t.record_round([0, 2]);
        assert_eq!(t.total_rounds(), 3);
        let r = t.rates();
        assert!((r[0] - 1.0).abs() < 1e-12);
        assert!((r[1] - 1.0 / 3.0).abs() < 1e-12);
        assert!((r[2] - 1.0 / 3.0).abs() < 1e-12);
        assert!((t.mean_rate() - 5.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn fraction_improved() {
        let mut a = ParticipationTracker::new(2);
        let mut b = ParticipationTracker::new(2);
        a.record_round([0, 1]);
        b.record_round([0]);
        // a: [1, 1], b: [1, 0] -> only client 1 improved
        assert_eq!(a.fraction_improved_over(&b), 0.5);
    }

    fn report_with(points: Vec<EvalPoint>) -> RunReport {
        RunReport {
            strategy: "t".into(),
            model: "m".into(),
            eval_points: points,
            rounds: vec![],
            participation: vec![],
            online_fraction: vec![],
            sim_secs: 0.0,
            wall_secs: 0.0,
            total_rounds: 0,
            events_processed: 0,
            real_train_steps: 0,
            trainings_executed: 0,
            trainings_avoided: 0,
            tail_dropped: 0,
            tail_avail_dropped: 0,
            downlink_wait_secs: 0.0,
            stale_starts: 0,
            edge_flushes: 0,
            edge_uplink_wait_secs: 0.0,
            edge_root_merges: 0,
        }
    }

    #[test]
    fn drop_attribution_sums() {
        let mut r = report_with(vec![]);
        r.rounds = vec![
            RoundRecord {
                round: 0,
                sim_secs: 10.0,
                participants: 3,
                dropped: 1,
                avail_dropped: 2,
                mean_train_loss: Some(1.5),
            },
            RoundRecord {
                round: 1,
                sim_secs: 20.0,
                participants: 0,
                dropped: 0,
                avail_dropped: 4,
                mean_train_loss: None,
            },
        ];
        r.online_fraction = vec![1.0, 0.5];
        assert_eq!(r.total_avail_drops(), 6);
        assert_eq!(r.total_deadline_drops(), 1);
        assert!((r.mean_online_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn zero_round_tail_counts_survive_into_totals() {
        // A run where the population was offline from t=0 records no
        // rounds; the tail counters still reach the totals.
        let mut r = report_with(vec![]);
        r.tail_dropped = 2;
        r.tail_avail_dropped = 5;
        assert!(r.rounds.is_empty());
        assert_eq!(r.total_deadline_drops(), 2);
        assert_eq!(r.total_avail_drops(), 5);
    }

    #[test]
    fn participation_gini_is_dispersion_of_the_rates() {
        let mut r = report_with(vec![]);
        assert_eq!(r.participation_gini(), 0.0, "no clients -> no dispersion");
        r.participation = vec![0.5; 8];
        assert_eq!(r.participation_gini(), 0.0, "equal rates -> 0");
        r.participation = vec![0.0, 0.0, 0.0, 1.0];
        assert!((r.participation_gini() - 0.75).abs() < 1e-12);
        r.participation = vec![0.5, 1.0];
        assert!((r.participation_gini() - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn time_to_target_accuracy() {
        let r = report_with(vec![
            EvalPoint { round: 1, sim_secs: 3600.0, mean_loss: 2.0, metric: 0.4 },
            EvalPoint { round: 2, sim_secs: 7200.0, mean_loss: 1.5, metric: 0.62 },
        ]);
        assert_eq!(r.time_to_target(0.6, true), Some(2.0));
        assert_eq!(r.time_to_target(0.9, true), None);
        assert_eq!(r.best_metric(true), Some(0.62));
    }

    #[test]
    fn wasted_work_ledger_counts_and_ratio() {
        let mut w = WastedWork::default();
        assert_eq!(w.avoided_ratio(), 0.0, "empty ledger must not divide by 0");
        for _ in 0..5 {
            w.on_dispatch();
        }
        w.on_execute();
        w.on_execute();
        w.on_avoid();
        assert_eq!(w, WastedWork { dispatched: 5, executed: 2, avoided: 1 });
        assert_eq!(w.pending(), 2);
        assert!((w.avoided_ratio() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn report_training_counters_settle() {
        let mut r = report_with(vec![]);
        r.trainings_executed = 7;
        r.trainings_avoided = 3;
        assert_eq!(r.total_train_dispatches(), 10);
        assert!((r.trainings_avoided_ratio() - 0.3).abs() < 1e-12);
        let zero = report_with(vec![]);
        assert_eq!(zero.trainings_avoided_ratio(), 0.0);
    }

    #[test]
    fn time_to_target_ppl() {
        let r = report_with(vec![
            EvalPoint { round: 1, sim_secs: 1800.0, mean_loss: 3.0, metric: 20.0 },
            EvalPoint { round: 2, sim_secs: 3600.0, mean_loss: 2.0, metric: 7.0 },
        ]);
        assert_eq!(r.time_to_target(7.0, false), Some(1.0));
        assert_eq!(r.best_metric(false), Some(7.0));
    }
}
