//! Machine-readable run-event stream: JSONL records in the machine-message
//! idiom of cargo's `machine_message.rs` — every record is one JSON object
//! per line carrying a `reason` discriminator, so external tooling can
//! consume runs (`timelyfl run --events FILE`) without parsing the aligned
//! text tables.
//!
//! Record kinds (`reason` values):
//!
//! ```text
//! {"reason":"round-complete","round":3,"sim_secs":412.5,"participants":14,
//!  "dropped":1,"avail_dropped":2,"downlink_wait_secs":37.5,"stale_starts":1,
//!  "edge_flushes":2,"edge_uplink_wait_secs":18.0,
//!  "mean_train_loss":1.83,
//!  "workloads":[{"alpha":0.75,"client":4,"epochs":2,"stay_prob":0.93}],
//!  "agg_weights":[{"client":4,"weight":0.5}]}
//! {"reason":"eval-point","round":3,"sim_secs":412.5,"mean_loss":1.79,"metric":0.41}
//! {"reason":"client-dropped","client":17,"sim_secs":390.0,"cause":"availability",
//!  "execution_avoided":true}
//! {"reason":"availability-transition","client":17,"sim_secs":390.0,"online":false}
//! ```
//!
//! `write_jsonl` / `parse_jsonl` round-trip the format through `util::json`;
//! unknown `reason` values are an error (the schema is versioned by the set
//! of reasons — see `docs/architecture.md`).

use std::io::Write;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Why a sampled / in-flight client's update was lost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropCause {
    /// The client's availability process took it offline mid-round.
    Availability,
    /// Deadline miss, staleness-cap discard, or injected delivery failure.
    Deadline,
}

impl DropCause {
    pub fn name(&self) -> &'static str {
        match self {
            DropCause::Availability => "availability",
            DropCause::Deadline => "deadline",
        }
    }

    pub fn parse(s: &str) -> Result<DropCause> {
        match s {
            "availability" => Ok(DropCause::Availability),
            "deadline" => Ok(DropCause::Deadline),
            other => anyhow::bail!("unknown drop cause {other:?}"),
        }
    }
}

/// One client's scheduled workload for a dispatch — the paper's Alg. 3
/// outputs (E_c local epochs, alpha_c partial-training ratio) as actually
/// dispatched: `alpha` is the AOT-compiled ratio the quantizer selected,
/// i.e. the fraction that really ran, not the scheduler's continuous
/// pre-quantization value. Event-driven protocols always dispatch the full
/// model (`alpha = 1.0`, fixed epochs); TimelyFL carries its per-round
/// adaptive assignments here. `stay_prob` is the sampler's decision score
/// for the client at its most recent sampling (survival estimate for the
/// weighted policies; 1.0 under `uniform`), so event streams expose WHY a
/// client was picked alongside what it was asked to do.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientWorkload {
    pub client: usize,
    /// Scheduled local epochs E_c.
    pub epochs: usize,
    /// Realized partial-training ratio alpha_c in (0, 1].
    pub alpha: f64,
    /// Sampler decision score in [0, 1] (`coordinator::sampler`).
    pub stay_prob: f64,
}

impl ClientWorkload {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("client", Json::num(self.client as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("alpha", Json::num(self.alpha)),
            ("stay_prob", Json::num(self.stay_prob)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ClientWorkload> {
        Ok(ClientWorkload {
            client: v.expect("client")?.as_usize()?,
            epochs: v.expect("epochs")?.as_usize()?,
            alpha: v.expect("alpha")?.as_f64()?,
            stay_prob: v.expect("stay_prob")?.as_f64()?,
        })
    }
}

/// One delivered update's aggregation weight, as assigned by the
/// configured weigher (`crate::scheduling`) immediately before the update
/// entered aggregation. `1.0` for every update under the default
/// `weigher = uniform`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AggWeight {
    pub client: usize,
    /// The weight written onto the contribution, in (0, 1].
    pub weight: f64,
}

impl AggWeight {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("client", Json::num(self.client as f64)),
            ("weight", Json::num(self.weight)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<AggWeight> {
        Ok(AggWeight {
            client: v.expect("client")?.as_usize()?,
            weight: v.expect("weight")?.as_f64()?,
        })
    }
}

/// One record in a run's event stream.
#[derive(Clone, Debug, PartialEq)]
pub enum RunEvent {
    /// One aggregation round finished (mirrors `metrics::RoundRecord`).
    /// `workloads` lists every client dispatch drawn since the previous
    /// round-complete record, in dispatch order — the Alg. 3 scheduling
    /// decisions as dispatched. For event-driven strategies this includes
    /// dispatches later cancelled by churn (their finish never validates);
    /// round-stepped strategies settle eligibility *before* training, so
    /// their entries cover exactly the clients that trained.
    RoundComplete {
        round: usize,
        sim_secs: f64,
        participants: usize,
        dropped: usize,
        avail_dropped: usize,
        /// Seconds the dispatches since the previous round-complete spent
        /// waiting on the model-dissemination downlink (`crate::network`);
        /// 0.0 under the default `network = free`.
        downlink_wait_secs: f64,
        /// Dispatches since the previous round-complete whose downlink was
        /// overtaken by a newer global version (stale starts); 0 under
        /// `network = free`.
        stale_starts: u64,
        /// Edge-aggregator flushes since the previous round-complete
        /// (`crate::fleet::RegionClock`); 0 under the default
        /// `hier_clock = shared`.
        edge_flushes: u64,
        /// Seconds those flushed partials spent on the priced edge→root
        /// uplink; 0.0 under `hier_clock = shared` / `hier_uplink = free`.
        edge_uplink_wait_secs: f64,
        mean_train_loss: Option<f64>,
        workloads: Vec<ClientWorkload>,
        /// Per-update aggregation weights assigned since the previous
        /// round-complete record (`crate::scheduling`), in aggregation
        /// order. All `1.0` under the default `weigher = uniform`.
        agg_weights: Vec<AggWeight>,
    },
    /// The global model was evaluated (mirrors `metrics::EvalPoint`).
    EvalPoint {
        round: usize,
        sim_secs: f64,
        mean_loss: f64,
        metric: f64,
    },
    /// A client's update was lost, with its attribution.
    /// `execution_avoided` is true when the drop cancelled a *deferred*
    /// dispatch before its PJRT executions ran — the wasted-work saving of
    /// the plan/execute split; false when the training had already burned
    /// (eager mode, or work that never reached the accelerator path).
    ClientDropped {
        client: usize,
        sim_secs: f64,
        cause: DropCause,
        execution_avoided: bool,
    },
    /// A client's availability state flipped (emitted where the engine
    /// processes transitions as simulation events, i.e. by event-driven
    /// strategies).
    AvailabilityTransition {
        client: usize,
        sim_secs: f64,
        online: bool,
    },
}

impl RunEvent {
    /// The record's `reason` discriminator.
    pub fn reason(&self) -> &'static str {
        match self {
            RunEvent::RoundComplete { .. } => "round-complete",
            RunEvent::EvalPoint { .. } => "eval-point",
            RunEvent::ClientDropped { .. } => "client-dropped",
            RunEvent::AvailabilityTransition { .. } => "availability-transition",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("reason", Json::str(self.reason()))];
        match self {
            RunEvent::RoundComplete {
                round,
                sim_secs,
                participants,
                dropped,
                avail_dropped,
                downlink_wait_secs,
                stale_starts,
                edge_flushes,
                edge_uplink_wait_secs,
                mean_train_loss,
                workloads,
                agg_weights,
            } => {
                pairs.push(("round", Json::num(*round as f64)));
                pairs.push(("sim_secs", Json::num(*sim_secs)));
                pairs.push(("participants", Json::num(*participants as f64)));
                pairs.push(("dropped", Json::num(*dropped as f64)));
                pairs.push(("avail_dropped", Json::num(*avail_dropped as f64)));
                pairs.push(("downlink_wait_secs", Json::num(*downlink_wait_secs)));
                pairs.push(("stale_starts", Json::num(*stale_starts as f64)));
                pairs.push(("edge_flushes", Json::num(*edge_flushes as f64)));
                pairs.push(("edge_uplink_wait_secs", Json::num(*edge_uplink_wait_secs)));
                pairs.push((
                    "mean_train_loss",
                    mean_train_loss.map_or(Json::Null, Json::num),
                ));
                pairs.push((
                    "workloads",
                    Json::arr(workloads.iter().map(|w| w.to_json()).collect()),
                ));
                pairs.push((
                    "agg_weights",
                    Json::arr(agg_weights.iter().map(|w| w.to_json()).collect()),
                ));
            }
            RunEvent::EvalPoint {
                round,
                sim_secs,
                mean_loss,
                metric,
            } => {
                pairs.push(("round", Json::num(*round as f64)));
                pairs.push(("sim_secs", Json::num(*sim_secs)));
                pairs.push(("mean_loss", Json::num(*mean_loss)));
                pairs.push(("metric", Json::num(*metric)));
            }
            RunEvent::ClientDropped {
                client,
                sim_secs,
                cause,
                execution_avoided,
            } => {
                pairs.push(("client", Json::num(*client as f64)));
                pairs.push(("sim_secs", Json::num(*sim_secs)));
                pairs.push(("cause", Json::str(cause.name())));
                pairs.push(("execution_avoided", Json::Bool(*execution_avoided)));
            }
            RunEvent::AvailabilityTransition {
                client,
                sim_secs,
                online,
            } => {
                pairs.push(("client", Json::num(*client as f64)));
                pairs.push(("sim_secs", Json::num(*sim_secs)));
                pairs.push(("online", Json::Bool(*online)));
            }
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<RunEvent> {
        let reason = v.expect("reason")?.as_str()?;
        Ok(match reason {
            "round-complete" => RunEvent::RoundComplete {
                round: v.expect("round")?.as_usize()?,
                sim_secs: v.expect("sim_secs")?.as_f64()?,
                participants: v.expect("participants")?.as_usize()?,
                dropped: v.expect("dropped")?.as_usize()?,
                avail_dropped: v.expect("avail_dropped")?.as_usize()?,
                downlink_wait_secs: v.expect("downlink_wait_secs")?.as_f64()?,
                stale_starts: v.expect("stale_starts")?.as_usize()? as u64,
                edge_flushes: v.expect("edge_flushes")?.as_usize()? as u64,
                edge_uplink_wait_secs: v.expect("edge_uplink_wait_secs")?.as_f64()?,
                mean_train_loss: match v.expect("mean_train_loss")? {
                    Json::Null => None,
                    other => Some(other.as_f64()?),
                },
                workloads: v
                    .expect("workloads")?
                    .as_arr()?
                    .iter()
                    .map(ClientWorkload::from_json)
                    .collect::<Result<_>>()?,
                agg_weights: v
                    .expect("agg_weights")?
                    .as_arr()?
                    .iter()
                    .map(AggWeight::from_json)
                    .collect::<Result<_>>()?,
            },
            "eval-point" => RunEvent::EvalPoint {
                round: v.expect("round")?.as_usize()?,
                sim_secs: v.expect("sim_secs")?.as_f64()?,
                mean_loss: v.expect("mean_loss")?.as_f64()?,
                metric: v.expect("metric")?.as_f64()?,
            },
            "client-dropped" => RunEvent::ClientDropped {
                client: v.expect("client")?.as_usize()?,
                sim_secs: v.expect("sim_secs")?.as_f64()?,
                cause: DropCause::parse(v.expect("cause")?.as_str()?)?,
                execution_avoided: v.expect("execution_avoided")?.as_bool()?,
            },
            "availability-transition" => RunEvent::AvailabilityTransition {
                client: v.expect("client")?.as_usize()?,
                sim_secs: v.expect("sim_secs")?.as_f64()?,
                online: v.expect("online")?.as_bool()?,
            },
            other => anyhow::bail!("unknown event reason {other:?}"),
        })
    }

    /// Parse one JSONL line.
    pub fn parse_line(line: &str) -> Result<RunEvent> {
        let v = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&v)
    }
}

/// Serialize events to the JSONL stream format.
pub fn write_jsonl(events: &[RunEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Parse a whole JSONL event stream. Blank lines are skipped; malformed
/// lines error with their line number.
pub fn parse_jsonl(text: &str) -> Result<Vec<RunEvent>> {
    let mut events = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        events.push(
            RunEvent::parse_line(line).with_context(|| format!("event line {}", lineno + 1))?,
        );
    }
    Ok(events)
}

/// Where the engine streams run events during a run.
pub trait EventSink {
    fn emit(&mut self, ev: &RunEvent);
}

/// Discards everything — the default for `Simulation::run`.
pub struct NullSink;

impl EventSink for NullSink {
    fn emit(&mut self, _ev: &RunEvent) {}
}

/// Buffers events in memory (tests, post-run analysis).
#[derive(Default)]
pub struct CollectSink {
    pub events: Vec<RunEvent>,
}

impl EventSink for CollectSink {
    fn emit(&mut self, ev: &RunEvent) {
        self.events.push(ev.clone());
    }
}

/// Streams JSONL records to a writer (the CLI's `--events FILE`). Write
/// errors are counted, not propagated — the run's result outranks its
/// telemetry; callers check `errors` after the run.
pub struct JsonlSink<W: Write> {
    w: W,
    pub errors: usize,
}

impl<W: Write> JsonlSink<W> {
    pub fn new(w: W) -> JsonlSink<W> {
        JsonlSink { w, errors: 0 }
    }

    pub fn into_inner(self) -> W {
        self.w
    }
}

impl<W: Write> EventSink for JsonlSink<W> {
    fn emit(&mut self, ev: &RunEvent) {
        if writeln!(self.w, "{}", ev.to_json()).is_err() {
            self.errors += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<RunEvent> {
        vec![
            RunEvent::RoundComplete {
                round: 3,
                sim_secs: 412.5,
                participants: 14,
                dropped: 1,
                avail_dropped: 2,
                downlink_wait_secs: 37.5,
                stale_starts: 1,
                edge_flushes: 2,
                edge_uplink_wait_secs: 18.0,
                mean_train_loss: Some(1.83),
                workloads: vec![
                    ClientWorkload { client: 4, epochs: 2, alpha: 0.75, stay_prob: 0.93 },
                    ClientWorkload { client: 9, epochs: 1, alpha: 1.0, stay_prob: 1.0 },
                ],
                agg_weights: vec![
                    AggWeight { client: 4, weight: 0.5 },
                    AggWeight { client: 9, weight: 1.0 },
                ],
            },
            RunEvent::RoundComplete {
                round: 4,
                sim_secs: 500.0,
                participants: 0,
                dropped: 0,
                avail_dropped: 6,
                downlink_wait_secs: 0.0,
                stale_starts: 0,
                edge_flushes: 0,
                edge_uplink_wait_secs: 0.0,
                mean_train_loss: None,
                workloads: vec![],
                agg_weights: vec![],
            },
            RunEvent::EvalPoint {
                round: 3,
                sim_secs: 412.5,
                mean_loss: 1.79,
                metric: 0.41,
            },
            RunEvent::ClientDropped {
                client: 17,
                sim_secs: 390.0,
                cause: DropCause::Availability,
                execution_avoided: true,
            },
            RunEvent::ClientDropped {
                client: 4,
                sim_secs: 391.0,
                cause: DropCause::Deadline,
                execution_avoided: false,
            },
            RunEvent::AvailabilityTransition {
                client: 17,
                sim_secs: 390.0,
                online: false,
            },
        ]
    }

    #[test]
    fn jsonl_round_trips() {
        let events = samples();
        let text = write_jsonl(&events);
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back, events);
    }

    #[test]
    fn reasons_match_schema() {
        let reasons: Vec<&str> = samples().iter().map(|e| e.reason()).collect();
        for want in [
            "round-complete",
            "eval-point",
            "client-dropped",
            "availability-transition",
        ] {
            assert!(reasons.contains(&want), "missing reason {want}");
        }
        // Every line carries the reason discriminator.
        for line in write_jsonl(&samples()).lines() {
            assert!(line.contains("\"reason\":"), "line without reason: {line}");
        }
    }

    #[test]
    fn null_loss_round_trips_as_none() {
        let ev = RunEvent::RoundComplete {
            round: 0,
            sim_secs: 1.0,
            participants: 0,
            dropped: 0,
            avail_dropped: 0,
            downlink_wait_secs: 0.0,
            stale_starts: 0,
            edge_flushes: 0,
            edge_uplink_wait_secs: 0.0,
            mean_train_loss: None,
            workloads: vec![],
            agg_weights: vec![],
        };
        let line = ev.to_json().to_string();
        assert!(line.contains("\"mean_train_loss\":null"));
        assert!(line.contains("\"workloads\":[]"));
        assert!(line.contains("\"agg_weights\":[]"));
        assert_eq!(RunEvent::parse_line(&line).unwrap(), ev);
    }

    #[test]
    fn workloads_round_trip_with_alg3_fields() {
        let line = samples()[0].to_json().to_string();
        assert!(line.contains("\"workloads\":["));
        assert!(line.contains("\"alpha\":0.75"));
        assert!(line.contains("\"epochs\":2"));
        assert!(line.contains("\"stay_prob\":0.93"));
        let back = RunEvent::parse_line(&line).unwrap();
        assert_eq!(back, samples()[0]);
        // Workload entries missing an Alg. 3 field are malformed — the
        // schema is versioned by its field set.
        assert!(RunEvent::parse_line(
            "{\"reason\":\"round-complete\",\"round\":0,\"sim_secs\":1.0,\"participants\":0,\
             \"dropped\":0,\"avail_dropped\":0,\"downlink_wait_secs\":0.0,\"stale_starts\":0,\
             \"edge_flushes\":0,\"edge_uplink_wait_secs\":0.0,\"mean_train_loss\":null,\
             \"workloads\":[{\"client\":1,\"epochs\":2}],\"agg_weights\":[]}"
        )
        .is_err());
        // Same for the sampler-decision field.
        assert!(RunEvent::parse_line(
            "{\"reason\":\"round-complete\",\"round\":0,\"sim_secs\":1.0,\"participants\":0,\
             \"dropped\":0,\"avail_dropped\":0,\"downlink_wait_secs\":0.0,\"stale_starts\":0,\
             \"edge_flushes\":0,\"edge_uplink_wait_secs\":0.0,\"mean_train_loss\":null,\
             \"workloads\":[{\"client\":1,\"epochs\":2,\"alpha\":1.0}],\"agg_weights\":[]}"
        )
        .is_err());
        // A round-complete without the dissemination counters is malformed.
        assert!(RunEvent::parse_line(
            "{\"reason\":\"round-complete\",\"round\":0,\"sim_secs\":1.0,\"participants\":0,\
             \"dropped\":0,\"avail_dropped\":0,\"edge_flushes\":0,\
             \"edge_uplink_wait_secs\":0.0,\"mean_train_loss\":null,\"workloads\":[],\
             \"agg_weights\":[]}"
        )
        .is_err());
        // ... without the edge-flush counters likewise.
        assert!(RunEvent::parse_line(
            "{\"reason\":\"round-complete\",\"round\":0,\"sim_secs\":1.0,\"participants\":0,\
             \"dropped\":0,\"avail_dropped\":0,\"downlink_wait_secs\":0.0,\"stale_starts\":0,\
             \"mean_train_loss\":null,\"workloads\":[],\"agg_weights\":[]}"
        )
        .is_err());
        // ... and one without the aggregation weights likewise.
        assert!(RunEvent::parse_line(
            "{\"reason\":\"round-complete\",\"round\":0,\"sim_secs\":1.0,\"participants\":0,\
             \"dropped\":0,\"avail_dropped\":0,\"downlink_wait_secs\":0.0,\"stale_starts\":0,\
             \"edge_flushes\":0,\"edge_uplink_wait_secs\":0.0,\"mean_train_loss\":null,\
             \"workloads\":[]}"
        )
        .is_err());
        // Weight entries missing their weight are malformed too.
        assert!(RunEvent::parse_line(
            "{\"reason\":\"round-complete\",\"round\":0,\"sim_secs\":1.0,\"participants\":0,\
             \"dropped\":0,\"avail_dropped\":0,\"downlink_wait_secs\":0.0,\"stale_starts\":0,\
             \"edge_flushes\":0,\"edge_uplink_wait_secs\":0.0,\"mean_train_loss\":null,\
             \"workloads\":[],\"agg_weights\":[{\"client\":1}]}"
        )
        .is_err());
    }

    #[test]
    fn rejects_malformed_streams() {
        let err = parse_jsonl("{\"reason\":\"eval-point\"}\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"));
        assert!(parse_jsonl("{\"reason\":\"bogus\",\"x\":1}\n").is_err());
        assert!(RunEvent::parse_line("not json").is_err());
        assert!(DropCause::parse("gravity").is_err());
        // client-dropped without the wasted-work attribution is malformed:
        // the schema is versioned by its field set, not just its reasons.
        assert!(RunEvent::parse_line(
            "{\"reason\":\"client-dropped\",\"client\":1,\"sim_secs\":2.0,\"cause\":\"deadline\"}"
        )
        .is_err());
        // Blank lines are fine.
        let ok = parse_jsonl("\n{\"reason\":\"availability-transition\",\"client\":1,\"sim_secs\":2.0,\"online\":true}\n\n").unwrap();
        assert_eq!(ok.len(), 1);
    }

    #[test]
    fn sinks_collect_and_write() {
        let mut collect = CollectSink::default();
        for e in samples() {
            collect.emit(&e);
        }
        assert_eq!(collect.events, samples());

        let mut sink = JsonlSink::new(Vec::new());
        for e in samples() {
            sink.emit(&e);
        }
        assert_eq!(sink.errors, 0);
        let text = String::from_utf8(sink.into_inner()).unwrap();
        assert_eq!(parse_jsonl(&text).unwrap(), samples());

        NullSink.emit(&samples()[0]); // no-op, must not panic
    }
}
