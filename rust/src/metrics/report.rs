//! Report serialization: JSON dumps and aligned-text tables for the bench
//! harnesses (each bench prints the same rows/series its paper table or
//! figure reports).

use std::fmt::Write as _;

use super::RunReport;
use crate::util::json::Json;

impl RunReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", Json::str(self.strategy.clone())),
            ("model", Json::str(self.model.clone())),
            ("sim_secs", Json::num(self.sim_secs)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("total_rounds", Json::num(self.total_rounds as f64)),
            ("events_processed", Json::num(self.events_processed as f64)),
            ("real_train_steps", Json::num(self.real_train_steps as f64)),
            (
                "trainings_executed",
                Json::num(self.trainings_executed as f64),
            ),
            (
                "trainings_avoided",
                Json::num(self.trainings_avoided as f64),
            ),
            (
                "mean_participation",
                Json::num(self.mean_participation()),
            ),
            (
                "participation_gini",
                Json::num(self.participation_gini()),
            ),
            (
                "participation",
                Json::arr(self.participation.iter().map(|&r| Json::num(r)).collect()),
            ),
            (
                "mean_online_fraction",
                Json::num(self.mean_online_fraction()),
            ),
            (
                "online_fraction",
                Json::arr(self.online_fraction.iter().map(|&r| Json::num(r)).collect()),
            ),
            ("avail_drops", Json::num(self.total_avail_drops() as f64)),
            (
                "deadline_drops",
                Json::num(self.total_deadline_drops() as f64),
            ),
            ("tail_dropped", Json::num(self.tail_dropped as f64)),
            (
                "tail_avail_dropped",
                Json::num(self.tail_avail_dropped as f64),
            ),
            ("downlink_wait_secs", Json::num(self.downlink_wait_secs)),
            ("stale_starts", Json::num(self.stale_starts as f64)),
            ("edge_flushes", Json::num(self.edge_flushes as f64)),
            (
                "edge_uplink_wait_secs",
                Json::num(self.edge_uplink_wait_secs),
            ),
            ("edge_root_merges", Json::num(self.edge_root_merges as f64)),
            (
                "eval_points",
                Json::arr(
                    self.eval_points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("round", Json::num(p.round as f64)),
                                ("sim_secs", Json::num(p.sim_secs)),
                                ("mean_loss", Json::num(p.mean_loss)),
                                ("metric", Json::num(p.metric)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "rounds",
                Json::arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", Json::num(r.round as f64)),
                                ("sim_secs", Json::num(r.sim_secs)),
                                ("participants", Json::num(r.participants as f64)),
                                ("dropped", Json::num(r.dropped as f64)),
                                ("avail_dropped", Json::num(r.avail_dropped as f64)),
                                (
                                    "mean_train_loss",
                                    r.mean_train_loss.map_or(Json::Null, Json::num),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// CSV of the learning curve (round, sim_hours, loss, metric).
    pub fn curve_csv(&self) -> String {
        let mut out = String::from("round,sim_hours,mean_loss,metric\n");
        for p in &self.eval_points {
            let _ = writeln!(
                out,
                "{},{:.4},{:.6},{:.6}",
                p.round,
                p.sim_secs / 3600.0,
                p.mean_loss,
                p.metric
            );
        }
        out
    }

    /// CSV of per-round bookkeeping with drop attribution; rounds where no
    /// client delivered render the train loss as `-`.
    pub fn rounds_csv(&self) -> String {
        let mut out =
            String::from("round,sim_hours,participants,deadline_dropped,avail_dropped,mean_train_loss\n");
        for r in &self.rounds {
            let _ = writeln!(
                out,
                "{},{:.4},{},{},{},{}",
                r.round,
                r.sim_secs / 3600.0,
                r.participants,
                r.dropped,
                r.avail_dropped,
                fmt_opt_loss(r.mean_train_loss),
            );
        }
        out
    }
}

/// Render an optional mean train loss: `-` when no client trained (instead
/// of a fabricated perfect 0.0).
pub fn fmt_opt_loss(loss: Option<f64>) -> String {
    match loss {
        Some(l) => format!("{l:.4}"),
        None => "-".into(),
    }
}

/// Participation/availability summary across runs: the Fig. 1/5-style
/// numbers (mean rate plus its Gini dispersion — the participation gap in
/// one column) with the availability columns that make them attributable
/// (online-fraction, availability-drops vs deadline-drops) plus the
/// wasted-work columns of the deferred dispatch path (accelerator
/// executions run vs skipped).
pub fn participation_table(rows: &[(&str, &RunReport)]) -> Table {
    let mut t = Table::new(&[
        "run",
        "mean_particip",
        "particip_gini",
        "online_frac",
        "avail_drops",
        "deadline_drops",
        "train_execs",
        "train_avoided",
        "rounds",
    ]);
    for (label, r) in rows {
        t.row(vec![
            label.to_string(),
            format!("{:.3}", r.mean_participation()),
            format!("{:.3}", r.participation_gini()),
            format!("{:.3}", r.mean_online_fraction()),
            r.total_avail_drops().to_string(),
            r.total_deadline_drops().to_string(),
            r.trainings_executed.to_string(),
            r.trainings_avoided.to_string(),
            r.total_rounds.to_string(),
        ]);
    }
    t
}

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String], widths: &[usize]| {
            for i in 0..ncols {
                let _ = write!(out, "{:width$}  ", cells[i], width = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers, &widths);
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncols));
        for row in &self.rows {
            line(&mut out, row, &widths);
        }
        out
    }
}

/// Format simulated hours like the paper's Table 1 cells ("5.50 hr",
/// "> budget" when the target was never reached).
pub fn fmt_hours(h: Option<f64>) -> String {
    match h {
        Some(h) => format!("{h:.2} hr"),
        None => "> budget".into(),
    }
}

/// "(1.43x)" speedup annotation relative to a baseline time.
pub fn fmt_speedup(ours: Option<f64>, theirs: Option<f64>) -> String {
    match (ours, theirs) {
        (Some(a), Some(b)) if a > 0.0 => format!("({:.2}x)", b / a),
        _ => "(—)".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EvalPoint;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("a   bbbb"));
        assert!(s.contains("xx  y"));
    }

    fn sample_report() -> RunReport {
        RunReport {
            strategy: "TimelyFL".into(),
            model: "vision".into(),
            eval_points: vec![EvalPoint {
                round: 5,
                sim_secs: 100.0,
                mean_loss: 1.0,
                metric: 0.5,
            }],
            rounds: vec![
                crate::metrics::RoundRecord {
                    round: 0,
                    sim_secs: 50.0,
                    participants: 2,
                    dropped: 1,
                    avail_dropped: 3,
                    mean_train_loss: Some(2.25),
                },
                crate::metrics::RoundRecord {
                    round: 1,
                    sim_secs: 100.0,
                    participants: 0,
                    dropped: 0,
                    avail_dropped: 6,
                    mean_train_loss: None,
                },
            ],
            participation: vec![0.5, 1.0],
            online_fraction: vec![0.25, 0.75],
            sim_secs: 100.0,
            wall_secs: 1.0,
            total_rounds: 5,
            events_processed: 7,
            real_train_steps: 10,
            trainings_executed: 9,
            trainings_avoided: 4,
            tail_dropped: 0,
            tail_avail_dropped: 1,
            downlink_wait_secs: 12.5,
            stale_starts: 2,
            edge_flushes: 6,
            edge_uplink_wait_secs: 3.5,
            edge_root_merges: 4,
        }
    }

    #[test]
    fn json_roundtrips() {
        let r = sample_report();
        let j = r.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("strategy").unwrap().as_str().unwrap(), "TimelyFL");
        assert_eq!(
            parsed.get("eval_points").unwrap().as_arr().unwrap().len(),
            1
        );
        assert_eq!(parsed.get("events_processed").unwrap().as_f64().unwrap(), 7.0);
        // 3 + 6 per-round churn drops plus the zero-round tail of 1.
        assert_eq!(parsed.get("avail_drops").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(parsed.get("deadline_drops").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(
            parsed.get("trainings_executed").unwrap().as_f64().unwrap(),
            9.0
        );
        assert_eq!(
            parsed.get("trainings_avoided").unwrap().as_f64().unwrap(),
            4.0
        );
        assert_eq!(parsed.get("tail_avail_dropped").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(
            parsed.get("downlink_wait_secs").unwrap().as_f64().unwrap(),
            12.5
        );
        assert_eq!(parsed.get("stale_starts").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(parsed.get("edge_flushes").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(
            parsed.get("edge_uplink_wait_secs").unwrap().as_f64().unwrap(),
            3.5
        );
        assert_eq!(parsed.get("edge_root_merges").unwrap().as_f64().unwrap(), 4.0);
        assert!(
            (parsed.get("mean_online_fraction").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12
        );
        // Gini of participation [0.5, 1.0] is 1/6.
        assert!(
            (parsed.get("participation_gini").unwrap().as_f64().unwrap() - 1.0 / 6.0).abs()
                < 1e-12
        );
    }

    #[test]
    fn rounds_csv_renders_dash_for_empty_rounds() {
        let csv = sample_report().rounds_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].ends_with(",2.2500"), "line: {}", lines[1]);
        assert!(lines[2].ends_with(",-"), "line: {}", lines[2]);
        assert_eq!(fmt_opt_loss(None), "-");
        assert_eq!(fmt_opt_loss(Some(1.0)), "1.0000");
    }

    #[test]
    fn participation_table_has_availability_columns() {
        let r = sample_report();
        let t = participation_table(&[("TimelyFL", &r)]);
        let s = t.render();
        assert!(s.contains("online_frac"));
        assert!(s.contains("particip_gini"));
        assert!(s.contains("0.167"), "gini of [0.5, 1.0] renders as 0.167: {s}");
        assert!(s.contains("avail_drops"));
        assert!(s.contains("deadline_drops"));
        assert!(s.contains("train_execs"));
        assert!(s.contains("train_avoided"));
        assert!(s.contains("0.500")); // online fraction
        assert!(s.contains("10")); // avail drops incl. run-level tail
    }

    #[test]
    fn hour_formatting() {
        assert_eq!(fmt_hours(Some(5.5)), "5.50 hr");
        assert_eq!(fmt_hours(None), "> budget");
        assert_eq!(fmt_speedup(Some(2.0), Some(5.0)), "(2.50x)");
        assert_eq!(fmt_speedup(None, Some(5.0)), "(—)");
    }
}
