//! Report serialization: JSON dumps and aligned-text tables for the bench
//! harnesses (each bench prints the same rows/series its paper table or
//! figure reports).

use std::fmt::Write as _;

use super::RunReport;
use crate::util::json::Json;

impl RunReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", Json::str(self.strategy.clone())),
            ("model", Json::str(self.model.clone())),
            ("sim_secs", Json::num(self.sim_secs)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("total_rounds", Json::num(self.total_rounds as f64)),
            ("real_train_steps", Json::num(self.real_train_steps as f64)),
            (
                "mean_participation",
                Json::num(self.mean_participation()),
            ),
            (
                "participation",
                Json::arr(self.participation.iter().map(|&r| Json::num(r)).collect()),
            ),
            (
                "eval_points",
                Json::arr(
                    self.eval_points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("round", Json::num(p.round as f64)),
                                ("sim_secs", Json::num(p.sim_secs)),
                                ("mean_loss", Json::num(p.mean_loss)),
                                ("metric", Json::num(p.metric)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// CSV of the learning curve (round, sim_hours, loss, metric).
    pub fn curve_csv(&self) -> String {
        let mut out = String::from("round,sim_hours,mean_loss,metric\n");
        for p in &self.eval_points {
            let _ = writeln!(
                out,
                "{},{:.4},{:.6},{:.6}",
                p.round,
                p.sim_secs / 3600.0,
                p.mean_loss,
                p.metric
            );
        }
        out
    }
}

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String], widths: &[usize]| {
            for i in 0..ncols {
                let _ = write!(out, "{:width$}  ", cells[i], width = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers, &widths);
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncols));
        for row in &self.rows {
            line(&mut out, row, &widths);
        }
        out
    }
}

/// Format simulated hours like the paper's Table 1 cells ("5.50 hr",
/// "> budget" when the target was never reached).
pub fn fmt_hours(h: Option<f64>) -> String {
    match h {
        Some(h) => format!("{h:.2} hr"),
        None => "> budget".into(),
    }
}

/// "(1.43x)" speedup annotation relative to a baseline time.
pub fn fmt_speedup(ours: Option<f64>, theirs: Option<f64>) -> String {
    match (ours, theirs) {
        (Some(a), Some(b)) if a > 0.0 => format!("({:.2}x)", b / a),
        _ => "(—)".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EvalPoint;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("a   bbbb"));
        assert!(s.contains("xx  y"));
    }

    #[test]
    fn json_roundtrips() {
        let r = RunReport {
            strategy: "TimelyFL".into(),
            model: "vision".into(),
            eval_points: vec![EvalPoint {
                round: 5,
                sim_secs: 100.0,
                mean_loss: 1.0,
                metric: 0.5,
            }],
            rounds: vec![],
            participation: vec![0.5, 1.0],
            sim_secs: 100.0,
            wall_secs: 1.0,
            total_rounds: 5,
            real_train_steps: 10,
        };
        let j = r.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("strategy").unwrap().as_str().unwrap(), "TimelyFL");
        assert_eq!(
            parsed.get("eval_points").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn hour_formatting() {
        assert_eq!(fmt_hours(Some(5.5)), "5.50 hr");
        assert_eq!(fmt_hours(None), "> budget");
        assert_eq!(fmt_speedup(Some(2.0), Some(5.0)), "(2.50x)");
        assert_eq!(fmt_speedup(None, Some(5.0)), "(—)");
    }
}
