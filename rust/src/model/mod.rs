//! Model parameter store: positionally-ordered f32 tensors matching the
//! manifest layout, plus the update/delta algebra the aggregators need.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::manifest::ModelMeta;

/// A full set of model parameters (one `Vec<f32>` per tensor, in manifest
/// order). Cheap to clone structurally via `Arc` snapshots at the
/// coordinator level; the inner data is cloned only when mutated.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamVec {
    pub tensors: Vec<Vec<f32>>,
}

/// Immutable snapshot of a global model version. Async strategies keep one
/// alive per in-flight client (a slow client trains against the version it
/// started from — that is what staleness *is*).
pub type ModelSnapshot = Arc<VersionedParams>;

#[derive(Clone, Debug)]
pub struct VersionedParams {
    /// Global aggregation round that produced these parameters.
    pub version: u64,
    pub params: ParamVec,
}

impl ParamVec {
    pub fn zeros_like(meta: &ModelMeta) -> ParamVec {
        ParamVec {
            tensors: meta.params.iter().map(|p| vec![0.0; p.size]).collect(),
        }
    }

    /// Validate tensor count + sizes against the manifest.
    pub fn check(&self, meta: &ModelMeta) -> Result<()> {
        anyhow::ensure!(
            self.tensors.len() == meta.params.len(),
            "param count {} != manifest {}",
            self.tensors.len(),
            meta.params.len()
        );
        for (t, p) in self.tensors.iter().zip(&meta.params) {
            anyhow::ensure!(
                t.len() == p.size,
                "tensor {} len {} != manifest {}",
                p.name,
                t.len(),
                p.size
            );
        }
        Ok(())
    }

    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Delta (self - base) restricted to the trainable suffix
    /// [boundary, ..): exactly what a partially-trained client uploads
    /// (paper §3.2.2 — frozen layers are unchanged, so they are not sent).
    pub fn delta_from(&self, base: &ParamVec, boundary: usize) -> Update {
        debug_assert_eq!(self.tensors.len(), base.tensors.len());
        let tensors = self.tensors[boundary..]
            .iter()
            .zip(&base.tensors[boundary..])
            .map(|(new, old)| new.iter().zip(old).map(|(a, b)| a - b).collect())
            .collect();
        Update { boundary, tensors }
    }

    /// Apply a (possibly staleness-scaled) update in place.
    pub fn apply(&mut self, update: &Update, scale: f32) {
        for (t, u) in self.tensors[update.boundary..].iter_mut().zip(&update.tensors) {
            debug_assert_eq!(t.len(), u.len());
            for (a, b) in t.iter_mut().zip(u) {
                *a += scale * b;
            }
        }
    }

    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    pub fn all_finite(&self) -> bool {
        self.tensors.iter().all(|t| t.iter().all(|x| x.is_finite()))
    }
}

/// A client's uploaded model update: the delta of the trainable suffix.
/// `boundary` is the first trainable tensor index; `tensors[i]` corresponds
/// to manifest tensor `boundary + i`.
#[derive(Clone, Debug, PartialEq)]
pub struct Update {
    pub boundary: usize,
    pub tensors: Vec<Vec<f32>>,
}

impl Update {
    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Upload size in bytes (f32), the communication cost of this update.
    pub fn bytes(&self) -> usize {
        self.num_params() * 4
    }

    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(tensors: Vec<Vec<f32>>) -> ParamVec {
        ParamVec { tensors }
    }

    #[test]
    fn delta_and_apply_roundtrip_full() {
        let base = pv(vec![vec![1.0, 2.0], vec![3.0]]);
        let new = pv(vec![vec![1.5, 1.0], vec![4.0]]);
        let d = new.delta_from(&base, 0);
        assert_eq!(d.num_params(), 3);
        let mut rebuilt = base.clone();
        rebuilt.apply(&d, 1.0);
        assert_eq!(rebuilt, new);
    }

    #[test]
    fn delta_partial_only_covers_suffix() {
        let base = pv(vec![vec![1.0, 2.0], vec![3.0], vec![5.0]]);
        let new = pv(vec![vec![9.0, 9.0], vec![4.0], vec![7.0]]);
        let d = new.delta_from(&base, 1);
        assert_eq!(d.boundary, 1);
        assert_eq!(d.tensors, vec![vec![1.0], vec![2.0]]);
        assert_eq!(d.bytes(), 8);
        let mut out = base.clone();
        out.apply(&d, 1.0);
        // frozen prefix untouched, suffix updated
        assert_eq!(out.tensors[0], vec![1.0, 2.0]);
        assert_eq!(out.tensors[1], vec![4.0]);
        assert_eq!(out.tensors[2], vec![7.0]);
    }

    #[test]
    fn apply_scaled() {
        let base = pv(vec![vec![0.0, 0.0]]);
        let new = pv(vec![vec![2.0, -4.0]]);
        let d = new.delta_from(&base, 0);
        let mut half = base.clone();
        half.apply(&d, 0.5);
        assert_eq!(half.tensors[0], vec![1.0, -2.0]);
    }

    #[test]
    fn norms() {
        let v = pv(vec![vec![3.0], vec![4.0]]);
        assert!((v.l2_norm() - 5.0).abs() < 1e-12);
        assert!(v.all_finite());
        let bad = pv(vec![vec![f32::NAN]]);
        assert!(!bad.all_finite());
    }
}
