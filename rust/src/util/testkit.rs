//! Tiny property-testing harness (proptest is not in the offline vendor
//! set). Runs a property over N seeded random cases; on failure reports the
//! failing case index and seed so it can be replayed deterministically.
//!
//! ```no_run
//! # // no_run: doctest binaries miss the -rpath to /opt/xla_extension/lib,
//! # // so executing them fails to load libstdc++ in this offline image.
//! use timelyfl::util::{rng::Rng, testkit::check};
//! check("sum is commutative", 256, |rng| {
//!     let a = rng.f64();
//!     let b = rng.f64();
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Base seed; override with TIMELYFL_PROP_SEED to reproduce CI failures.
fn base_seed() -> u64 {
    std::env::var("TIMELYFL_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` over `cases` independently-seeded RNGs; panics (with the
/// case's replay seed) on the first failing case.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u64, prop: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed on case {case}/{cases} \
                 (replay: TIMELYFL_PROP_SEED={base}): {msg}"
            );
        }
    }
}

/// Generator helpers for common test inputs.
pub mod gen {
    use super::Rng;

    /// Vec<f64> of length in [lo, hi], values in [-scale, scale].
    pub fn f64_vec(rng: &mut Rng, lo: usize, hi: usize, scale: f64) -> Vec<f64> {
        let n = lo + rng.usize_below(hi - lo + 1);
        (0..n).map(|_| rng.range(-scale, scale)).collect()
    }

    /// Vec<f32> of exact length n, values in [-scale, scale].
    pub fn f32_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n)
            .map(|_| rng.range(-scale as f64, scale as f64) as f32)
            .collect()
    }

    /// Strictly positive durations (seconds), log-uniform over ~4 decades.
    pub fn positive_time(rng: &mut Rng) -> f64 {
        10f64.powf(rng.range(-2.0, 2.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 64, |rng| {
            let a = rng.f64();
            let b = rng.f64();
            assert!((a + b - (b + a)).abs() < 1e-15);
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn reports_failing_case() {
        check("always-fails", 8, |_| panic!("boom"));
    }
}
