//! Small statistics helpers shared by metrics, benches and tests.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// k-th smallest value (1-based, clamped), used for the aggregation
/// interval T_k = kth smallest estimated total time (paper Alg. 1 line 7).
pub fn kth_smallest(xs: &[f64], k: usize) -> f64 {
    assert!(!xs.is_empty(), "kth_smallest of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = k.clamp(1, v.len()) - 1;
    v[idx]
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(kth_smallest(&xs, 1), 1.0);
        assert_eq!(kth_smallest(&xs, 3), 3.0);
        assert_eq!(kth_smallest(&xs, 99), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn std_dev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }
}
