//! Small statistics helpers shared by metrics, benches and tests.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// k-th smallest value (1-based, clamped), used for the aggregation
/// interval T_k = kth smallest estimated total time (paper Alg. 1 line 7).
pub fn kth_smallest(xs: &[f64], k: usize) -> f64 {
    assert!(!xs.is_empty(), "kth_smallest of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = k.clamp(1, v.len()) - 1;
    v[idx]
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Gini coefficient of a non-negative sample — the participation-dispersion
/// metric (0 = perfectly even shares, → 1 = concentrated on few). Computed
/// on a sorted copy via the rank formula
/// `G = (2 Σ_i i·x_(i)) / (n Σ x) - (n + 1) / n` with 1-based ranks.
/// 0.0 for an empty slice, a non-positive total (the dispersion of
/// "nobody participated" is defined as none), or any non-finite input —
/// a NaN count must degrade to the neutral value, never panic the sort
/// or propagate into a report.
pub fn gini(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n == 0 || xs.iter().any(|x| !x.is_finite()) {
        return 0.0;
    }
    let total: f64 = xs.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let weighted: f64 = v.iter().enumerate().map(|(i, x)| (i + 1) as f64 * x).sum();
    (2.0 * weighted) / (n as f64 * total) - (n as f64 + 1.0) / n as f64
}

/// Error function via the Abramowitz & Stegun 7.1.26 rational
/// approximation (|error| < 1.5e-7 — far below any tolerance the
/// availability-survival estimates care about; no libm `erf` in the
/// offline vendor set).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Standard normal CDF.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Survival function of LogNormal(mu, sigma): P(X > x). 1.0 for x <= 0;
/// degenerates to the deterministic point mass exp(mu) at sigma = 0.
pub fn lognormal_survival(x: f64, mu: f64, sigma: f64) -> f64 {
    if x <= 0.0 {
        return 1.0;
    }
    if sigma <= 0.0 {
        return if x < mu.exp() { 1.0 } else { 0.0 };
    }
    1.0 - normal_cdf((x.ln() - mu) / sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(kth_smallest(&xs, 1), 1.0);
        assert_eq!(kth_smallest(&xs, 3), 3.0);
        assert_eq!(kth_smallest(&xs, 99), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn gini_known_values() {
        // Perfect equality and the degenerate cases are exactly 0.
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[0.0, 0.0]), 0.0);
        assert_eq!(gini(&[0.7]), 0.0);
        assert_eq!(gini(&[0.3, 0.3, 0.3, 0.3]), 0.0);
        // One of n holding everything: G = (n - 1) / n.
        assert!((gini(&[0.0, 0.0, 0.0, 5.0]) - 0.75).abs() < 1e-12);
        // Hand-computed: [0.5, 1.0] -> 2*(0.5 + 2.0)/(2*1.5) - 3/2 = 1/6.
        assert!((gini(&[1.0, 0.5]) - 1.0 / 6.0).abs() < 1e-12, "order must not matter");
        // More concentration -> larger G.
        assert!(gini(&[1.0, 1.0, 8.0]) > gini(&[2.0, 3.0, 5.0]));
        // Scale invariance.
        assert!((gini(&[1.0, 2.0, 3.0]) - gini(&[10.0, 20.0, 30.0])).abs() < 1e-12);
    }

    #[test]
    fn gini_is_nan_safe() {
        // Non-finite inputs degrade to the neutral 0.0 — no panic, no NaN
        // in the output, wherever the poison sits in the vector.
        assert_eq!(gini(&[f64::NAN]), 0.0);
        assert_eq!(gini(&[1.0, f64::NAN, 3.0]), 0.0);
        assert_eq!(gini(&[f64::NAN, f64::NAN]), 0.0);
        assert_eq!(gini(&[2.0, f64::INFINITY]), 0.0);
        assert_eq!(gini(&[f64::NEG_INFINITY, 1.0]), 0.0);
        assert_eq!(gini(&[1.0, f64::NAN, f64::INFINITY]), 0.0);
        // Finite inputs are untouched by the guard.
        assert!((gini(&[0.0, 0.0, 0.0, 5.0]) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn std_dev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn erf_known_values() {
        // Reference values (Abramowitz & Stegun tables).
        for (x, want) in [
            (0.0, 0.0),
            (0.5, 0.520_499_878),
            (1.0, 0.842_700_793),
            (2.0, 0.995_322_265),
        ] {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x}) = {}", erf(x));
            assert!((erf(-x) + want).abs() < 2e-7, "erf is odd");
        }
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((normal_cdf(1.959_964) - 0.975).abs() < 1e-5);
    }

    #[test]
    fn lognormal_survival_basics() {
        // Median of LogNormal(mu, sigma) is exp(mu): survival there is 0.5.
        let mu = 6.0f64;
        assert!((lognormal_survival(mu.exp(), mu, 0.5) - 0.5).abs() < 1e-6);
        // Monotone decreasing in x, bounded in [0, 1].
        let mut prev = 1.0;
        for i in 0..50 {
            let s = lognormal_survival(10.0 * (i + 1) as f64, 4.0, 0.7);
            assert!((0.0..=1.0).contains(&s));
            assert!(s <= prev + 1e-12, "survival must decrease");
            prev = s;
        }
        assert_eq!(lognormal_survival(0.0, 1.0, 0.5), 1.0);
        assert_eq!(lognormal_survival(-3.0, 1.0, 0.5), 1.0);
        // sigma = 0: deterministic dwell of exp(mu).
        assert_eq!(lognormal_survival(1.0, 1.0, 0.0), 1.0);
        assert_eq!(lognormal_survival(3.0, 1.0, 0.0), 0.0);
    }
}
