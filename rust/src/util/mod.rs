//! In-tree substrates for facilities the offline build cannot pull from
//! crates.io: PRNG + distributions, JSON, stats helpers, and a tiny
//! property-testing harness (see `testkit`).

pub mod json;
pub mod rng;
pub mod stats;
pub mod testkit;
